"""End-to-end task accuracy under drift: LM logits on the photonic fleet.

The metric the paper actually cares about is *task accuracy on the
served model under hardware drift* — not probe/mapping distance.  This
benchmark closes that loop end to end:

1. **Train** the smoke LM (digital, jitted) on the synthetic order-1
   Markov stream until it predicts legal successors reliably.
2. **Deploy** every PTC layer of the trained model onto a 2-chip
   photonic fleet (one tenant per layer) and serve teacher-forced
   decode through the routed chips' *realized transfer*
   (``launch/serve.py --hw-logits`` machinery).
3. **Sweep σ_drift** with the closed loop on (probe → alarm →
   batch partial recalibration) and off, scoring *legality accuracy*:
   the fraction of positions whose argmax prediction is one of the
   Markov table's legal successors of the context token.  A healthy
   trained model scores ≈0.97; random logits score ≈ 4/vocab ≈ 0.016 —
   a real task metric with real dynamic range.

Emitted artifacts:

* ``e2e_accuracy.csv`` — accuracy / tail-accuracy vs σ for both loops;
* ``BENCH_e2e_accuracy.json`` — the curves plus four boolean **gates**
  the CI regression checker (``benchmarks/check_regression.py``)
  verifies:

  - ``sigma0_token_identical`` — at σ = 0 the hardware-routed path is
    token-identical to the shadow twin path (same deployment, digital
    execution of the readback transfer);
  - ``transport_bit_identical`` — the routed path's *logits* are
    bit-identical across twin / subprocess / socket transports;
  - ``open_loop_monotone`` — without recalibration, accuracy degrades
    monotonically with σ_drift (and strictly at the top);
  - ``closed_loop_recovers`` — with the loop on, steady-state (tail)
    accuracy stays within 1% of the σ = 0 baseline at every σ.

    PYTHONPATH=src python -m benchmarks.e2e_accuracy [--budget quick]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from .common import ART, emit

ARCH = "smoke:qwen3-4b"
SEED = 3
FLEET = 2
FLEET_K = 8


def _train_model(cfg, steps: int, batch: int = 16, seq: int = 32,
                 lr: float = 2e-3):
    """Digitally train the smoke LM on the Markov stream (jitted)."""
    import jax
    import jax.numpy as jnp
    from repro.launch.steps import init_train_state, build_update_step
    from repro.optim.optimizers import AdamWConfig
    from repro.data import lm_batch

    key = jax.random.PRNGKey(SEED)
    params, opt = init_train_state(key, cfg)
    step = jax.jit(build_update_step(cfg, AdamWConfig(lr=lr)))
    loss = float("nan")
    for i in range(steps):
        b = lm_batch(SEED, i, batch, seq, cfg.vocab)
        bj = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss, _ = step(params, opt, bj,
                                    jax.random.fold_in(key, i))
    return params, float(loss)


def _runtime_cfg(sigma: float, driver_kind: str = "twin"):
    """Closed-loop policy tuned for hw-logits serving: tight hysteresis
    just above the ~0.005 OSP deployment floor, probes every other
    tick, and *batch* partial recalibration (one chip outage re-tunes
    every alarmed layer — a served model's tenants drift together).
    Autotuned ZO budgets are quantized so the compiled-solver cache
    stays small."""
    from repro.core.noise import DEFAULT_NOISE
    from repro.hw.drift import DriftConfig
    from repro.runtime.fleet import RuntimeConfig
    from repro.runtime.monitor import MonitorConfig
    from repro.runtime.recalibrate import RecalConfig

    # hysteresis sits around the warm-recal floor (d≈0.003 with the
    # gentle ZCD schedule) and the probe estimator's noise at n=24, so
    # repairs CLEAR reliably instead of re-queuing on estimator noise
    mon = MonitorConfig(n_probes=24, alarm_threshold=0.010,
                        clear_threshold=0.006, consecutive=2)
    return RuntimeConfig(
        k=FLEET_K, noise=DEFAULT_NOISE.post_ic(),
        drift=DriftConfig(sigma_phase=sigma, theta=0.01), monitor=mon,
        recal=RecalConfig(zo_steps=200, delta0=0.02, decay=1.02),
        probe_every=2, recal_latency=1, max_concurrent_recals=1,
        driver_kind=driver_kind, router_policy="drift_aware",
        repair_batch=64)


def _serve_args(params, stream, sigma: float, *, recal: bool = True,
                mode: str = "route", driver: str = "twin",
                trace_logits: bool = False):
    return argparse.Namespace(
        arch=ARCH, batch=int(stream.shape[0]),
        prompt_len=int(stream.shape[1]), gen=0, seed=SEED,
        fleet=FLEET, drift=sigma > 0, drift_sigma=sigma, probe_every=2,
        fleet_k=FLEET_K, fleet_dim=8, fleet_tenants=1, fleet_driver=driver,
        hw_logits=(mode == "route"), hw_shadow=(mode == "shadow"),
        deploy_zo=False, no_recal=not recal, trace_logits=trace_logits,
        prompt_tokens=stream, runtime_cfg=_runtime_cfg(sigma, driver),
        params_override=params)


def _legality(preds: np.ndarray, stream: np.ndarray,
              table: np.ndarray) -> np.ndarray:
    """(B, S) bool: prediction at position i is a legal successor of the
    forced context token at i."""
    ctx = stream[:, :preds.shape[1]]
    legal = np.zeros(preds.shape, bool)
    for b in range(preds.shape[0]):
        for i in range(preds.shape[1]):
            legal[b, i] = preds[b, i] in table[ctx[b, i]]
    return legal


def _run(params, stream, table, sigma, tail, **kw):
    from repro.launch import serve as serve_mod

    t0 = time.time()
    out = serve_mod.run(_serve_args(params, stream, sigma, **kw))
    ok = _legality(out["preds"], stream, table)
    rep = out["report"]
    return dict(
        sigma=sigma,
        accuracy=float(ok.mean()),
        tail_accuracy=float(ok[:, -tail:].mean()),
        alarms=sum(c["alarms"] for c in rep["chips"]),
        recals=sum(c["recals"] for c in rep["chips"]),
        recal_ptc_calls=sum(c["recal_ptc_calls"] for c in rep["chips"]),
        serve_ptc_calls=sum(c["serve_ptc_calls"] for c in rep["chips"]),
        max_probe_distance=max(t["distance"] for c in rep["chips"]
                               for t in c["tenants"]),
        frames_per_step=rep["hw"]["frames_per_step"],
        dropped_passes=rep["hw"]["dropped_passes"],
        shadow_calls=rep["hw"]["shadow_calls"],
        wall_s=time.time() - t0), out


def main(budget: str = "quick") -> None:
    from repro.data import lm_batch
    from repro.data.synthetic import _markov_table
    from repro.launch.train import parse_arch

    if budget == "quick":
        train_steps, batch, stream_len, tail = 200, 6, 49, 24
        sigmas = [0.004, 0.008, 0.014]
        conf_len = 9
    else:
        # σ tops out at 0.014: beyond that the drift rate between probe
        # ticks exceeds what the repair cadence can hold, so the closed
        # loop's recovery gate would measure the probe budget, not the
        # recalibration machinery (the open loop already collapses well
        # inside this range)
        train_steps, batch, stream_len, tail = 400, 8, 81, 40
        sigmas = [0.003, 0.006, 0.01, 0.014]
        conf_len = 13

    cfg = parse_arch(ARCH)
    table = _markov_table(cfg.vocab, SEED)
    t0 = time.time()
    params, loss = _train_model(cfg, train_steps)
    train_s = time.time() - t0
    print(f"trained {ARCH} for {train_steps} steps "
          f"(loss {loss:.3f}, {train_s:.0f}s)", flush=True)

    stream = lm_batch(SEED, 999, batch, stream_len, cfg.vocab)["tokens"]

    # -- σ = 0 gates (loop off: a noise-tripped repair would rewrite
    # phases away from the deployment state the shadow path mirrors) ---------
    base, base_out = _run(params, stream, table, 0.0, tail, mode="route",
                          recal=False)
    print(f"σ=0: hw accuracy {base['accuracy']:.3f} "
          f"(tail {base['tail_accuracy']:.3f})", flush=True)

    # Token identity is gated on the UNTRAINED model: training this task
    # drives the 4 legal successors toward equal logits, so its argmax
    # sits on ~1e-7 margins and flips on contraction order — a property
    # of the task, not of the serving path.  The random-init model has
    # sharp margins, so route ≡ shadow is a meaningful path gate there
    # (tests/test_hw_serve.py locks the same property).
    import jax
    from repro.models.lm import init_model
    params0 = init_model(jax.random.PRNGKey(SEED), cfg)
    id_stream = stream[:2, :conf_len]
    idr, idr_out = _run(params0, id_stream, table, 0.0, tail=4,
                        mode="route", recal=False)
    ids, ids_out = _run(params0, id_stream, table, 0.0, tail=4,
                        mode="shadow", recal=False)
    sigma0_identical = bool(
        np.array_equal(idr_out["preds"], ids_out["preds"]))
    print(f"σ=0 token-identity (route ≡ shadow, untrained model): "
          f"{sigma0_identical}", flush=True)

    conf_stream = stream[:2, :conf_len]
    transports = {}
    ref_logits = None
    transport_identical = True
    for driver in ("twin", "subprocess", "socket"):
        r, out = _run(params, conf_stream, table, 0.0, tail=4,
                      mode="route", driver=driver, recal=False,
                      trace_logits=True)
        transports[driver] = dict(wall_s=r["wall_s"],
                                  accuracy=r["accuracy"])
        if ref_logits is None:
            ref_logits = out["logits"]
        else:
            same = bool(np.array_equal(ref_logits, out["logits"]))
            transports[driver]["bit_identical_to_twin"] = same
            transport_identical = transport_identical and same
    print(f"transport bit-identity (twin≡subprocess≡socket): "
          f"{transport_identical}", flush=True)

    # -- accuracy vs drift, closed and open loop -----------------------------
    sweep = []
    for sigma in sigmas:
        closed, _ = _run(params, stream, table, sigma, tail, recal=True)
        open_, _ = _run(params, stream, table, sigma, tail, recal=False)
        sweep.append(dict(sigma=sigma, closed=closed, open=open_))
        print(f"σ={sigma}: closed acc {closed['accuracy']:.3f} "
              f"(tail {closed['tail_accuracy']:.3f}, "
              f"{closed['recals']} recals) | open acc "
              f"{open_['accuracy']:.3f} (tail "
              f"{open_['tail_accuracy']:.3f})", flush=True)

    open_accs = [s["open"]["accuracy"] for s in sweep]
    monotone = all(open_accs[i + 1] <= open_accs[i] + 0.01
                   for i in range(len(open_accs) - 1))
    degrades = open_accs[-1] < base["accuracy"] - 0.02
    recovers = all(s["closed"]["tail_accuracy"]
                   >= base["tail_accuracy"] - 0.01 for s in sweep)
    gates = dict(
        sigma0_token_identical=sigma0_identical,
        transport_bit_identical=transport_identical,
        open_loop_monotone=bool(monotone and degrades),
        closed_loop_recovers=bool(recovers))

    header = ["sigma", "closed_acc", "closed_tail_acc", "closed_recals",
              "open_acc", "open_tail_acc", "open_max_probe_dist"]
    rows = [[0.0, f"{base['accuracy']:.4f}", f"{base['tail_accuracy']:.4f}",
             base["recals"], f"{base['accuracy']:.4f}",
             f"{base['tail_accuracy']:.4f}",
             f"{base['max_probe_distance']:.4f}"]]
    for s in sweep:
        rows.append([s["sigma"],
                     f"{s['closed']['accuracy']:.4f}",
                     f"{s['closed']['tail_accuracy']:.4f}",
                     s["closed"]["recals"],
                     f"{s['open']['accuracy']:.4f}",
                     f"{s['open']['tail_accuracy']:.4f}",
                     f"{s['open']['max_probe_distance']:.4f}"])
    emit("e2e_accuracy", header, rows)

    summary = dict(
        budget=budget, arch=ARCH, seed=SEED, train_steps=train_steps,
        train_loss=loss, batch=batch, stream_len=stream_len, tail=tail,
        fleet=FLEET, fleet_k=FLEET_K,
        n_ptc_layers=len(base_out["report"]["hw"]["layers"]),
        frames_per_step=base["frames_per_step"],
        baseline=base, transports=transports,
        sweep=sweep, gates=gates)
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "BENCH_e2e_accuracy.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"--- e2e_accuracy summary ({path}) ---")
    print(json.dumps(dict(gates=gates, baseline_accuracy=base["accuracy"],
                          baseline_tail=base["tail_accuracy"]), indent=2))
    for name, ok in gates.items():
        assert ok, f"e2e accuracy gate failed: {name}"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="quick", choices=["quick", "normal"])
    main(ap.parse_args().budget)
