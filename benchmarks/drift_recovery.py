"""Closed-loop drift recovery: fidelity vs. time, with and without the loop.

Runs the fleet simulation (``repro.runtime.demo.simulate``) twice from
the same seed — closed loop (monitor → alarm → recalibrate) vs. open
loop (drift runs away) — and emits:

* ``drift_recovery.csv`` — the per-tick recovery curves (fleet max/mean
  mapping distance, serve error, #chips in repair) for both loops;
* ``BENCH_drift_recovery.json`` — headline numbers: time-to-recovery per
  alarm (ticks from alarm to the post-recal probe clearing the
  hysteresis threshold), final/peak distances, serving continuity, and
  probe/recal overhead in PTC calls (Appendix-G energy model via
  ``core.profiler``).

    PYTHONPATH=src python -m benchmarks.drift_recovery [--budget quick]
"""

from __future__ import annotations

import argparse
import json
import os

from .common import ART, emit, Timer


def _time_to_recovery(events: list[dict], clear_threshold: float) -> list[dict]:
    """Pair each alarm with the first subsequent recal_done on the same
    chip whose post-recal distance clears the hysteresis threshold."""
    open_alarms: dict[int, int] = {}
    out = []
    for ev in events:
        chip = ev["chip"]
        if ev["event"] == "alarm":
            open_alarms.setdefault(chip, ev["tick"])
        elif (ev["event"] == "recal_done" and chip in open_alarms
              and ev["dist_after"] < clear_threshold):
            alarm_tick = open_alarms.pop(chip)
            out.append(dict(chip=chip, alarm_tick=alarm_tick,
                            recover_tick=ev["tick"],
                            ticks=ev["tick"] - alarm_tick,
                            dist_after=ev["dist_after"]))
    return out


def main(budget: str = "quick") -> None:
    from repro.runtime.demo import simulate, default_runtime_config

    chips, steps = (3, 120) if budget == "quick" else (4, 300)
    cfg = default_runtime_config()

    results = {}
    for mode, enabled in (("closed", True), ("open", False)):
        with Timer() as t:
            results[mode] = simulate(chips, steps, seed=0, cfg=cfg,
                                     recal_enabled=enabled)
        results[mode]["wall_s"] = t.dt

    closed, open_ = results["closed"], results["open"]
    tr_c, tr_o = closed["trace"], open_["trace"]

    header = ["t", "closed_max_dist", "closed_mean_dist", "closed_serve_err",
              "closed_in_repair", "open_max_dist", "open_mean_dist",
              "open_serve_err"]
    rows = []
    for i, t in enumerate(tr_c["t"]):
        rows.append([t,
                     f"{tr_c['max_dist'][i]:.5f}",
                     f"{tr_c['mean_dist'][i]:.5f}",
                     f"{tr_c['serve_err'][i]:.5f}",
                     tr_c["n_recalibrating"][i],
                     f"{tr_o['max_dist'][i]:.5f}",
                     f"{tr_o['mean_dist'][i]:.5f}",
                     f"{tr_o['serve_err'][i]:.5f}"])
    emit("drift_recovery", header, rows)

    rep_c = closed["report"]
    recoveries = _time_to_recovery(rep_c["events"],
                                   cfg.monitor.clear_threshold)
    probe_calls = sum(c["probe_ptc_calls"] for c in rep_c["chips"])
    recal_calls = sum(c["recal_ptc_calls"] for c in rep_c["chips"])
    # serve cost is now metered per chip by its driver (Appendix-G
    # PTC calls), not reconstructed from the profiler
    serve_calls = sum(c["serve_ptc_calls"] for c in rep_c["chips"])

    summary = dict(
        budget=budget, chips=chips, steps=steps,
        alarm_threshold=cfg.monitor.alarm_threshold,
        clear_threshold=cfg.monitor.clear_threshold,
        sigma_drift=cfg.drift.sigma_phase,
        closed=dict(
            peak_max_dist=max(tr_c["max_dist"]),
            final_max_dist=tr_c["max_dist"][-1],
            mean_serve_err=sum(tr_c["serve_err"]) / len(tr_c["serve_err"]),
            dropped=rep_c["dropped"],
            alarms=sum(c["alarms"] for c in rep_c["chips"]),
            recals=sum(c["recals"] for c in rep_c["chips"]),
            wall_s=closed["wall_s"],
        ),
        open=dict(
            peak_max_dist=max(tr_o["max_dist"]),
            final_max_dist=tr_o["max_dist"][-1],
            mean_serve_err=sum(tr_o["serve_err"]) / len(tr_o["serve_err"]),
            dropped=open_["report"]["dropped"],
            wall_s=open_["wall_s"],
        ),
        time_to_recovery_ticks=[r["ticks"] for r in recoveries],
        mean_time_to_recovery=(sum(r["ticks"] for r in recoveries)
                               / len(recoveries)) if recoveries else None,
        probe_overhead_ptc_calls=probe_calls,
        recal_overhead_ptc_calls=recal_calls,
        serve_ptc_calls=serve_calls,
        probe_overhead_frac=probe_calls / serve_calls,
    )
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "BENCH_drift_recovery.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"--- drift_recovery summary ({path}) ---")
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="quick", choices=["quick", "normal"])
    main(ap.parse_args().budget)
