"""Closed-loop drift recovery: fidelity vs. time, with and without the loop.

Runs the fleet simulation (``repro.runtime.demo.simulate``) twice from
the same seed — closed loop (monitor → alarm → recalibrate) vs. open
loop (drift runs away) — and emits:

* ``drift_recovery.csv`` — the per-tick recovery curves (fleet max/mean
  mapping distance, serve error, #chips in repair) for both loops;
* ``BENCH_drift_recovery.json`` — headline numbers: time-to-recovery per
  alarm (ticks from alarm to the post-recal probe clearing the
  hysteresis threshold), final/peak distances, serving continuity, and
  probe/recal overhead in PTC calls (Appendix-G energy model via
  ``core.profiler``).

``multi_tenant`` (registered separately in ``benchmarks/run.py``) is
the multi-tenant scenario: chips time-multiplexed across several mapped
layers, partial recalibration re-tuning only the alarmed tenant's
blocks.  It emits ``BENCH_multi_tenant.json`` showing — on BOTH the
in-process twin and the subprocess (HIL) transport — that every alarmed
tenant recovers below the alarm threshold while co-resident tenants'
true distances move no more than their natural per-window drift, and
(direct frozen-device check) that a partial recal leaves co-tenants'
true distances *exactly* unchanged.

    PYTHONPATH=src python -m benchmarks.drift_recovery [--budget quick]
"""

from __future__ import annotations

import argparse
import json
import os

from .common import ART, emit, Timer


def _time_to_recovery(events: list[dict], clear_threshold: float) -> list[dict]:
    """Pair each alarm with the first subsequent recal_done on the same
    (chip, tenant) slot whose post-recal distance clears the hysteresis
    threshold."""
    open_alarms: dict[tuple, int] = {}
    out = []
    for ev in events:
        slot = (ev["chip"], ev.get("tenant", 0))
        if ev["event"] == "alarm":
            open_alarms.setdefault(slot, ev["tick"])
        elif (ev["event"] == "recal_done" and slot in open_alarms
              and ev["dist_after"] < clear_threshold):
            alarm_tick = open_alarms.pop(slot)
            out.append(dict(chip=slot[0], tenant=slot[1],
                            alarm_tick=alarm_tick,
                            recover_tick=ev["tick"],
                            ticks=ev["tick"] - alarm_tick,
                            dist_after=ev["dist_after"]))
    return out


def main(budget: str = "quick") -> None:
    from repro.runtime.demo import simulate, default_runtime_config

    chips, steps = (3, 120) if budget == "quick" else (4, 300)
    cfg = default_runtime_config()

    results = {}
    for mode, enabled in (("closed", True), ("open", False)):
        with Timer() as t:
            results[mode] = simulate(chips, steps, seed=0, cfg=cfg,
                                     recal_enabled=enabled)
        results[mode]["wall_s"] = t.dt

    closed, open_ = results["closed"], results["open"]
    tr_c, tr_o = closed["trace"], open_["trace"]

    header = ["t", "closed_max_dist", "closed_mean_dist", "closed_serve_err",
              "closed_in_repair", "open_max_dist", "open_mean_dist",
              "open_serve_err"]
    rows = []
    for i, t in enumerate(tr_c["t"]):
        rows.append([t,
                     f"{tr_c['max_dist'][i]:.5f}",
                     f"{tr_c['mean_dist'][i]:.5f}",
                     f"{tr_c['serve_err'][i]:.5f}",
                     tr_c["n_recalibrating"][i],
                     f"{tr_o['max_dist'][i]:.5f}",
                     f"{tr_o['mean_dist'][i]:.5f}",
                     f"{tr_o['serve_err'][i]:.5f}"])
    emit("drift_recovery", header, rows)

    rep_c = closed["report"]
    recoveries = _time_to_recovery(rep_c["events"],
                                   cfg.monitor.clear_threshold)
    probe_calls = sum(c["probe_ptc_calls"] for c in rep_c["chips"])
    recal_calls = sum(c["recal_ptc_calls"] for c in rep_c["chips"])
    # serve cost is now metered per chip by its driver (Appendix-G
    # PTC calls), not reconstructed from the profiler
    serve_calls = sum(c["serve_ptc_calls"] for c in rep_c["chips"])

    summary = dict(
        budget=budget, chips=chips, steps=steps,
        alarm_threshold=cfg.monitor.alarm_threshold,
        clear_threshold=cfg.monitor.clear_threshold,
        sigma_drift=cfg.drift.sigma_phase,
        closed=dict(
            peak_max_dist=max(tr_c["max_dist"]),
            final_max_dist=tr_c["max_dist"][-1],
            mean_serve_err=sum(tr_c["serve_err"]) / len(tr_c["serve_err"]),
            dropped=rep_c["dropped"],
            alarms=sum(c["alarms"] for c in rep_c["chips"]),
            recals=sum(c["recals"] for c in rep_c["chips"]),
            wall_s=closed["wall_s"],
        ),
        open=dict(
            peak_max_dist=max(tr_o["max_dist"]),
            final_max_dist=tr_o["max_dist"][-1],
            mean_serve_err=sum(tr_o["serve_err"]) / len(tr_o["serve_err"]),
            dropped=open_["report"]["dropped"],
            wall_s=open_["wall_s"],
        ),
        time_to_recovery_ticks=[r["ticks"] for r in recoveries],
        mean_time_to_recovery=(sum(r["ticks"] for r in recoveries)
                               / len(recoveries)) if recoveries else None,
        probe_overhead_ptc_calls=probe_calls,
        recal_overhead_ptc_calls=recal_calls,
        serve_ptc_calls=serve_calls,
        probe_overhead_frac=probe_calls / serve_calls,
    )
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "BENCH_drift_recovery.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"--- drift_recovery summary ({path}) ---")
    print(json.dumps(summary, indent=2))


def _frozen_partial_recal(driver_kind: str, seed: int = 0) -> dict:
    """Direct check with the device frozen (no ticks during the job):
    drift a 3-tenant chip until its worst tenant is past the alarm
    threshold, partially recalibrate that tenant's block range, and
    read back every tenant's TRUE distance before/after — co-tenants
    must be *exactly* unchanged (their commanded state was never
    touched and the device did not move)."""
    import jax
    import numpy as np
    from repro.runtime.demo import default_runtime_config, _make_weights
    from repro.runtime.fleet import make_chip
    from repro.runtime.recalibrate import recalibrate

    cfg = default_runtime_config(k=4, sigma_drift=0.04, driver_kind=driver_kind)
    dim, tenants = 12, 3
    kw, kc, kr = jax.random.split(jax.random.PRNGKey(seed), 3)
    ws = _make_weights(kw, dim, tenants)
    chip = make_chip(kc, 0, ws, cfg)
    try:
        for _ in range(60):
            chip.driver.advance(1.0)
        h = chip.driver.unsafe_twin()
        pre = [h.true_mapping_distance(t.w_blocks, t.block_range)
               for t in chip.tenants]
        worst = int(np.argmax(pre))
        ten = chip.tenants[worst]
        res = recalibrate(kr, chip.driver, ten.w_blocks, cfg.recal,
                          block_range=ten.block_range)
        post = [h.true_mapping_distance(t.w_blocks, t.block_range)
                for t in chip.tenants]
    finally:
        chip.driver.close()
    return dict(
        driver=driver_kind, recal_tenant=worst,
        dist_pre=pre, dist_post=post,
        recovered=bool(post[worst] < cfg.monitor.alarm_threshold),
        cotenants_bit_identical=all(
            pre[j] == post[j] for j in range(tenants) if j != worst),
        ptc_calls=res.ptc_calls)


def multi_tenant(budget: str = "quick") -> None:
    """Multi-tenant drift recovery, on both driver transports."""
    from repro.runtime.demo import (simulate, default_runtime_config,
                                    cotenant_shifts, drift_noise_band,
                                    isolation_band)

    chips, steps, tenants = (2, 80, 3) if budget == "quick" else (3, 200, 3)
    summary = dict(budget=budget, chips=chips, steps=steps, tenants=tenants,
                   transports={})
    for driver_kind in ("twin", "subprocess"):
        cfg = default_runtime_config(k=4, sigma_drift=0.04, probe_every=5,
                                     driver_kind=driver_kind)
        with Timer() as t:
            out = simulate(chips, steps, dim=12, seed=0, cfg=cfg,
                           tenants=tenants)
        rep = out["report"]
        recoveries = _time_to_recovery(rep["events"],
                                       cfg.monitor.alarm_threshold)
        shifts = cotenant_shifts(out["trace"], rep["events"],
                                 cfg.recal_latency)
        noise = drift_noise_band(out["trace"], rep["events"],
                                 cfg.recal_latency)
        worst_shift = max((abs(s["shift"]) for s in shifts), default=0.0)
        frozen = _frozen_partial_recal(driver_kind)
        summary["transports"][driver_kind] = dict(
            wall_s=t.dt,
            alarms=sum(c["alarms"] for c in rep["chips"]),
            recals=sum(c["recals"] for c in rep["chips"]),
            dropped=rep["dropped"],
            recoveries=len(recoveries),
            mean_time_to_recovery=(sum(r["ticks"] for r in recoveries)
                                   / len(recoveries)) if recoveries else None,
            recal_done_below_alarm=all(
                ev["dist_after"] < cfg.monitor.alarm_threshold
                for ev in rep["events"] if ev["event"] == "recal_done"),
            cotenant_windows=len(shifts),
            worst_cotenant_shift=worst_shift,
            drift_noise_band=noise,
            cotenants_within_noise=bool(worst_shift <= isolation_band(
                noise, cfg.monitor.clear_threshold)),
            frozen_device_check=frozen,
            per_tenant=[[dict(tenant=t_["tenant"], served=t_["served"],
                              alarms=t_["alarms"], recals=t_["recals"],
                              distance=t_["distance"])
                         for t_ in c["tenants"]] for c in rep["chips"]])
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "BENCH_multi_tenant.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"--- multi_tenant summary ({path}) ---")
    print(json.dumps(summary, indent=2))
    for kind, s in summary["transports"].items():
        assert s["recals"] > 0 and s["recal_done_below_alarm"], kind
        assert s["cotenants_within_noise"], kind
        assert s["frozen_device_check"]["recovered"], kind
        assert s["frozen_device_check"]["cotenants_bit_identical"], kind


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="quick", choices=["quick", "normal"])
    ap.add_argument("--scenario", default="single",
                    choices=["single", "multi_tenant"])
    _args = ap.parse_args()
    (multi_tenant if _args.scenario == "multi_tenant" else main)(_args.budget)
