"""Serving-gateway benchmark: continuous batching vs sequential serving.

The gateway's claim (``src/repro/serving/``) is that cross-request PTC
frame coalescing turns N concurrent users into ONE chip round-trip per
layer group per step — so a photonic fleet serves strictly more tokens
per second per chip than the sequential batch-1 ``serve --hw-logits``
loop PR 5 shipped.  This benchmark measures that claim and locks the
correctness gates around it:

1. **Throughput** — the same open-loop workload (seeded Poisson
   arrivals) served two ways on an identical 2-chip fleet: one
   sequential batch-1 ``launch.serve --hw-logits`` run per request, vs
   one continuous-batching gateway run.  Both paths are warmed first
   (the jit/driver caches are process-wide, so cold compiles would
   bill whichever path runs first), then timed.  Gate:
   ``tokens/s-per-chip`` speedup ≥ 2×.
2. **Token identity** — the gateway's per-request outputs are
   token-identical to the sequential runs (twin transport, σ = 0), and
   the socket transport's gateway outputs match the twin's.  Paging,
   batching, and transport must all be invisible to the user.
3. **Latency vs offered load** — a digital-gateway sweep over arrival
   rates: p50/p99 request latency and admission wait in *virtual
   steps* (host-invariant), occupancy, busy fraction.
4. **Drift point** — one closed-loop hw run (σ > 0, recal on) proving
   the gateway completes under live drift/repair traffic.

Artifacts: ``serving_gateway.csv`` (load sweep) and
``BENCH_serving_gateway.json`` with the gates + host-invariant metrics
``check_regression.py`` gates in CI (speedup ratio, 1/p99 latency).

    PYTHONPATH=src python -m benchmarks.serving_gateway [--budget quick]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

from .common import ART, emit

ARCH = "smoke:qwen3-4b"
SEED = 5
FLEET = 2
FLEET_K = 8
SLOTS = 4
PAGE = dict(page_size=8, pages=32, max_pages_per_slot=4)


def _fresh(reqs):
    """Requests are mutated by a run (lifecycle stamps, out_tokens) —
    every serving leg gets its own copies."""
    return [dataclasses.replace(r, out_tokens=[]) for r in reqs]


def _seq_args(params, req, *, driver="twin", sigma=0.0, recal=False):
    return argparse.Namespace(
        arch=ARCH, batch=1, prompt_len=req.prompt_len, gen=req.max_new,
        seed=SEED, fleet=FLEET, drift=sigma > 0, drift_sigma=sigma,
        probe_every=10, fleet_k=FLEET_K, fleet_dim=8, fleet_tenants=1,
        fleet_driver=driver, hw_logits=True, hw_shadow=False,
        deploy_zo=False, no_recal=not recal,
        prompt_tokens=req.prompt[None], params_override=params)


def _gw_args(params, reqs, *, hw=True, driver="twin", sigma=0.0,
             recal=False, slots=SLOTS, chunk=1, page=None):
    return argparse.Namespace(
        arch=ARCH, seed=SEED, slots=slots, requests=len(reqs), rate=1.0,
        max_new=(4, 12), eos_id=None, **(page or PAGE),
        prefill_chunk=chunk,
        fleet=FLEET if hw else 0, drift=sigma > 0, drift_sigma=sigma,
        probe_every=10, fleet_k=FLEET_K, fleet_driver=driver,
        hw_logits=hw, hw_shadow=False, deploy_zo=False,
        no_recal=not recal, params_override=params,
        requests_override=_fresh(reqs))


def _seq_sweep(params, reqs, **kw):
    """One sequential batch-1 hw-logits run per request; returns
    (Σ wall_s of the decode loops, Σ tokens, per-request token lists)."""
    from repro.launch import serve as serve_mod

    wall, tokens, outs = 0.0, 0, []
    for r in reqs:
        out = serve_mod.run(_seq_args(params, r, **kw))
        wall += out["wall_s"]
        tokens += out["gen"].size
        outs.append([int(t) for t in out["gen"][0]])
    return wall, tokens, outs


def main(budget: str = "quick") -> None:
    import jax
    from repro.launch.train import parse_arch
    from repro.models.lm import init_model
    from repro.serving.gateway import run as gw_run
    from repro.serving.scheduler import poisson_workload

    if budget == "quick":
        n_req, max_new = 8, (12, 16)
        sweep_rates = [0.5, 1.0, 2.0, 4.0]
        sweep_req = 16
        sock_req, sock_new = 3, (4, 6)
    else:
        n_req, max_new = 12, (16, 24)
        sweep_rates = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0]
        sweep_req = 32
        sock_req, sock_new = 4, (6, 8)

    cfg = parse_arch(ARCH)
    params = init_model(jax.random.PRNGKey(0), cfg)
    reqs = poisson_workload(SEED, n_req, 2.0, cfg.vocab,
                            prompt_len=(4, 8), max_new=max_new)
    expected_tokens = sum(r.max_new for r in reqs)

    # -- throughput: sequential vs gateway on one fleet ----------------------
    # warm both paths first: jit caches (model step, twin layer kernels,
    # paged gather/scatter) are process-wide, so the first path to run
    # would otherwise be billed everyone's compiles
    _seq_sweep(params, reqs[:1])
    gw_run(_gw_args(params, reqs[:2]))

    seq_wall, seq_tokens, seq_outs = _seq_sweep(params, reqs)
    gw_rep = gw_run(_gw_args(params, reqs))
    gw_outs = [r["tokens"] for r in gw_rep["requests"]]
    seq_tps = seq_tokens / seq_wall / FLEET
    gw_tps = gw_rep["tokens_out"] / gw_rep["wall_s"] / FLEET
    speedup = gw_tps / seq_tps
    twin_identical = gw_outs == seq_outs
    frames = gw_rep["fleet"]["hw"]
    print(f"sequential: {seq_tokens} tok in {seq_wall:.2f}s "
          f"→ {seq_tps:.2f} tok/s/chip", flush=True)
    print(f"gateway:    {gw_rep['tokens_out']} tok in "
          f"{gw_rep['wall_s']:.2f}s → {gw_tps:.2f} tok/s/chip "
          f"({gw_rep['steps']} steps, occupancy "
          f"{gw_rep['occupancy']:.2f}/{SLOTS}, "
          f"{frames['frames_per_step']:.1f} coalesced frames/step)",
          flush=True)
    print(f"speedup {speedup:.2f}× | twin token-identity: "
          f"{twin_identical}", flush=True)

    # -- socket transport identity -------------------------------------------
    sreqs = poisson_workload(SEED + 1, sock_req, 2.0, cfg.vocab,
                             prompt_len=(3, 6), max_new=sock_new)
    _, _, sock_seq = _seq_sweep(params, sreqs, driver="socket")
    sock_rep = gw_run(_gw_args(params, sreqs, driver="socket"))
    sock_outs = [r["tokens"] for r in sock_rep["requests"]]
    socket_identical = sock_outs == sock_seq
    print(f"socket token-identity (gateway ≡ sequential): "
          f"{socket_identical}", flush=True)

    # -- chunked paged prefill: TTFT on a prompt-heavy workload --------------
    # Prompt tokens dominate this workload, so time-to-first-token is
    # governed by prefill throughput: C tokens/step/slot instead of 1.
    # TTFT is measured in VIRTUAL STEPS (a pure function of the seeded
    # schedule — bit-deterministic across hosts), so the ≥4× gate and
    # the drop-gated speedup metric are host-invariant.
    pre_page = dict(page_size=8, pages=64, max_pages_per_slot=8)
    pre_reqs = poisson_workload(SEED + 3, 6 if budget == "quick" else 8,
                                2.0, cfg.vocab, prompt_len=(24, 44),
                                max_new=(4, 6))
    pre_ttft, pre_busy, pre_outs = {}, {}, {}
    for c in (1, 8, 32):
        rep = gw_run(_gw_args(params, pre_reqs, hw=False, chunk=c,
                              page=pre_page))
        pre_ttft[str(c)] = rep["ttft_steps"]
        pre_busy[str(c)] = rep["busy_steps"]
        pre_outs[c] = [r["tokens"] for r in rep["requests"]]
        print(f"prefill chunk {c:2d}: ttft p50 "
              f"{rep['ttft_steps']['p50']:5.1f} p99 "
              f"{rep['ttft_steps']['p99']:5.1f} steps | "
              f"{rep['busy_steps']} busy steps", flush=True)
    chunk_digital_ok = pre_outs[8] == pre_outs[1] == pre_outs[32]
    ttft_speedup = pre_ttft["1"]["p50"] / max(pre_ttft["8"]["p50"], 1e-9)
    print(f"chunked ttft speedup (C=8 vs C=1): {ttft_speedup:.2f}× | "
          f"digital token-identity: {chunk_digital_ok}", flush=True)

    # twin transport: the wide (decode + Σ chunk) frames must stay
    # invisible to tokens while cutting the frame count
    tw_reqs = pre_reqs[:4]
    tw1 = gw_run(_gw_args(params, tw_reqs, chunk=1, page=pre_page))
    tw8 = gw_run(_gw_args(params, tw_reqs, chunk=8, page=pre_page))
    chunk_twin_ok = ([r["tokens"] for r in tw8["requests"]]
                     == [r["tokens"] for r in tw1["requests"]])
    hw1, hw8 = tw1["fleet"]["hw"], tw8["fleet"]["hw"]
    frames_reduced = hw8["frames"] < hw1["frames"]
    print(f"twin chunked: token-identity {chunk_twin_ok} | frames "
          f"{hw1['frames']}→{hw8['frames']} (cols/frame "
          f"{hw1['cols_per_frame']:.1f}→{hw8['cols_per_frame']:.1f})",
          flush=True)

    # socket transport: same identity through the real wire protocol
    sk_reqs = poisson_workload(SEED + 4, 3, 2.0, cfg.vocab,
                               prompt_len=(12, 20), max_new=(3, 4))
    sk_page = dict(page_size=8, pages=32, max_pages_per_slot=3)
    sk1 = gw_run(_gw_args(params, sk_reqs, driver="socket", chunk=1,
                          page=sk_page))
    sk8 = gw_run(_gw_args(params, sk_reqs, driver="socket", chunk=8,
                          page=sk_page))
    chunk_socket_ok = ([r["tokens"] for r in sk8["requests"]]
                       == [r["tokens"] for r in sk1["requests"]])
    print(f"socket chunked token-identity: {chunk_socket_ok}", flush=True)

    # -- latency vs offered load (digital gateway, virtual steps) ------------
    sweep = []
    for rate in sweep_rates:
        wl = poisson_workload(SEED + 2, sweep_req, rate, cfg.vocab,
                              prompt_len=(4, 8), max_new=(8, 12))
        rep = gw_run(_gw_args(params, wl, hw=False))
        lat, wait = rep["latency_steps"], rep["admission_wait_steps"]
        sweep.append(dict(
            rate=rate, steps=rep["steps"], busy_steps=rep["busy_steps"],
            occupancy=rep["occupancy"],
            p50_latency_steps=lat["p50"], p99_latency_steps=lat["p99"],
            p50_wait_steps=wait["p50"], p99_wait_steps=wait["p99"]))
        print(f"rate {rate:4.2f}: latency p50 {lat['p50']:5.1f} "
              f"p99 {lat['p99']:6.1f} steps | wait p99 "
              f"{wait['p99']:5.1f} | occupancy {rep['occupancy']:.2f}",
              flush=True)
    ref = next(s for s in sweep if s["rate"] == 2.0)

    # -- closed-loop drift point ---------------------------------------------
    drift_rep = gw_run(_gw_args(params, reqs, sigma=0.008, recal=True))
    drift_chips = drift_rep["fleet"]["chips"]
    drift_complete = drift_rep["tokens_out"] == expected_tokens
    print(f"drift σ=0.008 closed loop: {drift_rep['tokens_out']} tok, "
          f"{sum(c['alarms'] for c in drift_chips)} alarms, "
          f"{sum(c['recals'] for c in drift_chips)} recals, "
          f"complete={drift_complete}", flush=True)

    gates = dict(
        speedup_ge_2x=bool(speedup >= 2.0),
        sigma0_token_identical_twin=bool(twin_identical),
        sigma0_token_identical_socket=bool(socket_identical),
        drift_closed_loop_completes=bool(drift_complete),
        chunked_token_identical_digital=bool(chunk_digital_ok),
        chunked_token_identical_twin=bool(chunk_twin_ok),
        chunked_token_identical_socket=bool(chunk_socket_ok),
        chunked_ttft_ge_4x=bool(ttft_speedup >= 4.0),
        chunked_frames_reduced=bool(frames_reduced))

    emit("serving_gateway",
         ["rate", "steps", "occupancy", "p50_latency_steps",
          "p99_latency_steps", "p99_wait_steps"],
         [[s["rate"], s["steps"], f"{s['occupancy']:.3f}",
           f"{s['p50_latency_steps']:.1f}", f"{s['p99_latency_steps']:.1f}",
           f"{s['p99_wait_steps']:.1f}"] for s in sweep])

    summary = dict(
        budget=budget, arch=ARCH, seed=SEED, fleet=FLEET, slots=SLOTS,
        page=PAGE, n_requests=n_req,
        sequential=dict(wall_s=seq_wall, tokens=seq_tokens,
                        tokens_per_s_per_chip=seq_tps),
        gateway=dict(wall_s=gw_rep["wall_s"], tokens=gw_rep["tokens_out"],
                     tokens_per_s_per_chip=gw_tps,
                     steps=gw_rep["steps"], occupancy=gw_rep["occupancy"],
                     frames_per_step=frames["frames_per_step"],
                     latency_steps=gw_rep["latency_steps"]),
        tokens_per_chip_speedup=speedup,
        load_sweep=sweep,
        ref_rate=dict(rate=ref["rate"],
                      p50_latency_steps=ref["p50_latency_steps"],
                      p99_latency_steps=ref["p99_latency_steps"]),
        drift=dict(sigma=0.008, tokens_out=drift_rep["tokens_out"],
                   alarms=sum(c["alarms"] for c in drift_chips),
                   recals=sum(c["recals"] for c in drift_chips)),
        prefill=dict(
            workload=dict(n=len(pre_reqs), prompt_len=[24, 44],
                          max_new=[4, 6], page=pre_page),
            ttft=pre_ttft, busy_steps=pre_busy,
            ttft_speedup_c8=ttft_speedup,
            twin=dict(frames_c1=hw1["frames"], frames_c8=hw8["frames"],
                      cols_per_frame_c1=hw1["cols_per_frame"],
                      cols_per_frame_c8=hw8["cols_per_frame"])),
        gates=gates)
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "BENCH_serving_gateway.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"--- serving_gateway summary ({path}) ---")
    print(json.dumps(dict(gates=gates, speedup=speedup,
                          ttft_speedup_c8=ttft_speedup,
                          p99_latency_steps=ref["p99_latency_steps"]),
                     indent=2))
    for name, ok in gates.items():
        assert ok, f"serving gateway gate failed: {name}"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="quick", choices=["quick", "normal"])
    main(ap.parse_args().budget)
