"""§Perf hillclimbing harness: hypothesis → change → measure → validate.

Each named EXPERIMENT is a config variant of one of the three chosen
(arch × shape) pairs.  For each we record the three roofline terms (via
the unrolled 2-point extrapolation) plus the full-compile memory, into
``bench_artifacts/perf/<pair>__<variant>.json``.  The EXPERIMENTS.md
§Perf log narrates the hypotheses and outcomes.

    PYTHONPATH=src python -m benchmarks.perf_iterations <variant> [...]
    PYTHONPATH=src python -m benchmarks.perf_iterations --list
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "..", "bench_artifacts",
                   "perf")


def _variants():
    """variant name → (arch, shape, config-transform)"""
    from repro.configs import get_config

    def base(arch):
        return get_config(arch)

    def ptc_mode(cfg, mode):
        return dataclasses.replace(
            cfg, ptc=dataclasses.replace(cfg.ptc, mode=mode))

    v = {}
    # ---- pair 1: olmo-1b × train_4k (paper-technique representative;
    # supports the TRUE blocked photonic-dataflow lowering at LM scale)
    v["olmo__train__blocked"] = (
        "olmo-1b", "train_4k",
        lambda: ptc_mode(base("olmo-1b"), "blocked"))
    v["olmo__train__fused"] = (
        "olmo-1b", "train_4k",
        lambda: base("olmo-1b"))
    v["olmo__train__fused_noremat"] = (
        "olmo-1b", "train_4k",
        lambda: dataclasses.replace(base("olmo-1b"), remat=False))
    v["olmo__train__fused_fullattn"] = (
        "olmo-1b", "train_4k",
        lambda: dataclasses.replace(base("olmo-1b"), attn_chunk=None))
    v["olmo__train__fused_rematdots"] = (
        "olmo-1b", "train_4k",
        lambda: dataclasses.replace(base("olmo-1b"), remat_policy="dots"))
    # ---- pair 2: qwen3-moe × train_4k (most collective-bound)
    v["qwen3moe__train__base"] = (
        "qwen3-moe-30b-a3b", "train_4k",
        lambda: base("qwen3-moe-30b-a3b"))
    v["qwen3moe__train__a2a"] = (
        "qwen3-moe-30b-a3b", "train_4k",
        lambda: dataclasses.replace(base("qwen3-moe-30b-a3b"),
                                    moe_dispatch="a2a"))
    v["qwen3moe__train__a2a_rsgrad"] = (
        "qwen3-moe-30b-a3b", "train_4k",
        lambda: dataclasses.replace(base("qwen3-moe-30b-a3b"),
                                    moe_dispatch="a2a",
                                    remat_policy="dots"))
    # ---- pair 3: jamba × train_4k (worst roofline / memory)
    v["jamba__train__base"] = (
        "jamba-1.5-large-398b", "train_4k",
        lambda: base("jamba-1.5-large-398b"))
    v["jamba__train__outer_only"] = (
        "jamba-1.5-large-398b", "train_4k",
        lambda: base("jamba-1.5-large-398b"))
    v["jamba__train__chunk128"] = (
        "jamba-1.5-large-398b", "train_4k",
        lambda: dataclasses.replace(base("jamba-1.5-large-398b"),
                                    ssm_chunk=128))
    v["jamba__train__chunk512"] = (
        "jamba-1.5-large-398b", "train_4k",
        lambda: dataclasses.replace(base("jamba-1.5-large-398b"),
                                    ssm_chunk=512))
    v["jamba__train__ssm_sharded"] = (
        "jamba-1.5-large-398b", "train_4k",
        lambda: base("jamba-1.5-large-398b"))
    return v


def measure(name: str, arch: str, shape: str, cfg) -> dict:
    from repro.models.lm import period_plan
    from repro.launch.dryrun import run_cell
    from benchmarks.roofline import (extrapolated, PEAK_FLOPS, HBM_BW,
                                     LINK_BW, active_param_count)
    plan, n_periods = period_plan(cfg)
    ex = extrapolated(arch, shape, n_periods, cfg_override=cfg)
    full = run_cell(arch, shape, False, cfg_override=cfg)
    n_active = active_param_count(cfg)
    from repro.configs import SHAPES
    sh = SHAPES[shape]
    d_tokens = sh.global_batch * sh.seq_len
    model_flops = (6.0 if sh.kind == "train" else 2.0) * n_active * d_tokens
    t = dict(compute=ex["flops"] / PEAK_FLOPS,
             memory=ex["bytes"] / HBM_BW,
             collective=ex["coll_bytes"] / LINK_BW)
    bound = max(t.values())
    rec = {
        "variant": name, "arch": arch, "shape": shape,
        "terms_s": t,
        "dominant": max(t, key=t.get),
        "flops_per_dev": ex["flops"],
        "coll_breakdown": ex["coll"],
        "useful_ratio": model_flops / 256 / ex["flops"],
        "roofline_fraction": (model_flops / 256 / PEAK_FLOPS) / bound,
        "full_temp_gb": full["memory"]["temp_bytes"] / 1e9,
        "full_args_gb": full["memory"]["argument_bytes"] / 1e9,
        "compile_s": full["compile_s"],
    }
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"{name}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[{name}] comp={t['compute']:.2f}s mem={t['memory']:.2f}s "
          f"coll={t['collective']:.2f}s dom={rec['dominant']} "
          f"useful={rec['useful_ratio']:.2f} "
          f"roofline={rec['roofline_fraction']:.3f} "
          f"temp={rec['full_temp_gb']:.0f}GB", flush=True)
    return rec


def main():
    vs = _variants()
    args = sys.argv[1:]
    if not args or args[0] == "--list":
        print("\n".join(vs))
        return
    for name in args:
        arch, shape, mk = vs[name]
        measure(name, arch, shape, mk())


if __name__ == "__main__":
    main()
