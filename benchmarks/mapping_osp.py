"""Paper Fig. 5: ZO optimizers on Parallel Mapping + the OSP error drop.

Reproduces the figure's two claims: (1) coordinate-wise ZO (ZCD/ZTP)
beats gradient-estimate ZGD on the blockwise regression; (2) the final
analytic OSP projection gives a significant error drop "for free"."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noise import NoiseModel
from repro.core.mapping import parallel_map
from repro.optim.zo import ZOConfig

from .common import emit


def main(budget: str = "normal"):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((27, 27)) * 0.3, jnp.float32)
    # PM under a HARSH frame (extra bias residue) so ZO has work to do:
    # σ_γ ×5 emulates a poorly-calibrated chip (Fig. 5's regime)
    import dataclasses
    model = dataclasses.replace(NoiseModel().post_ic(), gamma_std=0.01,
                                crosstalk=0.01)
    steps = 1500 if budget == "quick" else 3500
    rows = []
    for method in ["zgd", "zcd", "ztp"]:
        cfg = ZOConfig(steps=steps, inner=72,
                       delta0=8 * 2 * np.pi / 255, decay=1.05, lr0=0.1)
        pm = parallel_map(jax.random.PRNGKey(1), w, 9, model,
                          method=method, cfg=cfg)
        rows.append([method,
                     round(float(np.asarray(pm.err_init).mean()), 5),
                     round(float(np.asarray(pm.err_zo).mean()), 5),
                     round(float(np.asarray(pm.err_osp).mean()), 5)])
    emit("fig5_mapping_osp",
         ["zo_method", "err_init", "err_after_zo", "err_after_osp"], rows)


if __name__ == "__main__":
    main()
