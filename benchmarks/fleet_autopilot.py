"""Fleet-autopilot benchmark: forecast-driven vs alarm-driven upkeep.

The autopilot's claim (``src/repro/runtime/autopilot.py``) is that the
maintenance loop should act on the forecasts the router already owns —
the OU relaxation law plus each tenant's measured degradation rate —
instead of waiting for hysteretic alarms.  This benchmark drives an
identical seeded **diurnal** workload (bursty Poisson arrivals over a
sinusoidal day, correlated drift bursts, injected chip outages) through
both schedulers on bit-identical fleets and locks gates around the SLO
story:

1. **Scheduler duel** — alarm-driven reactive loop (``drift_aware``
   routing, FIFO repair) vs autopilot (``accuracy_aware`` routing,
   degradation-rate priority queue, trough-scheduled proactive recals
   under a PTC-call envelope).  A queue model converts routable
   capacity into per-request latency; every served request's *realized*
   relative error is measured through the chip's drifted transfer.
   Gates: autopilot accuracy no worse, strictly fewer reactive alarms,
   every budget window's *proactive* recal spend within the envelope
   (reactive repairs are exempt by design — an alarm is already an SLO
   breach, and the envelope bounds the extra maintenance power
   prediction may add on top).
2. **Sensitivity calibration** — the ``logit_sensitivity`` prior
   (Frobenius energy per input column) that weights the
   ``accuracy_aware`` policy is validated against *measured* per-tenant
   output-error energy on drifted hardware (the PR-5 e2e methodology:
   realized transfer vs ideal logits), per tenant at matched relative
   distance.  Gate: the predicted ranking matches the measured one.
3. **Gateway leg** — one closed-loop continuous-batching run
   (``--hw-logits`` + ``--autopilot``) over a bursty arrival schedule,
   proving the trough signal flows gateway → router and the run
   completes under proactive maintenance.

Artifacts: ``fleet_autopilot.csv`` (per-phase load/latency/alarm
series) and ``BENCH_fleet_autopilot.json`` with the gates +
host-invariant metrics ``check_regression.py`` gates in CI (SLO
attainment, inverse p99 latency, alarms averted — all virtual-tick
quantities of a seeded schedule, bit-deterministic across hosts).

    PYTHONPATH=src python -m benchmarks.fleet_autopilot [--budget quick]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os

import numpy as np

from .common import ART, emit

SEED = 11
CHIPS = 3
TENANTS = 2
DIM = 12
K = 4
SIGMA = 0.02
PROBE_EVERY = 5
PERIOD = 80                      # ticks per diurnal cycle
RATE_BASE = 2.0                  # mean arrivals/tick at mid-day
RATE_AMP = 0.9                   # peak/trough swing
CAP_PER_CHIP = 2                 # requests a routable chip absorbs/tick
LAT_SLO = 6.0                    # ticks: queue-latency SLO
ERR_SLO = 0.08                   # realized relative serve error SLO
BUDGET_CALLS = 60_000.0          # proactive recal PTC-call envelope/window
HORIZON = 30
TROUGH = 0.55


def _runtime_cfg(autopilot=None, policy="drift_aware"):
    from repro.runtime.demo import default_runtime_config

    # auto_budget: jobs are sized to measured drift depth by the
    # knee-calibrated autotuner (RecalConfig.auto_coeff/auto_min) —
    # proactive repairs trigger shallow, so they cost a fraction of a
    # full-depth job and the PTC envelope buys several per window
    cfg = default_runtime_config(k=K, sigma_drift=SIGMA,
                                 probe_every=PROBE_EVERY,
                                 auto_budget=True)
    return dataclasses.replace(cfg, router_policy=policy,
                               autopilot=autopilot,
                               max_concurrent_recals=2)


def _tenant_weights():
    """Two mapped layers with distinct Frobenius energies, so the
    sensitivity prior has a real ranking to get right."""
    rng = np.random.default_rng(SEED)
    scales = [1.0, 1.7][:TENANTS]
    return [np.asarray(rng.standard_normal((DIM, DIM)) / np.sqrt(DIM)
                       * s, np.float32) for s in scales]


def _schedule(ticks: int):
    """The seeded day: per-tick arrival counts + correlated drift
    bursts + chip outages.  Precomputed once and replayed identically
    in both arms."""
    rng = np.random.default_rng(SEED + 1)
    lam = RATE_BASE * (1.0 + RATE_AMP
                       * np.sin(2.0 * np.pi * np.arange(ticks) / PERIOD))
    arrivals = rng.poisson(np.maximum(lam, 0.05))
    tenant_of = rng.integers(0, TENANTS, size=int(arrivals.sum()))
    # correlated drift bursts: a thermal event ages one chip by several
    # extra ticks at once (rate spike the EWMA must catch)
    bursts = {}
    for t in rng.choice(np.arange(10, ticks - 10), size=max(2, ticks // 60),
                        replace=False):
        bursts[int(t)] = (int(rng.integers(0, CHIPS)), 12.0)
    # one outage per day, mid-morning ramp: the board drops off the
    # network while its drift keeps walking
    outages = {int(PERIOD * (i + 0.3)): (i % CHIPS, 8)
               for i in range(max(1, ticks // PERIOD - 1))}
    return arrivals, tenant_of, bursts, outages


def _run_arm(label: str, ticks: int, autopilot=None,
             policy: str = "drift_aware") -> dict:
    """One scheduler arm over the seeded day.  Returns summary stats +
    the per-window recal spend series."""
    import jax
    from repro.runtime.fleet import make_fleet, make_router
    from repro.runtime.autopilot import logit_sensitivity

    weights = _tenant_weights()
    cfg = _runtime_cfg(autopilot=autopilot, policy=policy)
    chips = make_fleet(jax.random.PRNGKey(SEED + 2), CHIPS, weights, cfg)
    router = make_router(chips, cfg, seed=SEED + 3)
    if policy == "accuracy_aware":
        router.set_sensitivity(logit_sensitivity(weights))

    arrivals, tenant_of, bursts, outages = _schedule(ticks)
    xs = [np.asarray(np.random.default_rng(SEED + 4 + j)
                     .standard_normal((4, DIM)), np.float32)
          for j in range(TENANTS)]
    y_ref = [x @ w.T for x, w in zip(xs, weights)]
    ref_energy = [float((y ** 2).sum()) for y in y_ref]

    queue: list[tuple[int, int]] = []     # (arrival_tick, tenant)
    next_req = 0
    lat, err = [], []
    cap_full = CAP_PER_CHIP * CHIPS
    spend_series = []                     # cumulative recal calls per tick
    series = []
    for t in range(ticks):
        for _ in range(int(arrivals[t])):
            queue.append((t, int(tenant_of[next_req])))
            next_req += 1
        load = min(1.0, len(queue) / cap_full)
        router.observe_load(load)
        router.tick()
        if t in bursts:
            c, extra = bursts[t]
            chips[c].driver.advance(extra)
        if t in outages:
            c, dur = outages[t]
            router.inject_outage(c, dur)
        cap = CAP_PER_CHIP * sum(c.routable for c in chips)
        for _ in range(min(cap, len(queue))):
            t0, ten = queue.pop(0)
            y, _cid = router.serve(xs[ten], tenant=ten)
            lat.append(t - t0)
            err.append(float(((np.asarray(y) - y_ref[ten]) ** 2).sum())
                       / ref_energy[ten])
        spend_series.append(sum(c.recal_calls for c in chips))
        series.append(dict(tick=t, load=load, queue=len(queue)))

    rep = router.report()
    alarms = sum(c["alarms"] for c in rep["chips"])
    recals = sum(c["recals"] for c in rep["chips"])
    lat_a, err_a = np.asarray(lat, float), np.asarray(err, float)
    slo = float(np.mean((lat_a <= LAT_SLO) & (err_a <= ERR_SLO)))
    # per-window recal spend (public counters, not the router's private
    # window state): cumulative-call diffs at window boundaries
    window = (autopilot.budget_window if autopilot is not None else PERIOD)
    marks = [0.0] + [spend_series[min(i + window, ticks) - 1]
                     for i in range(0, ticks, window)]
    window_spend = [b - a for a, b in zip(marks, marks[1:])]
    deltas = [b - a for a, b in zip([0.0] + spend_series, spend_series)]
    max_job_cost = max(deltas) if deltas else 0.0
    out = dict(
        label=label, ticks=ticks, requests=len(lat),
        unserved=len(queue), dropped=rep["dropped"],
        alarms=alarms, recals=recals,
        p50_latency=float(np.percentile(lat_a, 50)),
        p99_latency=float(np.percentile(lat_a, 99)),
        mean_err=float(err_a.mean()), p99_err=float(np.percentile(err_a, 99)),
        max_err=float(err_a.max()), slo_attainment=slo,
        recal_ptc_calls=float(spend_series[-1]),
        window_spend=window_spend, max_job_cost=max_job_cost,
        autopilot=rep.get("autopilot"), series=series)
    print(f"{label:>10s}: {len(lat)} served | latency p50 "
          f"{out['p50_latency']:.1f} p99 {out['p99_latency']:.1f} | err "
          f"mean {out['mean_err']:.4f} p99 {out['p99_err']:.4f} | "
          f"{alarms} alarms, {recals} recals | SLO {slo:.3f}", flush=True)
    router.close()
    return out


def _sensitivity_validation() -> dict:
    """Measured e2e check of the ``logit_sensitivity`` prior: deploy
    tenants of distinct energies on ONE chip, drift it, and compare the
    predicted per-tenant error leverage (sensitivity × realized
    relative distance) against the *measured* output-error energy
    through the drifted transfer.  The prior is only trusted to rank."""
    import jax
    from repro.runtime.fleet import make_chip
    from repro.runtime.autopilot import logit_sensitivity

    rng = np.random.default_rng(SEED + 9)
    weights = [np.asarray(rng.standard_normal((DIM, DIM)) / np.sqrt(DIM)
                          * s, np.float32) for s in (0.6, 1.0, 1.8)]
    cfg = _runtime_cfg()
    chip = make_chip(jax.random.PRNGKey(SEED + 10), 0, weights, cfg)
    for _ in range(60):
        chip.driver.advance(1.0)
    sens = logit_sensitivity(weights)
    x = np.asarray(rng.standard_normal((16, DIM)), np.float32)
    measured, predicted = [], []
    for t, w in zip(chip.tenants, weights):
        y = np.asarray(chip.driver.forward_layer(
            x, block_range=t.block_range, out_dim=t.m))
        y_ref = x @ w.T
        e = float(((y - y_ref) ** 2).sum() / x.shape[0])
        d = float(((y - y_ref) ** 2).sum()) / float((y_ref ** 2).sum())
        measured.append(e)
        predicted.append(sens[t.tenant_id] * d)
    rank_ok = (list(np.argsort(measured)) == list(np.argsort(predicted)))
    print(f"sensitivity: prior {['%.2f' % s for s in sens]} | measured "
          f"err-energy {['%.4f' % e for e in measured]} | rank match "
          f"{rank_ok}", flush=True)
    return dict(sensitivity=sens, measured_err_energy=measured,
                predicted_leverage=predicted, rank_ok=bool(rank_ok))


def _gateway_leg() -> dict:
    """Closed-loop continuous-batching run with the autopilot on: the
    occupancy signal must flow gateway → LoadForecast and the run must
    complete every request under proactive maintenance."""
    import jax
    from repro.launch.train import parse_arch
    from repro.models.lm import init_model
    from repro.serving.gateway import run as gw_run
    from repro.serving.scheduler import poisson_workload

    arch = "smoke:qwen3-4b"
    cfg = parse_arch(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    reqs = poisson_workload(SEED + 5, 8, 2.0, cfg.vocab,
                            prompt_len=(4, 8), max_new=(8, 12))
    args = argparse.Namespace(
        arch=arch, seed=SEED, slots=3, requests=len(reqs), rate=1.0,
        max_new=(8, 12), eos_id=None, page_size=8, pages=32,
        max_pages_per_slot=4, prefill_chunk=1,
        fleet=2, drift=True, drift_sigma=0.008, probe_every=10,
        fleet_k=8, fleet_driver="twin", hw_logits=True, hw_shadow=False,
        deploy_zo=False, no_recal=False, params_override=params,
        requests_override=[dataclasses.replace(r, out_tokens=[])
                           for r in reqs],
        autopilot=True, ap_horizon=HORIZON, ap_trough=TROUGH,
        ap_budget=None, ap_window=PERIOD, fleet_policy="accuracy_aware")
    rep = gw_run(args)
    expected = sum(r.max_new for r in reqs)
    ap = rep["fleet"].get("autopilot") or {}
    complete = rep["tokens_out"] == expected
    print(f"gateway leg: {rep['tokens_out']}/{expected} tok | p99 latency "
          f"{rep['latency_steps']['p99']:.0f} steps | "
          f"{ap.get('proactive_recals', 0)} proactive recals | load "
          f"samples {ap.get('load_samples', 0)} | complete={complete}",
          flush=True)
    return dict(tokens_out=rep["tokens_out"], expected_tokens=expected,
                complete=bool(complete),
                p99_latency_steps=rep["latency_steps"]["p99"],
                occupancy=rep["occupancy"], autopilot=ap)


def main(budget: str = "quick") -> None:
    ticks = 240 if budget == "quick" else 480

    base = _run_arm("reactive", ticks)
    ap_cfg = _make_ap_cfg()
    ap = _run_arm("autopilot", ticks, autopilot=ap_cfg,
                  policy="accuracy_aware")
    sens = _sensitivity_validation()
    gw = _gateway_leg()

    # the envelope gates *admission*: a proactive job admitted while
    # window spend < budget can land after the gate closed, so a window
    # may legitimately overshoot by the jobs already committed.  Allow
    # one repair window's worth of in-flight work (the measured max
    # single-landing cost × repair-slot bandwidth) on top.  Reactive
    # spend is exempt and not counted here at all.
    slack = ap["max_job_cost"] * 2       # max_concurrent_recals = 2
    ap_rep = ap["autopilot"] or {}
    proactive_windows = (list(ap_rep.get("proactive_windows", []))
                         + [ap_rep.get("window_spent", 0.0)])
    budget_ok = all(w <= BUDGET_CALLS + slack for w in proactive_windows)

    gates = dict(
        autopilot_accuracy_no_worse=bool(
            ap["mean_err"] <= base["mean_err"] * 1.05 + 1e-9),
        fewer_reactive_alarms=bool(ap["alarms"] < base["alarms"]),
        recal_budget_within_envelope=bool(budget_ok),
        sensitivity_rank_validated=bool(sens["rank_ok"]),
        gateway_autopilot_completes=bool(gw["complete"]))

    emit("fleet_autopilot",
         ["arm", "requests", "p50_latency", "p99_latency", "mean_err",
          "p99_err", "alarms", "recals", "slo_attainment"],
         [[a["label"], a["requests"], f"{a['p50_latency']:.1f}",
           f"{a['p99_latency']:.1f}", f"{a['mean_err']:.5f}",
           f"{a['p99_err']:.5f}", a["alarms"], a["recals"],
           f"{a['slo_attainment']:.4f}"] for a in (base, ap)])

    for a in (base, ap):
        a.pop("series")
    summary = dict(
        budget=budget, seed=SEED, ticks=ticks,
        workload=dict(chips=CHIPS, tenants=TENANTS, dim=DIM, k=K,
                      sigma=SIGMA, period=PERIOD, rate_base=RATE_BASE,
                      rate_amp=RATE_AMP, cap_per_chip=CAP_PER_CHIP,
                      lat_slo=LAT_SLO, err_slo=ERR_SLO),
        autopilot_cfg=dict(horizon=HORIZON, trough_load=TROUGH,
                           budget_calls=BUDGET_CALLS, budget_window=PERIOD),
        reactive=base, autopilot=ap,
        alarms_averted_frac=(
            (base["alarms"] - ap["alarms"]) / max(1, base["alarms"])),
        budget_slack_used=slack, proactive_window_spend=proactive_windows,
        sensitivity=sens, gateway=gw, gates=gates)
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "BENCH_fleet_autopilot.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"--- fleet_autopilot summary ({path}) ---")
    print(json.dumps(dict(gates=gates,
                          alarms=(base["alarms"], ap["alarms"]),
                          slo=(base["slo_attainment"],
                               ap["slo_attainment"])), indent=2))
    for name, ok in gates.items():
        assert ok, f"fleet autopilot gate failed: {name}"


def _make_ap_cfg():
    from repro.runtime.autopilot import AutopilotConfig
    return AutopilotConfig(horizon=HORIZON, trough_load=TROUGH,
                           budget_calls=BUDGET_CALLS, budget_window=PERIOD,
                           forecast_period=PERIOD, forecast_alpha=0.3)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="quick", choices=["quick", "normal"])
    main(ap.parse_args().budget)
