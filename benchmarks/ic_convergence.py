"""Paper Fig. 4(b): ZO optimizer comparison on Identity Calibration.

Compares ZGD / ZCD / ZTP (all with best-solution recording) at k=9 under
the full noise model; emits the best-loss trace and final |U|-MSE.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core.noise import NoiseModel
from repro.core.calibration import calibrate_identity
from repro.optim.zo import ZOConfig

from .common import emit


def main(budget: str = "normal"):
    steps = 1200 if budget == "quick" else 2400
    model = NoiseModel()
    rows = []
    for method in ["zgd", "zcd", "ztp"]:
        cfg = ZOConfig(steps=steps // 2, inner=72, delta0=0.5, decay=1.05,
                       lr0=0.3, record_every=steps // 20)
        res = calibrate_identity(jax.random.PRNGKey(0), n_blocks=4, k=9,
                                 model=model, method=method, cfg=cfg,
                                 restarts=2)
        mse = (float(np.asarray(res.mse_u).mean())
               + float(np.asarray(res.mse_v).mean())) / 2
        trace = np.asarray(res.history).mean(0)
        rows.append([method, round(float(np.asarray(res.loss).mean()), 5),
                     round(mse, 4),
                     " ".join(f"{v:.4f}" for v in trace[:: max(1, len(trace)
                                                               // 8)])])
    emit("fig4_ic_convergence",
         ["method", "final_surrogate_loss", "identity_mse(T4:k9=0.013)",
          "loss_trace"], rows)


if __name__ == "__main__":
    main()
