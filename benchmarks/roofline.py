"""Roofline analysis (EXPERIMENTS.md §Roofline).

For each (arch × shape) on the single-pod mesh, derives the three
roofline terms from the compiled dry-run:

    compute    = HLO_FLOPs_per_device / 197 TFLOP/s (bf16, per chip)
    memory     = HLO_bytes_per_device / 819 GB/s (HBM)
    collective = collective_bytes_per_device / 50 GB/s (ICI link)

``cost_analysis`` counts a ``lax.scan`` body ONCE, so full-depth numbers
are reconstructed by the 2-point period extrapolation:
``f(L) = f(1) + (L−1)·(f(2)−f(1))`` from two reduced-depth compiles
(same widths).  MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D
(prefill) / 2·N_active·B (decode) gives the useful-compute ratio.

Run AFTER the dry-run sweep:  PYTHONPATH=src python -m benchmarks.roofline
"""

from __future__ import annotations

import json
import os
import sys

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # per chip
LINK_BW = 50e9               # per ICI link

ART = os.path.join(os.path.dirname(__file__), "..", "bench_artifacts")
DRY = os.path.join(ART, "dryrun")


def active_param_count(cfg) -> float:
    """Dense-equivalent ACTIVE parameter count (MoE scaled by top_k/E)."""
    import jax
    from repro.models.lm import init_model

    pshapes = jax.eval_shape(lambda k: init_model(k, cfg),
                             jax.random.PRNGKey(0))
    import jax.tree_util as jtu
    total = 0.0
    for path, leaf in jtu.tree_flatten_with_path(pshapes)[0]:
        names = [str(getattr(p, "key", "")) for p in path]
        frac = (cfg.top_k / cfg.n_experts
                if "experts" in names and cfg.n_experts else 1.0)
        last = names[-1]
        if last == "s":
            k = cfg.ptc.k
            total += leaf.size * k * frac       # P·Q·k·k = M·N
        elif last in ("u", "v"):
            continue                            # bases: not extra FLOPs
        else:
            total += leaf.size * frac
    return total


def extrapolated(arch: str, shape: str, periods_total: int,
                 cfg_override=None) -> dict:
    """Two reduced-depth UNROLLED compiles → full-depth terms."""
    from repro.launch.dryrun import run_cell
    r1 = run_cell(arch, shape, False, periods=1, unroll=True,
                  cfg_override=cfg_override)
    r2 = run_cell(arch, shape, False, periods=2, unroll=True,
                  cfg_override=cfg_override)

    def ext(a, b):
        return a + (periods_total - 1) * (b - a)

    coll = {k: ext(r1["collectives"][k], r2["collectives"][k])
            for k in r1["collectives"]}
    return {
        "flops": ext(r1["flops_per_device"], r2["flops_per_device"]),
        "bytes": ext(r1["bytes_per_device"], r2["bytes_per_device"]),
        "coll_bytes": sum(v for k, v in coll.items() if k != "count"),
        "coll": coll,
    }


def analyze_cell(arch: str, shape: str) -> dict | None:
    from repro.configs import get_config, SHAPES, shape_applicable
    from repro.models.lm import period_plan
    cfg = get_config(arch)
    sh = SHAPES[shape]
    ok, why = shape_applicable(cfg, sh)
    if not ok:
        return None
    plan, n_periods = period_plan(cfg)
    ex = extrapolated(arch, shape, n_periods)
    n_active = active_param_count(cfg)
    n_dev = 256
    if sh.kind == "train":
        d_tokens = sh.global_batch * sh.seq_len
        model_flops = 6.0 * n_active * d_tokens
    elif sh.kind == "prefill":
        d_tokens = sh.global_batch * sh.seq_len
        model_flops = 2.0 * n_active * d_tokens
    else:
        model_flops = 2.0 * n_active * sh.global_batch
    t_comp = ex["flops"] / PEAK_FLOPS
    t_mem = ex["bytes"] / HBM_BW
    t_coll = ex["coll_bytes"] / LINK_BW
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])
    # roofline fraction: useful model FLOPs per device over the bound
    # implied by the dominant term
    bound_s = max(t_comp, t_mem, t_coll)
    mfu = (model_flops / n_dev / PEAK_FLOPS) / bound_s if bound_s else 0.0
    rec = {
        "arch": arch, "shape": shape,
        "flops_per_dev": ex["flops"], "bytes_per_dev": ex["bytes"],
        "coll_bytes_per_dev": ex["coll_bytes"],
        "coll_breakdown": ex["coll"],
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dom[0],
        "model_flops_global": model_flops,
        "useful_ratio": model_flops / n_dev / ex["flops"]
        if ex["flops"] else 0.0,
        "roofline_fraction": mfu,
    }
    return rec


def main():
    from repro.configs import ARCH_NAMES, SHAPES
    os.makedirs(ART, exist_ok=True)
    only = sys.argv[1:] if len(sys.argv) > 1 else None
    results = []
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            if only and f"{arch}:{shape}" not in only and arch not in only:
                continue
            tag = f"{arch}__{shape}"
            try:
                rec = analyze_cell(arch, shape)
            except Exception as e:
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                continue
            if rec is None:
                print(f"[skip] {tag}", flush=True)
                continue
            results.append(rec)
            with open(os.path.join(ART, f"roofline_{tag}.json"), "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[ok] {tag}: comp={rec['t_compute_s']:.3f}s "
                  f"mem={rec['t_memory_s']:.3f}s "
                  f"coll={rec['t_collective_s']:.3f}s "
                  f"dom={rec['dominant']} "
                  f"useful={rec['useful_ratio']:.2f} "
                  f"roofline={rec['roofline_fraction']:.2f}", flush=True)
    # rebuild the full table from every per-cell artifact (merge-safe
    # across partial re-runs)
    allrecs = []
    for name in sorted(os.listdir(ART)):
        if name.startswith("roofline_") and name.endswith(".json") \
                and name != "roofline_table.json":
            with open(os.path.join(ART, name)) as f:
                allrecs.append(json.load(f))
    with open(os.path.join(ART, "roofline_table.json"), "w") as f:
        json.dump(allrecs, f, indent=1)


if __name__ == "__main__":
    main()
