"""Benchmark-regression gate: compare fresh bench JSON against baselines.

CI uploads benchmark artifacts on every PR, but until now nothing
*looked* at them — a silent throughput or accuracy regression (or a
disabled bit-identity gate) would merge unnoticed.  This checker makes
the artifact actionable:

* **Throughput / accuracy metrics** — host-speed-invariant numbers
  (stream-vs-twin throughput ratios, task accuracy) from the current
  run are compared against the committed baseline; a drop of more than
  ``--max-regression`` (default 25%) fails the job.  Absolute
  columns/second are deliberately NOT gated — a GitHub runner and the
  dev container differ by far more than any real regression, so only
  same-host ratios carry signal across machines.
* **Boolean gates** — bit-identity and accuracy-recovery flags written
  by the benchmarks themselves (``bit_identity_ok``, the
  ``BENCH_e2e_accuracy.json`` ``gates.*``).  A gate that is false —
  or *missing*, which would mean the check silently stopped running —
  fails the job.

Usage (what the ``bench-smoke`` CI job runs)::

    cp -r bench_artifacts bench_baseline          # committed baselines
    PYTHONPATH=src python -m benchmarks.driver_overhead --budget quick
    python -m benchmarks.check_regression --baseline bench_baseline
    python -m benchmarks.check_regression --baseline bench_baseline --self-test

``--self-test`` proves the gate is live: it synthesizes a degraded copy
of the current artifacts (throughput halved, one gate flipped), runs
the same check against it, and fails unless the check *rejects* it.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile

from .common import ART


def _max_batch(d: dict) -> str:
    return str(max(int(k) for k in d["twin"]["batch_sweep"]))


def _batch_speedup(d: dict, transport: str) -> float:
    """Probe throughput at max batch size over batch-1 throughput, on
    ONE transport.  Both numerator and denominator ride the same host,
    process, and load, so the ratio is far more repeatable than any
    cross-transport comparison (measured: single-op stream/twin ratios
    swing ±45% run-to-run on a busy 2-core host; same-transport
    amortization swings ≲20%) — while a genuine v3 data-plane
    regression (lost batching, lost pipelining, per-op round-trips
    back) collapses it ~10×, far past any tolerance."""
    bs = d[transport]["batch_sweep"]
    n = _max_batch(d)
    return bs[n]["probe_cols_per_s"] / bs["1"]["probe_cols_per_s"]


# Per-artifact spec: host-invariant higher-is-better metrics + boolean
# gate paths.  Files absent from BOTH dirs are skipped; a file present
# in the baseline but missing from the current run is only an error
# when listed via --require (bench-smoke produces a subset of the
# nightly artifact set).
def _amortization_geomean(d: dict) -> float:
    """Geometric mean of the three transports' batch amortization.
    Averaging across transports cancels most residual host jitter
    (measured ~4% run-to-run vs 7-17% per transport), while a real
    data-plane regression on even ONE transport (~10× collapse) still
    drops the geomean >50% — far past the 25% gate."""
    prod = 1.0
    for t in ("twin", "subprocess", "socket"):
        prod *= _batch_speedup(d, t)
    return prod ** (1.0 / 3.0)


SPECS = {
    "BENCH_driver_overhead.json": dict(
        metrics={
            "batch_amortization_geomean": _amortization_geomean,
            # raw batch-64 socket-vs-twin throughput ratio: the boolean
            # acceptance gate below adapts its threshold to the host's
            # core count, so this same-run ratio is ALSO drop-gated to
            # catch data-plane regressions that stay above the adaptive
            # floor (e.g. binary framing silently falling back to
            # base64 would roughly halve it)
            "socket_batch64_vs_twin_batch64":
                lambda d: d["socket_batch64_vs_twin_batch64"],
        },
        # v4 additions: v4≡v3 framing identity, every-concurrent-session
        # identity, and the batch-64 socket-within-2×-twin throughput
        # acceptance gate — all booleans computed by the benchmark run
        # itself, so "missing" means the check silently stopped running
        gates=["bit_identity_ok",
               "v4_v3_bit_identical",
               "concurrent_bit_identical",
               "v4_socket_batch64_within_2x_twin"],
    ),
    "BENCH_e2e_accuracy.json": dict(
        metrics={
            "baseline_accuracy": lambda d: d["baseline"]["accuracy"],
            "baseline_tail_accuracy":
                lambda d: d["baseline"]["tail_accuracy"],
        },
        gates=["gates.sigma0_token_identical",
               "gates.transport_bit_identical",
               "gates.open_loop_monotone",
               "gates.closed_loop_recovers"],
    ),
    "BENCH_serving_gateway.json": dict(
        metrics={
            # gateway vs sequential tokens/s-per-chip on the SAME fleet,
            # host, and workload: the continuous-batching dividend.  Both
            # sides ride one process, so the ratio is host-invariant the
            # same way the driver-overhead amortization is.
            "tokens_per_chip_speedup":
                lambda d: d["tokens_per_chip_speedup"],
            # p99 request latency in VIRTUAL STEPS at the reference
            # offered load — a pure function of the (seeded) schedule,
            # bit-deterministic across hosts.  Inverted: higher is
            # better, so a latency blow-up trips the drop gate.
            "inv_p99_latency_steps":
                lambda d: 1.0 / d["ref_rate"]["p99_latency_steps"],
            # chunked-prefill dividend: C=1 over C=8 TTFT p50 on the
            # prompt-heavy workload, in virtual steps — the ≥4× gate
            # below is the floor, this drop-gates erosion above it
            "chunked_ttft_speedup_c8":
                lambda d: d["prefill"]["ttft_speedup_c8"],
            # inverted absolute TTFT at C=8 (virtual steps, seeded
            # schedule → bit-deterministic): higher is better, so a
            # prefill slowdown that ALSO slowed the C=1 side (keeping
            # the ratio flat) still trips this one
            "inv_chunked_ttft_p50":
                lambda d: 1.0 / max(d["prefill"]["ttft"]["8"]["p50"], 1e-9),
        },
        gates=["gates.speedup_ge_2x",
               "gates.sigma0_token_identical_twin",
               "gates.sigma0_token_identical_socket",
               "gates.drift_closed_loop_completes",
               "gates.chunked_token_identical_digital",
               "gates.chunked_token_identical_twin",
               "gates.chunked_token_identical_socket",
               "gates.chunked_ttft_ge_4x",
               "gates.chunked_frames_reduced"],
    ),
    "BENCH_fleet_autopilot.json": dict(
        metrics={
            # all three ride the seeded virtual-tick schedule, so they
            # are bit-deterministic across hosts: SLO attainment under
            # the autopilot, inverted p99 queue latency (higher is
            # better → a latency blow-up trips the drop gate), and the
            # fraction of reactive alarms the forecast averted
            "slo_attainment_autopilot":
                lambda d: d["autopilot"]["slo_attainment"],
            "inv_p99_latency_autopilot":
                lambda d: 1.0 / max(d["autopilot"]["p99_latency"], 1e-9),
            "alarms_averted_frac": lambda d: d["alarms_averted_frac"],
        },
        gates=["gates.autopilot_accuracy_no_worse",
               "gates.fewer_reactive_alarms",
               "gates.recal_budget_within_envelope",
               "gates.sensitivity_rank_validated",
               "gates.gateway_autopilot_completes"],
    ),
}


def _lookup(d: dict, dotted: str):
    for part in dotted.split("."):
        if not isinstance(d, dict) or part not in d:
            return None
        d = d[part]
    return d


def check(baseline_dir: str, current_dir: str, max_regression: float,
          require: list[str]) -> list[str]:
    """Returns a list of failure messages (empty = pass)."""
    failures: list[str] = []
    checked_any = False
    for fname, spec in SPECS.items():
        base_path = os.path.join(baseline_dir, fname)
        cur_path = os.path.join(current_dir, fname)
        if not os.path.exists(cur_path):
            if fname in require:
                failures.append(f"{fname}: required artifact missing from "
                                f"current run ({cur_path})")
            continue
        with open(cur_path) as f:
            cur = json.load(f)
        checked_any = True

        for gate in spec["gates"]:
            val = _lookup(cur, gate)
            if val is None:
                failures.append(f"{fname}: gate {gate!r} missing — the "
                                f"check that writes it no longer runs")
            elif not val:
                failures.append(f"{fname}: gate {gate!r} is FALSE")

        if not os.path.exists(base_path):
            print(f"{fname}: no baseline — gates checked, metrics skipped")
            continue
        with open(base_path) as f:
            base = json.load(f)
        for name, fn in spec["metrics"].items():
            try:
                b, c = float(fn(base)), float(fn(cur))
            except (KeyError, TypeError) as e:
                failures.append(f"{fname}: metric {name} unreadable: {e!r}")
                continue
            drop = (b - c) / b if b > 0 else 0.0
            status = "FAIL" if drop > max_regression else "ok"
            print(f"{fname}: {name}: baseline {b:.4f} → current {c:.4f} "
                  f"({-drop:+.1%}) [{status}]")
            if drop > max_regression:
                failures.append(
                    f"{fname}: {name} regressed {drop:.1%} "
                    f"(baseline {b:.4f} → {c:.4f}, limit "
                    f"{max_regression:.0%})")
    if not checked_any:
        failures.append(f"no known benchmark artifacts found in "
                        f"{current_dir} — nothing was gated")
    return failures


def _degrade(src_dir: str, dst_dir: str) -> None:
    """Synthesize a regressed artifact set: halve one throughput ratio
    and flip one boolean gate in every known file present."""
    os.makedirs(dst_dir, exist_ok=True)
    for fname in SPECS:
        path = os.path.join(src_dir, fname)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            d = json.load(f)
        if fname == "BENCH_driver_overhead.json":
            # a lost-batching regression: max-batch throughput collapses
            # toward the per-op rate on one transport (geomean −54%)
            n = _max_batch(d)
            d["subprocess"]["batch_sweep"][n]["probe_cols_per_s"] *= 0.1
            d["socket_batch64_vs_twin_batch64"] *= 0.4
            d["bit_identity_ok"] = False
            d["concurrent_bit_identical"] = False
            d["v4_socket_batch64_within_2x_twin"] = False
        if fname == "BENCH_e2e_accuracy.json":
            d["baseline"]["accuracy"] *= 0.5
            d["gates"]["closed_loop_recovers"] = False
        if fname == "BENCH_serving_gateway.json":
            # a lost-coalescing regression: the gateway degenerates to
            # sequential throughput and tail latency blows up
            d["tokens_per_chip_speedup"] *= 0.4
            d["ref_rate"]["p99_latency_steps"] *= 3.0
            d["gates"]["sigma0_token_identical_twin"] = False
            # a chunked-prefill regression: ingestion degenerates back
            # toward one token/step (TTFT inflates, ratio collapses)
            # and the wide-frame path diverges from the legacy tokens
            d["prefill"]["ttft"]["8"]["p50"] *= 5.0
            d["prefill"]["ttft_speedup_c8"] *= 0.2
            d["gates"]["chunked_token_identical_digital"] = False
        if fname == "BENCH_fleet_autopilot.json":
            # a broken-forecast regression: the autopilot degenerates to
            # reactive (no alarms averted, SLO halves) and a scheduler
            # bug lets proactive spend blow the envelope
            d["autopilot"]["slo_attainment"] *= 0.5
            d["alarms_averted_frac"] = 0.0
            d["gates"]["fewer_reactive_alarms"] = False
            d["gates"]["recal_budget_within_envelope"] = False
        with open(os.path.join(dst_dir, fname), "w") as f:
            json.dump(d, f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="directory holding the committed BENCH_*.json "
                         "baselines")
    ap.add_argument("--current", default=ART,
                    help="directory holding the fresh run's artifacts "
                         "(default: bench_artifacts)")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="relative drop that fails the gate (default 25%%)")
    ap.add_argument("--require", nargs="*", default=[],
                    help="artifact files that MUST be present in the "
                         "current run")
    ap.add_argument("--self-test", action="store_true",
                    help="prove the gate is live: degrade a copy of the "
                         "current artifacts and require the check to fail")
    args = ap.parse_args(argv)

    if args.self_test:
        tmp = tempfile.mkdtemp(prefix="bench_degraded_")
        try:
            _degrade(args.current, tmp)
            failures = check(args.baseline, tmp, args.max_regression,
                             args.require)
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
        if failures:
            print(f"self-test OK: degraded artifacts rejected with "
                  f"{len(failures)} failure(s):")
            for msg in failures:
                print(f"  - {msg}")
            return 0
        print("self-test FAILED: degraded artifacts passed the gate")
        return 1

    failures = check(args.baseline, args.current, args.max_regression,
                     args.require)
    if failures:
        print("\nbenchmark regression gate FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("\nbenchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
