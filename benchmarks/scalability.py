"""Paper Fig. 10 / Table 1: scalability of on-chip training protocols.

Prior ZO protocols spend O(#params) PTC queries PER STEP on stochastic
loss probes (FLOPS: q gradient samples × forward; MixedTrn: sparse
mixed ZO); L²ight's SL needs a CONSTANT 3 passes (fwd + 2 reciprocal)
regardless of parameter count, and IC/PM are one-off deterministic
costs.  We count PTC calls per optimization step for growing model sizes
— the 3-order-of-magnitude scalability gap is structural."""

from __future__ import annotations


from repro.core.profiler import LayerSpec, layer_cost
from repro.core.sparsity import SparsityConfig

from .common import emit


def protocol_cost_per_step(n_params: int, d: int, n_cols: int, k: int = 9):
    """PTC calls per optimization step for each protocol on an
    n_params≈d² single layer processing n_cols columns."""
    spec = LayerSpec("l", c_out=d, c_in_eff=d, n_cols=n_cols, k=k)
    p, q = spec.grid
    fwd = p * q * n_cols
    out = {}
    # BFT: brute-force per-device tuning — 2 probes per parameter, each a
    # full forward
    out["BFT"] = 2 * n_params * fwd
    # FLOPS (ZO grad est., q=5 samples): (q+1) forwards per step
    out["FLOPS"] = 6 * fwd
    # MixedTrn: sparse ZO (10% params perturbed) + sparse probes
    out["MixedTrn"] = 2 * max(1, int(0.1 * n_params)) * fwd // 10
    # L²ight SL: fwd + 2 reciprocal passes (weight grad) + feedback
    c = layer_cost(spec, SparsityConfig(alpha_w=0.4, alpha_c=0.4))
    out["L2ight"] = c.e_total
    return out


def main(budget: str = "normal"):
    rows = []
    for d in [16, 64, 256, 1024, 3162]:     # ~10² … ~10⁷ params
        n_params = d * d
        costs = protocol_cost_per_step(n_params, d, n_cols=256)
        rows.append([n_params] + [f"{costs[k]:.3g}" for k in
                                  ["BFT", "FLOPS", "MixedTrn", "L2ight"]]
                    + [f"{costs['MixedTrn'] / costs['L2ight']:.1f}"])
    emit("fig10_scalability",
         ["n_params", "BFT_calls/step", "FLOPS_calls/step",
          "MixedTrn_calls/step", "L2ight_calls/step",
          "MixedTrn/L2ight"], rows)
    # Table 1 qualitative row
    emit("table1_protocols",
         ["protocol", "max_params", "algorithm", "resolution",
          "observability"],
         [["BFT", "~100", "ZO", "medium", "coh-IO"],
          ["PSO", "~100", "ZO", "high", "coh-IO"],
          ["AVM", "~100", "FO", "medium", "coh-IO+per-device"],
          ["FLOPS", "~1000", "ZO", "high", "coh-IO"],
          ["MixedTrn", "~2500", "ZO", "medium", "coh-IO"],
          ["L2ight", "~10M (demonstrated 30B-param LM dry-run)",
           "ZO+FO", "medium", "coh-IO"]])


if __name__ == "__main__":
    main()
