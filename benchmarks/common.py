"""Shared benchmark utilities: CSV emission + artifact paths."""

from __future__ import annotations

import os
import time

ART = os.path.join(os.path.dirname(__file__), "..", "bench_artifacts")


def emit(name: str, header: list[str], rows: list[list]) -> str:
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{name}.csv")
    lines = [",".join(header)]
    for r in rows:
        lines.append(",".join(str(x) for x in r))
    text = "\n".join(lines)
    with open(path, "w") as f:
        f.write(text + "\n")
    print(f"--- {name} ---")
    print(text, flush=True)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
