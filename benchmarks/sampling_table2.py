"""Paper Table 2: PTC energy & time-step accounting for the sampling
strategies on VGG-8 / ResNet-18 (Appendix-G cost model).

The paper's α annotations are DROP sparsities; our SparsityConfig stores
KEEP densities (keep = 1 − α_paper) — rows below quote the paper's α."""

from __future__ import annotations

from repro.core.profiler import model_cost, vgg8_specs, resnet18_specs
from repro.core.sparsity import SparsityConfig

from .common import emit

GIGA = 1e9


def _row(tag, specs, cfg, base=None, max_path=None):
    c = model_cost(specs, cfg, max_path=max_path)
    ratio_e = (base.e_total / c.e_total) if base else 1.0
    ratio_t = (base.t_total / c.t_total) if base else 1.0
    return [tag,
            round(c.e_fwd / GIGA, 2), round(c.e_bwd_w / GIGA, 2),
            round(c.e_bwd_x / GIGA, 2), round(c.e_total / GIGA, 2),
            round(ratio_e, 2),
            round(c.t_fwd / GIGA, 2), round(c.t_bwd_w / GIGA, 2),
            round(c.t_bwd_x / GIGA, 2), round(c.t_total / GIGA, 2),
            round(ratio_t, 2)], c


def main(budget: str = "normal"):
    header = ["config", "E_fwd", "E_gradW", "E_gradX", "E_total",
              "E_ratio", "T_fwd", "T_gradW", "T_gradX", "T_total",
              "T_ratio"]
    for name, specs in [("vgg8", vgg8_specs(batch=128)),
                        ("resnet18", resnet18_specs(batch=128))]:
        rows = []
        r, base = _row("SL-baseline", specs, SparsityConfig())
        rows.append(r)
        # paper: +feedback α_W=0.6 (keep 0.4)
        rows.append(_row("+feedback(a=0.6)", specs,
                         SparsityConfig(alpha_w=0.4), base)[0])
        # +column α_C=0.6 (keep 0.4)
        rows.append(_row("+column(a=0.6)", specs,
                         SparsityConfig(alpha_w=0.4, alpha_c=0.4), base)[0])
        # +data α_D=0.5
        rows.append(_row("+data(a=0.5)", specs,
                         SparsityConfig(alpha_w=0.4, alpha_c=0.4,
                                        alpha_d=0.5), base)[0])
        # RAD (spatial sampling): saves activations, NOT PTC energy/steps
        rows.append(_row("RAD(spatial,a=0.85)", specs, SparsityConfig(),
                         base)[0])
        # SWAT-U: forward+feedback weight sparsity, imbalanced paths
        p_max = max(s.grid[0] for s in specs)
        rows.append(_row("topk-imbalanced(a=0.6)", specs,
                         SparsityConfig(alpha_w=0.4, feedback_mode="topk"),
                         base, max_path=max(1, int(0.8 * p_max)))[0])
        emit(f"table2_{name}", header, rows)


if __name__ == "__main__":
    main()
