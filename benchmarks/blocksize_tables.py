"""Paper Tables 3, 4, 5: block-size (k) sweeps.

* Table 3 — noise-induced relative matrix error vs k (Q/Γ/Ω on a mapped
  256×256 weight, commanded-SVD parametrization, post-IC frame);
* Table 4 — IC solution quality (MSE) vs k;
* Table 5 — subspace-learning accuracy vs k (reduced-budget synthetic
  classification; the paper's trend — larger k ⇒ smaller trainable
  subspace ⇒ accuracy drop — is the claim under test).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noise import NoiseModel
from repro.core.mapping import parallel_map
from repro.core.calibration import calibrate_identity
from repro.core.ptc import PTCParams
from repro.core.subspace import ptc_linear
from repro.optim.zo import ZOConfig
from repro.optim.optimizers import AdamWConfig, init_opt_state, apply_updates
from repro.data import synthetic_vision

from .common import emit

PAPER_T3 = {8: 0.025, 9: 0.032, 12: 0.043, 16: 0.061, 24: 0.094, 32: 0.126}
PAPER_T4 = {8: 0.0135, 9: 0.013, 12: 0.03, 16: 0.039, 24: 0.04, 32: 0.045}
PAPER_T5 = {8: 84.26, 9: 84.45, 12: 83.36, 16: 81.27, 24: 80.68, 32: 78.40}


def table3(ks, size=72, seed=0):
    """Relative matrix error ‖W−W̃‖/‖W‖ vs k, commanded-SVD + noise."""
    rows = []
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((size, size)) * 0.3, jnp.float32)
    model = NoiseModel().post_ic()
    for k in ks:
        pm = parallel_map(jax.random.PRNGKey(seed + k), w, k, model,
                          run_zo=False)
        # sqrt of the normalized squared distance = the paper's rel err
        rel = float(np.sqrt(np.asarray(pm.err_osp).mean()))
        rows.append([k, round(rel, 4), PAPER_T3.get(k, "")])
    return emit("table3_noise_error_vs_k",
                ["k", "rel_err", "paper"], rows)


def table4(ks, budget="normal"):
    rows = []
    model = NoiseModel()
    for k in ks:
        t = k * (k - 1) // 2
        steps = (25 if budget == "quick" else 40) * t
        cfg = ZOConfig(steps=steps, inner=2 * t, delta0=0.5, decay=1.05)
        res = calibrate_identity(jax.random.PRNGKey(k), n_blocks=4, k=k,
                                 model=model, cfg=cfg, restarts=4)
        mse = (float(np.asarray(res.mse_u).mean())
               + float(np.asarray(res.mse_v).mean())) / 2
        rows.append([k, round(mse, 4), PAPER_T4.get(k, "")])
    return emit("table4_ic_mse_vs_k", ["k", "ic_mse", "paper"], rows)


def table5(ks, budget="normal", d=96, n_cls=8, steps=250):
    """Σ-only training accuracy vs k: larger k ⇒ fewer trainable Σ ⇒
    lower accuracy (N²/k trainable values)."""
    if budget == "quick":
        steps = 120
    rows = []
    data = synthetic_vision(3, 0, 1024, (d,), n_cls, noise=1.2)
    x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])
    te = synthetic_vision(3, 1, 512, (d,), n_cls, noise=1.2)
    xt, yt = jnp.asarray(te["x"]), jnp.asarray(te["y"])
    for k in ks:
        key = jax.random.PRNGKey(100 + k)
        from repro.core.ptc import random_factorize
        p1 = random_factorize(jax.random.fold_in(key, 0), d, d, k)
        p2 = random_factorize(jax.random.fold_in(key, 1),
                              max(n_cls, k), d, k)

        def pad_to(xb, params):
            q = params.grid[1] * k
            return jnp.pad(xb, ((0, 0), (0, q - xb.shape[1])))

        def loss(sv, xb, yb):
            a = PTCParams(p1.u, sv["s1"], p1.v)
            b = PTCParams(p2.u, sv["s2"], p2.v)
            h = jax.nn.relu(ptc_linear(pad_to(xb, a), a, mode="fused"))
            logits = ptc_linear(pad_to(h, b), b, mode="fused")[:, :n_cls]
            lse = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(logits, yb[:, None], -1)[:, 0]
            return jnp.mean(lse - gold)

        sv = {"s1": p1.s, "s2": p2.s}
        opt = init_opt_state(sv)
        ocfg = AdamWConfig(lr=5e-3)

        @jax.jit
        def step(sv, opt):
            g = jax.grad(lambda s: loss(s, x, y))(sv)
            sv, opt, _ = apply_updates(sv, g, opt, ocfg)
            return sv, opt

        for _ in range(steps):
            sv, opt = step(sv, opt)
        a = PTCParams(p1.u, sv["s1"], p1.v)
        b = PTCParams(p2.u, sv["s2"], p2.v)
        h = jax.nn.relu(ptc_linear(pad_to(xt, a), a, mode="fused"))
        acc = float((jnp.argmax(
            ptc_linear(pad_to(h, b), b, mode="fused")[:, :n_cls], -1)
            == yt).mean())
        rows.append([k, round(100 * acc, 2), PAPER_T5.get(k, ""),
                     d * d // k])
    return emit("table5_subspace_acc_vs_k",
                ["k", "acc_%", "paper_%(vgg8)", "trainable_sigma"], rows)


def main(budget: str = "normal"):
    ks = [8, 9, 12, 16] if budget == "quick" else [8, 9, 12, 16, 24, 32]
    table3(ks)
    table4(ks, budget)
    table5(ks, budget)


if __name__ == "__main__":
    main()
