"""Benchmark driver: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--budget quick|normal]

Emits every table as CSV under bench_artifacts/ and prints them.  The
multi-pod dry-run sweep (launch/dryrun.py) and roofline extraction
(benchmarks/roofline.py) are separate processes (they force a
512-device XLA host platform) — this driver summarizes their artifacts
if present.
"""

from __future__ import annotations

import argparse
import json
import os
import time

from .common import ART


def summarize_dryrun():
    dry = os.path.join(ART, "dryrun")
    if not os.path.isdir(dry):
        print("(no dry-run artifacts yet — run "
              "PYTHONPATH=src python -m repro.launch.dryrun)")
        return
    rows = {"ok": 0, "skipped": 0, "FAIL": 0}
    for name in sorted(os.listdir(dry)):
        with open(os.path.join(dry, name)) as f:
            rec = json.load(f)
        rows[rec["status"]] = rows.get(rec["status"], 0) + 1
    print(f"--- dryrun summary --- {rows}")


def summarize_roofline():
    path = os.path.join(ART, "roofline_table.json")
    if not os.path.exists(path):
        print("(no roofline table yet — run "
              "PYTHONPATH=src python -m benchmarks.roofline)")
        return
    with open(path) as f:
        recs = json.load(f)
    print("--- roofline (single-pod, per-device) ---")
    print("arch,shape,t_compute_s,t_memory_s,t_collective_s,dominant,"
          "useful_ratio,roofline_fraction")
    for r in recs:
        print(f"{r['arch']},{r['shape']},{r['t_compute_s']:.4f},"
              f"{r['t_memory_s']:.4f},{r['t_collective_s']:.4f},"
              f"{r['dominant']},{r['useful_ratio']:.3f},"
              f"{r['roofline_fraction']:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="quick",
                    choices=["quick", "normal"])
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (ic_convergence, blocksize_tables, mapping_osp,
                   grad_fidelity, sampling_table2, scalability,
                   drift_recovery, driver_overhead, e2e_accuracy,
                   serving_gateway, fleet_autopilot)
    benches = [
        ("fig4_ic_convergence", ic_convergence.main),
        ("tables345_blocksize", blocksize_tables.main),
        ("fig5_mapping_osp", mapping_osp.main),
        ("fig8_grad_fidelity", grad_fidelity.main),
        ("table2_sampling", sampling_table2.main),
        ("fig10_scalability", scalability.main),
        ("runtime_drift_recovery", drift_recovery.main),
        ("runtime_multi_tenant", drift_recovery.multi_tenant),
        ("hw_driver_overhead", driver_overhead.main),
        ("runtime_e2e_accuracy", e2e_accuracy.main),
        ("serving_gateway", serving_gateway.main),
        ("fleet_autopilot", fleet_autopilot.main),
    ]
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        print(f"\n=== {name} (budget={args.budget}) ===", flush=True)
        fn(args.budget)
        print(f"=== {name} done in {time.time() - t0:.0f}s ===", flush=True)
    summarize_dryrun()
    summarize_roofline()


if __name__ == "__main__":
    main()
