"""Driver-transport overhead: in-process twin vs op-stream transports.

The control-plane ABC costs nothing physically (same PTC-call budgets by
construction — the conformance suite asserts bit-equal results), so the
relevant question is *wall-clock*: what does the hardware-in-the-loop
transport add per op, and how far does the v3 batched data plane
(``driver.run_batch`` + write pipelining) close the gap?  This benchmark
times the hot control-plane ops on every transport (``twin``,
``subprocess``, ``socket``) and emits:

* ``driver_overhead.csv`` — per-op median latency (ms) and throughput
  for each transport, plus the multiplier vs twin;
* ``BENCH_driver_overhead.json`` — headline numbers (probe round-trip
  latency, probe/serve throughput, zo_refine job wall time) plus a
  **batch-size sweep**: probe throughput when 1 / 8 / 64 ``forward``
  ops ship per round-trip, with a bit-identity check that the batched
  stream matches the sequential twin exactly.

All timings are the **median of 3 repeats** (each repeat averaging
``iters`` calls), so a single scheduler hiccup cannot skew a headline
number; derived "overhead fraction" metrics are clamped at 0 (timer
noise on a near-zero overhead op used to report a nonsensical −0.7%).

    PYTHONPATH=src python -m benchmarks.driver_overhead [--budget quick]
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import ART, emit

K = 4
DIM = 12
BATCH_SIZES = (1, 8, 64)


def _time_op(fn, iters: int, repeats: int = 5,
             min_seconds: float = 0.25) -> float:
    """Median-of-``repeats`` mean wall seconds per call (one warmup).

    Each repeat runs at least ``iters`` calls AND at least
    ``min_seconds`` of wall time (timeit-style autorange): sub-ms op
    timings accumulated over a few dozen calls swing ~2× run-to-run on
    a shared host, and these numbers feed the CI regression gate —
    ~250 ms of measured work per repeat buys the variance down to the
    few-percent level the 25% gate needs."""
    fn()
    means = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        n = 0
        while True:
            for _ in range(iters):
                fn()
            n += iters
            dt = time.perf_counter() - t0
            if dt >= min_seconds:
                break
        means.append(dt / n)
    return statistics.median(means)


def _make(transport: str):
    from repro.core.noise import DEFAULT_NOISE
    from repro.hw import make_driver
    from repro.hw.drift import DriftConfig

    b = (-(-DIM // K)) ** 2
    return b, make_driver(transport, jax.random.PRNGKey(0), b, K,
                          DEFAULT_NOISE.post_ic(), m=DIM, n=DIM,
                          drift=DriftConfig(sigma_phase=0.01))


def _bench_transport(transport: str, iters: int, zo_steps: int) -> dict:
    from repro.optim.zo import ZOConfig

    b, driver = _make(transport)
    try:
        rng = np.random.default_rng(0)
        x_probe = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
        x_serve = jnp.asarray(rng.standard_normal((16, DIM)), jnp.float32)
        w_blocks = jnp.asarray(rng.standard_normal((b, K, K)) * 0.3,
                               jnp.float32)
        zo_cfg = ZOConfig(steps=zo_steps, inner=12, delta0=0.05, decay=1.05)

        def advance_flushed():
            # advance is pipelined on stream transports (the queue
            # append is ~1 µs); force it onto the device inside the
            # timed region so advance_s reports the real per-op cost of
            # landing a clock tick, comparable across transports
            driver.advance(1.0)
            driver.flush()

        out = dict(
            transport=transport,
            probe_s=_time_op(lambda: driver.forward(x_probe), iters),
            serve_s=_time_op(lambda: driver.forward_layer(x_serve), iters),
            readback_s=_time_op(lambda: driver.readback_bases(), iters),
            advance_s=_time_op(advance_flushed, iters),
            zo_refine_s=_time_op(
                lambda: driver.zo_refine(w_blocks, jax.random.PRNGKey(1),
                                         zo_cfg), max(2, iters // 10)),
        )
        out["probe_cols_per_s"] = x_probe.shape[0] / out["probe_s"]
        out["serve_rows_per_s"] = x_serve.shape[0] / out["serve_s"]

        # -- batch-size sweep: n forwards per round-trip ---------------------
        sweep = {}
        for n_ops in BATCH_SIZES:
            ops = [("forward", dict(x=x_probe))] * n_ops
            # floor of 12 iterations per repeat: at batch 64 the naive
            # iters//n_ops is 0-1, and a single measurement is at the
            # mercy of host-side scheduling noise — these numbers feed
            # the CI regression gate, so buy variance down with repeats
            batch_s = _time_op(lambda: driver.run_batch(ops),
                               max(12, iters // n_ops))
            sweep[str(n_ops)] = dict(
                batch_s=batch_s,
                probe_cols_per_s=n_ops * x_probe.shape[0] / batch_s,
                per_op_ms=batch_s / n_ops * 1e3)
        out["batch_sweep"] = sweep
        return out
    finally:
        driver.close()


def _assert_batched_bit_identical(transports) -> None:
    """Batched ≡ sequential for equal seeds, across every transport: the
    acceptance gate for shipping probe sweeps through ``run_batch``."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
    ref = None
    for transport in transports:
        _, driver = _make(transport)
        try:
            driver.advance(1.0)
            seq = [np.asarray(driver.forward(x)) for _ in range(3)]
        finally:
            driver.close()
        _, driver = _make(transport)
        try:
            driver.advance(1.0)
            bat = [np.asarray(y) for y in driver.run_batch(
                [("forward", dict(x=x))] * 3)]
        finally:
            driver.close()
        for s, g in zip(seq, bat):
            np.testing.assert_array_equal(s, g)
        if ref is None:
            ref = seq
        else:
            for s, g in zip(ref, seq):
                np.testing.assert_array_equal(s, g)


def main(budget: str = "quick") -> None:
    iters, zo_steps = (30, 60) if budget == "quick" else (150, 200)
    transports = ("twin", "subprocess", "socket")

    _assert_batched_bit_identical(transports)
    results = {t: _bench_transport(t, iters, zo_steps) for t in transports}
    tw = results["twin"]

    ops = ["probe_s", "serve_s", "readback_s", "advance_s", "zo_refine_s"]
    rows = []
    for transport in transports[1:]:
        sp = results[transport]
        rows += [[transport, op[:-2], f"{tw[op] * 1e3:.3f}",
                  f"{sp[op] * 1e3:.3f}", f"{sp[op] / tw[op]:.2f}"]
                 for op in ops]
        rows += [[transport, f"probe_batch{n}",
                  f"{tw['batch_sweep'][str(n)]['per_op_ms']:.3f}",
                  f"{sp['batch_sweep'][str(n)]['per_op_ms']:.3f}",
                  f"{sp['batch_sweep'][str(n)]['batch_s'] / tw['batch_sweep'][str(n)]['batch_s']:.2f}"]
                 for n in BATCH_SIZES]
    emit("driver_overhead",
         ["transport", "op", "twin_ms", "stream_ms", "overhead_x"], rows)

    summary = dict(
        budget=budget, k=K, dim=DIM, iters=iters, zo_steps=zo_steps,
        protocol="v3 (batch frame + write pipelining)",
        batch_sizes=list(BATCH_SIZES),
        # the batched≡sequential sweep above raises on any mismatch, so
        # reaching this line certifies the gate; recorded explicitly so
        # benchmarks/check_regression.py can verify it was RUN
        bit_identity_ok=True,
        **{t: results[t] for t in transports})
    for transport in transports[1:]:
        sp = results[transport]
        summary[f"{transport}_probe_rpc_overhead_ms"] = \
            (sp["probe_s"] - tw["probe_s"]) * 1e3
        summary[f"{transport}_probe_throughput_ratio"] = \
            sp["probe_cols_per_s"] / tw["probe_cols_per_s"]
        summary[f"{transport}_serve_throughput_ratio"] = \
            sp["serve_rows_per_s"] / tw["serve_rows_per_s"]
        # clamped: timer noise on an amortized-to-~0 job must not report
        # a negative overhead (the PR-3 artifact showed -0.0075)
        summary[f"{transport}_zo_job_overhead_frac"] = max(
            0.0, sp["zo_refine_s"] / tw["zo_refine_s"] - 1.0)
        summary[f"{transport}_batched_probe_cols_per_s"] = \
            sp["batch_sweep"][str(max(BATCH_SIZES))]["probe_cols_per_s"]
    # headline compatibility fields (subprocess = the HIL baseline)
    summary["probe_rpc_overhead_ms"] = summary[
        "subprocess_probe_rpc_overhead_ms"]
    summary["probe_throughput_ratio"] = summary[
        "subprocess_probe_throughput_ratio"]
    summary["serve_throughput_ratio"] = summary[
        "subprocess_serve_throughput_ratio"]
    summary["zo_job_overhead_frac"] = summary[
        "subprocess_zo_job_overhead_frac"]

    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "BENCH_driver_overhead.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"--- driver_overhead summary ({path}) ---")
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="quick", choices=["quick", "normal"])
    main(ap.parse_args().budget)
