"""Driver-transport overhead: in-process twin vs op-stream transports.

The control-plane ABC costs nothing physically (same PTC-call budgets by
construction — the conformance suite asserts bit-equal results), so the
relevant question is *wall-clock*: what does the hardware-in-the-loop
transport add per op, and how far do the v4 binary data plane
(``driver.run_batch`` + write pipelining + raw-payload frames), the
async client (``run_batch_async`` overlap), and the concurrent socket
server close the gap?  This benchmark times the hot control-plane ops
on every transport (``twin``, ``subprocess``, ``socket``) and emits:

* ``driver_overhead.csv`` — per-op median latency (ms) and throughput
  for each transport, plus the multiplier vs twin;
* ``BENCH_driver_overhead.json`` — headline numbers (probe round-trip
  latency, probe/serve throughput, zo_refine job wall time) plus a
  **batch-size sweep** (probe throughput when 1 / 8 / 64 ``forward``
  ops ship per round-trip), an **async overlap sweep** (``depth``
  in-flight batch frames vs the same work issued synchronously), and a
  **concurrent sweep** (N client threads sharing ONE ``--socket``
  server process).  Every sweep carries a bit-identity check: batched ≡
  sequential twin, v4 binary ≡ pinned v3 JSON lines, async ≡ sync, and
  every concurrent session ≡ the twin.

All timings are the **median of 3 repeats** (each repeat averaging
``iters`` calls), so a single scheduler hiccup cannot skew a headline
number; derived "overhead fraction" metrics are clamped at 0 (timer
noise on a near-zero overhead op used to report a nonsensical −0.7%).

    PYTHONPATH=src python -m benchmarks.driver_overhead [--budget quick]
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import ART, emit

K = 4
DIM = 12
BATCH_SIZES = (1, 8, 64)


def _time_op(fn, iters: int, repeats: int = 5,
             min_seconds: float = 0.25) -> float:
    """Median-of-``repeats`` mean wall seconds per call (one warmup).

    Each repeat runs at least ``iters`` calls AND at least
    ``min_seconds`` of wall time (timeit-style autorange): sub-ms op
    timings accumulated over a few dozen calls swing ~2× run-to-run on
    a shared host, and these numbers feed the CI regression gate —
    ~250 ms of measured work per repeat buys the variance down to the
    few-percent level the 25% gate needs."""
    fn()
    means = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        n = 0
        while True:
            for _ in range(iters):
                fn()
            n += iters
            dt = time.perf_counter() - t0
            if dt >= min_seconds:
                break
        means.append(dt / n)
    return statistics.median(means)


def _make(transport: str, protocol: int | None = None):
    from repro.core.noise import DEFAULT_NOISE
    from repro.hw import make_driver
    from repro.hw.drift import DriftConfig

    b = (-(-DIM // K)) ** 2
    return b, make_driver(transport, jax.random.PRNGKey(0), b, K,
                          DEFAULT_NOISE.post_ic(), m=DIM, n=DIM,
                          drift=DriftConfig(sigma_phase=0.01),
                          protocol=protocol)


def _bench_transport(transport: str, iters: int, zo_steps: int) -> dict:
    from repro.optim.zo import ZOConfig

    b, driver = _make(transport)
    try:
        rng = np.random.default_rng(0)
        x_probe = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
        x_serve = jnp.asarray(rng.standard_normal((16, DIM)), jnp.float32)
        w_blocks = jnp.asarray(rng.standard_normal((b, K, K)) * 0.3,
                               jnp.float32)
        zo_cfg = ZOConfig(steps=zo_steps, inner=12, delta0=0.05, decay=1.05)

        def advance_flushed():
            # advance is pipelined on stream transports (the queue
            # append is ~1 µs); force it onto the device inside the
            # timed region so advance_s reports the real per-op cost of
            # landing a clock tick, comparable across transports
            driver.advance(1.0)
            driver.flush()

        out = dict(
            transport=transport,
            probe_s=_time_op(lambda: driver.forward(x_probe), iters),
            serve_s=_time_op(lambda: driver.forward_layer(x_serve), iters),
            readback_s=_time_op(lambda: driver.readback_bases(), iters),
            advance_s=_time_op(advance_flushed, iters),
            zo_refine_s=_time_op(
                lambda: driver.zo_refine(w_blocks, jax.random.PRNGKey(1),
                                         zo_cfg), max(2, iters // 10)),
        )
        out["probe_cols_per_s"] = x_probe.shape[0] / out["probe_s"]
        out["serve_rows_per_s"] = x_serve.shape[0] / out["serve_s"]

        # -- batch-size sweep: n forwards per round-trip ---------------------
        sweep = {}
        for n_ops in BATCH_SIZES:
            ops = [("forward", dict(x=x_probe))] * n_ops
            # floor of 12 iterations per repeat: at batch 64 the naive
            # iters//n_ops is 0-1, and a single measurement is at the
            # mercy of host-side scheduling noise — these numbers feed
            # the CI regression gate, so buy variance down with repeats
            batch_s = _time_op(lambda: driver.run_batch(ops),
                               max(12, iters // n_ops))
            sweep[str(n_ops)] = dict(
                batch_s=batch_s,
                probe_cols_per_s=n_ops * x_probe.shape[0] / batch_s,
                per_op_ms=batch_s / n_ops * 1e3)
        out["batch_sweep"] = sweep
        return out
    finally:
        driver.close()


def _assert_batched_bit_identical(transports) -> None:
    """Batched ≡ sequential for equal seeds, across every transport: the
    acceptance gate for shipping probe sweeps through ``run_batch``."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
    ref = None
    for transport in transports:
        _, driver = _make(transport)
        try:
            driver.advance(1.0)
            seq = [np.asarray(driver.forward(x)) for _ in range(3)]
        finally:
            driver.close()
        _, driver = _make(transport)
        try:
            driver.advance(1.0)
            bat = [np.asarray(y) for y in driver.run_batch(
                [("forward", dict(x=x))] * 3)]
        finally:
            driver.close()
        for s, g in zip(seq, bat):
            np.testing.assert_array_equal(s, g)
        if ref is None:
            ref = seq
        else:
            for s, g in zip(ref, seq):
                np.testing.assert_array_equal(s, g)


def _assert_v4_v3_bit_identical(stream_transports) -> None:
    """The binary v4 framing is a transfer coat: a pinned-v3 (JSON line)
    session and a default v4 session return identical bytes for the
    same ops.  Raises on any mismatch."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
    for transport in stream_transports:
        outs = {}
        for proto in (3, 4):
            _, driver = _make(transport, protocol=proto)
            try:
                assert driver.protocol == proto
                driver.advance(1.0)
                outs[proto] = [np.asarray(y) for y in driver.run_batch(
                    [("forward", dict(x=x)), ("read_sigma", {})])]
            finally:
                driver.close()
        for a, b in zip(outs[3], outs[4]):
            np.testing.assert_array_equal(a, b)


def _bench_async(transport: str, iters: int, depth: int = 4) -> dict:
    """Async overlap: ``depth`` in-flight batch frames vs the same work
    issued synchronously, on one stream transport.  The win is the
    client-side encode of frame k+1 overlapping the server's work on
    frame k (plus, on real instruments, the instrument settling time).
    Starts with an async ≡ sync bit-identity check."""
    _, driver = _make(transport)
    try:
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
        ops = [("forward", dict(x=x))] * 8

        ref = [np.asarray(y) for y in driver.run_batch(ops)]
        for got, want in zip(driver.run_batch_async(ops).result(), ref):
            np.testing.assert_array_equal(np.asarray(got), want)

        def sync_round():
            for _ in range(depth):
                driver.run_batch(ops)

        def async_round():
            futs = [driver.run_batch_async(ops) for _ in range(depth)]
            for f in futs:
                f.result()

        rounds = max(4, iters // (len(ops) * depth))
        sync_s = _time_op(sync_round, rounds)
        async_s = _time_op(async_round, rounds)
        cols = depth * len(ops) * x.shape[0]
        return dict(depth=depth, batch_ops=len(ops),
                    sync_s=sync_s, async_s=async_s,
                    sync_cols_per_s=cols / sync_s,
                    async_cols_per_s=cols / async_s,
                    overlap_speedup=sync_s / async_s)
    finally:
        driver.close()


def _bench_concurrent(n_clients: int, iters: int) -> dict:
    """N client threads sharing ONE ``--socket`` server process, each
    with its own session (own driver).  Reports aggregate probe
    throughput and whether every session's results were bit-identical
    to the in-process twin's."""
    import subprocess
    import sys
    import threading

    from repro.core.noise import DEFAULT_NOISE
    from repro.hw import make_twin
    from repro.hw.drift import DriftConfig
    from repro.hw.socket_driver import SocketDriver
    from repro.hw.subprocess_driver import server_env

    b = (-(-DIM // K)) ** 2
    noise = DEFAULT_NOISE.post_ic()
    drift = DriftConfig(sigma_phase=0.01)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
    ops = [("forward", dict(x=x))] * 8
    rounds = max(6, iters // len(ops))

    twin = make_twin(jax.random.PRNGKey(0), b, K, noise, m=DIM, n=DIM,
                     drift=drift)
    ref = np.asarray(twin.forward(x))

    proc = subprocess.Popen(
        [sys.executable, "-u", "-m", "repro.hw.server",
         "--socket", "127.0.0.1:0", "--sessions", str(n_clients),
         "--max-conns", str(n_clients)],
        stdin=subprocess.DEVNULL, stdout=subprocess.PIPE,
        stderr=subprocess.DEVNULL, env=server_env())
    try:
        # our own trusted child on loopback; the driver's bounded
        # announce read is exercised by the conformance tests
        port = int(proc.stdout.readline().split()[1])
        barrier = threading.Barrier(n_clients)
        spans = [None] * n_clients
        oks = [False] * n_clients
        errs: list = []

        def worker(i):
            try:
                driver = SocketDriver(jax.random.PRNGKey(0), b, K, noise,
                                      m=DIM, n=DIM, drift=drift,
                                      address=("127.0.0.1", port))
                try:
                    out = driver.run_batch(ops)        # warm + handshake
                    barrier.wait()
                    t0 = time.perf_counter()
                    for _ in range(rounds):
                        out = driver.run_batch(ops)
                    t1 = time.perf_counter()
                finally:
                    driver.close()
                spans[i] = (t0, t1)
                oks[i] = all(
                    np.array_equal(np.asarray(y), ref) for y in out)
            except Exception as e:  # noqa: BLE001 - surfaced below
                errs.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errs:
            raise errs[0]
        wall = max(s[1] for s in spans) - min(s[0] for s in spans)
        total_cols = n_clients * rounds * len(ops) * x.shape[0]
        return dict(n_clients=n_clients, rounds=rounds,
                    batch_ops=len(ops), wall_s=wall,
                    aggregate_cols_per_s=total_cols / wall,
                    per_client_cols_per_s=total_cols / wall / n_clients,
                    bit_identical=all(oks))
    finally:
        proc.terminate()
        proc.wait(timeout=30)


def main(budget: str = "quick") -> None:
    iters, zo_steps = (30, 60) if budget == "quick" else (150, 200)
    transports = ("twin", "subprocess", "socket")

    _assert_batched_bit_identical(transports)
    _assert_v4_v3_bit_identical(transports[1:])
    results = {t: _bench_transport(t, iters, zo_steps) for t in transports}
    async_results = {t: _bench_async(t, iters) for t in transports[1:]}
    concurrent = _bench_concurrent(n_clients=3, iters=iters)
    tw = results["twin"]

    ops = ["probe_s", "serve_s", "readback_s", "advance_s", "zo_refine_s"]
    rows = []
    for transport in transports[1:]:
        sp = results[transport]
        rows += [[transport, op[:-2], f"{tw[op] * 1e3:.3f}",
                  f"{sp[op] * 1e3:.3f}", f"{sp[op] / tw[op]:.2f}"]
                 for op in ops]
        rows += [[transport, f"probe_batch{n}",
                  f"{tw['batch_sweep'][str(n)]['per_op_ms']:.3f}",
                  f"{sp['batch_sweep'][str(n)]['per_op_ms']:.3f}",
                  f"{sp['batch_sweep'][str(n)]['batch_s'] / tw['batch_sweep'][str(n)]['batch_s']:.2f}"]
                 for n in BATCH_SIZES]
    emit("driver_overhead",
         ["transport", "op", "twin_ms", "stream_ms", "overhead_x"], rows)

    summary = dict(
        budget=budget, k=K, dim=DIM, iters=iters, zo_steps=zo_steps,
        protocol="v4 (binary frames, negotiated; batch + async + "
                 "write pipelining; v3 JSON-line fallback)",
        batch_sizes=list(BATCH_SIZES),
        # the bit-identity sweeps above raise on any mismatch, so
        # reaching this line certifies the gates; recorded explicitly so
        # benchmarks/check_regression.py can verify they were RUN
        bit_identity_ok=True,
        v4_v3_bit_identical=True,
        concurrent_bit_identical=concurrent["bit_identical"],
        async_sweep=async_results,
        concurrent=concurrent,
        **{t: results[t] for t in transports})
    for transport in transports[1:]:
        sp = results[transport]
        summary[f"{transport}_probe_rpc_overhead_ms"] = \
            (sp["probe_s"] - tw["probe_s"]) * 1e3
        summary[f"{transport}_probe_throughput_ratio"] = \
            sp["probe_cols_per_s"] / tw["probe_cols_per_s"]
        summary[f"{transport}_serve_throughput_ratio"] = \
            sp["serve_rows_per_s"] / tw["serve_rows_per_s"]
        # clamped: timer noise on an amortized-to-~0 job must not report
        # a negative overhead (the PR-3 artifact showed -0.0075)
        summary[f"{transport}_zo_job_overhead_frac"] = max(
            0.0, sp["zo_refine_s"] / tw["zo_refine_s"] - 1.0)
        summary[f"{transport}_batched_probe_cols_per_s"] = \
            sp["batch_sweep"][str(max(BATCH_SIZES))]["probe_cols_per_s"]
    # headline compatibility fields (subprocess = the HIL baseline)
    summary["probe_rpc_overhead_ms"] = summary[
        "subprocess_probe_rpc_overhead_ms"]
    summary["probe_throughput_ratio"] = summary[
        "subprocess_probe_throughput_ratio"]
    summary["serve_throughput_ratio"] = summary[
        "subprocess_serve_throughput_ratio"]
    summary["zo_job_overhead_frac"] = summary[
        "subprocess_zo_job_overhead_frac"]
    # acceptance gate: the v4 data plane keeps a batch-64 socket probe
    # sweep within 2× of the twin's own batched throughput (≥ 0.5×) —
    # both sides measured in this same run on this same host.  The 0.5×
    # bar assumes the client and server processes can actually run
    # CONCURRENTLY; on a single-core host every frame serializes client
    # prep, two scheduler wakeups, and server dispatch into one lane,
    # which costs ~2× on its own (measured: an echo-only child turns a
    # frame around in ~0.02 ms, a jax-dispatching child in ~0.4 ms of
    # pure wakeup/scheduling on 1 CPU).  So the boolean gate degrades
    # to 0.25× there — and the RAW ratio is always recorded and
    # drop-gated against the committed baseline by check_regression, so
    # a protocol regression (lost coalescing, base64 creep, per-op
    # round-trips) still fails CI on ANY host class.
    n_max = str(max(BATCH_SIZES))
    summary["socket_batch64_vs_twin_batch64"] = (
        results["socket"]["batch_sweep"][n_max]["probe_cols_per_s"]
        / tw["batch_sweep"][n_max]["probe_cols_per_s"])
    threshold = 0.5 if (os.cpu_count() or 1) >= 2 else 0.25
    summary["v4_socket_batch64_threshold"] = threshold
    summary["v4_socket_batch64_within_2x_twin"] = \
        summary["socket_batch64_vs_twin_batch64"] >= threshold

    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "BENCH_driver_overhead.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"--- driver_overhead summary ({path}) ---")
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="quick", choices=["quick", "normal"])
    main(ap.parse_args().budget)
