"""Driver-transport overhead: in-process twin vs JSON-over-pipe subprocess.

The control-plane ABC costs nothing physically (same PTC-call budgets by
construction — the conformance suite asserts bit-equal results), so the
relevant question is *wall-clock*: what does the hardware-in-the-loop
transport add per op?  This benchmark times the hot control-plane ops on
both transports and emits:

* ``driver_overhead.csv`` — per-op mean latency (ms) and throughput for
  twin vs subprocess, plus the multiplier;
* ``BENCH_driver_overhead.json`` — headline numbers (probe round-trip
  latency, probe/serve throughput, zo_refine job wall time).

    PYTHONPATH=src python -m benchmarks.driver_overhead [--budget quick]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import ART, emit

K = 4
DIM = 12


def _time_op(fn, iters: int) -> float:
    """Mean wall seconds per call (after one warmup)."""
    fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def _bench_transport(transport: str, iters: int, zo_steps: int) -> dict:
    from repro.core.noise import DEFAULT_NOISE
    from repro.hw import make_driver
    from repro.hw.drift import DriftConfig
    from repro.optim.zo import ZOConfig

    b = (-(-DIM // K)) ** 2
    driver = make_driver(transport, jax.random.PRNGKey(0), b, K,
                         DEFAULT_NOISE.post_ic(), m=DIM, n=DIM,
                         drift=DriftConfig(sigma_phase=0.01))
    try:
        rng = np.random.default_rng(0)
        x_probe = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
        x_serve = jnp.asarray(rng.standard_normal((16, DIM)), jnp.float32)
        w_blocks = jnp.asarray(rng.standard_normal((b, K, K)) * 0.3,
                               jnp.float32)
        zo_cfg = ZOConfig(steps=zo_steps, inner=12, delta0=0.05, decay=1.05)

        out = dict(
            transport=transport,
            probe_s=_time_op(lambda: driver.forward(x_probe), iters),
            serve_s=_time_op(lambda: driver.forward_layer(x_serve), iters),
            readback_s=_time_op(lambda: driver.readback_bases(), iters),
            advance_s=_time_op(lambda: driver.advance(1.0), iters),
            zo_refine_s=_time_op(
                lambda: driver.zo_refine(w_blocks, jax.random.PRNGKey(1),
                                         zo_cfg), max(2, iters // 10)),
        )
        out["probe_cols_per_s"] = x_probe.shape[0] / out["probe_s"]
        out["serve_rows_per_s"] = x_serve.shape[0] / out["serve_s"]
        return out
    finally:
        driver.close()


def main(budget: str = "quick") -> None:
    iters, zo_steps = (30, 60) if budget == "quick" else (150, 200)

    results = {t: _bench_transport(t, iters, zo_steps)
               for t in ("twin", "subprocess")}
    tw, sp = results["twin"], results["subprocess"]

    ops = ["probe_s", "serve_s", "readback_s", "advance_s", "zo_refine_s"]
    rows = [[op[:-2], f"{tw[op] * 1e3:.3f}", f"{sp[op] * 1e3:.3f}",
             f"{sp[op] / tw[op]:.2f}"] for op in ops]
    emit("driver_overhead",
         ["op", "twin_ms", "subprocess_ms", "overhead_x"], rows)

    summary = dict(
        budget=budget, k=K, dim=DIM, iters=iters, zo_steps=zo_steps,
        twin=tw, subprocess=sp,
        probe_rpc_overhead_ms=(sp["probe_s"] - tw["probe_s"]) * 1e3,
        probe_throughput_ratio=sp["probe_cols_per_s"]
        / tw["probe_cols_per_s"],
        serve_throughput_ratio=sp["serve_rows_per_s"]
        / tw["serve_rows_per_s"],
        zo_job_overhead_frac=sp["zo_refine_s"] / tw["zo_refine_s"] - 1.0,
    )
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "BENCH_driver_overhead.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"--- driver_overhead summary ({path}) ---")
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="quick", choices=["quick", "normal"])
    main(ap.parse_args().budget)
