"""Paper Fig. 8: gradient approximation fidelity of the sampled in-situ
estimators — average angular similarity and normalized distance vs
(a) feedback sparsity / strategy, (b) normalization, (c) column vs
spatial sampling for CONV."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ptc import PTCParams, random_factorize, block_energy
from repro.core.subspace import ptc_linear, SubspaceMasks
from repro.core.sparsity import SparsityConfig, feedback_mask, column_mask

from .common import emit


def _true_grads(params, x, dy):
    _, vjp = jax.vjp(lambda xx, ss: ptc_linear(
        xx, PTCParams(params.u, ss, params.v), mode="blocked"), x, params.s)
    return vjp(dy)


def _angular(a, b):
    return float(jnp.vdot(a, b) /
                 (jnp.linalg.norm(a) * jnp.linalg.norm(b) + 1e-12))


def _ndist(a, b):
    return float(jnp.sum((a - b) ** 2) / (jnp.sum(b ** 2) + 1e-12))


def main(budget: str = "normal"):
    n_mc = 24 if budget == "quick" else 64
    rng = np.random.default_rng(0)
    m = n = 72
    params = random_factorize(jax.random.PRNGKey(0), m, n, 9)
    # skew block energies (real layers are skewed) so btopk has signal
    skew = jnp.exp(1.5 * jax.random.normal(
        jax.random.PRNGKey(9), (params.s.shape[0], params.s.shape[1], 1)))
    params = PTCParams(params.u, params.s * skew, params.v)
    x = jnp.asarray(rng.standard_normal((128, n)), jnp.float32)
    dy = jnp.asarray(rng.standard_normal((128, m)), jnp.float32)
    dx_true, ds_true = _true_grads(params, x, dy)
    be = block_energy(params)

    # (a)/(b): feedback strategy × sparsity × normalization
    rows = []
    for mode in ["uniform", "topk", "btopk"]:
        for alpha in [0.3, 0.6]:
            for norm in ["none", "exp", "var"]:
                cfg = SparsityConfig(alpha_w=alpha, feedback_mode=mode,
                                     feedback_norm=norm)
                cs, nd = 0.0, 0.0
                for kk in jax.random.split(jax.random.PRNGKey(5), n_mc):
                    masks = SubspaceMasks(feedback_mask(kk, be, cfg), None)
                    _, vjp = jax.vjp(lambda xx: ptc_linear(
                        xx, params, masks, mode="blocked"), x)
                    g = vjp(dy)[0]
                    cs += _angular(g, dx_true)
                    nd += _ndist(g, dx_true)
                rows.append([mode, alpha, norm, round(cs / n_mc, 4),
                             round(nd / n_mc, 4)])
    emit("fig8ab_feedback_fidelity",
         ["strategy", "alpha_keep", "norm", "avg_angular_sim",
          "avg_norm_dist"], rows)

    # (c)/(d): column sampling (ours) vs spatial sampling (RAD-style) for
    # the weight gradient of an im2col'd conv: spatial sampling zeroes
    # PIXELS (correlated columns), CS drops whole columns
    rows = []
    for alpha in [0.3, 0.6]:
        for kind in ["column", "spatial"]:
            cfg = SparsityConfig(alpha_c=alpha, column_norm="exp")
            cs, nd = 0.0, 0.0
            for kk in jax.random.split(jax.random.PRNGKey(6), n_mc):
                if kind == "column":
                    col = column_mask(kk, x.shape[0], cfg)
                else:
                    # spatial: drop input FEATURES (pre-im2col pixels) —
                    # the gradient contraction keeps all columns but each
                    # is partially corrupted
                    keep = jax.random.bernoulli(kk, alpha, (x.shape[1],))
                    col = None
                if kind == "column":
                    masks = SubspaceMasks(None, col)
                    _, vjp = jax.vjp(lambda ss: ptc_linear(
                        x, PTCParams(params.u, ss, params.v), masks,
                        mode="blocked"), params.s)
                    gs = vjp(dy)[0]
                else:
                    xs = x * keep[None, :] / alpha
                    _, vjp = jax.vjp(lambda ss: ptc_linear(
                        xs, PTCParams(params.u, ss, params.v),
                        mode="blocked"), params.s)
                    gs = vjp(dy)[0]
                cs += _angular(gs, ds_true)
                nd += _ndist(gs, ds_true)
            rows.append([kind, alpha, round(cs / n_mc, 4),
                         round(nd / n_mc, 4)])
    emit("fig8cd_column_vs_spatial",
         ["sampling", "alpha_keep", "avg_angular_sim", "avg_norm_dist"],
         rows)


if __name__ == "__main__":
    main()
