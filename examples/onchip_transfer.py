"""Paper Fig. 14: in-situ transferability of the restricted subspace.

The paper's setup: pre-train on task A, MAP onto the chip (PM — the
inherited unitaries now encode task-A structure), then adapt to task B
by training Σ ONLY.  Compared against Σ-only training from random
bases (from scratch).  The inherited bases span a good design space:
transfer reaches the target accuracy in fewer steps and ends higher.

    PYTHONPATH=src python examples/onchip_transfer.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noise import NoiseModel
from repro.core.mapping import parallel_map
from repro.core.ptc import PTCParams, random_factorize
from repro.core.subspace import ptc_linear
from repro.data import synthetic_vision, transfer_vision
from repro.optim.optimizers import AdamWConfig, init_opt_state, apply_updates

D, H, C, K = 36, 36, 9, 9
NOISE = 2.2


def sigma_loss(sv, layers, x, y):
    ps = [PTCParams(layers[i].u, sv["s"][i], layers[i].v) for i in range(2)]
    h = jax.nn.relu(ptc_linear(x, ps[0], mode="blocked"))
    logits = ptc_linear(h, ps[1], mode="blocked")[:, :C]
    return jnp.mean(jax.nn.logsumexp(logits, -1)
                    - jnp.take_along_axis(logits, y[:, None], -1)[:, 0])


def accuracy(sv, layers, x, y):
    ps = [PTCParams(layers[i].u, sv["s"][i], layers[i].v) for i in range(2)]
    h = jax.nn.relu(ptc_linear(x, ps[0], mode="blocked"))
    logits = ptc_linear(h, ps[1], mode="blocked")[:, :C]
    return float((jnp.argmax(logits, -1) == y).mean())


def train_sigma(layers, sv, x, y, xe, ye, steps, lr=4e-3, eval_every=20):
    opt = init_opt_state(sv)
    ocfg = AdamWConfig(lr=lr)

    @jax.jit
    def step(sv, opt):
        g = jax.grad(lambda s: sigma_loss(s, layers, x, y))(sv)
        sv, opt, _ = apply_updates(sv, g, opt, ocfg)
        return sv, opt

    curve = []
    for i in range(steps):
        if i % eval_every == 0:
            curve.append((i, accuracy(sv, layers, xe, ye)))
        sv, opt = step(sv, opt)
    curve.append((steps, accuracy(sv, layers, xe, ye)))
    return sv, curve


def main():
    # ---- task A: dense pre-training ------------------------------------
    a = synthetic_vision(1, 0, 1024, (D,), C, noise=NOISE)
    xa, ya = jnp.asarray(a["x"]), jnp.asarray(a["y"])
    rng = np.random.default_rng(0)
    ws = [jnp.asarray(rng.standard_normal((H, D)) * 0.4, jnp.float32),
          jnp.asarray(rng.standard_normal((C, H)) * 0.4, jnp.float32)]
    opt = init_opt_state({"w": ws})
    ocfg = AdamWConfig(lr=5e-3)

    def dloss(w):
        h = jax.nn.relu(xa @ w[0].T)
        logits = h @ w[1].T
        return jnp.mean(jax.nn.logsumexp(logits, -1)
                        - jnp.take_along_axis(logits, ya[:, None], -1)[:, 0])

    @jax.jit
    def dstep(ws, opt):
        g = jax.grad(lambda w: dloss(w["w"]))({"w": ws})
        new, opt, _ = apply_updates({"w": ws}, g, opt, ocfg)
        return new["w"], opt

    for _ in range(250):
        ws, opt = dstep(ws, opt)

    # ---- map task-A weights onto the chip (bases inherit A's structure)
    post = NoiseModel().post_ic()
    pmA = [parallel_map(jax.random.PRNGKey(10 + i), ws[i], K, post,
                        run_zo=False).params for i in range(2)]
    print(f"task A mapped accuracy: "
          f"{accuracy({'s': [p.s for p in pmA]}, pmA, xa, ya):.3f}")

    # ---- task B data ----------------------------------------------------
    b = transfer_vision(1, 0, 1024, (D,), C, noise=NOISE)
    xb, yb = jnp.asarray(b["x"]), jnp.asarray(b["y"])
    bt = transfer_vision(1, 7, 768, (D,), C, noise=NOISE)
    xbe, ybe = jnp.asarray(bt["x"]), jnp.asarray(bt["y"])

    steps = 240
    # transfer A: inherited (mapped) bases + inherited Σ, adapt Σ only
    sv_t = {"s": [p.s for p in pmA]}
    _, curve_t = train_sigma(pmA, sv_t, xb, yb, xbe, ybe, steps)

    # transfer B: inherited bases, Σ RE-INITIALIZED (beyond-paper
    # finding: the transferable structure lives in the unitary BASES;
    # the mapped all-positive SVD Σ is a poor optimization basin for a
    # new task, and re-randomizing it recovers the full benefit)
    rnd = [random_factorize(jax.random.PRNGKey(33), H, D, K),
           random_factorize(jax.random.PRNGKey(34), C, H, K)]
    sv_b = {"s": [r.s for r in rnd]}
    _, curve_b = train_sigma(pmA, sv_b, xb, yb, xbe, ybe, steps)

    # scratch: random bases, random Σ, Σ-only training
    layers_s = [random_factorize(jax.random.PRNGKey(70), H, D, K),
                random_factorize(jax.random.PRNGKey(71), C, H, K)]
    sv_s = {"s": [p.s for p in layers_s]}
    _, curve_s = train_sigma(layers_s, sv_s, xb, yb, xbe, ybe, steps)

    print("\nstep, transferAΣ, transfer_bases, scratch")
    for (i, at), (_, ab), (_, asr) in zip(curve_t, curve_b, curve_s):
        print(f"{i:4d}, {at:.3f}, {ab:.3f}, {asr:.3f}")
    print(f"\nfinal: inherited-bases+Σ {curve_t[-1][1]:.3f} | "
          f"inherited-bases (Σ re-init) {curve_b[-1][1]:.3f} | "
          f"scratch {curve_s[-1][1]:.3f}")
    print("paper Fig. 14 claim (transfer > scratch) holds through the "
          "BASES; see the Σ-re-init row — the Σ basin is the caveat we "
          "document in EXPERIMENTS.md.")


if __name__ == "__main__":
    main()
