"""Quickstart: the paper's full three-stage flow on a noisy photonic MLP.

    PYTHONPATH=src python examples/quickstart.py

Stage 1 — Identity Calibration: ZO search drives the unknown-biased
MZI meshes to sign-flip identities (observable: |UΣV*Σ⁻¹ − I|).
Stage 2 — Parallel Mapping: deploy an offline-trained MLP onto the
calibrated chip (commanded-SVD + OSP).
Stage 3 — Subspace Learning: first-order training of Σ only, with the
in-situ gradients and multi-level sampling.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noise import NoiseModel
from repro.core.calibration import calibrate_identity
from repro.core.mapping import parallel_map
from repro.core.ptc import PTCParams
from repro.core.subspace import ptc_linear, sample_masks
from repro.core.sparsity import SparsityConfig
from repro.data import synthetic_vision
from repro.optim.optimizers import AdamWConfig, init_opt_state, apply_updates

D_IN, D_H, D_OUT, K = 18, 18, 9, 9


def accuracy(layers, x, y):
    h = jax.nn.relu(ptc_linear(x, layers[0], mode="blocked"))
    logits = ptc_linear(h, layers[1], mode="blocked")
    return float((jnp.argmax(logits, -1) == y).mean())


def main():
    model = NoiseModel()    # 8-bit Q, Γ, crosstalk, unknown phase bias
    data = synthetic_vision(0, 0, 1024, (D_IN,), D_OUT, noise=0.8)
    x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])

    # ---- offline "pre-training" (the electronics baseline) -------------
    rng = np.random.default_rng(0)
    w1 = jnp.asarray(rng.standard_normal((D_H, D_IN)) * 0.4, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((D_OUT, D_H)) * 0.4, jnp.float32)
    ws, opt = [w1, w2], init_opt_state({"w": [w1, w2]})
    ocfg = AdamWConfig(lr=5e-3)

    def dense_loss(w, x, y):
        h = jax.nn.relu(x @ w[0].T)
        logits = h @ w[1].T
        return jnp.mean(jax.nn.logsumexp(logits, -1)
                        - jnp.take_along_axis(logits, y[:, None], -1)[:, 0])

    @jax.jit
    def dstep(ws, opt):
        g = jax.grad(lambda w: dense_loss(w["w"], x, y))({"w": ws})
        new, opt, _ = apply_updates({"w": ws}, g, opt, ocfg)
        return new["w"], opt

    for _ in range(200):
        ws, opt = dstep(ws, opt)
    dense_acc = float((jnp.argmax(jax.nn.relu(x @ ws[0].T) @ ws[1].T, -1)
                       == y).mean())
    print(f"[offline] dense pre-trained accuracy: {dense_acc:.3f}")

    # ---- stage 1: identity calibration ---------------------------------
    t0 = time.time()
    ic = calibrate_identity(jax.random.PRNGKey(0), n_blocks=4, k=K,
                            model=model)
    mse = (float(np.asarray(ic.mse_u).mean())
           + float(np.asarray(ic.mse_v).mean())) / 2
    print(f"[IC] identity MSE = {mse:.4f} (paper Table 4: 0.013 @ k=9)  "
          f"[{time.time()-t0:.0f}s]")

    # ---- stage 2: parallel mapping (post-IC frame) ----------------------
    t0 = time.time()
    post = model.post_ic()
    pm1 = parallel_map(jax.random.PRNGKey(1), ws[0], K, post)
    pm2 = parallel_map(jax.random.PRNGKey(2), ws[1], K, post)
    layers = [pm1.params, pm2.params]
    print(f"[PM] mapping error: init={float(np.asarray(pm1.err_init).mean()):.4f} "
          f"→ zo={float(np.asarray(pm1.err_zo).mean()):.4f} "
          f"→ osp={float(np.asarray(pm1.err_osp).mean()):.4f}  "
          f"[{time.time()-t0:.0f}s]")
    print(f"[PM] mapped accuracy: {accuracy(layers, x, y):.3f}")

    # ---- stage 3: subspace learning with multi-level sampling -----------
    scfg = SparsityConfig(alpha_w=0.6, alpha_c=0.6, alpha_d=0.2)
    sv = {"s": [p.s for p in layers]}
    opt = init_opt_state(sv)
    ocfg = AdamWConfig(lr=2e-3)

    def sl_loss(sv, key):
        ps = [PTCParams(layers[i].u, sv["s"][i], layers[i].v)
              for i in range(2)]
        m0 = sample_masks(jax.random.fold_in(key, 0), ps[0], x.shape[0],
                          scfg)
        h = jax.nn.relu(ptc_linear(x, ps[0], m0, mode="blocked"))
        m1 = sample_masks(jax.random.fold_in(key, 1), ps[1], x.shape[0],
                          scfg)
        logits = ptc_linear(h, ps[1], m1, mode="blocked")
        return jnp.mean(jax.nn.logsumexp(logits, -1)
                        - jnp.take_along_axis(logits, y[:, None], -1)[:, 0])

    @jax.jit
    def sl_step(sv, opt, key):
        g = jax.grad(lambda s: sl_loss(s, key))(sv)
        sv, opt, _ = apply_updates(sv, g, opt, ocfg)
        return sv, opt

    t0 = time.time()
    key = jax.random.PRNGKey(3)
    for step in range(150):
        kk = jax.random.fold_in(key, step)
        if float(jax.random.uniform(jax.random.fold_in(kk, 99))) < scfg.alpha_d:
            continue   # SMD: data-level sampling skips the iteration
        sv, opt = sl_step(sv, opt, kk)
    final = [PTCParams(layers[i].u, sv["s"][i], layers[i].v)
             for i in range(2)]
    print(f"[SL] subspace-trained accuracy: {accuracy(final, x, y):.3f} "
          f"(dense {dense_acc:.3f})  [{time.time()-t0:.0f}s]")


if __name__ == "__main__":
    main()
