"""End-to-end LM training driver on the PTC substrate.

    # ~100M-parameter model, a few hundred steps (the e2e deliverable):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

    # tiny sanity run (~1 min on CPU):
    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 60

Uses the public API end to end: ArchConfig → init_model →
build_update_step (sampled in-situ Σ gradients + AdamW on the trainable
partition) → checkpointed training on the synthetic Markov LM task.
Loss should fall from ~ln(vocab) toward the task's ~2-bit entropy floor.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import ArchConfig
from repro.models.layers import PTCLinearCfg
from repro.core.sparsity import SparsityConfig
from repro.checkpoint import CheckpointManager
from repro.data import lm_batch
from repro.optim.optimizers import AdamWConfig
from repro.optim.schedules import linear_warmup_cosine
from repro.launch.steps import build_update_step, init_train_state

PRESETS = {
    # ~100M params: 8L, d=640, ff=2560, vocab 8192 (PTC k=64, fused)
    "100m": dict(n_layers=8, d_model=640, n_heads=10, n_kv_heads=5,
                 head_dim=64, d_ff=2560, vocab=8192, k=64,
                 batch=4, seq=128),
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                 head_dim=32, d_ff=512, vocab=512, k=16,
                 batch=8, seq=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--alpha-w", type=float, default=1.0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ArchConfig(
        name=f"lm-{args.preset}", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], head_dim=p["head_dim"], d_ff=p["d_ff"],
        vocab=p["vocab"], remat=False,
        ptc=PTCLinearCfg(k=p["k"], mode="fused", base_dtype=jnp.float32),
    )
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    # dense-equivalent count (U/V store 2× the dense weight)
    print(f"model: {n_params/1e6:.1f}M stored params "
          f"({cfg.n_layers}L d={cfg.d_model})")

    scfg = SparsityConfig(alpha_w=args.alpha_w) \
        if args.alpha_w < 1.0 else None
    sched = lambda s: linear_warmup_cosine(s, 20, args.steps)
    update = jax.jit(build_update_step(cfg, AdamWConfig(lr=args.lr),
                                       scfg, sched))
    mgr = CheckpointManager(args.ckpt_dir, every=100) if args.ckpt_dir \
        else None

    key = jax.random.PRNGKey(1)
    first10, last10 = [], []
    t0 = time.time()
    for step in range(args.steps):
        b = lm_batch(0, step, p["batch"], p["seq"], cfg.vocab)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, loss, gnorm = update(
            params, opt_state, batch, jax.random.fold_in(key, step))
        loss = float(loss)
        (first10 if step < 10 else last10).append(loss)
        if step % 10 == 0:
            dt = (time.time() - t0) / (step + 1)
            print(f"step {step:4d}: loss={loss:.4f} "
                  f"gnorm={float(gnorm):.2f} ({dt:.2f}s/step)", flush=True)
        if mgr:
            mgr.maybe_save(step, (params, opt_state), {"loss": loss})
    print(f"\nfirst-10 mean loss {np.mean(first10):.4f} → "
          f"last-10 mean {np.mean(last10[-10:]):.4f} "
          f"(uniform={np.log(cfg.vocab):.2f}, task floor≈{np.log(4):.2f})")


if __name__ == "__main__":
    main()
