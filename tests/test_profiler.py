"""Appendix-G cost model: structural ratios the paper's Table 2 shows."""

from repro.core.profiler import (LayerSpec, layer_cost, model_cost,
                                 vgg8_specs, resnet18_specs)
from repro.core.sparsity import SparsityConfig


def test_dense_ratio_structure():
    """Dense training: E_∇Σ = 2·E_fwd (two reciprocal PTC passes), and
    E_∇x ≈ E_fwd (Table 2: 8.58 / 17.16 / 8.34)."""
    spec = LayerSpec("l", c_out=64, c_in_eff=64, n_cols=1024, k=9)
    c = layer_cost(spec, SparsityConfig())
    assert c.e_bwd_w == 2 * c.e_fwd
    assert abs(c.e_bwd_x - c.e_fwd) / c.e_fwd < 0.15


def test_feedback_sampling_scales_bwd_x():
    spec = LayerSpec("l", c_out=90, c_in_eff=90, n_cols=512, k=9)
    dense = layer_cost(spec, SparsityConfig())
    half = layer_cost(spec, SparsityConfig(alpha_w=0.5))
    assert abs(half.e_bwd_x / dense.e_bwd_x - 0.5) < 0.05
    assert half.e_fwd == dense.e_fwd              # forward untouched
    # time steps: accumulation path halves
    assert half.t_bwd_x < dense.t_bwd_x


def test_column_sampling_scales_bwd_w():
    spec = LayerSpec("l", c_out=64, c_in_eff=64, n_cols=1000, k=9)
    dense = layer_cost(spec, SparsityConfig())
    cs = layer_cost(spec, SparsityConfig(alpha_c=0.4))
    assert abs(cs.e_bwd_w / dense.e_bwd_w - 0.4) < 0.05


def test_data_sampling_scales_everything():
    spec = LayerSpec("l", c_out=64, c_in_eff=64, n_cols=1000, k=9)
    dense = layer_cost(spec, SparsityConfig())
    smd = layer_cost(spec, SparsityConfig(alpha_d=0.5))
    assert abs(smd.e_total / dense.e_total - 0.5) < 1e-6
    assert abs(smd.t_total / dense.t_total - 0.5) < 1e-6


def test_first_layer_no_error_feedback():
    spec = LayerSpec("l0", c_out=64, c_in_eff=27, n_cols=1000, k=9,
                     first_layer=True)
    c = layer_cost(spec, SparsityConfig())
    assert c.e_bwd_x == 0.0 and c.t_bwd_x == 0.0


def test_topk_load_imbalance_costs_latency():
    spec = LayerSpec("l", c_out=90, c_in_eff=90, n_cols=512, k=9)
    p, q = spec.grid
    balanced = layer_cost(spec, SparsityConfig(alpha_w=0.5))
    imbalanced = layer_cost(spec, SparsityConfig(alpha_w=0.5), max_path=p)
    assert imbalanced.t_bwd_x > balanced.t_bwd_x


def test_model_stacks():
    vgg = model_cost(vgg8_specs(batch=8), SparsityConfig())
    res = model_cost(resnet18_specs(batch=8), SparsityConfig())
    assert res.e_total > vgg.e_total      # ResNet-18 ≫ VGG-8 (Table 2)
    assert vgg.e_total > 0 and vgg.t_total > 0
