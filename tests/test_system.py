"""End-to-end system behaviour: the paper's full three-stage flow
(IC → PM → SL) on a small PTC model, plus train/resume integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.noise import NoiseModel
from repro.core.mapping import parallel_map
from repro.core.ptc import PTCParams
from repro.core.subspace import ptc_linear
from repro.data import synthetic_vision
from repro.optim.optimizers import AdamWConfig, init_opt_state, apply_updates


def _acc(params_list, xs, ys):
    x = xs
    for i, p in enumerate(params_list):
        x = ptc_linear(x, p, mode="blocked")
        if i < len(params_list) - 1:
            x = jax.nn.relu(x)
    return float((jnp.argmax(x, -1) == ys).mean())


@pytest.mark.slow
def test_three_stage_flow_recovers_accuracy():
    """Map a 'pre-trained' 2-layer MLP onto noisy PTCs (post-IC frame),
    then subspace-train Σ only — accuracy recovers toward the dense
    model's (paper Figs. 5/13 behaviour)."""
    rng = np.random.default_rng(0)
    d_in, d_h, d_out, k = 18, 18, 9, 9

    w1 = jnp.asarray(rng.standard_normal((d_h, d_in)) * 0.4, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((d_out, d_h)) * 0.4, jnp.float32)

    def dense_loss(ws, x, y):
        h = jax.nn.relu(x @ ws[0].T)
        logits = h @ ws[1].T
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, y[:, None], -1)[:, 0]
        return jnp.mean(lse - gold)

    ws = [w1, w2]
    opt = init_opt_state({"w": ws})
    cfg = AdamWConfig(lr=5e-3)
    data = synthetic_vision(0, 0, 512, (d_in,), d_out, noise=0.8)
    x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])

    @jax.jit
    def dense_step(ws, opt):
        g = jax.grad(lambda w: dense_loss(w["w"], x, y))({"w": ws})
        new, opt, _ = apply_updates({"w": ws}, g, opt, cfg)
        return new["w"], opt

    for _ in range(150):
        ws, opt = dense_step(ws, opt)
    dense_acc = float((jnp.argmax(jax.nn.relu(x @ ws[0].T) @ ws[1].T, -1)
                       == y).mean())
    assert dense_acc > 0.8

    # stage 2: parallel mapping under the post-IC noise frame
    model = NoiseModel().post_ic()
    pm1 = parallel_map(jax.random.PRNGKey(1), ws[0], k, model, run_zo=False)
    pm2 = parallel_map(jax.random.PRNGKey(2), ws[1], k, model, run_zo=False)
    mapped = [pm1.params, pm2.params]
    mapped_acc = _acc(mapped, x, y)
    assert mapped_acc > dense_acc - 0.15          # mapping recovers most

    # stage 3: subspace learning — train Σ only on the frozen noisy bases
    sl = mapped
    opt_s = init_opt_state({"s": [p.s for p in sl]})
    ocfg = AdamWConfig(lr=2e-3)

    def sl_loss(svals):
        ps = [PTCParams(sl[i].u, svals["s"][i], sl[i].v) for i in range(2)]
        h = jax.nn.relu(ptc_linear(x, ps[0], mode="blocked"))
        logits = ptc_linear(h, ps[1], mode="blocked")
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, y[:, None], -1)[:, 0]
        return jnp.mean(lse - gold)

    svals = {"s": [p.s for p in sl]}

    @jax.jit
    def sl_step(svals, opt_s):
        g = jax.grad(sl_loss)(svals)
        return apply_updates(svals, g, opt_s, ocfg)[:2]

    for _ in range(100):
        svals, opt_s = sl_step(svals, opt_s)
    final = [PTCParams(sl[i].u, svals["s"][i], sl[i].v) for i in range(2)]
    final_acc = _acc(final, x, y)
    assert final_acc >= mapped_acc - 0.02
    assert final_acc > dense_acc - 0.08           # Σ-only recovers


@pytest.mark.slow
def test_train_driver_loss_decreases_and_resumes(tmp_path):
    """launch/train.py end-to-end: loss falls; a restart resumes from the
    checkpointed step (fault-tolerance contract)."""
    from repro.launch import train as train_mod
    args = ["--arch", "smoke:olmo-1b", "--steps", "30", "--batch", "8",
            "--seq", "32", "--lr", "5e-3",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
            "--log-every", "5"]
    assert train_mod.main(args) == 0
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) is not None
    # resume pass: picks up from the checkpoint, runs the extra steps
    assert train_mod.main(args[:3] + ["35"] + args[4:]) == 0


def test_smd_skips_iterations():
    from repro.launch import train as train_mod
    rc = train_mod.main(["--arch", "smoke:olmo-1b", "--steps", "10",
                         "--batch", "4", "--seq", "16",
                         "--alpha-d", "0.99", "--log-every", "100"])
    assert rc == 0   # nearly all iterations skipped, still exits cleanly
