"""Shared pytest config.  NOTE: no XLA_FLAGS here — smoke tests and
benches must see 1 device; only launch/dryrun.py forces 512."""

import jax

# Pin the x64 mode the full suite has ALWAYS effectively run under:
# tests/test_unitary.py enables jax_enable_x64 at import, which pytest's
# collection used to apply to every test in the process — so a file run
# in isolation (e.g. `pytest tests/test_calibration.py`) saw different
# numerics than the same file inside the full suite and
# test_ic_converges_k9 flipped between pass and fail on collection
# order.  Pinning it here makes every invocation shape identical; the
# CI `test-isolation` leg runs that file alone to prove it stays fixed.
jax.config.update("jax_enable_x64", True)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration tests")
