"""Shared pytest config.  NOTE: no XLA_FLAGS here — smoke tests and
benches must see 1 device; only launch/dryrun.py forces 512."""



def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration tests")
