"""Seeded e2e regression for ``launch/serve.py --fleet``.

Runs the real serving driver (greedy decode through the KV cache, every
decode step routed through a drifting multi-tenant photonic fleet) at
tiny scale, twice from the same seed: the decode output and the fleet
report's tick/recal counters must be deterministic — the whole stack is
seeded (model init, prompt, device realizations, drift chains, probe
streams), so any nondeterminism here is a regression.
"""

import argparse

import numpy as np

from repro.launch import serve as serve_mod


def _args(**over):
    base = dict(arch="smoke:qwen3-4b", batch=2, prompt_len=5, gen=6, seed=3,
                fleet=2, drift=True, drift_sigma=0.05, probe_every=4,
                fleet_k=4, fleet_dim=8, fleet_tenants=2,
                fleet_driver="twin")
    base.update(over)
    return argparse.Namespace(**base)


def test_serve_fleet_deterministic_for_fixed_seed():
    out1 = serve_mod.run(_args())
    out2 = serve_mod.run(_args())

    # decode output is bit-deterministic
    np.testing.assert_array_equal(out1["gen"], out2["gen"])
    assert out1["gen"].shape == (2, 6)

    rep1, rep2 = out1["report"], out2["report"]
    # the fleet clock ticked once per serve-path step:
    # prompt_len + gen - 1 (prefill included; see greedy_decode)
    assert rep1["ticks"] == rep2["ticks"] == 5 + 6 - 1
    for key in ("dropped",):
        assert rep1[key] == rep2[key]
    for c1, c2 in zip(rep1["chips"], rep2["chips"]):
        for key in ("served", "alarms", "recals", "status", "distance"):
            assert c1[key] == c2[key], key
        assert c1["ptc_calls"] == c2["ptc_calls"]
        for t1, t2 in zip(c1["tenants"], c2["tenants"]):
            assert t1 == t2
    # the run exercised the multi-tenant surface: both tenants served
    served = [sum(c["tenants"][j]["served"] for c in rep1["chips"])
              for j in range(2)]
    assert all(s > 0 for s in served)
    assert sum(served) == rep1["ticks"] - rep1["dropped"]


def test_serve_without_fleet_has_no_report():
    out = serve_mod.run(_args(fleet=0))
    assert out["report"] is None
    assert out["gen"].shape == (2, 6)
