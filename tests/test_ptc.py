"""PTC substrate: blocking layout, factorizations, forward paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev extra; shim keeps properties running
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import ptc


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 40), n=st.integers(1, 40), k=st.integers(2, 12))
def test_blockize_roundtrip(m, n, k):
    rng = np.random.default_rng(m * 1000 + n * 10 + k)
    w = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    blocks = ptc.blockize(w, k)
    p, q = -(-m // k), -(-n // k)
    assert blocks.shape == (p, q, k, k)
    back = ptc.unblockize(blocks, m, n)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))


def test_svd_factorize_reconstructs():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((27, 18)), jnp.float32)
    f = ptc.svd_factorize(w, 9)
    w2 = ptc.unblockize(ptc.compose_weight(f), 27, 18)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w), atol=1e-5)


@pytest.mark.parametrize("m,n,k", [(36, 27, 9), (16, 16, 8), (20, 30, 7)])
def test_forward_paths_agree(m, n, k):
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((m, n)) * 0.2, jnp.float32)
    f = ptc.svd_factorize(w, k)
    x = jnp.asarray(rng.standard_normal((5, n)), jnp.float32)
    y_ref = x @ w.T
    yb = ptc.ptc_forward_blocked(f, x, out_dim=m)
    yf = ptc.ptc_forward_fused(f, x, out_dim=m)
    np.testing.assert_allclose(np.asarray(yb), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(y_ref), atol=2e-5)


def test_random_factorize_orthogonal_and_scaled():
    key = jax.random.PRNGKey(0)
    f = ptc.random_factorize(key, 64, 64, 8)
    u = np.asarray(f.u, np.float64)
    eye = np.eye(8)
    err = np.abs(u @ np.swapaxes(u, -1, -2) - eye).max()
    assert err < 1e-5
    # element variance of composed W ≈ glorot 2/(M+N)
    w = np.asarray(ptc.unblockize(ptc.compose_weight(f)))
    var = w.var()
    assert 0.3 * (2 / 128) < var < 3.0 * (2 / 128)


def test_identity_factorize_blocks_are_identity():
    """Post-IC state: every PTC block individually implements I (the
    composed multi-block W is all-identity-blocks, not the identity map)."""
    f = ptc.identity_factorize(16, 16, 8)
    w = np.asarray(ptc.compose_weight(f))
    for pp in range(2):
        for qq in range(2):
            np.testing.assert_allclose(w[pp, qq], np.eye(8), atol=1e-6)
    # single-block case IS the identity map
    f1 = ptc.identity_factorize(16, 16, 16)
    x = jnp.arange(16, dtype=jnp.float32)[None]
    y = ptc.ptc_forward_blocked(f1, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_block_energy_matches_frobenius():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((18, 18)), jnp.float32)
    f = ptc.svd_factorize(w, 9)
    e = np.asarray(ptc.block_energy(f))
    blocks = np.asarray(ptc.blockize(w, 9))
    fro = (blocks ** 2).sum((-2, -1))
    np.testing.assert_allclose(e, fro, rtol=1e-4)


def test_phases_to_factors_roundtrip():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((18, 9)) * 0.3, jnp.float32)
    f = ptc.svd_factorize(w, 9)
    ph = ptc.factors_to_phases(f, kind="clements")
    f2 = ptc.phases_to_factors(ph, model=None)
    w2 = ptc.unblockize(ptc.compose_weight(f2), 18, 9)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w), atol=1e-4)
