"""Sharding rules: role classification, divisibility guard, spec shapes.

Uses AbstractMesh (no devices needed) so these run on the 1-CPU test
runner; the real 512-device lowering is exercised by launch/dryrun.py
and test_train_integration's subprocess test."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config, get_config
from repro.models.lm import init_model
from repro.launch.sharding import _leaf_spec, _path_names
import jax.tree_util as jtu


def _specs(cfg, model_size=16):
    pshapes = jax.eval_shape(lambda k: init_model(k, cfg),
                             jax.random.PRNGKey(0))
    out = {}
    for path, leaf in jtu.tree_flatten_with_path(pshapes)[0]:
        key = "/".join(_path_names(path))
        out[key] = (_leaf_spec(path, leaf, model_size), leaf.shape)
    return out


def test_out_projection_shards_p_axis():
    specs = _specs(get_config("qwen3-4b"))
    spec, shape = specs["pos0/attn/wq/u"]
    # (L, P, Q, k, k): P axis sharded
    assert spec[1] == "model" and spec[2] is None


def test_in_projection_shards_q_axis():
    specs = _specs(get_config("qwen3-4b"))
    spec, shape = specs["pos0/attn/wo/u"]
    assert spec[1] is None and spec[2] == "model"
    spec, _ = specs["pos0/mlp/down/s"]
    assert spec[2] == "model"


def test_gqa_small_kv_replicated():
    """qwen3-4b kv=8 heads × hd=128 = 1024 → P=8 blocks < 16 ⇒ the
    divisibility guard replicates wk/wv."""
    specs = _specs(get_config("qwen3-4b"))
    spec, shape = specs["pos0/attn/wk/u"]
    assert shape[1] == 8                      # P blocks
    assert all(s is None for s in spec)


def test_whisper_attention_replicated():
    """whisper-base attention dims (512 = 8 k-blocks) < TP ⇒ replicated;
    only the 2048-wide MLP (32 k=64-blocks) is eligible for TP."""
    specs = _specs(get_config("whisper-base"))
    for key, (spec, shape) in specs.items():
        if "/attn/" in key or "/cross/" in key or key.startswith("embed"):
            assert all(s != "model" for s in spec), (key, spec)


def test_moe_experts_shard_e_axis():
    specs = _specs(get_config("qwen3-moe-30b-a3b"))
    spec, shape = specs["pos0/moe/experts/gate/u"]
    # (L, E, P, Q, k, k): E axis sharded
    assert shape[1] == 128
    assert spec[1] == "model"
    rspec, _ = specs["pos0/moe/router"]
    assert all(s is None for s in rspec)


def test_embed_vocab_sharded():
    specs = _specs(get_config("olmo-1b"))
    spec, shape = specs["embed/e"]
    assert spec[0] == "model" and shape[0] == 50304


def test_mamba_dinner_sharded():
    specs = _specs(get_config("falcon-mamba-7b"))
    spec, shape = specs["pos0/mamba/conv_w"]
    assert spec[-1] == "model"
    spec, shape = specs["pos0/mamba/a_log"]
    assert spec[1] == "model"
    spec, _ = specs["pos0/mamba/in_proj/u"]   # out-shard
    assert spec[1] == "model"
    spec, _ = specs["pos0/mamba/out_proj/u"]  # in-shard
    assert spec[2] == "model"


def test_norms_replicated():
    specs = _specs(get_config("olmo-1b"))
    spec, _ = specs["final_norm/g"] if "final_norm/g" in specs else (P(), ())
    assert all(s is None for s in spec)


def test_batch_and_cache_shardings_build():
    """batch/cache sharding builders run against a concrete 1-device
    mesh (structure check only)."""
    from repro.launch.sharding import batch_shardings, cache_shardings
    from repro.models.lm import init_decode_cache
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = smoke_config("qwen3-4b")
    batch = {"tokens": jax.ShapeDtypeStruct((4, 8), jnp.int32),
             "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}
    bs = batch_shardings(mesh, batch)
    assert len(jax.tree.leaves(bs)) == 2
    cache = jax.eval_shape(lambda: init_decode_cache(cfg, 4, 8))
    cs = cache_shardings(mesh, cache, 4)
    assert jax.tree.structure(cs) == jax.tree.structure(cache)
