"""Optimizers: AdamW semantics, frozen masking, ZO search, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizers import (AdamWConfig, SGDConfig, init_opt_state,
                                    apply_updates, clip_by_global_norm)
from repro.optim.zo import ZOConfig, zo_minimize
from repro.optim.compression import (init_compression, compress_decompress,
                                     CompressionState)
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = init_opt_state(params)
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = apply_updates(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_frozen_leaves_untouched():
    params = {"s": jnp.ones(4), "u": jnp.ones(4)}
    tr = {"s": True, "u": False}
    state = init_opt_state(params, tr)
    assert state.master["u"].shape == ()          # scalar placeholder
    g = {"s": jnp.ones(4), "u": jnp.ones(4)}
    p2, state, _ = apply_updates(params, g, state, AdamWConfig(),
                                 trainable=tr)
    assert float(jnp.abs(p2["u"] - 1.0).max()) == 0.0
    assert float(jnp.abs(p2["s"] - 1.0).max()) > 0.0


def test_bf16_params_fp32_master():
    params = {"s": jnp.ones(4, jnp.bfloat16)}
    state = init_opt_state(params)
    assert state.master["s"].dtype == jnp.float32
    g = {"s": jnp.full(4, 1e-3, jnp.bfloat16)}
    cfg = SGDConfig(lr=1e-4, momentum=0.0)
    p, state, _ = apply_updates(params, g, state, cfg)
    assert p["s"].dtype == jnp.bfloat16
    # master accumulates below bf16 resolution
    assert float(state.master["s"][0]) != 1.0


def test_clip_global_norm():
    g = {"a": jnp.full(4, 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 20.0)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


@pytest.mark.parametrize("method", ["zcd", "ztp", "zgd"])
def test_zo_minimizes_quadratic(method):
    target = jnp.asarray([0.5, -0.3, 0.8, 0.0])

    def loss(x):
        return jnp.sum((x - target) ** 2)

    cfg = ZOConfig(steps=400, inner=20, delta0=0.3, decay=1.1,
                   delta_min=1e-3, lr0=0.05)
    res = zo_minimize(loss, jnp.zeros(4), jax.random.PRNGKey(0), cfg,
                      method=method)
    assert float(res.f) < float(loss(jnp.zeros(4)))
    assert float(res.f) < 0.12, float(res.f)
    # best-recording: history is monotone non-increasing
    h = np.asarray(res.history)
    assert (np.diff(h) <= 1e-9).all()


def test_zo_vmappable():
    def loss(x):
        return jnp.sum(x ** 2)
    cfg = ZOConfig(steps=100, delta0=0.3)
    x0 = jnp.ones((5, 3))
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    res = jax.vmap(lambda x, k: zo_minimize(loss, x, k, cfg))(x0, keys)
    assert res.x.shape == (5, 3)
    assert (np.asarray(res.f) < 3.0).all()


def test_compression_error_feedback():
    """int8 EF: single-step error bounded by quant step; accumulated
    updates converge to the true sum (EF property)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(256), jnp.float32)
    err = jnp.zeros(256)
    total_dq = jnp.zeros(256)
    for _ in range(50):
        dq, err = compress_decompress(g, err)
        total_dq += dq
    np.testing.assert_allclose(np.asarray(total_dq / 50), np.asarray(g),
                               atol=float(jnp.abs(g).max()) / 127 + 1e-3)


def test_psum_compressed_single_device():
    """shard_map psum path on a 1-device mesh (semantics check)."""
    from repro.optim.compression import psum_compressed
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    st = init_compression(g)

    def f(g, e):
        out, st2 = psum_compressed(g, CompressionState(error=e), "data")
        return out, st2.error

    fm = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    out, err = fm(g, st.error)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=3 / 127 + 1e-4)


def test_schedules():
    assert float(cosine_schedule(0, 100)) == 1.0
    assert float(cosine_schedule(100, 100)) < 1e-6
    assert float(linear_warmup_cosine(0, 10, 100)) == 0.0
    assert 0.9 < float(linear_warmup_cosine(10, 10, 100)) <= 1.0
