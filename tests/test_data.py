"""Synthetic data pipelines: determinism, learnability structure."""

import numpy as np

from repro.data import lm_batch, synthetic_vision, transfer_vision, \
    vowel_stream


def test_lm_batch_deterministic():
    b1 = lm_batch(0, 5, 4, 32, 256)
    b2 = lm_batch(0, 5, 4, 32, 256)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = lm_batch(0, 6, 4, 32, 256)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_lm_batch_markov_structure():
    """Next-token entropy is ~log2(branch) ≪ log2(vocab) — learnable."""
    b = lm_batch(0, 0, 64, 128, 256)
    toks, labels = b["tokens"], b["labels"]
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])
    # successors per context bounded by the branch factor (4)
    succ = {}
    for row_t, row_l in zip(toks.reshape(-1, 128), labels.reshape(-1, 128)):
        for c, n in zip(row_t, row_l):
            succ.setdefault(int(c), set()).add(int(n))
    max_branch = max(len(v) for v in succ.values())
    assert max_branch <= 4


def test_vision_labels_and_shapes():
    b = synthetic_vision(0, 0, 32, (8, 8, 1), 4)
    assert b["x"].shape == (32, 8, 8, 1)
    assert b["y"].shape == (32,) and b["y"].max() < 4
    # deterministic templates: same class → correlated images
    b2 = synthetic_vision(0, 1, 512, (8, 8, 1), 4, noise=0.1)
    m0 = b2["x"][b2["y"] == 0].mean(0).ravel()
    m1 = b2["x"][b2["y"] == 1].mean(0).ravel()
    assert np.linalg.norm(m0 - m1) > 1.0    # classes separable


def test_transfer_task_differs():
    a = synthetic_vision(0, 0, 16, (4, 4, 1), 4, noise=0.0)
    b = transfer_vision(0, 0, 16, (4, 4, 1), 4, noise=0.0)
    assert not np.allclose(a["x"], b["x"])


def test_vowel_stream():
    batches = list(vowel_stream(0, 16, 3))
    assert len(batches) == 3
    assert batches[0]["x"].shape == (16, 8)
