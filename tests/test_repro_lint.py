"""Tests for ``repro.analysis`` — the repo-specific AST lint engine.

Three layers of evidence that the gate is live:

* every rule fires on an injected violation and stays quiet on a clean
  twin (the same fixtures ``--self-test`` runs in CI);
* the suppression machinery round-trips: ``# repro: noqa[...]`` lines,
  the fingerprint baseline (grandfather -> silence -> stale -> drop);
* the wire-protocol rules demonstrably catch a *half-wired op* on a
  copy of the real ``repro/hw`` trio — a fake op added to
  ``BATCHABLE_OPS`` only must produce both a missing-server-branch and
  a missing-client-emitter finding.

The package is pure stdlib, so none of this touches jax.
"""

import json
import shutil
from pathlib import Path

import pytest

from repro.analysis import run_lint, all_rules
from repro.analysis.engine import baseline_payload, load_baseline
from repro.analysis.findings import Finding, fingerprint, noqa_codes
from repro.analysis.lint import main as lint_main
from repro.analysis.selftest import CASES, run_self_test

REPO = Path(__file__).resolve().parents[1]
HW = REPO / "src" / "repro" / "hw"


def _write_tree(root: Path, files: dict) -> Path:
    for rel, text in files.items():
        dest = root / rel
        dest.parent.mkdir(parents=True, exist_ok=True)
        dest.write_text(text)
    return root


def _codes(result):
    return sorted(f.code for f in result.findings)


# ---------------------------------------------------------------------------
# per-rule fixtures: positive (fires) and negative (quiet)
# ---------------------------------------------------------------------------

def test_every_rule_has_a_selftest_fixture():
    assert {r.code for r in all_rules()} == {c.code for c in CASES}


@pytest.mark.parametrize("case", CASES, ids=lambda c: c.code)
def test_rule_fires_on_violation_and_not_on_clean(case, tmp_path):
    bad = _write_tree(tmp_path / "bad" / "fixture", case.bad)
    clean = _write_tree(tmp_path / "clean" / "fixture", case.clean)
    assert case.code in _codes(run_lint([str(bad)])), case.code
    assert case.code not in _codes(run_lint([str(clean)])), case.code


def test_self_test_driver_reports_all_ok():
    lines = []
    assert run_self_test(emit=lines.append)
    assert len([ln for ln in lines if ln.startswith("ok")]) == len(CASES)


# ---------------------------------------------------------------------------
# suppression: noqa lines
# ---------------------------------------------------------------------------

def test_noqa_parsing():
    assert noqa_codes("x = 1") is None
    assert noqa_codes("x = 1  # repro: noqa") == frozenset()
    assert noqa_codes("x = 1  # repro: noqa[RPL101]") == {"RPL101"}
    assert noqa_codes("# repro: noqa[RPL101, RPL203]") == {"RPL101",
                                                           "RPL203"}


VIOLATION = {"repro/core/opt.py":
             "def probe(driver):\n    return driver.unsafe_twin()\n"}


def test_noqa_suppresses_matching_code_only(tmp_path):
    src = VIOLATION["repro/core/opt.py"]
    for comment, silenced in [
        ("  # repro: noqa", True),
        ("  # repro: noqa[RPL102]", True),
        ("  # repro: noqa[RPL999]", False),
    ]:
        root = tmp_path / comment.strip("# :[]").replace(" ", "_")
        _write_tree(root / "fixture", {
            "repro/core/opt.py": src.replace(
                "driver.unsafe_twin()", "driver.unsafe_twin()" + comment)})
        result = run_lint([str(root / "fixture")])
        if silenced:
            assert not result.findings
            assert [f.code for f in result.noqa_suppressed] == ["RPL102"]
        else:
            assert _codes(result) == ["RPL102"]


# ---------------------------------------------------------------------------
# suppression: fingerprint baseline round-trip
# ---------------------------------------------------------------------------

def test_baseline_round_trip(tmp_path):
    root = _write_tree(tmp_path / "fixture", VIOLATION)
    baseline_file = tmp_path / "baseline.json"

    # 1. the violation is an active finding
    first = run_lint([str(root)])
    assert _codes(first) == ["RPL102"]

    # 2. grandfather it -> silenced, counted as baselined
    baseline_file.write_text(json.dumps(baseline_payload(first.findings)))
    fps = load_baseline(str(baseline_file))
    assert len(fps) == 1
    second = run_lint([str(root)], baseline=fps)
    assert not second.findings
    assert [f.code for f in second.baseline_suppressed] == ["RPL102"]
    assert not second.stale_baseline

    # 3. editing the offending line resurfaces the finding (fingerprint
    #    hashes the code, not the line number)
    path = root / "repro/core/opt.py"
    path.write_text(path.read_text().replace(
        "driver.unsafe_twin()", "driver.unsafe_twin( )"))
    resurfaced = run_lint([str(root)], baseline=fps)
    assert _codes(resurfaced) == ["RPL102"]
    assert resurfaced.stale_baseline  # old fingerprint no longer matches

    # 4. fixing the violation leaves only a stale entry...
    path.write_text("def probe(driver):\n    return driver.read_phases()\n")
    fixed = run_lint([str(root)], baseline=fps)
    assert fixed.ok and fixed.stale_baseline == sorted(fps)

    # 5. ...which --update-baseline drops
    rc = lint_main(["--baseline", str(baseline_file), "--update-baseline",
                    str(root)])
    assert rc == 0
    assert load_baseline(str(baseline_file)) == set()


def test_fingerprint_survives_line_drift():
    a = Finding("RPL102", "repro/core/opt.py", 2, 11, "m", "x.unsafe_twin()")
    b = Finding("RPL102", "repro/core/opt.py", 40, 3, "m", "x.unsafe_twin()")
    c = Finding("RPL102", "repro/core/opt.py", 2, 11, "m", "y.unsafe_twin()")
    assert fingerprint(a) == fingerprint(b)
    assert fingerprint(a) != fingerprint(c)


# ---------------------------------------------------------------------------
# the half-wired-op demonstration on the REAL protocol trio
# ---------------------------------------------------------------------------

def _copy_real_trio(tmp_path: Path) -> Path:
    root = tmp_path / "fixture" / "repro" / "hw"
    root.mkdir(parents=True)
    for name in ("driver.py", "server.py", "stream_driver.py"):
        shutil.copy(HW / name, root / name)
    return tmp_path / "fixture"


def test_real_tree_trio_is_fully_wired(tmp_path):
    root = _copy_real_trio(tmp_path)
    result = run_lint([str(root)],
                      codes=["RPL201", "RPL202", "RPL203", "RPL204"])
    assert result.ok, "\n".join(f.format() for f in result.findings)


def test_half_wired_op_is_caught(tmp_path):
    # a fake op lands in BATCHABLE_OPS with no server branch and no
    # client emitter — exactly the "whitelist admitted it, nobody
    # implemented it" state RPL201/RPL202 exist to catch
    root = _copy_real_trio(tmp_path)
    driver = root / "repro" / "hw" / "driver.py"
    text = driver.read_text()
    assert "BATCHABLE_OPS = frozenset([" in text
    driver.write_text(text.replace(
        "BATCHABLE_OPS = frozenset([",
        'BATCHABLE_OPS = frozenset([\n    "phantom_op",'))
    result = run_lint([str(root)],
                      codes=["RPL201", "RPL202", "RPL203", "RPL204"])
    assert "RPL201" in _codes(result) and "RPL202" in _codes(result)
    assert any("phantom_op" in f.message for f in result.findings
               if f.code == "RPL201")
    assert any("phantom_op" in f.message for f in result.findings
               if f.code == "RPL202")


def test_dropped_payload_key_is_caught(tmp_path):
    # the client encodes a key the server branch never reads — silent
    # payload loss on the wire (RPL204, the subtlest half-wiring)
    root = _copy_real_trio(tmp_path)
    client = root / "repro" / "hw" / "stream_driver.py"
    text = client.read_text()
    target = 'self._wire_kw("advance", dict(dt=dt))'
    assert target in text
    client.write_text(text.replace(
        target, 'self._wire_kw("advance", dict(dt=dt, ghost=1))'))
    result = run_lint([str(root)], codes=["RPL204"])
    assert _codes(result) == ["RPL204"]
    assert "ghost" in result.findings[0].message


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------

def test_cli_explain_and_list_rules(capsys):
    assert lint_main(["--list-rules"]) == 0
    listed = capsys.readouterr().out
    for rule in all_rules():
        assert rule.code in listed
    assert lint_main(["--explain", "RPL204"]) == 0
    assert "payload" in capsys.readouterr().out
    assert lint_main(["--explain", "RPL999"]) == 2


def test_cli_self_test_passes(capsys):
    assert lint_main(["--self-test"]) == 0
    out = capsys.readouterr().out
    assert "FAIL" not in out


def test_cli_exit_codes_and_json(tmp_path, capsys):
    root = _write_tree(tmp_path / "fixture", VIOLATION)
    report = tmp_path / "findings.json"
    rc = lint_main([str(root), "--baseline", str(tmp_path / "absent.json"),
                    "--json", str(report)])
    assert rc == 1
    data = json.loads(report.read_text())
    assert data["ok"] is False
    assert [f["code"] for f in data["findings"]] == ["RPL102"]
    assert all("fingerprint" in f for f in data["findings"])
    capsys.readouterr()

    clean = _write_tree(tmp_path / "clean",
                        {"repro/core/opt.py": "def f():\n    return 1\n"})
    rc = lint_main([str(clean), "--baseline", str(tmp_path / "absent.json")])
    assert rc == 0


def test_cli_select_unknown_code_is_usage_error(tmp_path, capsys):
    root = _write_tree(tmp_path / "fixture", VIOLATION)
    assert lint_main([str(root), "--select", "RPL999"]) == 2
    assert lint_main([str(root), "--select", "RPL101",
                      "--baseline", str(tmp_path / "absent.json")]) == 0


def test_parse_errors_are_reported_not_swallowed(tmp_path):
    root = _write_tree(tmp_path / "fixture",
                       {"repro/broken.py": "def f(:\n"})
    result = run_lint([str(root)])
    assert not result.ok
    assert result.errors and "SyntaxError" in result.errors[0][1]


# ---------------------------------------------------------------------------
# the real tree stays clean (the CI gate, as a test)
# ---------------------------------------------------------------------------

def test_real_tree_is_lint_clean():
    baseline = load_baseline(str(REPO / "repro-lint-baseline.json"))
    result = run_lint([str(REPO / "src"), str(REPO / "benchmarks")],
                      baseline=baseline)
    assert result.ok, "\n".join(f.format() for f in result.findings)
