"""Hardware-in-the-loop serving: conformance + regression gates.

The ``--hw-logits`` serve path executes the served model's PTC layers
on routed photonic chips (one fleet tenant per layer) instead of the
digital twin.  These tests lock the contracts the benchmark and the
paper story rest on:

* at σ_drift = 0 the hardware-routed decode is **token-identical** to
  the shadow twin path (same deployment, digital execution of the
  deployment-time readback transfer) — the realized transfer and its
  digital twin are the same operator when the device never moves;
* the routed path's **logits are bit-identical across all three driver
  transports** (in-process twin, subprocess pipe, TCP socket) — the
  stream transports reproduce the twin exactly for equal seeds;
* the whole stack is seeded: a rerun reproduces tokens and fleet
  accounting bit-for-bit;
* every decode-path PTC layer is placed as a tenant, sibling
  projections batch into one driver frame, and the serve accounting
  adds up.
"""

import argparse
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.launch import serve as serve_mod
from repro.models.layers import PTCLinearCfg
from repro.models.lm import ArchConfig

# one period (attn + mlp), 7 PTC layers — small enough that the three
# transport runs stay CI-cheap, big enough to exercise grouping and
# heterogeneous tenant geometries (32x32, 16x32, 48x32, 32x48)
ARCH = ArchConfig(name="hwtest", family="dense", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=1, d_ff=48, vocab=64, head_dim=16,
                  remat=False,
                  ptc=PTCLinearCfg(k=8, base_dtype=jnp.float32))

EXPECTED_LAYERS = [
    "p0.s0.attn.wq", "p0.s0.attn.wk", "p0.s0.attn.wv", "p0.s0.attn.wo",
    "p0.s0.mlp.gate", "p0.s0.mlp.up", "p0.s0.mlp.down",
]


def _args(**over):
    base = dict(arch=ARCH, batch=2, prompt_len=3, gen=3, seed=5,
                fleet=1, drift=False, drift_sigma=0.0, probe_every=4,
                fleet_k=8, fleet_dim=8, fleet_tenants=1,
                fleet_driver="twin", hw_logits=False, hw_shadow=False,
                deploy_zo=False, no_recal=False, trace_logits=True)
    base.update(over)
    return argparse.Namespace(**base)


def test_hw_logits_token_identical_to_shadow_at_sigma0():
    """σ=0: routed hardware execution ≡ shadow twin execution, token for
    token — the conformance gate the drift benchmark is anchored to."""
    route = serve_mod.run(_args(hw_logits=True))
    shadow = serve_mod.run(_args(hw_shadow=True))

    np.testing.assert_array_equal(route["gen"], shadow["gen"])
    np.testing.assert_array_equal(route["preds"], shadow["preds"])
    # ... and the two modes really took different execution paths
    hw_r, hw_s = route["report"]["hw"], shadow["report"]["hw"]
    assert hw_r["mode"] == "route" and hw_s["mode"] == "shadow"
    assert hw_r["shadow_calls"] == 0 and hw_r["hw_calls"] > 0
    assert hw_s["hw_calls"] == 0 and hw_s["shadow_calls"] > 0
    # route ≈ shadow numerically but NOT bit-identically (different
    # contraction order): the token identity above is the meaningful gate
    assert np.abs(route["logits"] - shadow["logits"]).max() < 1e-4


def test_hw_logits_bit_identical_across_transports():
    """The routed path's logits are bit-identical on twin, subprocess,
    and socket transports — the v3 data plane reproduces the in-process
    twin exactly, layer math included."""
    outs = {}
    for driver in ("twin", "subprocess", "socket"):
        outs[driver] = serve_mod.run(_args(hw_logits=True,
                                           fleet_driver=driver))
    ref = outs["twin"]
    for driver in ("subprocess", "socket"):
        np.testing.assert_array_equal(ref["logits"], outs[driver]["logits"])
        np.testing.assert_array_equal(ref["gen"], outs[driver]["gen"])
        # metering is transport-invariant too
        ref_chips = ref["report"]["chips"]
        cur_chips = outs[driver]["report"]["chips"]
        for c1, c2 in zip(ref_chips, cur_chips):
            assert c1["ptc_calls"] == c2["ptc_calls"]


def test_hw_logits_deterministic_and_accounted():
    """Same seed → bit-identical rerun; tenant placement covers every
    decode-path PTC layer; sibling grouping keeps the per-step frame
    count at the group count, not the layer count."""
    out1 = serve_mod.run(_args(hw_logits=True))
    out2 = serve_mod.run(_args(hw_logits=True))
    np.testing.assert_array_equal(out1["gen"], out2["gen"])
    np.testing.assert_array_equal(out1["logits"], out2["logits"])

    rep = out1["report"]
    hw = rep["hw"]
    assert [l["name"] for l in hw["layers"]] == EXPECTED_LAYERS
    n_steps = 3 + 3 - 1
    assert rep["ticks"] == n_steps == hw["steps"]
    # qkv + wo + gate/up + down = 4 frames per step for this arch
    assert hw["frames"] == 4 * n_steps
    assert hw["hw_calls"] == len(EXPECTED_LAYERS) * n_steps
    assert hw["shadow_calls"] == 0 and hw["dropped_passes"] == 0
    # chip serve counters aggregate the tenant counters
    chip = rep["chips"][0]
    assert chip["served"] == sum(t["served"] for t in chip["tenants"])
    assert all(t["served"] == n_steps for t in chip["tenants"])


def test_hw_logits_under_drift_closed_loop_runs():
    """Drifted serving still closes: alarms fire, batch partial recals
    land while traffic fails over, and every layer call is accounted
    either to hardware or to the shadow fallback."""
    from repro.runtime.fleet import RuntimeConfig
    from repro.runtime.monitor import MonitorConfig
    from repro.runtime.recalibrate import RecalConfig
    from repro.hw.drift import DriftConfig
    from repro.core.noise import DEFAULT_NOISE

    mon = MonitorConfig(n_probes=6, alarm_threshold=0.02,
                        clear_threshold=0.01, consecutive=1)
    rcfg = RuntimeConfig(
        k=8, noise=DEFAULT_NOISE.post_ic(),
        drift=DriftConfig(sigma_phase=0.05, theta=0.01), monitor=mon,
        recal=RecalConfig(zo_steps=100, delta0=0.05),
        probe_every=2, recal_latency=1, max_concurrent_recals=1,
        driver_kind="twin", repair_batch=8)
    out = serve_mod.run(_args(hw_logits=True, fleet=2, drift=True,
                              drift_sigma=0.05, gen=8,
                              runtime_cfg=rcfg))
    rep = out["report"]
    hw = rep["hw"]
    n_steps = 3 + 8 - 1
    assert sum(c["alarms"] for c in rep["chips"]) > 0
    assert sum(c["recals"] for c in rep["chips"]) > 0
    assert hw["hw_calls"] + hw["shadow_calls"] \
        == len(EXPECTED_LAYERS) * n_steps
    # batch repair re-tunes several alarmed tenants in one outage
    done = [ev for ev in rep["events"] if ev["event"] == "recal_done"]
    ticks = [ev["tick"] for ev in done]
    assert len(done) > len(set(ticks))


def test_hw_flags_require_fleet_and_exclusive():
    import pytest
    with pytest.raises(ValueError):
        serve_mod.run(_args(hw_logits=True, fleet=0))
    with pytest.raises(ValueError):
        serve_mod.run(_args(hw_logits=True, hw_shadow=True))


def test_legacy_fleet_path_unchanged_surface():
    """The pre-existing synthetic-traffic fleet path still serves and
    reports without the hw section."""
    out = serve_mod.run(_args(arch=dataclasses.replace(ARCH),
                              fleet=1, hw_logits=False))
    assert out["report"] is not None
    assert "hw" not in out["report"]
    assert out["gen"].shape == (2, 3)
