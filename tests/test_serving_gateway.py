"""Serving gateway: scheduler/paging properties + decode conformance.

Three layers of guarantees:

* **Allocator/scheduler properties** (pure python, hypothesis-style):
  pool capacity is never exceeded, pages are never double-allocated or
  leaked, admission is strict FIFO so no request starves, and a fixed
  seed reproduces the schedule trace bit-for-bit.
* **Paged-KV kernels**: the Pallas gather/scatter path assembles and
  updates page pools exactly like the jnp reference.
* **Decode conformance**: continuous-batched gateway decode of N
  concurrent requests is token-identical to N sequential ``serve``
  runs — digitally, and through the hardware-in-the-loop plane on the
  twin AND socket transports (σ_drift = 0); per-sequence EOS early
  termination matches between the two paths.
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from tests._hypothesis_shim import given, settings, strategies as st

from repro.launch import serve as serve_mod
from repro.launch.steps import greedy_decode
from repro.models.layers import PTCLinearCfg
from repro.models.lm import (ArchConfig, build_serve_step, init_decode_cache,
                             init_model)
from repro.serving import (GatewayConfig, PageConfig, PagedKVPool, Request,
                           Scheduler, ServingGateway, poisson_workload)

# the hwtest arch from tests/test_hw_serve.py: 1 period, 7 PTC layers —
# small enough that the socket-transport leg stays CI-cheap
ARCH = ArchConfig(name="hwtest", family="dense", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=1, d_ff=48, vocab=64, head_dim=16,
                  remat=False,
                  ptc=PTCLinearCfg(k=8, base_dtype=jnp.float32))


# ---------------------------------------------------------------------------
# allocator / scheduler properties
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n_pages=st.integers(4, 24),
       page_size=st.integers(1, 8), slots=st.integers(1, 6))
def test_pool_invariants_under_random_schedules(seed, n_pages, page_size,
                                                slots):
    """Random reserve/advance/free interleavings: capacity respected,
    no page double-allocated, none leaked, full reservations returned."""
    rng = np.random.default_rng(seed)
    cfg = PageConfig(page_size=page_size, n_pages=n_pages,
                     max_pages_per_slot=max(1, n_pages // 2))
    pool = PagedKVPool(cfg, slots)
    live: dict[int, int] = {}          # slot -> remaining budget
    for _ in range(200):
        op = rng.integers(0, 3)
        if op == 0:                    # reserve a free slot
            free_slots = [s for s in range(slots) if s not in live]
            if free_slots:
                slot = int(rng.choice(free_slots))
                want = int(rng.integers(1, cfg.max_tokens_per_slot + 1))
                if pool.can_reserve(want):
                    pool.reserve(slot, want)
                    live[slot] = want
        elif op == 1 and live:         # write one token somewhere
            slot = int(rng.choice(list(live)))
            if int(pool.lens[slot]) < live[slot]:
                pid, off = pool.write_pos(slot)
                assert 0 <= pid < n_pages and 0 <= off < page_size
                pool.advance(slot)
        elif op == 2 and live:         # evict
            slot = int(rng.choice(list(live)))
            pool.free(slot)
            del live[slot]
        assert pool.used_pages + pool.free_pages == n_pages
        assert pool.used_pages <= n_pages
        pool.check_invariants()
    for slot in list(live):
        pool.free(slot)
    pool.check_invariants()
    assert pool.free_pages == n_pages


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), slots=st.integers(1, 4),
       n_req=st.integers(1, 12), rate=st.floats(0.1, 4.0))
def test_scheduler_fifo_and_no_starvation(seed, slots, n_req, rate):
    """Every submitted request is eventually admitted and finished
    (strict FIFO admission order), no matter the arrival pattern."""
    cfg = PageConfig(page_size=4, n_pages=8 * slots, max_pages_per_slot=8)
    sched = Scheduler(PagedKVPool(cfg, slots))
    reqs = poisson_workload(seed, n_req, rate, vocab=64,
                            prompt_len=(1, 8), max_new=(1, 8))
    nxt, step, progress = 0, 0, {}
    while step < 10_000:
        while nxt < len(reqs) and reqs[nxt].arrival <= step:
            sched.submit(reqs[nxt], step)
            nxt += 1
        for slot, req in sched.admit(step):
            progress[req.rid] = 0
        for slot, req in list(enumerate(sched.running)):
            if req is None:
                continue
            sched.pool.write_pos(slot)
            sched.pool.advance(slot)
            progress[req.rid] += 1
            if progress[req.rid] >= req.total_tokens:
                sched.finish(slot, step, "max_new")
        if sched.idle and nxt >= len(reqs):
            break
        step += 1
    assert len(sched.finished) == n_req, "a request starved"
    admits = [rid for _, ev, rid, _ in sched.trace if ev == "admit"]
    submits = [rid for _, ev, rid, _ in sched.trace if ev == "submit"]
    assert admits == submits, "admission broke FIFO order"
    sched.pool.check_invariants()
    assert sched.pool.free_pages == cfg.n_pages


def test_oversized_request_rejected():
    cfg = PageConfig(page_size=4, n_pages=16, max_pages_per_slot=2)
    sched = Scheduler(PagedKVPool(cfg, 2))
    big = Request(rid=0, prompt=np.zeros(6, np.int32), max_new=6)
    sched.submit(big, 0)
    try:
        sched.admit(0)
        assert False, "expected ValueError for oversized request"
    except ValueError:
        pass


def test_schedule_trace_deterministic():
    """Same seed → bit-identical schedule trace (and page assignment,
    via the LIFO free list the engine's determinism rests on)."""
    def run_once():
        cfg = PageConfig(page_size=4, n_pages=18, max_pages_per_slot=6)
        sched = Scheduler(PagedKVPool(cfg, 3))
        reqs = poisson_workload(11, 8, 1.5, vocab=64)
        nxt, step = 0, 0
        pos = {}
        while not (sched.idle and nxt >= len(reqs)):
            while nxt < len(reqs) and reqs[nxt].arrival <= step:
                sched.submit(reqs[nxt], step)
                nxt += 1
            for slot, req in sched.admit(step):
                pos[req.rid] = 0
            tables = sched.pool.table.copy()
            for slot, req in list(enumerate(sched.running)):
                if req is None:
                    continue
                sched.pool.advance(slot)
                pos[req.rid] += 1
                if pos[req.rid] >= req.total_tokens:
                    sched.finish(slot, step, "max_new")
            step += 1
        return list(sched.trace), tables
    t1, tab1 = run_once()
    t2, tab2 = run_once()
    assert t1 == t2
    np.testing.assert_array_equal(tab1, tab2)


# ---------------------------------------------------------------------------
# paged-KV kernels
# ---------------------------------------------------------------------------


def test_paged_gather_matches_reference():
    from repro.kernels.ops import paged_gather

    rng = np.random.default_rng(0)
    n_pages, ps, d, b, j = 10, 4, 6, 3, 2
    pages = jnp.asarray(rng.normal(size=(n_pages, ps, d)), jnp.float32)
    table = jnp.asarray(rng.integers(0, n_pages, size=(b, j)), jnp.int32)
    got = paged_gather(table, pages)
    want = np.asarray(pages)[np.asarray(table)].reshape(b, j * ps, d)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_paged_scatter_matches_reference():
    from repro.kernels.ops import paged_scatter

    rng = np.random.default_rng(1)
    n_pages, ps, d, b = 8, 4, 5, 3
    pages = rng.normal(size=(n_pages, ps, d)).astype(np.float32)
    new = rng.normal(size=(b, d)).astype(np.float32)
    # distinct (page, offset) targets, as the allocator guarantees
    idx = np.asarray([[2, 1], [5, 0], [2, 3]], np.int32)
    got = paged_scatter(jnp.asarray(idx), jnp.asarray(new),
                        jnp.asarray(pages))
    want = pages.copy()
    for r in range(b):
        want[idx[r, 0], idx[r, 1]] = new[r]
    np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# decode conformance: gateway ≡ sequential serve
# ---------------------------------------------------------------------------


def _requests(n=4, seed=3, max_new=3, eos_id=None):
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(n):
        ln = int(rng.integers(2, 6))
        prompt = rng.integers(0, ARCH.vocab, size=(ln,)).astype(np.int32)
        out.append(Request(rid=rid, prompt=prompt, max_new=max_new,
                           arrival=rid, eos_id=eos_id))
    return out


def _gw_args(reqs, **over):
    base = dict(arch=ARCH, seed=5, slots=3, requests=len(reqs), rate=1.0,
                page_size=4, pages=24, max_pages_per_slot=4,
                max_new=(2, 4), eos_id=None, fleet=0, drift=False,
                drift_sigma=0.0, probe_every=4, fleet_k=8,
                fleet_driver="twin", hw_logits=False, hw_shadow=False,
                deploy_zo=False, no_recal=False,
                requests_override=[dataclasses.replace(r, out_tokens=[])
                                   for r in reqs])
    base.update(over)
    return argparse.Namespace(**base)


def _sequential_digital(reqs, eos_id=None):
    cfg = dataclasses.replace(ARCH, unroll=False)
    params = init_model(jax.random.PRNGKey(5), cfg)
    step = jax.jit(build_serve_step(cfg))
    outs = []
    for r in reqs:
        cache = init_decode_cache(cfg, 1, r.prompt_len + r.max_new)
        gen, _ = greedy_decode(step, params, cache, r.prompt[None],
                               r.max_new, eos_id=eos_id)
        outs.append([int(t) for t in gen[0]])
    return outs


def test_gateway_digital_token_identical_to_sequential():
    from repro.serving.gateway import run as gw_run

    reqs = _requests()
    ref = _sequential_digital(reqs)
    rep = gw_run(_gw_args(reqs))
    got = [r["tokens"] for r in rep["requests"]]
    assert got == ref
    assert rep["tokens_out"] == sum(len(t) for t in ref)
    # paging really happened: prompts+decode cross page boundaries
    assert any(r.prompt_len + r.max_new > 4 for r in reqs)


def test_gateway_hw_token_identical_on_twin_and_socket():
    """The tentpole gate: continuous-batched hw-logits decode ≡ N
    sequential batch-1 ``serve --hw-logits`` runs, with every layer's
    frames coalesced across requests — on the in-process twin AND the
    TCP socket transport (σ_drift = 0)."""
    from repro.serving.gateway import run as gw_run

    reqs = _requests(n=3, max_new=2)
    params = init_model(jax.random.PRNGKey(5),
                        dataclasses.replace(ARCH, unroll=True, remat=False))
    for driver in ("twin", "socket"):
        ref = []
        for r in reqs:
            out = serve_mod.run(argparse.Namespace(
                arch=ARCH, batch=1, prompt_len=r.prompt_len, gen=r.max_new,
                seed=5, fleet=2, drift=False, drift_sigma=0.0, probe_every=4,
                fleet_k=8, fleet_dim=8, fleet_tenants=1, fleet_driver=driver,
                hw_logits=True, hw_shadow=False, deploy_zo=False,
                no_recal=True, prompt_tokens=r.prompt[None],
                params_override=params))
            ref.append([int(t) for t in out["gen"][0]])
        rep = gw_run(_gw_args(reqs, hw_logits=True, fleet=2, no_recal=True,
                              fleet_driver=driver, params_override=params))
        got = [r["tokens"] for r in rep["requests"]]
        assert got == ref, f"{driver}: gateway diverged from sequential"
        hw = rep["fleet"]["hw"]
        assert hw["mode"] == "route" and hw["hw_calls"] > 0
        # coalescing really happened: one frame per layer-group per step
        # regardless of how many requests are in flight (7 layers in 4
        # sibling groups on this arch)
        assert hw["frames_per_step"] == 4.0


def test_gateway_shadow_matches_route_at_sigma0():
    from repro.serving.gateway import run as gw_run

    reqs = _requests(n=3, max_new=2)
    route = gw_run(_gw_args(reqs, hw_logits=True, fleet=1, no_recal=True))
    shadow = gw_run(_gw_args(reqs, hw_shadow=True, fleet=1, no_recal=True))
    assert ([r["tokens"] for r in route["requests"]]
            == [r["tokens"] for r in shadow["requests"]])
    assert shadow["fleet"]["hw"]["hw_calls"] == 0
    assert shadow["fleet"]["hw"]["shadow_calls"] > 0


def test_gateway_deterministic_rerun():
    from repro.serving.gateway import run as gw_run

    reqs = _requests(n=5, max_new=3)
    r1 = gw_run(_gw_args(reqs))
    r2 = gw_run(_gw_args(reqs))
    assert r1["requests"] == r2["requests"]
    assert r1["schedule_trace"] == r2["schedule_trace"]
    assert r1["steps"] == r2["steps"]


# ---------------------------------------------------------------------------
# EOS early termination
# ---------------------------------------------------------------------------


def _first_emitted(reqs):
    """The first token the model emits for request 0 — a guaranteed-hit
    stop token for the EOS tests."""
    ref = _sequential_digital(reqs)
    return ref, ref[0][0]


def test_greedy_decode_eos_early_termination():
    """greedy_decode(eos_id=...) stops a finished sequence: the row is
    eos-padded, and once all rows finish no further steps run."""
    cfg = dataclasses.replace(ARCH, unroll=False)
    params = init_model(jax.random.PRNGKey(5), cfg)
    step = jax.jit(build_serve_step(cfg))
    prompt = np.asarray([[7, 3, 11]], np.int32)
    cache = init_decode_cache(cfg, 1, prompt.shape[1] + 6)
    free, _ = greedy_decode(step, params, cache, prompt, 6)
    eos = int(free[0][0])
    steps = []
    cache = init_decode_cache(cfg, 1, prompt.shape[1] + 6)
    gen, _ = greedy_decode(step, params, cache, prompt, 6, eos_id=eos,
                           on_step=steps.append)
    assert gen.shape == free.shape
    assert list(gen[0]) == [eos] * 6          # emitted once, then padded
    # loop exited right after the first emission, not after 6
    assert len(steps) == prompt.shape[1]      # prompt_len-1 prefill + 1 emit
    # without eos the loop runs the full budget
    assert len(free[0]) == 6


def test_gateway_eos_matches_sequential():
    """Per-request EOS in the gateway: finish_reason='eos', tokens match
    the sequential eos-truncated decode, slot is reused afterwards."""
    from repro.serving.gateway import run as gw_run

    reqs = _requests(n=4, max_new=4)
    ref, eos = _first_emitted(reqs)
    eos_reqs = [dataclasses.replace(r, eos_id=eos, out_tokens=[])
                for r in reqs]
    rep = gw_run(_gw_args(eos_reqs, slots=2))
    for got, want in zip(rep["requests"], ref):
        if eos in want:
            cut = want[:want.index(eos) + 1]
            assert got["finish_reason"] == "eos"
            assert got["tokens"] == cut
        else:
            assert got["finish_reason"] == "max_new"
            assert got["tokens"] == want
    assert any(r["finish_reason"] == "eos" for r in rep["requests"])


# ---------------------------------------------------------------------------
# engine bookkeeping
# ---------------------------------------------------------------------------


def test_gateway_respects_arrivals_and_reports_latency():
    from repro.serving.gateway import run as gw_run

    reqs = _requests(n=4, max_new=3)
    for r in reqs:
        r.arrival = r.rid * 5              # forced gaps: idle steps exist
    rep = gw_run(_gw_args(reqs, slots=1))  # single slot: strict FIFO queue
    recs = rep["requests"]
    for r, rec in zip(reqs, recs):
        assert rec["admitted"] >= r.arrival
        assert rec["finished"] > rec["admitted"]
    # single slot → at most one request in flight: finishes are ordered
    fins = [rec["finished"] for rec in recs]
    assert fins == sorted(fins)
    assert rep["latency_steps"]["p99"] >= rep["latency_steps"]["p50"] > 0
    assert 0 < rep["occupancy"] <= 1.0


def test_gateway_refuses_jit_hw_combo():
    params = init_model(jax.random.PRNGKey(5), ARCH)
    try:
        ServingGateway(dataclasses.replace(ARCH, unroll=False), params,
                       GatewayConfig(slots=2), hw_plane=object())
        assert False, "expected ValueError: hw plane needs unroll=True"
    except ValueError:
        pass
