"""Forecast-driven autopilot: crossing prediction, priority queue,
proactive scheduling, budget envelope, and policy equivalence.

Scheduler-level tests drive the real fleet machinery (twin drivers)
through the ``PhotonicDriver`` boundary; pure-function properties
(``predicted_crossing``, ``LoadForecast``) need no hardware at all.
"""

import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.hw.drift import DriftConfig
from repro.runtime.autopilot import (AutopilotConfig, AutopilotRouter,
                                     LoadForecast, logit_sensitivity,
                                     predicted_crossing)
from repro.runtime.fleet import (RECALIBRATING, RuntimeConfig, make_fleet,
                                 make_router)
from repro.runtime.monitor import MonitorConfig
from repro.runtime.recalibrate import RecalConfig
from repro.core.noise import DEFAULT_NOISE

K = 4
DIM = 8
DRIFT = DriftConfig(sigma_phase=0.03, theta=0.01)


def _cfg(**kw):
    defaults = dict(
        k=K, noise=DEFAULT_NOISE.post_ic(), drift=DRIFT,
        monitor=MonitorConfig(n_probes=8, alarm_threshold=0.05,
                              clear_threshold=0.03, consecutive=2),
        recal=RecalConfig(zo_steps=120, delta0=0.05),
        probe_every=5, recal_latency=2, max_concurrent_recals=1)
    defaults.update(kw)
    return RuntimeConfig(**defaults)


def _weights(n=2, seed=0):
    rng = np.random.default_rng(seed)
    return [np.asarray(rng.standard_normal((DIM, DIM)) / np.sqrt(DIM),
                       np.float32) for _ in range(n)]


def _autopilot_router(ap=None, seed=3, **cfg_kw):
    cfg = _cfg(autopilot=ap if ap is not None else AutopilotConfig(),
               **cfg_kw)
    chips = make_fleet(jax.random.PRNGKey(0), 2, _weights(), cfg)
    router = make_router(chips, cfg, seed=seed)
    assert isinstance(router, AutopilotRouter)
    return router, chips


# ---------------------------------------------------------------------------
# predicted_crossing: the OU inversion
# ---------------------------------------------------------------------------


def test_crossing_zero_when_already_past_threshold():
    assert predicted_crossing(0.08, 0.01, 0.05, DRIFT) == 0.0
    assert predicted_crossing(0.05, 0.01, 0.05, DRIFT) == 0.0


def test_crossing_inf_without_measured_growth():
    assert predicted_crossing(0.01, 0.0, 0.05, DRIFT) == math.inf
    assert predicted_crossing(0.01, -0.002, 0.05, DRIFT) == math.inf


def test_crossing_inf_when_saturating_inside_tolerance():
    # d_inf = d + rate/2θ must exceed the threshold for a crossing:
    # rate small enough that drift plateaus below the alarm never fires
    rate = (0.05 - 0.02) * 2 * DRIFT.theta * 0.9   # d_inf = 0.047 < 0.05
    assert predicted_crossing(0.02, rate, 0.05, DRIFT) == math.inf


def test_crossing_monotone_in_rate_and_distance():
    crossings = [predicted_crossing(0.02, r, 0.05, DRIFT)
                 for r in (0.002, 0.004, 0.008, 0.016)]
    assert all(a > b for a, b in zip(crossings, crossings[1:]))
    crossings = [predicted_crossing(d, 0.004, 0.05, DRIFT)
                 for d in (0.01, 0.02, 0.03, 0.04)]
    assert all(a > b for a, b in zip(crossings, crossings[1:]))


def test_crossing_reduces_to_linear_extrapolation_for_fast_rates():
    # rate >> (thr−d)·2θ: the OU curvature is negligible over the gap,
    # so Δ* → (threshold − d̂)/rate
    d, thr, rate = 0.02, 0.05, 0.5
    assert predicted_crossing(d, rate, thr, DRIFT) == \
        pytest.approx((thr - d) / rate, rel=0.05)


# ---------------------------------------------------------------------------
# LoadForecast
# ---------------------------------------------------------------------------


def test_cold_forecast_is_pessimistic():
    f = LoadForecast(period=10)
    assert f.forecast(0) == 1.0   # ignorance must never read as a trough


def test_diurnal_bins_learn_the_phase_profile():
    f = LoadForecast(period=4, alpha=0.5)
    profile = [0.9, 0.6, 0.2, 0.5]
    for tick in range(40):
        f.observe(profile[tick % 4], tick)
    for phase, want in enumerate(profile):
        assert abs(f.forecast(100 + phase) - want) < 0.05
    # phases never observed fall back to the global EWMA, not 1.0
    g = LoadForecast(period=0, alpha=0.5)
    g.observe(0.3, 0)
    assert g.forecast(7) == 0.3


# ---------------------------------------------------------------------------
# the priority queue
# ---------------------------------------------------------------------------


def test_repair_queue_reactive_first_then_fastest_degrading():
    router, chips = _autopilot_router(AutopilotConfig(horizon=1000))
    t00, t01 = chips[0].tenants
    t10, _ = chips[1].tenants
    # chip0/tenant0: alarmed, slow; chip0/tenant1: alarmed, fast;
    # chip1/tenant0: not alarmed but degrading inside the horizon
    t00.health = dataclasses.replace(t00.health, alarmed=True, rate=0.001)
    t01.health = dataclasses.replace(t01.health, alarmed=True, rate=0.01)
    t10.health = dataclasses.replace(t10.health, distance=0.03, rate=0.01)
    pending = [(c, 0, None, None) for c in chips]
    queue = router._repair_queue(pending)
    kinds = [(key[0], t.tenant_id, c.chip_id) for key, c, t in queue]
    # both reactive entries precede the proactive one; within the
    # reactive class the faster-degrading tenant wins
    assert kinds[0] == (0, 1, 0)
    assert kinds[1] == (0, 0, 0)
    assert kinds[2][0] == 1 and kinds[2][2] == 1


def test_repair_queue_is_monotone_in_degradation_rate():
    router, chips = _autopilot_router(AutopilotConfig(horizon=1000))
    rates = [0.003, 0.012, 0.007, 0.001]
    tenants = [t for c in chips for t in c.tenants]
    for t, r in zip(tenants, rates):
        t.health = dataclasses.replace(t.health, alarmed=True, rate=r)
    pending = [(c, 0, None, None) for c in chips]
    got = [t.health.rate for _, _, t in router._repair_queue(pending)]
    assert got == sorted(rates, reverse=True)


def test_queue_skips_offline_and_recalibrating_chips():
    router, chips = _autopilot_router(AutopilotConfig(horizon=1000))
    for c in chips:
        for t in c.tenants:
            t.health = dataclasses.replace(t.health, alarmed=True,
                                           rate=0.01)
    chips[0].status = RECALIBRATING
    chips[1].offline_ticks_left = 3
    pending = [(c, 0, None, None) for c in chips]
    assert router._repair_queue(pending) == []


# ---------------------------------------------------------------------------
# proactive scheduling
# ---------------------------------------------------------------------------


def _drive(router, chips, ticks):
    for _ in range(ticks):
        router.observe_load(0.0)   # permanent trough
        router.tick()


def test_proactive_recal_fires_before_predicted_crossing():
    """With a generous horizon and an always-trough forecast, the
    autopilot repairs a degrading tenant before its alarm: proactive
    recals happen, reactive alarms do not."""
    router, chips = _autopilot_router(
        AutopilotConfig(horizon=40, trough_load=0.5),
        drift=DriftConfig(sigma_phase=0.02, theta=0.01))
    _drive(router, chips, 120)
    rep = router.report()
    assert router.proactive_recals > 0
    assert sum(c["alarms"] for c in rep["chips"]) == 0
    # every recal event carries the proactive marker
    starts = [e for e in router.events if e["event"] == "recal_start"]
    assert starts and all(e.get("proactive") for e in starts)


def test_zero_budget_blocks_proactive_but_not_reactive():
    router, chips = _autopilot_router(
        AutopilotConfig(horizon=40, trough_load=0.5, budget_calls=0.0),
        drift=DriftConfig(sigma_phase=0.02, theta=0.01))
    _drive(router, chips, 120)
    assert router.proactive_recals == 0
    assert router.deferred_budget > 0
    # alarms must still earn repairs: the envelope never gates reactive
    rep = router.report()
    if sum(c["alarms"] for c in rep["chips"]):
        assert sum(c["recals"] for c in rep["chips"]) > 0


def test_budget_meters_proactive_spend_only():
    router, chips = _autopilot_router(
        AutopilotConfig(horizon=40, trough_load=0.5,
                        budget_window=10 ** 6),
        drift=DriftConfig(sigma_phase=0.02, theta=0.01))
    _drive(router, chips, 120)
    total = sum(c.recal_calls for c in chips)
    n_pro = sum(1 for e in router.events
                if e["event"] == "recal_start" and e.get("proactive"))
    n_all = sum(1 for e in router.events if e["event"] == "recal_start")
    assert router.proactive_calls <= total + 1e-9
    if n_pro == n_all:
        assert router.proactive_calls == pytest.approx(total, rel=1e-9)


def test_urgent_crossing_overrides_the_trough_gate():
    """A tenant whose crossing is inside the loop's reaction time is
    repaired even at forecast peak load — waiting for the trough would
    lose the race to the alarm."""
    router, chips = _autopilot_router(
        AutopilotConfig(horizon=40, trough_load=0.05),
        drift=DriftConfig(sigma_phase=0.02, theta=0.01))
    for _ in range(120):
        router.observe_load(1.0)   # permanent peak: trough gate never opens
        router.tick()
    # proactive work still happened — only via the urgency override —
    # and non-urgent candidates were deferred for the trough
    assert router.proactive_recals > 0 or router.deferred_trough > 0


# ---------------------------------------------------------------------------
# policy equivalence and sensitivity
# ---------------------------------------------------------------------------


def test_accuracy_aware_matches_drift_aware_at_sigma_zero():
    """With drift off the device never moves, so every tenant's
    forecast excess over its deployment floor is 0 and the
    accuracy_aware key degenerates to the drift_aware one: both
    policies dispatch identically.  (Probes are held off — a σ=0 probe
    still carries sampling noise, which is re-measurement jitter, not
    drift excess.)"""
    routers = []
    for policy in ("drift_aware", "accuracy_aware"):
        cfg = _cfg(drift=DriftConfig(sigma_phase=0.0, theta=0.01),
                   router_policy=policy, probe_every=10 ** 6)
        chips = make_fleet(jax.random.PRNGKey(0), 3, _weights(), cfg)
        routers.append((make_router(chips, cfg, seed=5), chips))
    rng = np.random.default_rng(11)
    xs = rng.standard_normal((20, 4, DIM)).astype(np.float32)
    for i, x in enumerate(xs):
        picked = []
        for router, chips in routers:
            router.tick()
            _, chip_id = router.serve(x, tenant=i % 2)
            picked.append(chip_id)
        assert picked[0] == picked[1]


def test_logit_sensitivity_ranks_by_frobenius_energy():
    rng = np.random.default_rng(0)
    base = rng.standard_normal((DIM, DIM)).astype(np.float32)
    ws = [0.5 * base, base, 2.0 * base]
    sens = logit_sensitivity(ws)
    assert sens[0] < sens[1] < sens[2]
    assert abs(sum(sens) / len(sens) - 1.0) < 1e-6


def test_outage_makes_chip_unroutable_until_it_lifts():
    router, chips = _autopilot_router(AutopilotConfig())
    router.inject_outage(chips[0].chip_id, 3)
    assert chips[0].offline and not chips[0].routable
    x = np.zeros((2, DIM), np.float32)
    for _ in range(3):
        _, chip_id = router.serve(x, tenant=0)
        assert chip_id == chips[1].chip_id
        router.tick()
    assert not chips[0].offline
