"""Protocol v3 (JSON-line) framing + batched data-plane tests.

Covers the wire layer the conformance suite assumes: encode/decode
round-trips, malformed- and oversized-frame rejection, the ``batch``
frame's semantics (ordered execution, per-op results, index-named
failures, no nested control ops), client-side write pipelining (flush
order and round-trip counts), and batched ≡ sequential bit-identity on
both stream transports.  The in-process server scripts pin ``v=3`` so
the whole session stays on the JSON-line framing (the v4 binary frames
and the negotiation itself are covered by ``test_protocol_v4.py``);
streams are binary-mode either way — the wire is bytes.
"""

import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.noise import DEFAULT_NOISE
from repro.hw import make_driver, make_twin
from repro.hw.drift import DriftConfig
from repro.hw.protocol import (encode, decode, send, recv, ProtocolError,
                               MAX_FRAME_BYTES)
from repro.hw.server import serve
from repro.optim.zo import ZOConfig

K = 3
M = N = 6
B = (M // K) * (N // K)
MODEL = DEFAULT_NOISE.post_ic()
DRIFT = DriftConfig(sigma_phase=0.03, theta=0.01)
KEY = jax.random.PRNGKey(42)
STREAM_TRANSPORTS = ["subprocess", "socket"]


def _mk(transport):
    return make_driver(transport, KEY, B, K, MODEL, m=M, n=N, drift=DRIFT)


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def test_encode_decode_roundtrip_bit_exact():
    """Arrays of every dtype the drivers ship survive the wire exactly;
    nested trees keep their structure."""
    rng = np.random.default_rng(0)
    tree = dict(
        f32=rng.standard_normal((3, 4)).astype(np.float32),
        f64=rng.standard_normal((2, 2)),
        u32=np.arange(6, dtype=np.uint32).reshape(2, 3),
        i64=np.asarray([-5, 9]),
        scalars=[1, 2.5, True, None, "s"],
        nested=dict(x=[np.float32(1.25) * np.ones((1, 1), np.float32)]),
    )
    out = decode(json.loads(json.dumps(encode(tree))))
    for name in ("f32", "f64", "u32", "i64"):
        assert out[name].dtype == tree[name].dtype
        np.testing.assert_array_equal(out[name], tree[name])
    assert out["scalars"] == [1, 2.5, True, None, "s"]
    np.testing.assert_array_equal(out["nested"]["x"][0],
                                  tree["nested"]["x"][0])


def test_send_recv_roundtrip():
    buf = io.BytesIO()
    msg = dict(id=3, op="forward", kw=encode(dict(x=np.eye(2, dtype=np.float32))))
    send(buf, msg)
    buf.seek(0)
    got = recv(buf)
    assert got["id"] == 3 and got["op"] == "forward"
    np.testing.assert_array_equal(decode(got["kw"])["x"],
                                  np.eye(2, dtype=np.float32))


def test_recv_rejects_malformed_frame():
    with pytest.raises(ProtocolError, match="malformed"):
        recv(io.BytesIO(b"this is not json\n"))


def test_recv_rejects_oversized_frame_without_buffering_it():
    line = (json.dumps(dict(id=1, op="x", kw={"pad": "y" * 4096}))
            + "\n").encode()
    with pytest.raises(ProtocolError, match="oversized"):
        recv(io.BytesIO(line), max_bytes=1024)
    # a frame exactly at the ceiling still parses
    small = (json.dumps(dict(id=1, op="x")) + "\n").encode()
    assert recv(io.BytesIO(small), max_bytes=len(small))["op"] == "x"


def test_send_refuses_oversized_frame():
    big = np.zeros(MAX_FRAME_BYTES // 4 + 1024, np.float32)
    with pytest.raises(ProtocolError, match="oversized"):
        send(io.BytesIO(), dict(id=1, op="write_sigma",
                                 kw=encode(dict(sigma=big))))


def test_server_answers_malformed_payloads_without_dying():
    """Valid JSON that is not a valid request — a non-dict frame, or a
    corrupt __nd__ payload — draws an error frame and the session keeps
    serving (a socket daemon must survive one bad client frame)."""
    bad_nd = dict(id=1, op="init", kw={"key": {"__nd__": "!!!",
                                               "dtype": "float32",
                                               "shape": [1]}})
    resp = _serve_script(bad_nd, _init_msg(rid=2))
    assert resp[0]["ok"] is False
    assert resp[1]["ok"] is True                  # session survived

    fin = io.BytesIO(b"5\n" + (json.dumps(_init_msg(rid=2)) + "\n").encode())
    fout = io.BytesIO()
    serve(fin, fout)
    frames = [json.loads(l) for l in fout.getvalue().splitlines()]
    assert frames[0]["ok"] is False
    assert frames[1]["ok"] is True


@pytest.mark.parametrize("transport", ["subprocess"])
def test_charge_category_validated_at_call_site(transport):
    """A typo'd meter category raises ValueError when charge() is
    called, not as a server error at some later flush (or never, if the
    driver closes first)."""
    driver = _mk(transport)
    try:
        with pytest.raises(ValueError, match="category"):
            driver.charge("prob", 64.0)
        with pytest.raises(ValueError, match="category"):
            driver.forward(jnp.ones((2, K)), category="bogus")
        driver.charge("probe", 1.5)               # valid still queues
        assert driver.stats.probe == 1.5
    finally:
        driver.close()


def test_server_rejects_malformed_frame_and_drops_connection():
    """A garbage line draws an explicit error frame, then the server
    stops serving the (desynced) stream instead of guessing."""
    fin = io.BytesIO(b"not json at all\n"
                     + (json.dumps(dict(id=2, op="stats", kw={}))
                        + "\n").encode())
    fout = io.BytesIO()
    serve(fin, fout)
    frames = [json.loads(l) for l in fout.getvalue().splitlines()]
    assert len(frames) == 1                      # second frame never served
    assert frames[0]["ok"] is False
    assert "protocol error" in frames[0]["error"]


# ---------------------------------------------------------------------------
# batch frame semantics (in-process server, no subprocess cost)
# ---------------------------------------------------------------------------

def _serve_script(*msgs):
    fin = io.BytesIO("".join(json.dumps(m) + "\n" for m in msgs).encode())
    fout = io.BytesIO()
    serve(fin, fout)
    return [json.loads(l) for l in fout.getvalue().splitlines()]


def _init_msg(rid=1):
    # pin v=3: the whole scripted session stays on JSON-line framing,
    # so responses parse as lines (v4 negotiation switches to binary
    # frames mid-stream — covered in test_protocol_v4.py)
    import dataclasses
    return dict(id=rid, op="init", kw=encode(dict(
        v=3, key=np.asarray(KEY), n_blocks=B, k=K,
        m=M, n=N, model=dataclasses.asdict(MODEL), drift=None)))


def test_batch_executes_in_order_and_returns_per_op_results():
    x = np.ones((2, K), np.float32)
    resp = _serve_script(
        _init_msg(),
        dict(id=2, op="batch", kw=encode(dict(ops=[
            dict(op="advance", kw=dict(dt=1.0)),
            dict(op="forward", kw=dict(x=x)),
            dict(op="stats", kw={}),
        ]))))
    assert resp[1]["ok"] is True
    results = decode(resp[1]["result"])
    assert results[0] is None                    # advance: result-less
    assert results[1]["y"].shape == (B, 2, K)
    assert results[2]["probe"] == B * 2          # forward metered inside


def test_batch_failure_names_index_and_keeps_prior_ops_applied():
    x = np.ones((2, K), np.float32)
    resp = _serve_script(
        _init_msg(),
        dict(id=2, op="batch", kw=encode(dict(ops=[
            dict(op="forward", kw=dict(x=x)),
            dict(op="forward", kw=dict(x=x, block_range=[0, B + 7])),
        ]))),
        dict(id=3, op="stats", kw={}))
    assert resp[1]["ok"] is False
    assert "batch op 1" in resp[1]["error"]
    # op 0 executed (and was charged) before op 1 failed
    assert decode(resp[2]["result"])["probe"] == B * 2


@pytest.mark.parametrize("nested", ["init", "shutdown", "batch",
                                    "unsafe/dev", "meta"])
def test_control_ops_cannot_nest_inside_batch(nested):
    resp = _serve_script(
        _init_msg(),
        dict(id=2, op="batch",
             kw=encode(dict(ops=[dict(op=nested, kw={})]))))
    assert resp[1]["ok"] is False
    assert "cannot appear inside a batch" in resp[1]["error"]


# ---------------------------------------------------------------------------
# batched ≡ sequential bit-identity + pipelining, on real transports
# ---------------------------------------------------------------------------

def _sequential_session(driver):
    """The reference encoding: every op its own round-trip shape."""
    rng = np.random.default_rng(3)
    t = driver.read_phases()[0].shape[-1]
    pu = jnp.asarray(rng.uniform(0, 1, (B, t)), jnp.float32)
    pv = jnp.asarray(rng.uniform(0, 1, (B, t)), jnp.float32)
    sg = jnp.asarray(rng.uniform(0.5, 1.5, (B, K)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((B, K, K)) * 0.4, jnp.float32)
    cfg = ZOConfig(steps=15, inner=6, delta0=0.1, decay=1.05)

    out = {}
    driver.write_phases(pu, pv)
    driver.write_sigma(sg)
    driver.advance(1.0)
    driver.advance(1.0)
    out["fwd"] = driver.forward(x)
    res = driver.zo_refine(w, jax.random.PRNGKey(5), cfg)
    out["zo_phi"], out["zo_loss"] = res.phi, res.loss
    out["sigma"] = driver.read_sigma()
    out["u"], out["v"] = driver.readback_bases()
    out["stats"] = driver.stats.as_dict()
    return out


def _batched_session(driver):
    """The same ops, same order, shipped as explicit batches."""
    rng = np.random.default_rng(3)
    t = driver.read_phases()[0].shape[-1]
    pu = jnp.asarray(rng.uniform(0, 1, (B, t)), jnp.float32)
    pv = jnp.asarray(rng.uniform(0, 1, (B, t)), jnp.float32)
    sg = jnp.asarray(rng.uniform(0.5, 1.5, (B, K)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((B, K, K)) * 0.4, jnp.float32)
    cfg = ZOConfig(steps=15, inner=6, delta0=0.1, decay=1.05)

    out = {}
    fwd, zo, sigma, (u, v), stats = driver.run_batch([
        ("write_phases", dict(phi_u=pu, phi_v=pv)),
        ("write_sigma", dict(sigma=sg)),
        ("advance", dict(dt=1.0)),
        ("advance", dict(dt=1.0)),
        ("forward", dict(x=x)),
        ("zo_refine", dict(w_blocks=w, key=jax.random.PRNGKey(5), cfg=cfg)),
        ("read_sigma", {}),
        ("readback_bases", {}),
        ("stats", {}),
    ])[4:]
    out["fwd"] = fwd
    out["zo_phi"], out["zo_loss"] = zo.phi, zo.loss
    out["sigma"] = sigma
    out["u"], out["v"] = u, v
    out["stats"] = stats.as_dict()
    return out


@pytest.mark.parametrize("transport", STREAM_TRANSPORTS)
def test_batched_equals_sequential_bit_identical(transport):
    """One batch frame ≡ the op-per-frame encoding ≡ the in-process
    twin, bit for bit, on both stream transports."""
    ref = _sequential_session(make_twin(KEY, B, K, MODEL, m=M, n=N,
                                        drift=DRIFT))
    d_seq = _mk(transport)
    try:
        seq = _sequential_session(d_seq)
    finally:
        d_seq.close()
    d_bat = _mk(transport)
    try:
        bat = _batched_session(d_bat)
        n_frames = d_bat._rpc_count
    finally:
        d_bat.close()
    for name in ("fwd", "zo_phi", "zo_loss", "sigma", "u", "v"):
        np.testing.assert_array_equal(np.asarray(ref[name]),
                                      np.asarray(seq[name]), err_msg=name)
        np.testing.assert_array_equal(np.asarray(ref[name]),
                                      np.asarray(bat[name]), err_msg=name)
    assert ref["stats"] == seq["stats"] == bat["stats"]
    # init + read_phases + ONE batch frame
    assert n_frames == 3


@pytest.mark.parametrize("transport", STREAM_TRANSPORTS)
def test_write_pipelining_flushes_before_reads_in_one_frame(transport):
    """Result-less ops queue client-side (zero round-trips) and land,
    in issue order, inside the next observable op's frame."""
    driver = _mk(transport)
    try:
        rng = np.random.default_rng(1)
        t = driver.read_phases()[0].shape[-1]
        frames0 = driver._rpc_count
        pu = jnp.asarray(rng.uniform(0, 1, (B, t)), jnp.float32)
        pv = jnp.asarray(rng.uniform(0, 1, (B, t)), jnp.float32)
        driver.write_phases(pu, pv)
        driver.advance(1.0)
        driver.charge("probe", 2.5)
        assert driver._rpc_count == frames0      # nothing sent yet
        ru, rv = driver.read_phases()            # flush + read: one frame
        assert driver._rpc_count == frames0 + 1
        np.testing.assert_array_equal(np.asarray(ru), np.asarray(pu))
        assert driver.stats.probe == 2.5         # charge landed before read
    finally:
        driver.close()


@pytest.mark.parametrize("transport", STREAM_TRANSPORTS)
def test_pipelined_write_validates_at_call_site(transport):
    """Client-side geometry validation keeps ValueError at the call
    site even though the write itself is deferred — for both the block
    range and the written bank's size (a bad bank must not surface as a
    server error at some later flush, or vanish in close())."""
    driver = _mk(transport)
    try:
        with pytest.raises(ValueError):
            driver.write_sigma(jnp.ones((2, K)), block_range=(0, B + 1))
        with pytest.raises(ValueError, match="elements"):
            driver.write_sigma(jnp.ones((B, K + 1)))
        t = K * (K - 1) // 2
        with pytest.raises(ValueError, match="elements"):
            driver.write_phases(jnp.ones((B, t + 1)), jnp.ones((B, t + 1)))
        # the session is still healthy after rejected writes
        assert driver.read_sigma().shape == (B, K)
    finally:
        driver.close()


def test_oversized_aggregate_frame_splits_transparently(monkeypatch):
    """Ops that are individually legal must not fail because pipelining
    packed them into one over-limit frame: the client halves the list
    (send() refuses BEFORE writing, so no op ran twice)."""
    from repro.hw import protocol

    driver = _mk("subprocess")
    try:
        rng = np.random.default_rng(2)
        t = driver.read_phases()[0].shape[-1]
        pu = jnp.asarray(rng.uniform(0, 1, (B, t)), jnp.float32)
        pv = jnp.asarray(rng.uniform(0, 1, (B, t)), jnp.float32)
        # client-side limit only (the unpatched server still speaks
        # 64 MiB): each write frame is a few hundred bytes, several
        # together overflow 1200
        monkeypatch.setattr(protocol, "MAX_FRAME_BYTES", 1200)
        frames0 = driver._rpc_count
        for _ in range(6):
            driver.write_phases(pu, pv)
        ru, _ = driver.read_phases()      # flush: must split, not fail
        assert driver._rpc_count - frames0 > 1
        np.testing.assert_array_equal(np.asarray(ru), np.asarray(pu))
    finally:
        monkeypatch.undo()
        driver.close()


def test_run_batch_error_notes_pipelined_head_offset():
    """A server-side batch failure whose frame carried pipelined writes
    tells the caller how to translate the reported index."""
    driver = _mk("subprocess")
    try:
        cfg = ZOConfig(steps=5, inner=5, delta0=0.1, decay=1.05)
        driver.advance(1.0)               # pipelined head of 1
        with pytest.raises(RuntimeError, match="pipelined write"):
            driver.run_batch([
                ("forward", dict(x=jnp.ones((2, K)))),
                ("zo_refine", dict(w_blocks=jnp.ones((B, K, K, 2)),
                                   key=jax.random.PRNGKey(0), cfg=cfg)),
            ])
    finally:
        driver.close()


@pytest.mark.parametrize("transport", ["twin"] + STREAM_TRANSPORTS)
@pytest.mark.parametrize("name", ["close", "unsafe_twin", "_slice", "nope"])
def test_run_batch_rejects_non_batchable_ops_on_every_transport(transport,
                                                                name):
    """Lifecycle ops and private internals are rejected by run_batch on
    EVERY transport — a list that works in-process must work over the
    wire and vice versa (regression: getattr dispatch used to accept
    anything in-process)."""
    driver = _mk(transport)
    try:
        with pytest.raises(ValueError, match="batch"):
            driver.run_batch([(name, {})])
    finally:
        driver.close()


@pytest.mark.parametrize("transport", ["twin"] + STREAM_TRANSPORTS)
def test_coalesced_probe_sweep_bit_identical_and_metered(transport):
    """A batch of same-shape forwards (the probe-sweep shape) coalesces
    into one vmapped device call — results must stay bit-identical to
    sequential execution and every op must be charged individually."""
    rng = np.random.default_rng(9)
    xs = [jnp.asarray(rng.standard_normal((6, K)), jnp.float32)
          for _ in range(10)]

    d_seq = _mk(transport)
    try:
        d_seq.reset_stats()
        seq = [np.asarray(d_seq.forward(x)) for x in xs]
        seq_stats = d_seq.stats.as_dict()
    finally:
        d_seq.close()

    d_bat = _mk(transport)
    try:
        d_bat.reset_stats()
        bat = d_bat.run_batch([("forward", dict(x=x)) for x in xs])
        bat_stats = d_bat.stats.as_dict()
    finally:
        d_bat.close()

    for s, g in zip(seq, bat):
        np.testing.assert_array_equal(s, np.asarray(g))
    assert seq_stats == bat_stats
    assert bat_stats["probe"] == 10 * 6 * B


@pytest.mark.parametrize("transport", STREAM_TRANSPORTS)
def test_unsafe_readout_flushes_pipelined_writes_first(transport):
    """unsafe/* ops are not batchable, so a pending pipelined write
    must flush in its own frame first — and still land BEFORE the
    readout (regression: the whitelist briefly made unsafe_twin()
    unusable while advances were queued)."""
    twin = make_twin(KEY, B, K, MODEL, m=M, n=N, drift=DRIFT)
    twin.advance(1.0)
    ref = twin.unsafe_twin().bias_deviation()
    driver = _mk(transport)
    try:
        driver.advance(1.0)               # queued client-side
        got = driver.unsafe_twin().bias_deviation()
    finally:
        driver.close()
    assert got == ref                     # advance landed first


def test_socket_driver_explicit_address():
    """A SocketDriver can attach to an already-running --socket server
    (the remote-host topology), not just self-host one."""
    import subprocess, sys, time
    from repro.hw.socket_driver import SocketDriver
    from repro.hw.subprocess_driver import server_env

    proc = subprocess.Popen(
        [sys.executable, "-u", "-m", "repro.hw.server",
         "--socket", "127.0.0.1:0", "--sessions", "1"],
        stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, text=True,
        env=server_env())
    try:
        line = proc.stdout.readline()
        assert line.startswith("LISTENING ")
        port = int(line.split()[1])
        d = SocketDriver(KEY, B, K, MODEL, m=M, n=N,
                         address=("127.0.0.1", port))
        try:
            y = d.forward(jnp.ones((2, K)))
            assert y.shape == (B, 2, K)
        finally:
            d.close()
        proc.wait(timeout=10)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=5)
