"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.core import unitary as un


@pytest.mark.parametrize("t,p,q,k", [(8, 2, 3, 8), (64, 4, 4, 16),
                                     (32, 1, 1, 9), (16, 3, 2, 4),
                                     (128, 2, 2, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ptc_block_matmul_sweep(t, p, q, k, dtype):
    rng = np.random.default_rng(t * 100 + p * 10 + q)
    x = jnp.asarray(rng.standard_normal((t, q * k)), dtype)
    u = jnp.asarray(rng.standard_normal((p, q, k, k)), dtype)
    s = jnp.asarray(rng.standard_normal((p, q, k)), dtype)
    v = jnp.asarray(rng.standard_normal((p, q, k, k)), dtype)
    y = ops.ptc_block_matmul(x, u, s, v)
    yr = ref.ptc_block_matmul_ref(x, u, s, v)
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    scale = float(jnp.abs(yr.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(y.astype(jnp.float32)
                        - yr.astype(jnp.float32)).max()) / scale
    assert err < tol, err


@pytest.mark.parametrize("k", [2, 4, 8, 9, 13, 16])
@pytest.mark.parametrize("kind", ["clements", "reck"])
def test_mesh_apply_sweep(k, kind):
    rng = np.random.default_rng(k)
    spec = un.mesh_spec(k, kind)
    ph = jnp.asarray(rng.uniform(-np.pi, np.pi, spec.n_rot), jnp.float32)
    d = jnp.asarray(rng.choice([-1.0, 1.0], k), jnp.float32)
    x = jnp.asarray(rng.standard_normal((24, k)), jnp.float32)
    y = ops.mesh_apply(spec, ph, x, d)
    yr = un.apply_mesh(spec, ph, x, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
    # vs the materialized unitary
    u_mat = un.build_unitary(spec, ph, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ u_mat.T),
                               atol=1e-5)


@pytest.mark.parametrize("t,p,q,k", [(16, 3, 2, 8), (32, 4, 4, 16),
                                     (8, 2, 2, 9)])
@pytest.mark.parametrize("density", [0.0, 0.5, 1.0])
def test_feedback_matmul_sweep(t, p, q, k, density):
    rng = np.random.default_rng(int(t + 10 * density))
    dy = jnp.asarray(rng.standard_normal((t, p * k)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((p, q, k, k)), jnp.float32)
    s = jnp.asarray(rng.standard_normal((p, q, k)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((p, q, k, k)), jnp.float32)
    mask = jnp.asarray(
        (rng.random((q, p)) < density).astype(np.float32) * 2.0)
    dx = ops.feedback_matmul(dy, u, s, v, mask)
    dxr = ref.feedback_matmul_ref(dy, u, s, v, mask)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dxr), atol=1e-4)


def test_mesh_apply_ref_agrees_with_core():
    """ref.mesh_apply_ref is itself validated against core.apply_mesh."""
    rng = np.random.default_rng(5)
    spec = un.mesh_spec(9, "clements")
    ph = jnp.asarray(rng.uniform(-np.pi, np.pi, spec.n_rot), jnp.float32)
    x = jnp.asarray(rng.standard_normal((7, 9)), jnp.float32)
    y1 = ref.mesh_apply_ref(x, ph, jnp.asarray(spec.layer_slot),
                            jnp.asarray(spec.layer_partner),
                            jnp.asarray(spec.layer_sign))
    y2 = un.apply_mesh(spec, ph, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


@pytest.mark.parametrize("t,p,q,k", [(16, 2, 3, 8), (64, 4, 4, 16),
                                     (32, 1, 2, 9)])
def test_sigma_grad_sweep(t, p, q, k):
    rng = np.random.default_rng(t + p)
    dy = jnp.asarray(rng.standard_normal((t, p * k)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((t, q * k)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((p, q, k, k)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((p, q, k, k)), jnp.float32)
    ds = ops.sigma_grad(dy, x, u, v)
    dsr = ref.sigma_grad_ref(dy, x, u, v)
    scale = float(jnp.abs(dsr).max()) + 1e-6
    assert float(jnp.abs(ds - dsr).max()) / scale < 1e-4


def test_sigma_grad_matches_custom_vjp():
    """The kernel computes exactly what the subspace custom_vjp produces
    for ds (dense, no sampling)."""
    from repro.core.ptc import svd_factorize, PTCParams
    from repro.core.subspace import ptc_linear
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((18, 27)) * 0.3, jnp.float32)
    params = svd_factorize(w, 9)
    x = jnp.asarray(rng.standard_normal((16, 27)), jnp.float32)
    dy = jnp.asarray(rng.standard_normal((16, 18)), jnp.float32)
    _, vjp = jax.vjp(lambda ss: ptc_linear(
        x, PTCParams(params.u, ss, params.v), mode="blocked"), params.s)
    ds_vjp = vjp(dy)[0]
    ds_kernel = ops.sigma_grad(dy, x, params.u, params.v)
    np.testing.assert_allclose(np.asarray(ds_kernel), np.asarray(ds_vjp),
                               atol=1e-4)
