"""MoE EP paths: a2a (shard_map all_to_all) ≡ pjit path, multi-device.

Runs in a subprocess with 8 forced host devices so the main test
process keeps its single-device view (conftest contract)."""

import subprocess
import sys
import os

import pytest

SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.models.ffn import MoECfg, init_moe, moe
from repro.models.layers import PTCLinearCfg
mesh = jax.make_mesh((2, 4), ("data", "model"))
ptc = PTCLinearCfg(k=8, mode="fused", base_dtype=jnp.float32)
kw = dict(d_model=32, d_ff=64, n_experts=8, top_k=2, capacity_factor=8.0)
cfg_p = MoECfg(dispatch="pjit", **kw)
cfg_a = MoECfg(dispatch="a2a", **kw)
p = init_moe(jax.random.PRNGKey(0), cfg_p, ptc)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
with mesh:
    yp, _ = jax.jit(lambda p, x: moe(p, cfg_p, ptc, x))(p, x)
    ya, _ = jax.jit(lambda p, x: moe(p, cfg_a, ptc, x))(p, x)
    assert float(jnp.abs(yp - ya).max()) < 1e-5, "forward mismatch"
    gx_a = jax.jit(jax.grad(lambda p, x: moe(p, cfg_a, ptc, x)[0].sum(),
                            argnums=1))(p, x)
    gx_p = jax.jit(jax.grad(lambda p, x: moe(p, cfg_p, ptc, x)[0].sum(),
                            argnums=1))(p, x)
    assert float(jnp.abs(gx_a - gx_p).max()) < 1e-4, "dx mismatch"
    gs_a = jax.jit(jax.grad(lambda p, x: moe(p, cfg_a, ptc, x)[0].sum()))(p, x)
    gs_p = jax.jit(jax.grad(lambda p, x: moe(p, cfg_p, ptc, x)[0].sum()))(p, x)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(gs_a), jax.tree.leaves(gs_p)))
    assert err < 1e-4, f"param grad mismatch {err}"
print("A2A_OK")
"""


@pytest.mark.slow
def test_a2a_matches_pjit_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "A2A_OK" in r.stdout, r.stderr[-2000:]


def test_a2a_falls_back_single_device():
    """On 1 device (no mesh) the a2a config transparently uses the pjit
    path — smoke configs keep working everywhere."""
    import jax
    import jax.numpy as jnp
    from repro.models.ffn import MoECfg, init_moe, moe
    from repro.models.layers import PTCLinearCfg
    ptc = PTCLinearCfg(k=8, mode="fused", base_dtype=jnp.float32)
    cfg = MoECfg(d_model=32, d_ff=64, n_experts=4, top_k=2, dispatch="a2a")
    p = init_moe(jax.random.PRNGKey(0), cfg, ptc)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    y, aux = jax.jit(lambda p, x: moe(p, cfg, ptc, x))(p, x)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
