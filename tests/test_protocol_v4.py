"""Protocol v4: binary framing, negotiation/fallback, the concurrent
socket server, the async client — plus regression tests for the
transport-lifecycle bugfixes that shipped with v4.

Conformance spine: everything v4 changes is *encoding and scheduling*,
never values — binary frames carry the identical raw array bytes, a
shared concurrent server gives every session its own driver, and async
futures resolve to exactly what the synchronous call would have
returned.  Every test here therefore ends in a bit-identity assertion
against the in-process twin or the v3 encoding.

The bugfix regressions (each failed before the fix):

* ``SocketDriver`` construction failure leaked the spawned server child
  and its stderr spool; the announce ``readline()`` could block forever.
* One poison socket session (a non-OSError escaping ``serve``) killed
  the daemon for every other client.
* Frame limits counted *characters*, so multi-byte UTF-8 slipped past
  the byte ceiling on the JSON-line path.
* ``unsafe_twin()``'s capability cache survived ``close()``, turning a
  dead stream into a confusing ``ProtocolError`` instead of
  ``TwinUnavailable``.
"""

import io
import json
import os
import stat
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.noise import DEFAULT_NOISE
from repro.hw import make_driver, make_twin
from repro.hw.drift import DriftConfig
from repro.hw.driver import CompletedBatch, TwinUnavailable
from repro.hw.protocol import (encode, decode, send, recv, ProtocolError,
                               PROTOCOL_VERSION, SUPPORTED_VERSIONS)
from repro.hw import protocol as protocol_mod
from repro.hw import server as server_mod
from repro.hw.socket_driver import SocketDriver

K = 3
M = N = 6
B = (M // K) * (N // K)
MODEL = DEFAULT_NOISE.post_ic()
DRIFT = DriftConfig(sigma_phase=0.03, theta=0.01)
KEY = jax.random.PRNGKey(42)
STREAM_TRANSPORTS = ["subprocess", "socket"]


def _mk(transport, protocol=None):
    return make_driver(transport, KEY, B, K, MODEL, m=M, n=N, drift=DRIFT,
                       protocol=protocol)


# ---------------------------------------------------------------------------
# binary framing
# ---------------------------------------------------------------------------

ALL_DTYPES = ["float32", "float64", "int8", "int16", "int32", "int64",
              "uint8", "uint16", "uint32", "uint64", "bool",
              "complex64", "complex128"]


def test_binary_roundtrip_bit_exact_every_dtype():
    """Raw-payload frames round-trip every dtype the drivers could ship
    bit-for-bit — dtype, shape, and bytes all preserved."""
    rng = np.random.default_rng(0)
    tree = {}
    for name in ALL_DTYPES:
        dt = np.dtype(name)
        if dt.kind == "f":
            a = rng.standard_normal((2, 3)).astype(dt)
        elif dt.kind == "c":
            a = (rng.standard_normal((2, 3))
                 + 1j * rng.standard_normal((2, 3))).astype(dt)
        elif dt.kind == "b":
            a = rng.integers(0, 2, (2, 3)).astype(dt)
        else:
            a = rng.integers(0, 100, (2, 3)).astype(dt)
        tree[name] = a
    tree["scalars"] = [1, 2.5, True, None, "s"]
    tree["nested"] = dict(x=[np.arange(4, dtype=np.float32).reshape(2, 2)])

    buf = io.BytesIO()
    send(buf, dict(id=1, op="x", kw=encode(tree, binary=True)), binary=True)
    buf.seek(0)
    out = decode(recv(buf)["kw"])
    for name in ALL_DTYPES:
        assert out[name].dtype == tree[name].dtype, name
        assert out[name].shape == tree[name].shape, name
        assert out[name].tobytes() == tree[name].tobytes(), name
    assert out["scalars"] == [1, 2.5, True, None, "s"]
    np.testing.assert_array_equal(out["nested"]["x"][0],
                                  tree["nested"]["x"][0])


def test_binary_frame_is_raw_bytes_not_base64():
    """The array payload appears verbatim in the frame (no base64), and
    the JSON section references it by [offset, nbytes]."""
    arr = np.arange(7, dtype=np.float32)
    buf = io.BytesIO()
    send(buf, dict(id=1, op="x", kw=encode(dict(a=arr), binary=True)),
         binary=True)
    frame = buf.getvalue()
    assert frame[:4] == b"\x00RB4"
    assert arr.tobytes() in frame                 # raw LE payload
    json_len, payload_len = np.frombuffer(frame[4:12], "<u4")
    head = json.loads(frame[12:12 + json_len])
    assert head["kw"]["a"]["__nd__"] == [0, int(payload_len)]


def test_big_endian_arrays_are_normalized_to_wire_order():
    a = np.arange(5, dtype=">f8")
    for binary in (False, True):
        buf = io.BytesIO()
        send(buf, dict(id=1, op="x", kw=encode(dict(a=a), binary=binary)),
             binary=binary)
        buf.seek(0)
        out = decode(recv(buf)["kw"])["a"]
        np.testing.assert_array_equal(out, a.astype("<f8"))


def test_recv_auto_detects_interleaved_framings():
    """One stream can carry both encodings (exactly what the v4 session
    does across the init boundary): recv dispatches per frame."""
    buf = io.BytesIO()
    send(buf, dict(id=1, op="a", kw=encode(dict(x=np.ones(2, np.float32)))))
    send(buf, dict(id=2, op="b",
                   kw=encode(dict(x=np.zeros(3, np.float32), ), binary=True)),
         binary=True)
    send(buf, dict(id=3, op="c", kw={}))
    buf.seek(0)
    assert recv(buf)["id"] == 1
    got = recv(buf)
    assert got["id"] == 2
    np.testing.assert_array_equal(decode(got["kw"])["x"],
                                  np.zeros(3, np.float32))
    assert recv(buf)["id"] == 3


def test_binary_frame_bounds_checked():
    """A hostile [offset, nbytes] payload reference cannot read outside
    the payload section."""
    arr = np.arange(4, dtype=np.float32)
    buf = io.BytesIO()
    send(buf, dict(id=1, op="x", kw=encode(dict(a=arr), binary=True)),
         binary=True)
    frame = bytearray(buf.getvalue())
    json_len = int(np.frombuffer(frame[4:8], "<u4")[0])
    head = json.loads(bytes(frame[12:12 + json_len]))
    head["kw"]["a"]["__nd__"] = [8, 64]          # past the 16-byte payload
    new_head = json.dumps(head, separators=(",", ":")).encode()
    rebuilt = (bytes(frame[:4])
               + np.asarray([len(new_head), 16], "<u4").tobytes()
               + new_head + arr.tobytes())
    with pytest.raises(ProtocolError, match="out of bounds"):
        recv(io.BytesIO(rebuilt))


# ---------------------------------------------------------------------------
# negotiation + fallback
# ---------------------------------------------------------------------------

class _Announce:
    """Capture serve_socket's ``LISTENING <port>`` line."""

    def __init__(self):
        self.port = None
        self.ready = threading.Event()

    def write(self, s):
        if s.startswith("LISTENING"):
            self.port = int(s.split()[1])
            self.ready.set()

    def flush(self):
        pass


def _inprocess_server(sessions, max_conns=None):
    """serve_socket on an ephemeral port in a daemon thread; returns
    (port, thread)."""
    ann = _Announce()
    t = threading.Thread(
        target=server_mod.serve_socket,
        args=("127.0.0.1", 0),
        kwargs=dict(sessions=sessions, max_conns=max_conns, announce=ann),
        daemon=True)
    t.start()
    assert ann.ready.wait(timeout=30), "server never announced its port"
    return ann.port, t


@pytest.mark.parametrize("transport", STREAM_TRANSPORTS)
def test_default_session_negotiates_v4(transport):
    driver = _mk(transport)
    try:
        assert driver.protocol == 4
        assert driver._binary is True
        y = driver.forward(jnp.ones((2, K)))
        assert y.shape == (B, 2, K)
    finally:
        driver.close()


@pytest.mark.parametrize("transport", STREAM_TRANSPORTS)
def test_pinned_v3_session_is_bit_identical_to_v4(transport):
    """The same ops on a pinned-v3 (JSON line) and a v4 (binary) session
    return identical bytes — the framing is a transfer coat."""
    x = jnp.asarray(np.random.default_rng(1).standard_normal((4, K)),
                    jnp.float32)
    outs = {}
    for proto in (3, 4):
        d = _mk(transport, protocol=proto)
        try:
            assert d.protocol == proto
            outs[proto] = (np.asarray(d.forward(x)),
                           np.asarray(d.readback_bases()[0]),
                           d.stats.as_dict())
        finally:
            d.close()
    np.testing.assert_array_equal(outs[3][0], outs[4][0])
    np.testing.assert_array_equal(outs[3][1], outs[4][1])
    assert outs[3][2] == outs[4][2]


def test_v4_client_falls_back_to_v3_only_server(monkeypatch):
    """A v3-only peer refuses the v4 init with a 'protocol mismatch'
    error frame; the client retries the init at v3 on the SAME
    connection and the session works (bit-identical to the twin)."""
    monkeypatch.setattr(server_mod, "SUPPORTED_VERSIONS", (3,))
    port, t = _inprocess_server(sessions=1)
    x = jnp.ones((2, K))
    twin = make_twin(KEY, B, K, MODEL, m=M, n=N, drift=DRIFT)
    ref = np.asarray(twin.forward(x))
    d = SocketDriver(KEY, B, K, MODEL, m=M, n=N, drift=DRIFT,
                     address=("127.0.0.1", port))
    try:
        assert d.protocol == 3
        assert d._binary is False
        np.testing.assert_array_equal(np.asarray(d.forward(x)), ref)
    finally:
        d.close()
    t.join(timeout=30)
    assert not t.is_alive()


def test_pinned_v4_client_errors_on_v3_only_server(monkeypatch):
    """protocol=4 means *no* fallback: the mismatch surfaces."""
    monkeypatch.setattr(server_mod, "SUPPORTED_VERSIONS", (3,))
    port, t = _inprocess_server(sessions=1)
    with pytest.raises(RuntimeError, match="protocol mismatch"):
        SocketDriver(KEY, B, K, MODEL, m=M, n=N, drift=DRIFT,
                     address=("127.0.0.1", port), protocol=4)
    t.join(timeout=30)


# ---------------------------------------------------------------------------
# concurrent server
# ---------------------------------------------------------------------------

def _session_results(port):
    d = SocketDriver(KEY, B, K, MODEL, m=M, n=N, drift=DRIFT,
                     address=("127.0.0.1", port))
    try:
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.standard_normal((4, K)), jnp.float32)
        d.advance(1.0)
        fwd = np.asarray(d.forward(x))
        batch = d.run_batch([("forward", dict(x=x)),
                             ("read_sigma", {}),
                             ("stats", {})])
        return fwd, np.asarray(batch[0]), np.asarray(batch[1]), \
            batch[2].as_dict()
    finally:
        d.close()


def test_n_threads_one_server_bit_identical_to_dedicated_sessions():
    """N clients sharing ONE server process concurrently each get their
    own independent session (own driver), and every result is
    bit-identical to a dedicated single-session server's."""
    n = 3
    port, t = _inprocess_server(sessions=n)
    results = [None] * n
    errs = []

    def worker(i):
        try:
            results[i] = _session_results(port)
        except Exception as e:  # noqa: BLE001 - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=120)
    assert not errs, errs
    t.join(timeout=30)
    assert not t.is_alive()

    # dedicated reference server, one session
    ref_port, ref_t = _inprocess_server(sessions=1)
    ref = _session_results(ref_port)
    ref_t.join(timeout=30)

    for got in results:
        assert got is not None
        np.testing.assert_array_equal(got[0], ref[0])
        np.testing.assert_array_equal(got[1], ref[1])
        np.testing.assert_array_equal(got[2], ref[2])
        assert got[3] == ref[3]


def test_max_conns_bounds_concurrency_not_lifetime():
    """--max-conns 1 serializes sessions but keeps serving: two
    sequential clients both succeed against one bounded server."""
    port, t = _inprocess_server(sessions=2, max_conns=1)
    a = _session_results(port)
    b = _session_results(port)
    t.join(timeout=30)
    np.testing.assert_array_equal(a[0], b[0])


# ---------------------------------------------------------------------------
# async client
# ---------------------------------------------------------------------------

def test_twin_run_batch_async_is_completed_batch():
    """The no-round-trip driver's async handle is already resolved and
    carries exactly what the sync call returns."""
    twin = make_twin(KEY, B, K, MODEL, m=M, n=N, drift=DRIFT)
    x = jnp.ones((2, K))
    fut = twin.run_batch_async([("forward", dict(x=x))])
    assert isinstance(fut, CompletedBatch)
    assert fut.done() is True
    np.testing.assert_array_equal(np.asarray(fut.result(timeout=1)[0]),
                                  np.asarray(twin.forward(x)))


@pytest.mark.parametrize("transport", ["subprocess"])
def test_async_futures_complete_and_collect_out_of_order(transport):
    """Several in-flight batches resolve correctly even when collected
    in reverse issue order, and sync ops interleave safely once the
    reader thread owns the stream — all bit-identical to the twin."""
    rng = np.random.default_rng(5)
    xs = [jnp.asarray(rng.standard_normal((3, K)), jnp.float32)
          for _ in range(4)]
    twin = make_twin(KEY, B, K, MODEL, m=M, n=N, drift=DRIFT)
    refs = [np.asarray(twin.forward(x)) for x in xs]
    ref_stats = twin.stats.as_dict()

    driver = _mk(transport)
    try:
        futs = [driver.run_batch_async([("forward", dict(x=x))])
                for x in xs]
        # a sync op through the id-matched path, mid-flight
        stats = driver.stats.as_dict()
        assert stats == ref_stats
        for fut, ref in zip(reversed(futs), reversed(refs)):
            y = fut.result(timeout=60)[0]
            np.testing.assert_array_equal(np.asarray(y), ref)
        assert all(f.done() for f in futs)
    finally:
        driver.close()


@pytest.mark.parametrize("transport", ["subprocess"])
def test_async_flushes_pipelined_head_in_same_frame(transport):
    """run_batch_async carries queued pipelined writes ahead of its ops
    in the SAME frame — program order is preserved and the head's
    results are not leaked into the future's value."""
    twin = make_twin(KEY, B, K, MODEL, m=M, n=N, drift=DRIFT)
    twin.advance(1.0)
    ref = np.asarray(twin.forward(jnp.ones((2, K))))

    driver = _mk(transport)
    try:
        frames0 = driver._rpc_count
        driver.advance(1.0)                       # queued client-side
        fut = driver.run_batch_async([("forward", dict(x=jnp.ones((2, K))))])
        assert driver._rpc_count == frames0 + 1   # ONE frame, head included
        ys = fut.result(timeout=60)
        assert len(ys) == 1                       # head result not leaked
        np.testing.assert_array_equal(np.asarray(ys[0]), ref)
    finally:
        driver.close()


# ---------------------------------------------------------------------------
# bugfix regressions
# ---------------------------------------------------------------------------

def _fake_python(tmp_path, body):
    """An executable that stands in for the server interpreter."""
    script = tmp_path / "fake-python"
    script.write_text("#!/bin/sh\n" + body)
    script.chmod(script.stat().st_mode | stat.S_IXUSR)
    return str(script)


def _spy_child_resources(monkeypatch):
    """Record the Popen children and stderr spool files SocketDriver
    creates, so a failed construction can be audited for leaks."""
    import subprocess as sp
    import tempfile
    from repro.hw import socket_driver as sd_mod

    procs, spools = [], []
    real_popen, real_ntf = sp.Popen, tempfile.NamedTemporaryFile

    def spy_popen(*a, **kw):
        p = real_popen(*a, **kw)
        procs.append(p)
        return p

    def spy_ntf(*a, **kw):
        f = real_ntf(*a, **kw)
        spools.append(f.name)
        return f

    monkeypatch.setattr(sd_mod.subprocess, "Popen", spy_popen)
    monkeypatch.setattr(sd_mod.tempfile, "NamedTemporaryFile", spy_ntf)
    return procs, spools


def test_socket_ctor_announce_timeout_reaps_child_and_spool(
        tmp_path, monkeypatch):
    """Regression: a child that never announces used to block
    construction forever on readline(); killing that, the half-built
    driver used to leak the child process and the stderr spool."""
    procs, spools = _spy_child_resources(monkeypatch)
    fake = _fake_python(tmp_path, "sleep 30\n")
    t0 = time.monotonic()
    with pytest.raises(ProtocolError, match="did not announce"):
        SocketDriver(KEY, B, K, MODEL, m=M, n=N, drift=DRIFT,
                     python=fake, connect_timeout=0.5)
    assert time.monotonic() - t0 < 10             # bounded, not forever
    assert len(procs) == 1 and len(spools) == 1
    assert procs[0].poll() is not None            # child reaped
    assert not os.path.exists(spools[0])          # spool unlinked


def test_socket_ctor_child_death_fails_fast_without_leaks(
        tmp_path, monkeypatch):
    procs, spools = _spy_child_resources(monkeypatch)
    fake = _fake_python(tmp_path, "echo oops >&2\nexit 1\n")
    with pytest.raises(ProtocolError, match="exited before announcing"):
        SocketDriver(KEY, B, K, MODEL, m=M, n=N, drift=DRIFT,
                     python=fake, connect_timeout=10.0)
    assert procs[0].poll() is not None
    assert not os.path.exists(spools[0])


def test_socket_daemon_survives_poison_session(monkeypatch):
    """Regression: a non-OSError escaping one session used to kill the
    accept loop — one hostile/unlucky client took the daemon down for
    everyone.  Now the session is contained, logged, counted, and the
    next client gets a full session."""
    calls = {"n": 0}
    real_serve = server_mod.serve

    def poisoned(fin, fout):
        calls["n"] += 1
        if calls["n"] == 1:
            raise MemoryError("poison session")
        return real_serve(fin, fout)

    monkeypatch.setattr(server_mod, "serve", poisoned)
    port, t = _inprocess_server(sessions=2)
    with pytest.raises((ProtocolError, RuntimeError, OSError)):
        _session_results(port)                    # session 1: poisoned
    got = _session_results(port)                  # session 2: full session
    assert got[0].shape == (B, 4, K)
    t.join(timeout=30)
    assert not t.is_alive()                       # drained after 2 sessions
    assert calls["n"] == 2


def test_frame_limit_counts_bytes_not_characters(monkeypatch):
    """Regression: the JSON-line limit was enforced on the *string*
    length, so a peer's multi-byte UTF-8 slipped past the byte ceiling
    (our own encoder escapes to ASCII, but the wire accepts any valid
    JSON — recv must bound what it buffers in BYTES)."""
    line = '{"id":1,"op":"x","kw":{"pad":"' + "é" * 40 + '"}}\n'
    data = line.encode("utf-8")
    assert len(line) < len(data)                  # multi-byte payload
    limit = len(line) + 5                         # chars fit, bytes don't
    assert limit < len(data)

    # generous ceiling: the frame parses fine
    assert recv(io.BytesIO(data),
                max_bytes=len(data))["kw"]["pad"] == "é" * 40
    # byte-exact ceiling: rejected even though the CHARACTER count fits
    with pytest.raises(ProtocolError, match="oversized"):
        recv(io.BytesIO(data), max_bytes=limit)

    # send side: the byte count is checked BEFORE anything is written
    monkeypatch.setattr(protocol_mod, "MAX_FRAME_BYTES", 16)
    buf = io.BytesIO()
    with pytest.raises(ProtocolError, match="oversized"):
        send(buf, dict(id=1, op="x", kw={"pad": "a" * 64}))
    assert buf.getvalue() == b""
    buf = io.BytesIO()
    with pytest.raises(ProtocolError, match="oversized"):
        send(buf, dict(id=1, op="x",
                       kw=encode(dict(a=np.zeros(64, np.float32)),
                                 binary=True)), binary=True)
    assert buf.getvalue() == b""


@pytest.mark.parametrize("transport", STREAM_TRANSPORTS)
def test_unsafe_twin_capability_cache_dies_with_the_stream(transport):
    """Regression: the one-time unsafe/* capability probe was cached
    past close(), so a dead stream raised ProtocolError from deep
    inside a RemoteTwinHandle instead of TwinUnavailable up front."""
    driver = _mk(transport)
    try:
        assert driver.unsafe_twin().bias_deviation() >= 0.0
    finally:
        driver.close()
    assert driver._twin_verified is False
    with pytest.raises(TwinUnavailable):
        driver.unsafe_twin()


# ---------------------------------------------------------------------------
# fleet async plumbing
# ---------------------------------------------------------------------------

def test_fleet_serve_pass_async_matches_sync():
    """serve_pass_async ≡ serve_pass: same results, same counters."""
    from repro.runtime.fleet import RuntimeConfig, make_chip, FleetRouter

    cfg = RuntimeConfig(k=K, probe_every=10)
    rng = np.random.default_rng(11)
    w = [jnp.asarray(rng.standard_normal((M, N)) * 0.3, jnp.float32),
         jnp.asarray(rng.standard_normal((M, N)) * 0.3, jnp.float32)]
    xs = [jnp.asarray(rng.standard_normal((2, N)), jnp.float32)
          for _ in range(2)]
    items = list(enumerate(xs))

    chip_a = make_chip(jax.random.PRNGKey(3), 0, w, cfg)
    chip_b = make_chip(jax.random.PRNGKey(3), 0, w, cfg)
    router_a = FleetRouter([chip_a], cfg, seed=0)
    router_b = FleetRouter([chip_b], cfg, seed=0)

    ys_sync = router_a.serve_pass(chip_a, items)
    ys_async = router_b.serve_pass_async(chip_b, items).result()
    for a, b in zip(ys_sync, ys_async):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert chip_a.served == chip_b.served == len(items)
    assert [t.served for t in chip_a.tenants] == \
        [t.served for t in chip_b.tenants]


def test_protocol_constants():
    assert PROTOCOL_VERSION == 4
    assert SUPPORTED_VERSIONS == (3, 4)
