"""Identity Calibration (paper §3.2, Fig. 4, Table 4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.noise import NoiseModel
from repro.core.calibration import (calibrate_identity, identity_mse,
                                    calibration_sigma)
from repro.hw.device import sample_device
from repro.optim.zo import ZOConfig


def test_calibration_sigma_probes_distinct():
    sigs = calibration_sigma(9, n_probes=3)
    assert sigs.shape == (3, 9)
    # all probes strictly positive and mutually distinct orderings
    assert (np.asarray(sigs) > 0).all()
    assert not np.allclose(np.asarray(sigs[0]), np.asarray(sigs[1]))


@pytest.mark.slow
def test_ic_converges_k9():
    """Default IC reaches the paper's MSE regime (Table 4: 0.013 at k=9;
    we accept < 0.06 for the CI-budget step count)."""
    model = NoiseModel()
    res = calibrate_identity(jax.random.PRNGKey(0), n_blocks=4, k=9,
                             model=model)
    mse = (float(np.asarray(res.mse_u).mean())
           + float(np.asarray(res.mse_v).mean())) / 2
    assert mse < 0.06, mse
    # realized matrices are near sign-flip identities: |diag| ≈ 1
    dmag = np.abs(np.diagonal(np.asarray(res.u), axis1=-2, axis2=-1))
    assert dmag.mean() > 0.85


def test_ic_fast_improves_loss():
    """Short-budget IC strictly improves the surrogate loss."""
    model = NoiseModel()
    cfg = ZOConfig(steps=300, inner=72, delta0=0.5, decay=1.05)
    res = calibrate_identity(jax.random.PRNGKey(1), n_blocks=2, k=6,
                             model=model, cfg=cfg, restarts=2)
    h = np.asarray(res.history)
    assert (h[:, -1] < h[:, 0]).all()
    assert float(np.asarray(res.loss).mean()) < float(h[:, 0].mean())


def test_device_realization_reproducible():
    model = NoiseModel()
    d1 = sample_device(jax.random.PRNGKey(5), (3,), 9, model)
    d2 = sample_device(jax.random.PRNGKey(5), (3,), 9, model)
    np.testing.assert_array_equal(np.asarray(d1.noise_u.bias),
                                  np.asarray(d2.noise_u.bias))
    assert set(np.unique(np.asarray(d1.d_u))) <= {-1.0, 1.0}


def test_post_ic_frame_removes_bias():
    m = NoiseModel()
    assert m.phase_bias and m.post_ic().phase_bias is False
    assert m.post_ic().gamma_std == m.gamma_std   # Γ/Ω/Q remain


def test_identity_mse_metric():
    eye = jnp.eye(5)[None]
    assert float(identity_mse(eye)[0]) == 0.0
    flip = jnp.diag(jnp.asarray([1.0, -1, 1, -1, 1]))[None]
    assert float(identity_mse(flip)[0]) == 0.0     # sign flips are free
