"""Paper CNN/MLP models: im2col correctness, shapes, sampled training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.cnn import (MLP_VOWEL, CNN_S, CNN_L, VGG8, init_cnn,
                              cnn_forward, build_cnn_train_step, _im2col)
from repro.core.sparsity import SparsityConfig


def test_im2col_matches_conv():
    """PTC-conv (im2col + linear) ≡ lax.conv with the same kernel."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 3, 5)), jnp.float32)  # HWIO
    ref = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    cols = _im2col(x, 3, 1, "SAME")                   # (B, H, W, C·K·K)
    # dilated_patches orders features as (C, KH, KW) — reorder w to match
    w_flat = jnp.transpose(w, (2, 0, 1, 3)).reshape(-1, 5)
    out = cols.reshape(-1, cols.shape[-1]) @ w_flat
    np.testing.assert_allclose(np.asarray(out.reshape(ref.shape)),
                               np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("cfg,bsz", [(MLP_VOWEL, 16), (CNN_S, 4),
                                     (CNN_L, 2), (VGG8, 2)])
def test_forward_shapes(cfg, bsz):
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (bsz,) + cfg.in_shape)
    y = cnn_forward(params, cfg, x)
    assert y.shape == (bsz, cfg.n_classes)
    assert bool(jnp.isfinite(y).all())


def test_sampled_training_step_runs_and_learns():
    from repro.data import synthetic_vision
    from repro.optim.optimizers import AdamWConfig, init_opt_state, \
        apply_updates
    cfg = MLP_VOWEL
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    scfg = SparsityConfig(alpha_w=0.6, alpha_c=0.6)
    ts = build_cnn_train_step(cfg, scfg)
    d = synthetic_vision(0, 0, 128, (8,), 4, noise=0.5)
    batch = {"x": jnp.asarray(d["x"]), "y": jnp.asarray(d["y"])}
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=5e-3)
    losses = []
    step = jax.jit(ts)
    for i in range(40):
        loss, grads = step(params, batch, jax.random.PRNGKey(i))
        params, opt, _ = apply_updates(params, grads, opt, ocfg)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])   # learns
