"""Property-based runtime invariants (hypothesis, or the seeded shim).

Three invariants the closed loop's correctness rests on, exercised over
randomized inputs rather than single examples:

* the router NEVER dispatches to a non-``routable`` chip, whatever the
  fleet's status/health configuration;
* the monitor's per-tenant hysteresis is monotone in the probe
  distance — a larger estimate can never produce a *less* alarmed
  state than a smaller one from the same starting point;
* partial recalibration is surgical — the untouched tenants' Σ banks
  and commanded phases are bit-identical across the job, for any
  tenant layout and any choice of repaired tenant.
"""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev extra; fall back to the shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.noise import DEFAULT_NOISE
from repro.hw.drift import DriftConfig
from repro.runtime.monitor import (MonitorConfig, HealthState, update_health,
                                   clear_health)
from repro.runtime.recalibrate import RecalConfig, recalibrate
from repro.runtime.fleet import (RuntimeConfig, FleetRouter, make_chip,
                                 make_fleet, HEALTHY, DEGRADED,
                                 RECALIBRATING)

K = 3
POST_IC = DEFAULT_NOISE.post_ic()
STATUSES = [HEALTHY, DEGRADED, RECALIBRATING]


def _cfg(**kw):
    defaults = dict(
        k=K, noise=POST_IC,
        drift=DriftConfig(sigma_phase=0.04, theta=0.01),
        monitor=MonitorConfig(n_probes=6, alarm_threshold=0.05,
                              clear_threshold=0.03, consecutive=2),
        recal=RecalConfig(zo_steps=30, delta0=0.05),
        probe_every=5, recal_latency=2, max_concurrent_recals=1)
    defaults.update(kw)
    return RuntimeConfig(**defaults)


def _weights(seed: int, n_tenants: int, dim: int = 6) -> list[jax.Array]:
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((dim, dim)) / np.sqrt(dim),
                        jnp.float32) for _ in range(n_tenants)]


# ---------------------------------------------------------------------------
# invariant 1: the router never dispatches to a non-routable chip
# ---------------------------------------------------------------------------

_FLEET = make_fleet(jax.random.PRNGKey(0), 4, _weights(0, 2), _cfg())


@settings(max_examples=40, deadline=None)
@given(s0=st.sampled_from(STATUSES), s1=st.sampled_from(STATUSES),
       s2=st.sampled_from(STATUSES), s3=st.sampled_from(STATUSES),
       d0=st.floats(0.0, 0.5), d1=st.floats(0.0, 0.5),
       now=st.integers(0, 200), tenant=st.integers(0, 1),
       policy=st.sampled_from(["drift_aware", "least_served"]))
def test_dispatch_only_routable(s0, s1, s2, s3, d0, d1, now, tenant, policy):
    router = FleetRouter(_FLEET, _cfg(router_policy=policy), seed=1)
    router.tick_count = now
    for chip, status in zip(_FLEET, (s0, s1, s2, s3)):
        chip.status = status
        chip.tenants[0].health.distance = d0
        chip.tenants[1].health.distance = d1
    try:
        got = router.dispatch(tenant)
        if all(s == RECALIBRATING for s in (s0, s1, s2, s3)):
            assert got is None
        else:
            assert got is not None and got.routable
            assert got.status != RECALIBRATING
            # HEALTHY pool is strictly preferred over DEGRADED
            if any(s == HEALTHY for s in (s0, s1, s2, s3)):
                assert got.status == HEALTHY
    finally:
        for chip in _FLEET:
            chip.status = HEALTHY


# ---------------------------------------------------------------------------
# invariant 2: hysteresis is monotone in the probe distance
# ---------------------------------------------------------------------------

_MON = MonitorConfig(alarm_threshold=0.05, clear_threshold=0.02,
                     consecutive=2)


@settings(max_examples=60, deadline=None)
@given(lo=st.floats(0.0, 0.4), delta=st.floats(0.0, 0.4),
       strikes=st.integers(0, 3), alarmed=st.sampled_from([False, True]))
def test_update_health_monotone_in_distance(lo, delta, strikes, alarmed):
    hi = lo + delta
    h0 = HealthState(distance=0.0, strikes=strikes, alarmed=alarmed)
    h_lo = update_health(h0, lo, _MON)
    h_hi = update_health(h0, hi, _MON)
    assert h_hi.strikes >= h_lo.strikes
    assert h_hi.alarmed >= h_lo.alarmed
    # and monotone along sequences: element-wise larger probe streams
    # never yield a less-alarmed terminal state
    a, b = h0, h0
    for _ in range(3):
        a = update_health(a, lo, _MON)
        b = update_health(b, hi, _MON)
        assert b.strikes >= a.strikes
        assert b.alarmed >= a.alarmed


@settings(max_examples=60, deadline=None)
@given(lo=st.floats(0.0, 0.4), delta=st.floats(0.0, 0.4),
       strikes=st.integers(0, 3))
def test_clear_health_monotone_in_distance(lo, delta, strikes):
    hi = lo + delta
    h0 = HealthState(distance=0.9, strikes=strikes, alarmed=True)
    c_lo = clear_health(h0, lo, _MON)
    c_hi = clear_health(h0, hi, _MON)
    assert c_hi.alarmed >= c_lo.alarmed
    # clearing obeys the LOWER threshold exactly
    assert c_lo.alarmed == (lo >= _MON.clear_threshold)


# ---------------------------------------------------------------------------
# invariant 3: partial recal never touches co-tenant Σ banks / phases
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(n_tenants=st.integers(2, 3), victim=st.integers(0, 2),
       seed=st.integers(0, 1000), ticks=st.integers(10, 50))
def test_partial_recal_leaves_cotenants_bit_identical(n_tenants, victim,
                                                      seed, ticks):
    victim = victim % n_tenants
    cfg = _cfg()
    chip = make_chip(jax.random.PRNGKey(seed), 0,
                     _weights(seed, n_tenants), cfg)
    for _ in range(ticks):
        chip.driver.advance(1.0)
    ten = chip.tenants[victim]
    sig0 = np.asarray(chip.driver.read_sigma())
    pu0, pv0 = map(np.asarray, chip.driver.read_phases())
    recalibrate(jax.random.PRNGKey(seed + 1), chip.driver, ten.w_blocks,
                cfg.recal, block_range=ten.block_range)
    sig1 = np.asarray(chip.driver.read_sigma())
    pu1, pv1 = map(np.asarray, chip.driver.read_phases())
    start, stop = ten.block_range
    outside = np.r_[0:start, stop:chip.driver.n_blocks]
    np.testing.assert_array_equal(sig0[outside], sig1[outside])
    np.testing.assert_array_equal(pu0[outside], pu1[outside])
    np.testing.assert_array_equal(pv0[outside], pv1[outside])
    # ... while the repaired tenant's state DID move (the job is real)
    assert not np.array_equal(pu0[start:stop], pu1[start:stop])
