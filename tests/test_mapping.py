"""Parallel Mapping + OSP (paper §3.3, Claim 1, Fig. 5)."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev extra; shim keeps properties running
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.noise import NoiseModel, IDEAL
from repro.core.mapping import parallel_map, osp, matrix_distance
from repro.core import unitary as un


@settings(max_examples=20, deadline=None)
@given(k=st.integers(2, 10), seed=st.integers(0, 500))
def test_osp_optimality_property(k, seed):
    """Claim 1: Σ_opt = diag(U* W V) minimizes ‖UΣV* − W‖ over diagonals —
    any perturbation of Σ_opt is no better."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(un.random_orthogonal(seed, k))
    v = jnp.asarray(un.random_orthogonal(seed + 1, k))
    w = jnp.asarray(rng.standard_normal((k, k)))
    s = osp(u, v, w)
    base = float(jnp.sum(((u * s) @ v - w) ** 2))
    for trial in range(5):
        ds = 0.1 * rng.standard_normal(k)
        pert = float(jnp.sum(((u * (s + ds)) @ v - w) ** 2))
        assert pert >= base - 1e-9


@settings(max_examples=20, deadline=None)
@given(k=st.integers(2, 10), seed=st.integers(0, 500))
def test_osp_sign_flip_invariance(k, seed):
    """Sign flips Ĩ on U columns / V* rows cancel on the OSP diagonal:
    the projected weight U Σ V* is invariant (the paper's on-chip
    reciprocity argument)."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(un.random_orthogonal(seed, k))
    v = jnp.asarray(un.random_orthogonal(seed + 1, k))
    w = jnp.asarray(rng.standard_normal((k, k)))
    flips = jnp.asarray(rng.choice([-1.0, 1.0], k))
    u2 = u * flips[None, :]          # flip columns of U
    v2 = v * flips[:, None]          # flip the SAME rows of V*
    s1 = osp(u, v, w)
    s2 = osp(u2, v2, w)
    w1 = (u * s1) @ v
    w2 = (u2 * s2) @ v2
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-9)


def test_parallel_map_ideal_is_exact():
    """With no noise, commanded-SVD mapping is exact (error ≈ 0)."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((18, 18)) * 0.3, jnp.float32)
    pm = parallel_map(jax.random.PRNGKey(0), w, 9, IDEAL, run_zo=False)
    assert float(np.asarray(pm.err_osp).mean()) < 1e-6


def test_parallel_map_noisy_osp_improves():
    """Post-IC noise frame: OSP error ≤ ZO error ≤ ~init error, and the
    final mapping error is small (paper Fig. 5 / Table 3 regime)."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((18, 18)) * 0.3, jnp.float32)
    model = NoiseModel().post_ic()
    pm = parallel_map(jax.random.PRNGKey(1), w, 9, model)
    e_init = float(np.asarray(pm.err_init).mean())
    e_zo = float(np.asarray(pm.err_zo).mean())
    e_osp = float(np.asarray(pm.err_osp).mean())
    assert e_zo <= e_init + 1e-6
    assert e_osp <= e_zo + 1e-6
    assert e_osp < 0.05          # k=9 noise floor (Table 3: rel err ~0.03)


def test_mapped_params_reproduce_weight():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((18, 27)) * 0.3, jnp.float32)
    model = NoiseModel().post_ic()
    pm = parallel_map(jax.random.PRNGKey(2), w, 9, model, run_zo=False)
    from repro.core.ptc import compose_weight, unblockize
    w_hat = unblockize(compose_weight(pm.params), 18, 27)
    dist = float(matrix_distance(w_hat, w))
    assert dist < 0.05
