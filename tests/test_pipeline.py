"""GPipe pipeline parallelism over the pod axis: exactness vs the
standard forward, gradient flow (subprocess: 8 forced devices)."""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import jax, jax.numpy as jnp, dataclasses
from repro.configs import smoke_config
from repro.models.lm import init_model, forward, cross_entropy
from repro.launch.pipeline import build_pp_loss

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = dataclasses.replace(smoke_config("olmo-1b"), n_layers=4, remat=True)
params = init_model(jax.random.PRNGKey(0), cfg)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                      cfg.vocab),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                      cfg.vocab)}
logits, _ = forward(params, cfg, batch)
ref = float(cross_entropy(logits, batch["labels"]))
pp = build_pp_loss(cfg, n_stages=2, n_micro=2)
with mesh:
    got = float(jax.jit(lambda p, b: pp(p, b, mesh))(params, batch))
    assert abs(ref - got) < 1e-5, (ref, got)
    if hasattr(jax, "shard_map"):
        # grad-of-shard_map transpose is broken on jax 0.4.x (scalar
        # residuals that vary over manual axes fail the spec check both
        # with and without check_rep); forward equivalence above still
        # runs everywhere via repro.compat's full-manual fallback.
        g = jax.jit(jax.grad(lambda p, b: pp(p, b, mesh)))(params, batch)
        gref = jax.grad(lambda p: cross_entropy(
            forward(p, cfg, {"tokens": batch["tokens"]})[0],
            batch["labels"]))(params)
        a = g["pos0"]["attn"]["wq"]["s"]
        b = gref["pos0"]["attn"]["wq"]["s"]
        assert float(jnp.abs(a - b).max()) < 1e-5
    else:
        print("PP_GRAD_SKIPPED(jax<0.5)")
print("PP_OK")
"""


@pytest.mark.slow
def test_pp_matches_standard_forward_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "PP_OK" in r.stdout, r.stderr[-2000:]
