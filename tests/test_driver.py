"""PhotonicDriver conformance suite.

Parametrized over the three shipped transports (in-process
``TwinDriver``, JSON-over-pipe ``SubprocessDriver``, and TCP
``SocketDriver``): a scripted control-plane session must produce
*bit-identical* results on all — same physics, same seeds, same
backend — and the PTC-call meter must charge exactly the Appendix-G
costs (including ops shipped inside a v3 ``batch`` frame, which are
metered individually).  The tenant-addressable session exercises every
``block_range``-scoped op (v2 protocol surface) the same way, including
scoped-write/whole-read consistency.  Plus the guard test: control-plane
modules (``repro.runtime``, ``core.calibration``, ``core.mapping``)
must never touch twin internals except through the audited
``unsafe_twin()`` escape hatch.

(Protocol v3 framing — batch round-trips, pipelining flush order,
malformed/oversized-frame rejection — is covered by
``tests/test_protocol_v3.py``.)
"""

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.noise import DEFAULT_NOISE
from repro.core.calibration import calibrate_identity
from repro.core.mapping import parallel_map
from repro.optim.zo import ZOConfig
from repro.hw import make_driver, make_twin, TwinUnavailable
from repro.hw.drift import DriftConfig
from repro.hw.driver import PhotonicDriver
from repro.runtime.recalibrate import RecalConfig, recalibrate

K = 3
M = N = 6
B = (M // K) * (N // K)          # 4 blocks
MODEL = DEFAULT_NOISE.post_ic()
DRIFT = DriftConfig(sigma_phase=0.03, theta=0.01)
TRANSPORTS = ["twin", "subprocess", "socket"]
STREAM_TRANSPORTS = ["subprocess", "socket"]

KEY = jax.random.PRNGKey(42)


def _mk(transport):
    return make_driver(transport, KEY, B, K, MODEL, m=M, n=N, drift=DRIFT)


def _reference_twin():
    return make_twin(KEY, B, K, MODEL, m=M, n=N, drift=DRIFT)


def _blocks(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((B, K, K)) * 0.4, jnp.float32)


def _session(driver) -> dict:
    """One scripted control-plane session exercising every ABC op."""
    rng = np.random.default_rng(7)
    t = driver.read_phases()[0].shape[-1]
    pu = jnp.asarray(rng.uniform(0, 1, (B, t)), jnp.float32)
    pv = jnp.asarray(rng.uniform(0, 1, (B, t)), jnp.float32)
    sg = jnp.asarray(rng.uniform(0.5, 1.5, (B, K)), jnp.float32)
    du = jnp.asarray(rng.choice([-1.0, 1.0], (B, K)), jnp.float32)
    dv = jnp.asarray(rng.choice([-1.0, 1.0], (B, K)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((5, K)), jnp.float32)
    xl = jnp.asarray(rng.standard_normal((3, N)), jnp.float32)
    w = _blocks(1)

    out = {}
    driver.write_signs(du, dv)
    driver.write_phases(pu, pv)
    driver.write_sigma(sg)
    out["phi_u"], out["phi_v"] = driver.read_phases()
    out["sigma"] = driver.read_sigma()
    out["fwd"] = driver.forward(x)
    out["layer"] = driver.forward_layer(xl)
    res = driver.zo_refine(w, jax.random.PRNGKey(3),
                           ZOConfig(steps=30, inner=12, delta0=0.1,
                                    decay=1.05))
    out["zo_phi"], out["zo_loss"] = res.phi, res.loss
    out["u"], out["v"] = driver.readback_bases()
    for _ in range(5):
        driver.advance(1.0)
    out["fwd_drifted"] = driver.forward(x)
    out["true_d"] = driver.unsafe_twin().true_mapping_distance(w)
    out["stats"] = driver.stats.as_dict()
    return out


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_scripted_session_matches_reference_twin(transport):
    """Every op's result is bit-identical to the in-process twin run
    from the same construction seed (float32 survives the pipe exactly;
    jobs execute the same code on the same backend)."""
    driver = _mk(transport)
    try:
        got = _session(driver)
    finally:
        driver.close()
    ref = _session(_reference_twin())
    for name in ("phi_u", "phi_v", "sigma", "fwd", "layer", "zo_phi",
                 "zo_loss", "u", "v", "fwd_drifted"):
        np.testing.assert_array_equal(np.asarray(ref[name]),
                                      np.asarray(got[name]), err_msg=name)
    assert got["true_d"] == ref["true_d"]
    assert got["stats"] == ref["stats"]


def _tenant_session(driver) -> dict:
    """A scripted MULTI-TENANT control-plane session: two tenants on one
    chip (blocks [0, 4) and [4, 6) when B=6... here B=4 → [0, 3)/[3, 4)),
    exercising every block_range-scoped op of the v2 surface."""
    rng = np.random.default_rng(11)
    t = driver.read_phases()[0].shape[-1]
    br0, br1 = (0, 3), (3, B)
    b0, b1 = 3, B - 3
    out = {}
    # scoped writes: tenant 0 then tenant 1, different states
    driver.write_signs(
        jnp.asarray(rng.choice([-1.0, 1.0], (b0, K)), jnp.float32),
        jnp.asarray(rng.choice([-1.0, 1.0], (b0, K)), jnp.float32),
        block_range=br0)
    driver.write_phases(
        jnp.asarray(rng.uniform(0, 1, (b0, t)), jnp.float32),
        jnp.asarray(rng.uniform(0, 1, (b0, t)), jnp.float32),
        block_range=br0)
    driver.write_sigma(
        jnp.asarray(rng.uniform(0.5, 1.5, (b0, K)), jnp.float32),
        block_range=br0)
    driver.write_phases(
        jnp.asarray(rng.uniform(0, 1, (b1, t)), jnp.float32),
        jnp.asarray(rng.uniform(0, 1, (b1, t)), jnp.float32),
        block_range=br1)
    driver.write_sigma(
        jnp.asarray(rng.uniform(0.5, 1.5, (b1, K)), jnp.float32),
        block_range=br1)
    # whole-chip reads see the per-tenant writes landed in place
    out["phi_u"], out["phi_v"] = driver.read_phases()
    out["sigma"] = driver.read_sigma()
    # scoped probes + scoped serve path
    x = jnp.asarray(rng.standard_normal((4, K)), jnp.float32)
    out["fwd0"] = driver.forward(x, block_range=br0)
    out["fwd1"] = driver.forward(x, block_range=br1)
    xl = jnp.asarray(rng.standard_normal((2, b1 * K)), jnp.float32)
    out["layer1"] = driver.forward_layer(xl, block_range=br1, out_dim=K)
    # scoped in-situ job (the partial-recal primitive): tenant 0 only
    w0 = jnp.asarray(rng.standard_normal((b0, K, K)) * 0.4, jnp.float32)
    res = driver.zo_refine(w0, jax.random.PRNGKey(5),
                           ZOConfig(steps=20, inner=12, delta0=0.1,
                                    decay=1.05), block_range=br0)
    out["zo_phi"] = res.phi
    out["u1"], out["v1"] = driver.readback_bases(block_range=br1)
    out["u0_cols"], _ = driver.readback_bases(cols=[0, 2], block_range=br0)
    for _ in range(4):
        driver.advance(1.0)
    out["fwd0_drifted"] = driver.forward(x, block_range=br0)
    out["true0"] = driver.unsafe_twin().true_mapping_distance(w0, br0)
    out["stats"] = driver.stats.as_dict()
    return out


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_tenant_session_matches_reference_twin(transport):
    """Every tenant-scoped op is bit-identical across transports (the
    v2 wire protocol forwards block ranges losslessly)."""
    driver = _mk(transport)
    try:
        got = _tenant_session(driver)
    finally:
        driver.close()
    ref = _tenant_session(_reference_twin())
    for name in ("phi_u", "phi_v", "sigma", "fwd0", "fwd1", "layer1",
                 "zo_phi", "u1", "v1", "u0_cols", "fwd0_drifted"):
        np.testing.assert_array_equal(np.asarray(ref[name]),
                                      np.asarray(got[name]), err_msg=name)
    assert got["true0"] == ref["true0"]
    assert got["stats"] == ref["stats"]


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_tenant_scoped_ptc_accounting(transport):
    """Scoped ops charge for the tenant's block count, not the chip's."""
    driver = _mk(transport)
    try:
        driver.reset_stats()
        driver.forward(jnp.ones((5, K)), block_range=(0, 3))
        assert driver.stats.probe == 3 * 5
        driver.readback_bases(block_range=(3, B))
        assert driver.stats.readback == 2 * (B - 3) * K
        driver.forward_layer(jnp.ones((7, K)), block_range=(3, B),
                             out_dim=K)
        assert driver.stats.serve == (B - 3) * 7
        steps = 5
        driver.zo_refine(_blocks()[:3], jax.random.PRNGKey(0),
                         ZOConfig(steps=steps, inner=6, delta0=0.1,
                                  decay=1.05), block_range=(0, 3))
        assert driver.stats.search == steps * 2 * 3 * K
    finally:
        driver.close()


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_block_range_bounds_rejected(transport):
    """Out-of-bounds tenant ranges are a hard error on every transport."""
    driver = _mk(transport)
    try:
        for bad in ((0, B + 1), (-1, 2), (2, 2), (3, 1)):
            with pytest.raises((ValueError, RuntimeError)):
                driver.forward(jnp.ones((2, K)), block_range=bad)
    finally:
        driver.close()


@pytest.mark.parametrize("peer_version", [1, 2])
def test_protocol_version_handshake_rejects_mismatch(peer_version):
    """A v1 or v2 client is refused by the server (which speaks v3 and
    v4) — no silent fallback onto a surface it would misread (a v2 peer
    would treat a ``batch`` frame as an unknown op mid-session)."""
    import io
    from repro.hw.protocol import encode, PROTOCOL_VERSION, SUPPORTED_VERSIONS
    from repro.hw.server import serve

    assert PROTOCOL_VERSION == 4
    assert peer_version not in SUPPORTED_VERSIONS
    req = {"id": 1, "op": "init", "kw": encode(dict(
        v=peer_version, key=np.zeros(2, np.uint32), n_blocks=B, k=K,
        model=dict(), drift=None))}
    import json as _json
    fin = io.BytesIO((_json.dumps(req) + "\n").encode())
    fout = io.BytesIO()
    serve(fin, fout)
    resp = _json.loads(fout.getvalue().splitlines()[0])
    assert resp["ok"] is False
    assert "protocol mismatch" in resp["error"]


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_batch_ops_metered_individually(transport):
    """PTC-call metering counts every op INSIDE a batch frame at its
    full Appendix-G charge — one batch ≠ one PTC call (regression: a
    transport must not meter the frame instead of its ops)."""
    driver = _mk(transport)
    try:
        driver.reset_stats()
        x = jnp.ones((5, K))
        _ = driver.run_batch([
            ("forward", dict(x=x)),
            ("forward", dict(x=x)),
            ("forward", dict(x=x, block_range=(0, 3))),
            ("readback_bases", {}),
        ])
        s = driver.stats
        assert s.probe == 2 * B * 5 + 3 * 5       # each forward charged
        assert s.readback == 2 * B * K
    finally:
        driver.close()


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_ic_pm_recal_identical_across_transports(transport):
    """The three control-plane flows (IC, PM, closed-loop recal) return
    identical results over any transport."""
    # IC on a fresh device (driver-generic entry point)
    ic_cfg = ZOConfig(steps=40, inner=12, delta0=0.5, decay=1.05)
    d1 = _mk(transport)
    try:
        ic = calibrate_identity(KEY, B, K, MODEL, cfg=ic_cfg, restarts=2,
                                driver=d1)
    finally:
        d1.close()
    ic_ref = calibrate_identity(KEY, B, K, MODEL, cfg=ic_cfg, restarts=2,
                                driver=_reference_twin())
    np.testing.assert_array_equal(np.asarray(ic_ref.phi_u),
                                  np.asarray(ic.phi_u))
    np.testing.assert_array_equal(np.asarray(ic_ref.mse_u),
                                  np.asarray(ic.mse_u))

    # PM deployment + drift + recalibration on the same chip
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((M, N)) / np.sqrt(M), jnp.float32)
    pm_cfg = ZOConfig(steps=30, inner=12, delta0=0.2, decay=1.05)

    def flow(driver):
        pm = parallel_map(KEY, w, K, MODEL, cfg=pm_cfg, driver=driver)
        for _ in range(30):
            driver.advance(1.0)
        rc = recalibrate(jax.random.PRNGKey(9), driver, _blocks(1),
                         RecalConfig(zo_steps=40, delta0=0.05))
        return pm, rc

    d2 = _mk(transport)
    try:
        pm, rc = flow(d2)
    finally:
        d2.close()
    pm_ref, rc_ref = flow(_reference_twin())
    np.testing.assert_array_equal(np.asarray(pm_ref.err_osp),
                                  np.asarray(pm.err_osp))
    np.testing.assert_array_equal(np.asarray(pm_ref.phi_u),
                                  np.asarray(pm.phi_u))
    np.testing.assert_array_equal(np.asarray(rc_ref.phi),
                                  np.asarray(rc.phi))
    np.testing.assert_array_equal(np.asarray(rc_ref.sigma),
                                  np.asarray(rc.sigma))
    assert float(rc_ref.dist_after) == float(rc.dist_after)
    assert rc_ref.ptc_calls == rc.ptc_calls


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_ptc_call_accounting(transport):
    """The driver meters exactly the Appendix-G charges per op."""
    driver = _mk(transport)
    try:
        driver.reset_stats()
        assert driver.stats.total == 0.0

        driver.forward(jnp.ones((5, K)))
        assert driver.stats.probe == B * 5           # E_fwd = B·n_cols

        driver.readback_bases()
        assert driver.stats.readback == 2 * B * K    # 2 reciprocal passes

        driver.forward_layer(jnp.ones((7, N)))
        assert driver.stats.serve == B * 7

        steps = 10
        driver.zo_refine(_blocks(), jax.random.PRNGKey(0),
                         ZOConfig(steps=steps, inner=6, delta0=0.1,
                                  decay=1.05))
        assert driver.stats.search == steps * 2 * B * K

        driver.charge("probe", 3.5)                  # controller-side meter
        assert driver.stats.probe == B * 5 + 3.5
        assert driver.stats.total == (B * 5 + 3.5 + 2 * B * K + B * 7
                                      + steps * 2 * B * K)
        driver.reset_stats()
        assert driver.stats.total == 0.0
    finally:
        driver.close()


def test_unsafe_twin_raises_without_twin_backing():
    """A driver not backed by an inspectable twin refuses the hatch."""

    class HardwareDriver(PhotonicDriver):
        k = 3
        kind = "clements"
        n_blocks = 1
        layer_shape = (3, 3)

        def write_phases(self, *a):
            pass

        write_sigma = write_signs = write_phases

        def read_phases(self):
            return None, None

        def read_sigma(self):
            return None

        def forward(self, x, category="probe"):
            return x

        forward_layer = read_sigma

        def readback_bases(self):
            return None, None

        def zo_refine(self, *a, **k):
            raise NotImplementedError

        run_ic = zo_refine

        def advance(self, dt=1.0):
            pass

        stats = property(lambda self: None)

        def charge(self, *a):
            pass

    with pytest.raises(TwinUnavailable):
        HardwareDriver().unsafe_twin()


# ---------------------------------------------------------------------------
# guard: control-plane modules stay on the legal surface
# ---------------------------------------------------------------------------

SRC = Path(__file__).resolve().parents[1] / "src" / "repro"


def test_control_plane_never_imports_twin_internals():
    # The old line-regex guard that lived here grew into the RPL1xx
    # analyzers of repro.analysis (AST-accurate, covers every spelling,
    # audits unsafe_twin call sites).  This is the thin assertion that
    # the whole source tree has zero twin-boundary findings.
    from repro.analysis import run_lint

    assert SRC.is_dir(), "guard scope is empty — layout changed?"
    result = run_lint([str(SRC)], codes=["RPL101", "RPL102", "RPL103"])
    assert not result.errors, result.errors
    offenders = [f.format() for f in result.findings]
    assert not offenders, (
        "control-plane code reached into twin internals outside "
        "unsafe_twin():\n" + "\n".join(offenders))
