"""In-situ subspace gradients: exactness (dense), unbiasedness (sampled),
frozen-basis structure — the paper's Eq. 5 and Appendix D."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ptc import PTCParams, svd_factorize, block_energy
from repro.core.subspace import (ptc_linear, ptc_linear_ref, SubspaceMasks,
                                 sample_masks)
from repro.core.sparsity import SparsityConfig, feedback_mask, column_mask


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(0)
    m, n, k = 36, 27, 9
    w = jnp.asarray(rng.standard_normal((m, n)) * 0.2, jnp.float32)
    params = svd_factorize(w, k)
    x = jnp.asarray(rng.standard_normal((32, n)), jnp.float32)
    dy = jnp.asarray(rng.standard_normal((32, m)), jnp.float32)
    return params, x, dy


@pytest.mark.parametrize("mode", ["blocked", "fused"])
def test_dense_vjp_matches_autodiff(setup, mode):
    params, x, _ = setup

    def f_custom(x, s):
        return jnp.sum(jnp.sin(ptc_linear(
            x, PTCParams(params.u, s, params.v), mode=mode)))

    def f_ref(x, s):
        return jnp.sum(jnp.sin(ptc_linear_ref(
            x, PTCParams(params.u, s, params.v))))

    gx1, gs1 = jax.grad(f_custom, (0, 1))(x, params.s)
    gx2, gs2 = jax.grad(f_ref, (0, 1))(x, params.s)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gs1), np.asarray(gs2), atol=1e-5)


@pytest.mark.parametrize("mode", ["blocked", "fused"])
def test_frozen_bases_get_zero_grads(setup, mode):
    params, x, _ = setup

    def f(u, v):
        return jnp.sum(ptc_linear(x, PTCParams(u, params.s, v), mode=mode))

    gu, gv = jax.grad(f, (0, 1))(params.u, params.v)
    assert float(jnp.abs(gu).max()) == 0.0
    assert float(jnp.abs(gv).max()) == 0.0


@pytest.mark.parametrize("mode", ["blocked", "fused"])
@pytest.mark.parametrize("fb_mode", ["uniform", "btopk"])
def test_sampled_gradients_unbiased(setup, mode, fb_mode):
    """Appendix D: E[sampled grad] == dense grad (exp normalization)."""
    params, x, dy = setup
    cfg = SparsityConfig(alpha_w=0.5, feedback_mode=fb_mode,
                         feedback_norm="exp", alpha_c=0.5, column_norm="exp")
    be = block_energy(params)

    _, vjp = jax.vjp(lambda xx, ss: ptc_linear(
        xx, PTCParams(params.u, ss, params.v), mode=mode), x, params.s)
    dx_true, ds_true = vjp(dy)

    @jax.jit
    def one(key):
        k1, k2 = jax.random.split(key)
        masks = SubspaceMasks(feedback_mask(k1, be, cfg),
                              column_mask(k2, x.shape[0], cfg))
        _, vjp = jax.vjp(lambda xx, ss: ptc_linear(
            xx, PTCParams(params.u, ss, params.v), masks, mode=mode),
            x, params.s)
        return vjp(dy)

    n_mc = 1500 if fb_mode == "uniform" else 600
    accx = jnp.zeros_like(dx_true)
    accs = jnp.zeros_like(ds_true)
    for k in jax.random.split(jax.random.PRNGKey(7), n_mc):
        gx, gs = one(k)
        accx += gx
        accs += gs
    relx = float(jnp.abs(accx / n_mc - dx_true).max()
                 / jnp.abs(dx_true).max())
    rels = float(jnp.abs(accs / n_mc - ds_true).max()
                 / jnp.abs(ds_true).max())
    if fb_mode == "uniform":
        assert relx < 0.12, relx     # exact unbiasedness, MC noise only
        assert rels < 0.12, rels
    else:
        # btopk trades a small bias for variance (guided distribution) —
        # direction must stay well aligned (paper Fig. 8)
        cos = float(jnp.vdot(accx, dx_true)
                    / (jnp.linalg.norm(accx) * jnp.linalg.norm(dx_true)))
        assert cos > 0.98, cos


def test_sampled_gradient_angular_similarity(setup):
    """A single btopk sample aligns better than a uniform sample at equal
    density (the paper's Fig. 8 ordering), on energy-skewed blocks."""
    params, x, dy = setup
    # skew the block energies so importance sampling has signal
    # (explicit f32: test_unitary enables x64 globally in-process)
    s_skew = params.s * jnp.exp(
        2.0 * jax.random.normal(jax.random.PRNGKey(3),
                                (params.s.shape[0], params.s.shape[1], 1))
        ).astype(jnp.float32)
    p2 = PTCParams(params.u, s_skew, params.v)
    be = block_energy(p2)
    _, vjp = jax.vjp(lambda xx: ptc_linear(xx, p2, mode="blocked"), x)
    dx_true = vjp(dy)[0]

    def mean_cos(fb_mode, n=64):
        cfg = SparsityConfig(alpha_w=0.34, feedback_mode=fb_mode,
                             feedback_norm="exp")
        tot = 0.0
        for k in jax.random.split(jax.random.PRNGKey(11), n):
            masks = SubspaceMasks(feedback_mask(k, be, cfg), None)
            _, vjp = jax.vjp(lambda xx: ptc_linear(xx, p2, masks,
                                                   mode="blocked"), x)
            g = vjp(dy)[0]
            tot += float(jnp.vdot(g, dx_true) /
                         (jnp.linalg.norm(g) * jnp.linalg.norm(dx_true)
                          + 1e-12))
        return tot / n

    assert mean_cos("btopk") > mean_cos("uniform") - 0.02


def test_sample_masks_helper(setup):
    params, x, _ = setup
    cfg = SparsityConfig(alpha_w=0.5, alpha_c=0.5)
    masks = sample_masks(jax.random.PRNGKey(0), params, 32, cfg)
    assert masks.feedback.shape == (3, 4)      # (Q, P)
    assert masks.column.shape == (32,)
    dense = sample_masks(jax.random.PRNGKey(0), params, 32,
                         SparsityConfig())
    assert dense.feedback is None and dense.column is None
