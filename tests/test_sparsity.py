"""Multi-level sparsity properties: balance, normalization, rates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev extra; shim keeps properties running
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.sparsity import (SparsityConfig, feedback_mask, column_mask,
                                 smd_keep_iteration, accumulation_depths)


@settings(max_examples=30, deadline=None)
@given(p=st.integers(2, 24), q=st.integers(1, 16),
       alpha=st.floats(0.1, 0.9), seed=st.integers(0, 1000),
       mode=st.sampled_from(["uniform", "btopk"]))
def test_row_balance_property(p, q, alpha, seed, mode):
    """btopk/uniform guarantee EQUAL kept blocks per feedback row — the
    load-balance invariant (paper Fig. 7)."""
    cfg = SparsityConfig(alpha_w=alpha, feedback_mode=mode,
                         feedback_norm="exp")
    energy = jax.random.uniform(jax.random.PRNGKey(seed), (p, q)) + 0.1
    mask = feedback_mask(jax.random.PRNGKey(seed + 1), energy, cfg)
    assert mask.shape == (q, p)
    depths = np.asarray(accumulation_depths(mask))
    keep = max(1, round(alpha * p))
    assert (depths == keep).all()


def test_topk_can_imbalance():
    """Global topk concentrates on high-energy rows (the failure mode
    btopk fixes)."""
    energy = jnp.ones((8, 4)).at[0].mul(100.0)   # one hot column in W^T
    cfg = SparsityConfig(alpha_w=0.5, feedback_mode="topk")
    mask = feedback_mask(jax.random.PRNGKey(0), energy, cfg)
    depths = np.asarray(accumulation_depths(mask))
    assert depths.max() > depths.min()


@pytest.mark.parametrize("norm,expect", [("none", 1.0), ("exp", 2.0),
                                         ("var", 2.0 ** 0.5)])
def test_normalization_factors(norm, expect):
    cfg = SparsityConfig(alpha_w=0.5, feedback_mode="uniform",
                         feedback_norm=norm)
    energy = jnp.ones((8, 8))
    mask = feedback_mask(jax.random.PRNGKey(0), energy, cfg)
    vals = np.unique(np.asarray(mask))
    nz = vals[vals > 0]
    np.testing.assert_allclose(nz, [expect], rtol=1e-5)


def test_column_mask_count_and_scale():
    cfg = SparsityConfig(alpha_c=0.25, column_norm="exp")
    m = column_mask(jax.random.PRNGKey(0), 64, cfg)
    assert int((m > 0).sum()) == 16
    np.testing.assert_allclose(float(m.max()), 4.0, rtol=1e-5)


def test_smd_rate():
    cfg = SparsityConfig(alpha_d=0.5)
    keys = jax.random.split(jax.random.PRNGKey(0), 2000)
    kept = sum(bool(smd_keep_iteration(k, cfg)) for k in keys)
    assert 0.42 < kept / 2000 < 0.58
    assert bool(smd_keep_iteration(keys[0], SparsityConfig()))


def test_dense_mask_is_ones():
    m = feedback_mask(jax.random.PRNGKey(0), jnp.ones((4, 4)),
                      SparsityConfig())
    np.testing.assert_array_equal(np.asarray(m), np.ones((4, 4)))
