"""Checkpointing: atomicity, keep-k, resume, mesh-independence."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                              latest_step, CheckpointManager)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 4)),
            "nest": {"b": jnp.arange(6, dtype=jnp.int32),
                     "c": jnp.float32(seed)}}


def test_roundtrip(tmp_path):
    t = _tree(3)
    save_checkpoint(str(tmp_path), 7, t, {"note": "x"})
    like = jax.tree.map(jnp.zeros_like, t)
    restored, meta = restore_checkpoint(str(tmp_path), like)
    assert meta["step"] == 7 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_k(tmp_path):
    t = _tree()
    for s in range(6):
        save_checkpoint(str(tmp_path), s, t, keep=2)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_4", "step_5"]
    assert latest_step(str(tmp_path)) == 5


def test_atomic_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    assert not any(n.startswith("tmp") for n in os.listdir(tmp_path))


def test_restore_specific_step(tmp_path):
    for s in (1, 2, 3):
        save_checkpoint(str(tmp_path), s, _tree(s), keep=5)
    like = jax.tree.map(jnp.zeros_like, _tree())
    r, meta = restore_checkpoint(str(tmp_path), like, step=2)
    assert meta["step"] == 2
    assert float(r["nest"]["c"]) == 2.0


def test_manager_cadence_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=5, install_sigterm=False)
    t = _tree()
    for s in range(12):
        mgr.maybe_save(s, t, {"loss": 1.0})
    assert latest_step(str(tmp_path)) == 10
    restored, meta = mgr.restore_or_none(jax.tree.map(jnp.zeros_like, t))
    assert restored is not None and meta["step"] == 10


def test_manager_none_when_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path), install_sigterm=False)
    r, m = mgr.restore_or_none(_tree())
    assert r is None and m is None


def test_elastic_restore_new_sharding(tmp_path):
    """Mesh-independence: restore with explicit shardings (single-device
    stand-in for the 512→256 elastic-rescale path)."""
    t = _tree()
    save_checkpoint(str(tmp_path), 0, t)
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), t)
    restored, _ = restore_checkpoint(str(tmp_path), t, shardings=shardings)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
