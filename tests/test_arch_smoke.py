"""Per-arch smoke tests: REDUCED same-family configs, one real forward +
train step + decode step on CPU, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config, ARCH_NAMES
from repro.models.lm import (init_model, forward, build_train_step,
                             build_serve_step, init_decode_cache)

B, S = 2, 16


def _batch(cfg, key):
    kt, kl, kf = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = 0.5 * jax.random.normal(kf, (B, S, cfg.d_model))
    if cfg.family == "vlm":
        batch["img"] = 0.5 * jax.random.normal(
            kf, (B, cfg.n_img_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_smoke(name):
    cfg = smoke_config(name)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), name
    loss, grads = jax.jit(build_train_step(cfg))(
        params, batch, jax.random.PRNGKey(2))
    assert np.isfinite(float(loss)), name
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
    # at least one Σ leaf receives nonzero gradient
    import jax.tree_util as jtu
    s_norms = [float(jnp.linalg.norm(g))
               for path, g in jtu.tree_flatten_with_path(grads)[0]
               if str(getattr(path[-1], "key", "")) == "s" and g.ndim > 0]
    assert s_norms and max(s_norms) > 0, name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_serve_step_smoke(name):
    cfg = smoke_config(name)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    cache = init_decode_cache(cfg, B, S)
    batch = {"token": jnp.zeros((B, 1), jnp.int32),
             "cache_len": jnp.asarray(3, jnp.int32)}
    if cfg.family == "vlm":
        batch["img"] = 0.5 * jax.random.normal(
            jax.random.PRNGKey(1), (B, cfg.n_img_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["enc_out"] = 0.5 * jax.random.normal(
            jax.random.PRNGKey(1), (B, S, cfg.d_model))
    logits, new_cache = jax.jit(build_serve_step(cfg))(params, cache, batch)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), name
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_decode_matches_prefill_logits():
    """Teacher-forced decode reproduces the prefill logits (same params,
    same tokens) — the serve path is consistent with the train path."""
    cfg = smoke_config("qwen3-4b")
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits_all, _ = forward(params, cfg, {"tokens": toks})
    cache = init_decode_cache(cfg, B, S)
    serve = jax.jit(build_serve_step(cfg))
    for i in range(4):
        batch = {"token": toks[:, i: i + 1],
                 "cache_len": jnp.asarray(i, jnp.int32)}
        logits_i, cache = serve(params, cache, batch)
        np.testing.assert_allclose(np.asarray(logits_i),
                                   np.asarray(logits_all[:, i]),
                                   atol=2e-2, rtol=2e-2)


def test_chunked_attention_matches_full():
    import dataclasses
    cfg = smoke_config("olmo-1b")
    cfgc = dataclasses.replace(cfg, attn_chunk=4)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l1, _ = forward(params, cfg, batch)
    l2, _ = forward(params, cfgc, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=1e-3, rtol=1e-3)
