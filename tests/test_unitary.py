"""Mesh parametrization tests: exactness, orthogonality, transpose, oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev extra; shim keeps properties running
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import unitary as un

jax.config.update("jax_enable_x64", True)


@pytest.mark.parametrize("kind", ["reck", "clements"])
@pytest.mark.parametrize("k", [2, 3, 4, 5, 8, 9, 16])
def test_spec_counts(kind, k):
    spec = un.mesh_spec(k, kind)
    assert spec.n_rot == k * (k - 1) // 2
    if kind == "clements":
        assert spec.n_layers <= k
    else:
        assert spec.n_layers <= 2 * k - 3 or k == 2
    # every layer has disjoint pairs
    for l in range(spec.n_layers):
        live = np.where(spec.layer_slot[l] >= 0)[0]
        partners = spec.layer_partner[l][live]
        assert sorted(live) == sorted(partners)


@pytest.mark.parametrize("kind", ["reck", "clements"])
@pytest.mark.parametrize("k", [2, 3, 4, 5, 8, 9, 13, 16])
def test_decompose_reconstruct_roundtrip(kind, k):
    for seed in range(3):
        Q = un.random_orthogonal(seed, k)
        phases, d = un.decompose(Q, kind)
        spec = un.mesh_spec(k, kind)
        # numpy oracle requires phases in application-order; for clements the
        # canonical slot order IS application order (layers ascending).
        U_np = un.np_build_unitary(spec, phases, d)
        np.testing.assert_allclose(U_np, Q, atol=1e-10)
        # JAX layered reconstruction agrees
        U_jax = un.build_unitary(spec, jnp.asarray(phases), jnp.asarray(d))
        np.testing.assert_allclose(np.asarray(U_jax), Q, atol=1e-9)


@pytest.mark.parametrize("kind", ["reck", "clements"])
def test_apply_matches_build(kind):
    k = 9
    rng = np.random.default_rng(0)
    spec = un.mesh_spec(k, kind)
    phases = jnp.asarray(rng.uniform(-np.pi, np.pi, spec.n_rot))
    d = jnp.asarray(rng.choice([-1.0, 1.0], k))
    U = un.build_unitary(spec, phases, d)
    x = jnp.asarray(rng.standard_normal((7, k)))
    y = un.apply_mesh(spec, phases, x, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ np.asarray(U).T,
                               atol=1e-9)
    # transpose apply
    yt = un.apply_mesh_transpose(spec, phases, x, d)
    np.testing.assert_allclose(np.asarray(yt), np.asarray(x) @ np.asarray(U),
                               atol=1e-9)


@pytest.mark.parametrize("kind", ["reck", "clements"])
def test_unitary_is_orthogonal(kind):
    k = 12
    rng = np.random.default_rng(1)
    spec = un.mesh_spec(k, kind)
    phases = jnp.asarray(rng.uniform(-np.pi, np.pi, (5, spec.n_rot)))
    U = un.build_unitary(spec, phases)
    eye = np.eye(k)
    for i in range(5):
        np.testing.assert_allclose(np.asarray(U[i] @ U[i].T), eye, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(k=st.integers(min_value=2, max_value=12), seed=st.integers(0, 2**31 - 1),
       kind=st.sampled_from(["reck", "clements"]))
def test_roundtrip_property(k, seed, kind):
    Q = un.random_orthogonal(seed, k)
    phases, d = un.decompose(Q, kind)
    spec = un.mesh_spec(k, kind)
    np.testing.assert_allclose(un.np_build_unitary(spec, phases, d), Q,
                               atol=1e-9)


def test_batched_build():
    spec = un.mesh_spec(6, "clements")
    rng = np.random.default_rng(2)
    phases = jnp.asarray(rng.uniform(-np.pi, np.pi, (3, 4, spec.n_rot)))
    U = un.build_unitary(spec, phases)
    assert U.shape == (3, 4, 6, 6)
    np.testing.assert_allclose(
        np.asarray(U[1, 2]),
        np.asarray(un.build_unitary(spec, phases[1, 2])), atol=1e-12)
