"""Chunked paged prefill: kernel conformance + end-to-end identity.

Guarantee structure:

* **Kernel**: the Pallas online-softmax prefill attention matches a
  dense masked-softmax reference for every block size / window /
  soft-cap combination, and rerunning it is bitwise deterministic.
* **Pool spans**: ``write_span`` splits page-boundary-crossing chunks
  against the page table exactly and refuses to write past a
  reservation.
* **Bitwise KV property** (hypothesis): at a FIXED padded chunk width
  C, advancing ``stride`` tokens per step produces a page pool
  bit-identical to advancing one token per step — for random prompt
  lengths, chunk widths, page sizes and kernel KV blocks, including
  chunks straddling page boundaries and prompts shorter than one
  chunk.  (XLA:CPU matmul rows are position-invariant at fixed shape
  but NOT invariant across shapes, so bit-identity is defined at equal
  width; vs the (B, 1)-shaped legacy path the gate is token identity,
  the same relation the legacy path itself bears to sequential serve.)
* **Token identity**: chunked prefill (C>1) emits exactly the legacy
  path's tokens — digitally under mixed prefill+decode multi-request
  schedules, and through the hardware-in-the-loop twin transport with
  wide compacted frames (σ_drift = 0).  The socket-transport leg rides
  in ``benchmarks/serving_gateway.py`` (gated in the artifact).
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from tests._hypothesis_shim import given, settings, strategies as st

from repro.models.layers import PTCLinearCfg
from repro.models.lm import (ArchConfig, build_gateway_prefill_step,
                             init_model)
from repro.serving import (GatewayConfig, PageConfig, PagedKVPool, Request,
                           ServingGateway)

ARCH = ArchConfig(name="hwtest", family="dense", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=1, d_ff=48, vocab=64, head_dim=16,
                  remat=False,
                  ptc=PTCLinearCfg(k=8, base_dtype=jnp.float32))
PARAMS = init_model(jax.random.PRNGKey(5), ARCH)


# ---------------------------------------------------------------------------
# kernel conformance
# ---------------------------------------------------------------------------


def _reference(lens, q, k, v, window=None, cap=None):
    b, c, h, hd = q.shape
    s, hkv = k.shape[1], k.shape[2]
    kr = np.repeat(np.asarray(k, np.float64), h // hkv, axis=2)
    vr = np.repeat(np.asarray(v, np.float64), h // hkv, axis=2)
    out = np.zeros((b, c, h, hd))
    for bb in range(b):
        for cc in range(c):
            qi = int(lens[bb]) + cc
            lg = np.einsum("hd,khd->hk", np.asarray(q, np.float64)[bb, cc],
                           kr[bb]) * hd ** -0.5
            if cap is not None:
                lg = cap * np.tanh(lg / cap)
            ki = np.arange(s)
            ok = ki <= qi
            if window is not None:
                ok &= ki > qi - window
            lg = np.where(ok[None], lg, -np.inf)
            w = np.exp(lg - lg.max(-1, keepdims=True))
            w /= w.sum(-1, keepdims=True)
            out[bb, cc] = np.einsum("hk,khd->hd", w, vr[bb])
    return out


@pytest.mark.parametrize("blk", [None, 8, 4])
@pytest.mark.parametrize("window,cap", [(None, None), (6, None),
                                        (None, 3.0), (5, 2.0)])
def test_prefill_kernel_matches_dense_reference(blk, window, cap):
    from repro.kernels.ops import prefill_attention

    rng = np.random.default_rng(0)
    b, c, h, hkv, hd, s = 3, 5, 4, 2, 8, 24
    lens = jnp.asarray([0, 7, 19], jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, c, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    got = prefill_attention(lens, q, k, v, blk=blk, window=window, cap=cap)
    want = _reference(lens, q, k, v, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-5)
    again = prefill_attention(lens, q, k, v, blk=blk, window=window, cap=cap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(again))


def test_prefill_kernel_fully_masked_block_is_exact_zero():
    """A KV block entirely outside the causal window must contribute
    exactly nothing — the masked-exp discipline, not just allclose."""
    from repro.kernels.ops import prefill_attention

    rng = np.random.default_rng(1)
    b, c, h, hkv, hd, s = 1, 2, 2, 1, 4, 16
    lens = jnp.asarray([12], jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, c, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    base = prefill_attention(lens, q, k, v, blk=4, window=3)
    # rewrite the keys/values the window can never see; output unchanged
    k2 = k.at[:, :8].set(999.0)
    v2 = v.at[:, :8].set(-999.0)
    poked = prefill_attention(lens, q, k2, v2, blk=4, window=3)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(poked))


def test_prefill_kernel_rejects_indivisible_block():
    from repro.kernels.ops import prefill_attention

    with pytest.raises(ValueError, match="not divisible"):
        prefill_attention(jnp.zeros((1,), jnp.int32),
                          jnp.zeros((1, 2, 2, 4), jnp.float32),
                          jnp.zeros((1, 10, 1, 4), jnp.float32),
                          jnp.zeros((1, 10, 1, 4), jnp.float32), blk=4)


# ---------------------------------------------------------------------------
# pool write spans
# ---------------------------------------------------------------------------


def test_write_span_splits_page_boundaries():
    cfg = PageConfig(page_size=4, n_pages=8, max_pages_per_slot=3)
    pool = PagedKVPool(cfg, 1)
    pool.reserve(0, 10)
    pool.advance(0, 3)                     # next position: page 0, off 3
    span = pool.write_span(0, 6)           # crosses 0→1 and 1→...
    pages = pool.table[0]
    want = np.asarray([[pages[0], 3], [pages[1], 0], [pages[1], 1],
                       [pages[1], 2], [pages[1], 3], [pages[2], 0]],
                      np.int32)
    np.testing.assert_array_equal(span, want)
    # one-row span degenerates to write_pos
    assert tuple(pool.write_span(0, 1)[0]) == pool.write_pos(0)


def test_write_span_refuses_past_reservation():
    cfg = PageConfig(page_size=4, n_pages=8, max_pages_per_slot=3)
    pool = PagedKVPool(cfg, 1)
    pool.reserve(0, 6)                     # 2 pages
    pool.advance(0, 5)
    with pytest.raises(RuntimeError, match="past its reservation"):
        pool.write_span(0, 4)
    assert pool.write_span(0, 3).shape == (3, 2)


# ---------------------------------------------------------------------------
# bitwise KV + token identity properties
# ---------------------------------------------------------------------------


def _run_single(prompt_len, max_new, chunk, stride, page_size, kv_block,
                seed=9):
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=0, prompt=rng.integers(
        0, ARCH.vocab, size=(prompt_len,)).astype(np.int32),
        max_new=max_new, arrival=0)]
    gcfg = GatewayConfig(
        slots=2,
        pages=PageConfig(page_size=page_size, n_pages=24,
                         max_pages_per_slot=-(-(prompt_len + max_new)
                                              // page_size)),
        prefill_chunk=chunk, prefill_stride=stride, kv_block=kv_block)
    gw = ServingGateway(ARCH, PARAMS, gcfg)
    rep = gw.run(reqs)
    stripe = gcfg.pages.n_pages + 1
    keep = np.asarray([r for r in range(gw.n_periods * stripe)
                       if r % stripe != gcfg.pages.n_pages])
    pools = {f"{n}.{kk}": np.asarray(p[kk])[keep]
             for n, p in gw._pools.items() for kk in ("k", "v")}
    return rep["requests"][0]["tokens"], pools


@settings(max_examples=6, deadline=None)
@given(prompt_len=st.integers(1, 22), chunk=st.sampled_from([2, 3, 5, 8]),
       stride=st.integers(1, 8), page_size=st.sampled_from([2, 4, 8]),
       kv_block=st.sampled_from([None, 8]))
def test_chunked_prefill_bitwise_kv_and_token_identity(
        prompt_len, chunk, stride, page_size, kv_block):
    """stride s ≤ C at padded width C is bit-identical in pool contents
    and tokens to stride C at width C; both emit the legacy one-token
    path's tokens.  kv_block=8 exercises the multi-block online-softmax
    accumulation end-to-end (S_max is a multiple of 8 by geometry)."""
    stride = min(stride, chunk)
    max_new = 3
    if kv_block is not None:
        page_size = 8       # keep S_max divisible by the kernel block
    tok_c, pool_c = _run_single(prompt_len, max_new, chunk, None,
                                page_size, kv_block)
    tok_s, pool_s = _run_single(prompt_len, max_new, chunk, stride,
                                page_size, kv_block)
    tok_1, _ = _run_single(prompt_len, max_new, 1, None, page_size, None)
    assert tok_c == tok_s == tok_1
    assert pool_c.keys() == pool_s.keys() and len(pool_c) > 0
    for name in pool_c:
        np.testing.assert_array_equal(pool_c[name], pool_s[name],
                                      err_msg=f"{name} diverged bitwise")


def test_chunked_mixed_prefill_decode_token_identical_to_legacy():
    """Multi-request schedule: chunked steps mix prefilling slots
    (n_valid up to C) with decoding slots (n_valid == 1) and still emit
    the legacy path's tokens, in fewer busy steps."""
    def run(chunk):
        rng = np.random.default_rng(7)
        reqs = [Request(rid=i, prompt=rng.integers(
            0, ARCH.vocab, size=(ln,)).astype(np.int32),
            max_new=mn, arrival=ar)
            for i, (ln, mn, ar) in enumerate(
                [(11, 3, 0), (15, 4, 1), (5, 3, 2), (14, 3, 4)])]
        gcfg = GatewayConfig(
            slots=3, pages=PageConfig(page_size=4, n_pages=40,
                                      max_pages_per_slot=8),
            prefill_chunk=chunk)
        gw = ServingGateway(ARCH, PARAMS, gcfg)
        rep = gw.run(reqs)
        return [r["tokens"] for r in rep["requests"]], rep

    tok_1, rep_1 = run(1)
    tok_8, rep_8 = run(8)
    assert tok_8 == tok_1
    assert rep_8["busy_steps"] < rep_1["busy_steps"]
    assert rep_8["ttft_steps"]["p50"] < rep_1["ttft_steps"]["p50"]
    assert all(r["first_token"] >= 0 for r in rep_8["requests"])


def test_chunked_prefill_hw_twin_token_identical_with_wide_frames():
    """Hardware-in-the-loop chunked prefill (twin transport, σ=0):
    tokens match the one-token hw path, frames drop, and each wide
    frame ships only the valid (compacted) activation columns."""
    from repro.serving.gateway import run as gw_run

    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, ARCH.vocab, size=(int(rng.integers(6, 14)),)).astype(np.int32),
        max_new=2, arrival=i) for i in range(3)]
    params = init_model(jax.random.PRNGKey(5),
                        dataclasses.replace(ARCH, unroll=True, remat=False))

    def args(**over):
        base = dict(arch=ARCH, seed=5, slots=3, requests=len(reqs),
                    rate=1.0, page_size=4, pages=24, max_pages_per_slot=4,
                    max_new=(2, 4), eos_id=None, fleet=2, drift=False,
                    drift_sigma=0.0, probe_every=4, fleet_k=8,
                    fleet_driver="twin", hw_logits=True, hw_shadow=False,
                    deploy_zo=False, no_recal=True,
                    params_override=params,
                    requests_override=[dataclasses.replace(r, out_tokens=[])
                                       for r in reqs])
        base.update(over)
        return argparse.Namespace(**base)

    rep_1 = gw_run(args())
    rep_4 = gw_run(args(prefill_chunk=4))
    assert ([r["tokens"] for r in rep_4["requests"]]
            == [r["tokens"] for r in rep_1["requests"]])
    hw_1, hw_4 = rep_1["fleet"]["hw"], rep_4["fleet"]["hw"]
    assert hw_4["frames"] < hw_1["frames"]
    # coalescing untouched: still one frame per layer group per step
    assert hw_4["frames_per_step"] == hw_1["frames_per_step"] == 4.0
    # wide frames really carry >1 column/slot on average, but fewer than
    # the uncompacted B·C — the valid-mask compaction is live
    assert hw_1["cols_per_frame"] <= 3.0
    assert 3.0 < hw_4["cols_per_frame"] < 12.0


def test_prefill_step_refuses_non_attention_archs():
    ssm = ArchConfig(name="s", family="ssm", n_layers=2, d_model=16,
                     n_heads=2, n_kv_heads=1, d_ff=16, vocab=32,
                     ssm_state=4)
    with pytest.raises(ValueError, match="attention-only"):
        build_gateway_prefill_step(ssm)
    moe = dataclasses.replace(ARCH, n_experts=4, top_k=2)
    with pytest.raises(ValueError, match="MoE"):
        build_gateway_prefill_step(moe)
