"""Closed-loop runtime: drift, monitor, recalibration, fleet routing.

Everything here drives devices through the ``PhotonicDriver`` boundary;
twin internals are reached only via the ``unsafe_twin()`` escape hatch
(which tests are explicitly allowed to use).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noise import DEFAULT_NOISE, IDEAL
from repro.core.profiler import linear_layer_spec, layer_cost
from repro.core.sparsity import SparsityConfig
from repro.hw.drift import DriftConfig, advance, bias_deviation
from repro.hw.twin import make_twin
from repro.runtime.monitor import (MonitorConfig, HealthState,
                                   probe_mapping_distance,
                                   probe_identity_distance,
                                   readout_mapping_distance, update_health,
                                   clear_health)
from repro.runtime.recalibrate import RecalConfig, recalibrate
from repro.runtime.fleet import (RuntimeConfig, FleetRouter, make_chip,
                                 make_fleet, predicted_distance, HEALTHY,
                                 DEGRADED, RECALIBRATING)

K = 4
DIM = 8
POST_IC = DEFAULT_NOISE.post_ic()


def _small_cfg(**kw):
    defaults = dict(
        k=K, noise=POST_IC,
        drift=DriftConfig(sigma_phase=0.03, theta=0.01),
        monitor=MonitorConfig(n_probes=8, alarm_threshold=0.05,
                              clear_threshold=0.03, consecutive=2),
        recal=RecalConfig(zo_steps=200, delta0=0.05),
        probe_every=5, recal_latency=2, max_concurrent_recals=1)
    defaults.update(kw)
    return RuntimeConfig(**defaults)


def _weight(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((DIM, DIM)) / np.sqrt(DIM),
                       jnp.float32)


def _drift_chip(chip, ticks):
    for _ in range(ticks):
        chip.driver.advance(1.0)


# ---------------------------------------------------------------------------
# drift
# ---------------------------------------------------------------------------


def test_drift_deterministic_under_fixed_seed():
    cfg = _small_cfg()
    chip = make_chip(jax.random.PRNGKey(0), 0, _weight(), cfg)
    st0 = chip.driver.unsafe_twin().drift_state

    def run():
        st = st0
        for t in range(10):
            st = advance(st, 1.0, jax.random.fold_in(jax.random.PRNGKey(7), t),
                         cfg.drift)
        return st

    s1, s2 = run(), run()
    np.testing.assert_array_equal(np.asarray(s1.dev.noise_u.bias),
                                  np.asarray(s2.dev.noise_u.bias))
    np.testing.assert_array_equal(np.asarray(s1.dev.noise_v.gamma),
                                  np.asarray(s2.dev.noise_v.gamma))
    assert float(s1.t) == 10.0


def test_driver_drift_chain_reproducible():
    """Two chips built from the same seed walk identical drift paths —
    the driver owns its entropy, so construction seeds pin trajectories."""
    cfg = _small_cfg()
    c1 = make_chip(jax.random.PRNGKey(3), 0, _weight(3), cfg)
    c2 = make_chip(jax.random.PRNGKey(3), 0, _weight(3), cfg)
    _drift_chip(c1, 7)
    _drift_chip(c2, 7)
    np.testing.assert_array_equal(
        np.asarray(c1.driver.unsafe_twin().dev.noise_u.bias),
        np.asarray(c2.driver.unsafe_twin().dev.noise_u.bias))


def test_drift_moves_device_and_preserves_anchor():
    cfg = _small_cfg()
    chip = make_chip(jax.random.PRNGKey(1), 0, _weight(1), cfg)
    h = chip.driver.unsafe_twin()
    st0 = h.drift_state
    assert float(bias_deviation(st0)) == 0.0
    st = advance(st0, 1.0, jax.random.PRNGKey(3), cfg.drift)
    assert float(bias_deviation(st)) > 0.0
    # the anchor (manufacturing state) never moves; signs are topological
    np.testing.assert_array_equal(np.asarray(st.anchor.noise_u.bias),
                                  np.asarray(st0.anchor.noise_u.bias))
    np.testing.assert_array_equal(np.asarray(st.dev.d_u),
                                  np.asarray(st0.dev.d_u))


def test_drift_degrades_mapping_distance():
    cfg = _small_cfg()
    chip = make_chip(jax.random.PRNGKey(2), 0, _weight(2), cfg)
    h = chip.driver.unsafe_twin()
    d0 = h.true_mapping_distance(chip.w_blocks)
    _drift_chip(chip, 60)
    d1 = h.true_mapping_distance(chip.w_blocks)
    assert d1 > d0 * 2, (d0, d1)


# ---------------------------------------------------------------------------
# monitor
# ---------------------------------------------------------------------------


def test_probe_estimates_true_distance():
    cfg = _small_cfg()
    chip = make_chip(jax.random.PRNGKey(4), 0, _weight(4), cfg)
    _drift_chip(chip, 40)
    true = chip.driver.unsafe_twin().true_mapping_distance(chip.w_blocks)
    ests = [float(probe_mapping_distance(
        jax.random.PRNGKey(100 + i), chip.driver, chip.w_blocks, 16))
        for i in range(8)]
    assert abs(np.mean(ests) - true) < 0.5 * true + 1e-3
    # the full k-column readout is exact
    exact = float(readout_mapping_distance(chip.driver, chip.w_blocks))
    np.testing.assert_allclose(exact, true, rtol=1e-5)


def test_alarm_fires_exactly_at_threshold_policy():
    cfg = MonitorConfig(alarm_threshold=0.05, clear_threshold=0.02,
                        consecutive=2)
    h = HealthState()
    # below threshold: never alarms, strikes reset
    h = update_health(h, 0.04, cfg)
    assert not h.alarmed and h.strikes == 0
    # one strike is not enough (hysteresis against probe noise)
    h = update_health(h, 0.06, cfg)
    assert not h.alarmed and h.strikes == 1
    # a dip resets the streak
    h = update_health(h, 0.01, cfg)
    assert not h.alarmed and h.strikes == 0
    # two consecutive strikes fire
    h = update_health(h, 0.07, cfg)
    h = update_health(h, 0.08, cfg)
    assert h.alarmed and h.strikes == 2
    # clearing requires the LOWER threshold
    h = clear_health(h, 0.04, cfg)       # above clear_threshold: stays up
    assert h.alarmed
    h = clear_health(h, 0.01, cfg)
    assert not h.alarmed


def test_probe_identity_distance_branches():
    """Identity-state probing: zero for a perfect (sign-flipped) identity
    chip in both the full-readout and sampled-columns branches; positive
    once the commanded phases are perturbed."""
    driver = make_twin(jax.random.PRNGKey(0), 3, K, IDEAL)
    key = jax.random.PRNGKey(1)
    full = float(probe_identity_distance(key, driver, n_probes=K))
    sampled = float(probe_identity_distance(key, driver, n_probes=2))
    assert full < 1e-10 and sampled < 1e-10
    phi_u, phi_v = driver.read_phases()
    driver.write_phases(phi_u.at[:, 0].add(0.5), phi_v)
    assert float(probe_identity_distance(key, driver, n_probes=K)) > 1e-3
    assert float(probe_identity_distance(key, driver, n_probes=2)) >= 0.0


def test_probe_cost_matches_profiler_grid():
    """Driver-metered probe cost equals the Appendix-G profiler charge:
    one probe column through a P×Q grid = P·Q PTC calls."""
    cfg = _small_cfg()
    chip = make_chip(jax.random.PRNGKey(6), 0, _weight(6), cfg)
    grid = (DIM // K) ** 2

    def profiler_charge(n_probes):
        spec = linear_layer_spec("health_probe", DIM, DIM, n_probes, k=K)
        return layer_cost(spec, SparsityConfig(), inference_only=True).e_fwd

    chip.driver.reset_stats()
    probe_mapping_distance(jax.random.PRNGKey(0), chip.driver,
                           chip.w_blocks, 1)
    assert chip.driver.stats.probe == grid == profiler_charge(1)
    probe_mapping_distance(jax.random.PRNGKey(1), chip.driver,
                           chip.w_blocks, 6)
    assert chip.driver.stats.probe == grid + 6 * grid
    # serve traffic is metered separately, per streamed row
    chip.driver.forward_layer(jnp.ones((5, DIM)))
    assert chip.driver.stats.serve == 5 * grid == profiler_charge(5)


# ---------------------------------------------------------------------------
# recalibration
# ---------------------------------------------------------------------------


def test_recalibration_restores_distance_below_threshold():
    cfg = _small_cfg()
    chip = make_chip(jax.random.PRNGKey(5), 0, _weight(5), cfg)
    _drift_chip(chip, 80)
    res = recalibrate(jax.random.PRNGKey(6), chip.driver, chip.w_blocks,
                      cfg.recal)
    assert float(res.dist_before) > cfg.monitor.alarm_threshold
    assert float(res.dist_after) < cfg.monitor.alarm_threshold
    assert float(res.dist_after) < float(res.dist_before)
    assert res.ptc_calls > 0
    # the result is self-consistent with the twin's exact read-out
    d = chip.driver.unsafe_twin().true_mapping_distance(chip.w_blocks)
    np.testing.assert_allclose(d, float(res.dist_after), rtol=1e-4)


def test_recal_sl_steps_approach_osp():
    """In-situ stochastic Σ descent must not undo the OSP refresh."""
    cfg = _small_cfg(recal=RecalConfig(zo_steps=100, delta0=0.05,
                                       sl_steps=20, sl_probes=8))
    chip = make_chip(jax.random.PRNGKey(8), 0, _weight(8), cfg)
    _drift_chip(chip, 40)
    res = recalibrate(jax.random.PRNGKey(9), chip.driver, chip.w_blocks,
                      cfg.recal)
    assert float(res.dist_after) <= float(res.dist_before)


def test_recal_budget_autotunes_with_drift_depth():
    """Budget autotuning: a mild excursion gets a smaller ZO budget than
    deep drift, both bounded by [auto_min, zo_steps], and recovery still
    lands below the alarm threshold."""
    recal_cfg = RecalConfig(zo_steps=400, delta0=0.05, auto_budget=True,
                            auto_target=0.03, auto_min=60)
    cfg = _small_cfg(recal=recal_cfg)
    shallow = make_chip(jax.random.PRNGKey(20), 0, _weight(20), cfg)
    deep = make_chip(jax.random.PRNGKey(21), 1, _weight(21), cfg)
    _drift_chip(shallow, 25)
    _drift_chip(deep, 150)
    r_shallow = recalibrate(jax.random.PRNGKey(22), shallow.driver,
                            shallow.w_blocks, recal_cfg)
    r_deep = recalibrate(jax.random.PRNGKey(23), deep.driver,
                         deep.w_blocks, recal_cfg)
    assert float(r_deep.dist_before) > float(r_shallow.dist_before)
    assert r_shallow.zo_steps <= r_deep.zo_steps
    assert recal_cfg.auto_min <= r_shallow.zo_steps <= recal_cfg.zo_steps
    assert float(r_deep.dist_after) < cfg.monitor.alarm_threshold


# ---------------------------------------------------------------------------
# fleet routing
# ---------------------------------------------------------------------------


def test_router_never_dispatches_mid_recalibration():
    cfg = _small_cfg()
    chips = make_fleet(jax.random.PRNGKey(10), 3, _weight(10), cfg)
    router = FleetRouter(chips, cfg, seed=0)
    chips[1].status = RECALIBRATING
    for _ in range(20):
        c = router.dispatch()
        assert c is not None and c.chip_id != 1
        c.served += 0  # dispatch() itself must not mutate
    # all chips in repair → no dispatch, drop is accounted
    for c in chips:
        c.status = RECALIBRATING
    y, cid = router.serve(jnp.ones((2, DIM)))
    assert y is None and cid is None and router.dropped == 1


def test_closed_loop_simulation_invariants():
    """Aggressive drift: alarms fire, recals run, serving never routes to
    a chip in repair, and no batch is dropped (N−1 chips stay up)."""
    cfg = _small_cfg()
    chips = make_fleet(jax.random.PRNGKey(12), 3, _weight(12), cfg)
    router = FleetRouter(chips, cfg, seed=1)
    for t in range(1, 61):
        statuses = {c.chip_id: c.status for c in router.chips}
        y, cid = router.serve(jnp.ones((2, DIM)))
        if cid is not None:
            assert statuses[cid] != RECALIBRATING
        router.tick()
    rep = router.report()
    assert rep["dropped"] == 0
    assert sum(c["alarms"] for c in rep["chips"]) > 0
    assert sum(c["recals"] for c in rep["chips"]) > 0
    # recal_done events restore below the alarm threshold
    done = [e for e in rep["events"] if e["event"] == "recal_done"]
    assert done and all(e["dist_after"] < cfg.monitor.alarm_threshold
                        for e in done)
    # repair bandwidth respected at every event boundary
    assert sum(c["served"] for c in rep["chips"]) == 60


def test_fleet_chips_are_independent_realizations():
    cfg = _small_cfg()
    chips = make_fleet(jax.random.PRNGKey(14), 2, _weight(14), cfg)
    g0 = np.asarray(chips[0].driver.unsafe_twin().dev.noise_u.gamma)
    g1 = np.asarray(chips[1].driver.unsafe_twin().dev.noise_u.gamma)
    assert not np.allclose(g0, g1)
    # but they serve the same logical weight
    np.testing.assert_array_equal(np.asarray(chips[0].w_blocks),
                                  np.asarray(chips[1].w_blocks))


def test_router_prefers_healthy_and_balances_load():
    cfg = _small_cfg(router_policy="least_served")
    chips = make_fleet(jax.random.PRNGKey(15), 3, _weight(15), cfg)
    router = FleetRouter(chips, cfg, seed=2)
    chips[0].status = DEGRADED
    for _ in range(10):
        c = router.dispatch()
        assert c.status == HEALTHY
        c.served += 1
    assert abs(chips[1].served - chips[2].served) <= 1


def test_multi_tenant_chip_layout_and_compat_views():
    """Tenants pack contiguous block ranges of one shared device; the
    single-tenant compatibility views (w_blocks/health) keep working."""
    cfg = _small_cfg()
    ws = [_weight(30), _weight(31)[:4]]          # (8,8) + (4,8) layers
    chip = make_chip(jax.random.PRNGKey(30), 0, ws, cfg)
    t0, t1 = chip.tenants
    assert t0.block_range == (0, 4) and t1.block_range == (4, 6)
    assert chip.driver.n_blocks == 6
    assert (t0.m, t0.n) == (8, 8) and (t1.m, t1.n) == (4, 8)
    # aggregate view concatenates tenant targets in block order
    np.testing.assert_array_equal(
        np.asarray(chip.w_blocks),
        np.concatenate([np.asarray(t0.w_blocks), np.asarray(t1.w_blocks)]))
    assert chip.health is t0.health
    # single-tenant construction is the degenerate case
    solo = make_chip(jax.random.PRNGKey(31), 1, _weight(31), cfg)
    assert len(solo.tenants) == 1
    assert solo.tenants[0].block_range == (0, solo.driver.n_blocks)


def test_multi_tenant_serve_routes_block_range():
    """serve(tenant=j) forwards through tenant j's sub-grid only: the
    output matches the tenant's logical weight (to mapping error), and
    per-tenant served counters account the traffic."""
    cfg = _small_cfg()
    ws = [_weight(32), _weight(33)]
    chips = make_fleet(jax.random.PRNGKey(32), 2, ws, cfg)
    router = FleetRouter(chips, cfg, seed=5)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((4, DIM)),
                    jnp.float32)
    for j, w in enumerate(ws):
        y, cid = router.serve(x, tenant=j)
        assert cid is not None
        y_ref = x @ w.T
        err = float(jnp.sum((y - y_ref) ** 2) / jnp.sum(y_ref ** 2))
        assert err < 0.05, (j, err)
    assert sum(c.tenants[0].served for c in chips) == 1
    assert sum(c.tenants[1].served for c in chips) == 1
    assert sum(c.served for c in chips) == 2


def test_multi_tenant_closed_loop_partial_recal():
    """Closed loop over a 2-tenant fleet: alarms and recals are
    per-tenant, repairs recover the alarmed tenant, and throughput
    holds (N−1 chips keep serving)."""
    cfg = _small_cfg()
    chips = make_fleet(jax.random.PRNGKey(34), 3, [_weight(34), _weight(35)],
                       cfg)
    router = FleetRouter(chips, cfg, seed=6)
    for t in range(1, 81):
        y, cid = router.serve(jnp.ones((2, DIM)), tenant=(t - 1) % 2)
        if cid is not None:
            assert chips[cid].status != RECALIBRATING
        router.tick()
    rep = router.report()
    assert rep["dropped"] == 0
    assert sum(c["alarms"] for c in rep["chips"]) > 0
    done = [e for e in rep["events"] if e["event"] == "recal_done"]
    assert done
    assert all("tenant" in e for e in done)
    assert all(e["dist_after"] < cfg.monitor.alarm_threshold for e in done)
    # tenant counters carry the breakdown the chip counters aggregate
    for c in rep["chips"]:
        assert sum(t["recals"] for t in c["tenants"]) == c["recals"]
        assert sum(t["alarms"] for t in c["tenants"]) == c["alarms"]
        assert sum(t["served"] for t in c["tenants"]) == c["served"]


def test_fleet_close_survives_failing_driver_and_mid_recal():
    """close() releases EVERY driver handle — chips parked
    mid-recalibration included — even when an earlier handle's close
    raises (the failure is re-raised after all handles are attempted)."""
    cfg = _small_cfg()
    chips = make_fleet(jax.random.PRNGKey(36), 3, _weight(36), cfg)
    router = FleetRouter(chips, cfg, seed=7)
    chips[1].status = RECALIBRATING        # mid-repair at shutdown
    closed = []

    class _Boom:
        def __init__(self, inner, i):
            self._inner, self._i = inner, i

        def close(self):
            if self._i == 0:
                raise OSError("transport already gone")
            closed.append(self._i)
            self._inner.close()

    for i, c in enumerate(chips):
        c.driver = _Boom(c.driver, i)
    with np.testing.assert_raises(RuntimeError):
        router.close()
    assert closed == [1, 2]                # the rest still closed


def test_no_subprocess_server_leak_after_multi_tenant_demo(monkeypatch):
    """A multi-tenant demo run over the subprocess transport leaves no
    twin server process behind: every driver spawned during the run has
    been closed (child reaped) by the router's shutdown path."""
    from repro.hw import subprocess_driver as sd
    from repro.runtime.demo import simulate, default_runtime_config

    spawned = []
    orig_init = sd.SubprocessDriver.__init__

    def spy_init(self, *a, **kw):
        orig_init(self, *a, **kw)
        spawned.append(self)

    monkeypatch.setattr(sd.SubprocessDriver, "__init__", spy_init)
    cfg = default_runtime_config(k=4, sigma_drift=0.05, probe_every=4,
                                 zo_steps=60, driver_kind="subprocess")
    out = simulate(2, 12, dim=8, batch=2, seed=0, cfg=cfg, tenants=2)
    assert out["report"]["ticks"] == 12
    assert len(spawned) == 2
    for d in spawned:
        assert d._proc is None          # close() ran and reaped the child


def test_drift_aware_routing_ranks_by_predicted_decay():
    """The default policy dispatches the chip with the lowest *predicted*
    distance (last estimate + OU extrapolation), preferring HEALTHY."""
    cfg = _small_cfg(router_policy="drift_aware")
    chips = make_fleet(jax.random.PRNGKey(16), 3, _weight(16), cfg)
    router = FleetRouter(chips, cfg, seed=3)
    router.tick_count = 50
    for c in chips:
        c.health.distance = 0.010
        c.last_probe_tick = 50
    chips[1].health.distance = 0.002          # freshest, fittest
    assert router.dispatch().chip_id == 1
    # a stale estimate is inflated toward the OU stationary floor, so a
    # long-unprobed chip loses to one probed just now at equal d̂
    chips[1].last_probe_tick = 0
    d_stale = predicted_distance(chips[1], 50, cfg.drift)
    d_fresh = predicted_distance(chips[0], 50, cfg.drift)
    assert d_stale > chips[1].health.distance
    assert router.dispatch().chip_id != 1 or d_stale < d_fresh
    # HEALTHY pool still beats DEGRADED regardless of prediction
    chips[0].status = DEGRADED
    chips[2].status = DEGRADED
    chips[1].health.distance = 0.9
    assert router.dispatch().chip_id == 1
