"""Closed-loop runtime: drift, monitor, recalibration, fleet routing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import unitary as un
from repro.core.calibration import sample_device
from repro.core.noise import NoiseModel, DEFAULT_NOISE, IDEAL
from repro.runtime.drift import (DriftConfig, init_drift, advance,
                                 bias_deviation)
from repro.runtime.monitor import (MonitorConfig, HealthState,
                                   probe_mapping_distance,
                                   probe_identity_distance,
                                   true_mapping_distance, update_health,
                                   clear_health, probe_ptc_calls)
from repro.runtime.recalibrate import RecalConfig, recalibrate
from repro.runtime.fleet import (RuntimeConfig, FleetRouter, make_chip,
                                 make_fleet, HEALTHY, DEGRADED,
                                 RECALIBRATING)

K = 4
DIM = 8
POST_IC = DEFAULT_NOISE.post_ic()


def _small_cfg(**kw):
    defaults = dict(
        k=K, noise=POST_IC,
        drift=DriftConfig(sigma_phase=0.03, theta=0.01),
        monitor=MonitorConfig(n_probes=8, alarm_threshold=0.05,
                              clear_threshold=0.03, consecutive=2),
        recal=RecalConfig(zo_steps=200, delta0=0.05),
        probe_every=5, recal_latency=2, max_concurrent_recals=1)
    defaults.update(kw)
    return RuntimeConfig(**defaults)


def _weight(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((DIM, DIM)) / np.sqrt(DIM),
                       jnp.float32)


# ---------------------------------------------------------------------------
# drift
# ---------------------------------------------------------------------------


def test_drift_deterministic_under_fixed_seed():
    cfg = _small_cfg()
    chip = make_chip(jax.random.PRNGKey(0), 0, _weight(), cfg)

    def run():
        st = chip.drift
        for t in range(10):
            st = advance(st, 1.0, jax.random.fold_in(jax.random.PRNGKey(7), t),
                         cfg.drift)
        return st

    s1, s2 = run(), run()
    np.testing.assert_array_equal(np.asarray(s1.dev.noise_u.bias),
                                  np.asarray(s2.dev.noise_u.bias))
    np.testing.assert_array_equal(np.asarray(s1.dev.noise_v.gamma),
                                  np.asarray(s2.dev.noise_v.gamma))
    assert float(s1.t) == 10.0


def test_drift_moves_device_and_preserves_anchor():
    cfg = _small_cfg()
    chip = make_chip(jax.random.PRNGKey(1), 0, _weight(1), cfg)
    st0 = chip.drift
    assert float(bias_deviation(st0)) == 0.0
    st = advance(st0, 1.0, jax.random.PRNGKey(3), cfg.drift)
    assert float(bias_deviation(st)) > 0.0
    # the anchor (manufacturing state) never moves; signs are topological
    np.testing.assert_array_equal(np.asarray(st.anchor.noise_u.bias),
                                  np.asarray(st0.anchor.noise_u.bias))
    np.testing.assert_array_equal(np.asarray(st.dev.d_u),
                                  np.asarray(st0.dev.d_u))


def test_drift_degrades_mapping_distance():
    cfg = _small_cfg()
    chip = make_chip(jax.random.PRNGKey(2), 0, _weight(2), cfg)
    spec = un.mesh_spec(K, cfg.kind)
    d0 = float(true_mapping_distance(spec, chip.phi, chip.sigma,
                                     chip.drift.dev, cfg.noise,
                                     chip.w_blocks))
    st = chip.drift
    for t in range(60):
        st = advance(st, 1.0, jax.random.fold_in(jax.random.PRNGKey(11), t),
                     cfg.drift)
    d1 = float(true_mapping_distance(spec, chip.phi, chip.sigma, st.dev,
                                     cfg.noise, chip.w_blocks))
    assert d1 > d0 * 2, (d0, d1)


# ---------------------------------------------------------------------------
# monitor
# ---------------------------------------------------------------------------


def test_probe_estimates_true_distance():
    cfg = _small_cfg()
    chip = make_chip(jax.random.PRNGKey(4), 0, _weight(4), cfg)
    spec = un.mesh_spec(K, cfg.kind)
    st = chip.drift
    for t in range(40):
        st = advance(st, 1.0, jax.random.fold_in(jax.random.PRNGKey(13), t),
                     cfg.drift)
    true = float(true_mapping_distance(spec, chip.phi, chip.sigma, st.dev,
                                       cfg.noise, chip.w_blocks))
    ests = [float(probe_mapping_distance(
        jax.random.PRNGKey(100 + i), spec, chip.phi, chip.sigma, st.dev,
        cfg.noise, chip.w_blocks, 16)) for i in range(8)]
    assert abs(np.mean(ests) - true) < 0.5 * true + 1e-3


def test_alarm_fires_exactly_at_threshold_policy():
    cfg = MonitorConfig(alarm_threshold=0.05, clear_threshold=0.02,
                        consecutive=2)
    h = HealthState()
    # below threshold: never alarms, strikes reset
    h = update_health(h, 0.04, cfg)
    assert not h.alarmed and h.strikes == 0
    # one strike is not enough (hysteresis against probe noise)
    h = update_health(h, 0.06, cfg)
    assert not h.alarmed and h.strikes == 1
    # a dip resets the streak
    h = update_health(h, 0.01, cfg)
    assert not h.alarmed and h.strikes == 0
    # two consecutive strikes fire
    h = update_health(h, 0.07, cfg)
    h = update_health(h, 0.08, cfg)
    assert h.alarmed and h.strikes == 2
    # clearing requires the LOWER threshold
    h = clear_health(h, 0.04, cfg)       # above clear_threshold: stays up
    assert h.alarmed
    h = clear_health(h, 0.01, cfg)
    assert not h.alarmed


def test_probe_identity_distance_branches():
    """Identity-state probing: zero for a perfect (sign-flipped) identity
    chip in both the full-readout and sampled-columns branches; positive
    once the commanded phases are perturbed."""
    spec = un.mesh_spec(K, "clements")
    dev = sample_device(jax.random.PRNGKey(0), (3,), K, IDEAL)
    phi = jnp.zeros((3, 2 * spec.n_rot))
    key = jax.random.PRNGKey(1)
    full = float(probe_identity_distance(key, spec, phi, dev, IDEAL,
                                         n_probes=K))
    sampled = float(probe_identity_distance(key, spec, phi, dev, IDEAL,
                                            n_probes=2))
    assert full < 1e-10 and sampled < 1e-10
    bad = phi.at[:, 0].add(0.5)
    assert float(probe_identity_distance(key, spec, bad, dev, IDEAL,
                                         n_probes=K)) > 1e-3
    assert float(probe_identity_distance(key, spec, bad, dev, IDEAL,
                                         n_probes=2)) >= 0.0


def test_probe_cost_matches_profiler_grid():
    # one probe column through a P×Q grid = P·Q PTC calls
    assert probe_ptc_calls(DIM, DIM, K, 1) == (DIM // K) ** 2
    assert probe_ptc_calls(DIM, DIM, K, 6) == 6 * (DIM // K) ** 2


# ---------------------------------------------------------------------------
# recalibration
# ---------------------------------------------------------------------------


def test_recalibration_restores_distance_below_threshold():
    cfg = _small_cfg()
    chip = make_chip(jax.random.PRNGKey(5), 0, _weight(5), cfg)
    spec = un.mesh_spec(K, cfg.kind)
    st = chip.drift
    for t in range(80):
        st = advance(st, 1.0, jax.random.fold_in(jax.random.PRNGKey(17), t),
                     cfg.drift)
    res = recalibrate(jax.random.PRNGKey(6), spec, chip.phi, chip.sigma,
                      st.dev, cfg.noise, chip.w_blocks, cfg.recal)
    assert float(res.dist_before) > cfg.monitor.alarm_threshold
    assert float(res.dist_after) < cfg.monitor.alarm_threshold
    assert float(res.dist_after) < float(res.dist_before)
    assert res.ptc_calls > 0
    # the result is self-consistent with an exact read-out
    d = float(true_mapping_distance(spec, res.phi, res.sigma, st.dev,
                                    cfg.noise, chip.w_blocks))
    np.testing.assert_allclose(d, float(res.dist_after), rtol=1e-5)


def test_recal_sl_steps_approach_osp():
    """In-situ stochastic Σ descent must not undo the OSP refresh."""
    cfg = _small_cfg(recal=RecalConfig(zo_steps=100, delta0=0.05,
                                       sl_steps=20, sl_probes=8))
    chip = make_chip(jax.random.PRNGKey(8), 0, _weight(8), cfg)
    spec = un.mesh_spec(K, cfg.kind)
    st = chip.drift
    for t in range(40):
        st = advance(st, 1.0, jax.random.fold_in(jax.random.PRNGKey(19), t),
                     cfg.drift)
    res = recalibrate(jax.random.PRNGKey(9), spec, chip.phi, chip.sigma,
                      st.dev, cfg.noise, chip.w_blocks, cfg.recal)
    assert float(res.dist_after) <= float(res.dist_before)


# ---------------------------------------------------------------------------
# fleet routing
# ---------------------------------------------------------------------------


def test_router_never_dispatches_mid_recalibration():
    cfg = _small_cfg()
    chips = make_fleet(jax.random.PRNGKey(10), 3, _weight(10), cfg)
    router = FleetRouter(chips, cfg, seed=0)
    chips[1].status = RECALIBRATING
    for _ in range(20):
        c = router.dispatch()
        assert c is not None and c.chip_id != 1
        c.served += 0  # dispatch() itself must not mutate
    # all chips in repair → no dispatch, drop is accounted
    for c in chips:
        c.status = RECALIBRATING
    y, cid = router.serve(jnp.ones((2, DIM)))
    assert y is None and cid is None and router.dropped == 1


def test_closed_loop_simulation_invariants():
    """Aggressive drift: alarms fire, recals run, serving never routes to
    a chip in repair, and no batch is dropped (N−1 chips stay up)."""
    cfg = _small_cfg()
    chips = make_fleet(jax.random.PRNGKey(12), 3, _weight(12), cfg)
    router = FleetRouter(chips, cfg, seed=1)
    for t in range(1, 61):
        statuses = {c.chip_id: c.status for c in router.chips}
        y, cid = router.serve(jnp.ones((2, DIM)))
        if cid is not None:
            assert statuses[cid] != RECALIBRATING
        router.tick()
    rep = router.report()
    assert rep["dropped"] == 0
    assert sum(c["alarms"] for c in rep["chips"]) > 0
    assert sum(c["recals"] for c in rep["chips"]) > 0
    # recal_done events restore below the alarm threshold
    done = [e for e in rep["events"] if e["event"] == "recal_done"]
    assert done and all(e["dist_after"] < cfg.monitor.alarm_threshold
                        for e in done)
    # repair bandwidth respected at every event boundary
    assert sum(c["served"] for c in rep["chips"]) == 60


def test_fleet_chips_are_independent_realizations():
    cfg = _small_cfg()
    chips = make_fleet(jax.random.PRNGKey(14), 2, _weight(14), cfg)
    g0 = np.asarray(chips[0].drift.dev.noise_u.gamma)
    g1 = np.asarray(chips[1].drift.dev.noise_u.gamma)
    assert not np.allclose(g0, g1)
    # but they serve the same logical weight
    np.testing.assert_array_equal(np.asarray(chips[0].w_blocks),
                                  np.asarray(chips[1].w_blocks))


def test_router_prefers_healthy_and_balances_load():
    cfg = _small_cfg()
    chips = make_fleet(jax.random.PRNGKey(15), 3, _weight(15), cfg)
    router = FleetRouter(chips, cfg, seed=2)
    chips[0].status = DEGRADED
    for _ in range(10):
        c = router.dispatch()
        assert c.status == HEALTHY
        c.served += 1
    assert abs(chips[1].served - chips[2].served) <= 1
