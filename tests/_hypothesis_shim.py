"""Minimal drop-in stand-in for the `hypothesis` API used by this suite.

The property tests only need ``@settings``, ``@given`` and three strategy
constructors (`integers`, `floats`, `sampled_from`).  When the real
`hypothesis` package is unavailable (it is an optional dev extra, see
pyproject.toml), this shim runs each property as a deterministic, seeded
sweep of examples so the suite still collects and exercises the
properties.  It intentionally implements no shrinking or database — with
`hypothesis` installed the real library is used instead (see the
``try/except ImportError`` at each test module's top).
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

# Cap the number of examples when running under the shim: without
# shrinking there is little value in large sweeps, and shape-polymorphic
# jax tests pay a retrace per example.
_SHIM_MAX_EXAMPLES = 10


class settings:  # noqa: N801 - mirrors the hypothesis name
    def __init__(self, max_examples: int = 20, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_max_examples = min(self.max_examples, _SHIM_MAX_EXAMPLES)
        return fn


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # noqa: N801 - mirrors `from hypothesis import strategies`
    @staticmethod
    def integers(min_value, max_value) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(options) -> _Strategy:
        opts = list(options)
        return _Strategy(lambda rng: opts[rng.randrange(len(opts))])


def given(**strats):
    """Run the property over a deterministic seeded sweep of examples."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", None)
            if n is None:
                n = min(20, _SHIM_MAX_EXAMPLES)
            # Seed from the test name so every run draws the same examples.
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in sorted(strats.items())}
                fn(*args, **drawn, **kwargs)

        # Hide the property arguments from pytest's fixture resolution:
        # the wrapper itself takes none (every argument is drawn here).
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
