"""Docs gate: relative-link/anchor checking + quickstart execution.

Pure stdlib, run from the repo root::

    python tools/check_docs.py              # link + anchor check
    python tools/check_docs.py --run-smoke  # also execute smoke blocks

Checks every markdown link in README.md and docs/*.md whose target is
not an absolute URL: the target file must exist (relative to the file
containing the link), and a ``#fragment`` must name a real anchor in
the target — either an explicit ``<a id="...">`` or a heading's
GitHub-style slug.  Links inside fenced code blocks are ignored.

``--run-smoke`` additionally extracts each fenced code block in
``docs/benchmarks.md`` that is immediately preceded by a
``<!-- smoke -->`` marker and executes it with ``bash -e`` from the
repo root (``PYTHONPATH=src`` preset) — the documented quickstart
commands are CI-executed, so they cannot rot.
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_LINK = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"^(```|~~~)")
_EXPLICIT_ANCHOR = re.compile(r"<a\s+id=[\"']([^\"']+)[\"']")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")
_SMOKE = re.compile(r"<!--\s*smoke\s*-->")


def _doc_files() -> list[str]:
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, n) for n in os.listdir(docs)
                        if n.endswith(".md"))
    return [f for f in files if os.path.exists(f)]


def _strip_fences(text: str) -> str:
    """Blank out fenced code blocks (links inside them are examples)."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE.match(line.strip()):
            in_fence = not in_fence
            out.append("")
            continue
        out.append("" if in_fence else line)
    return "\n".join(out)


def _slugify(heading: str) -> str:
    """GitHub's anchor slug: strip markup, lowercase, spaces → dashes,
    drop everything that is not alphanumeric/dash/underscore."""
    h = re.sub(r"[`*_]", "", heading.strip()).lower()
    h = h.replace(" ", "-")
    return re.sub(r"[^0-9a-zÀ-￿_-]", "", h)


def _anchors(path: str) -> set[str]:
    with open(path, encoding="utf-8") as fh:
        raw = fh.read()
    anchors = set(_EXPLICIT_ANCHOR.findall(raw))
    for line in _strip_fences(raw).splitlines():
        m = _HEADING.match(line)
        if m:
            anchors.add(_slugify(m.group(1)))
    return anchors


def check_links() -> list[str]:
    errors = []
    for path in _doc_files():
        rel = os.path.relpath(path, REPO)
        with open(path, encoding="utf-8") as fh:
            text = _strip_fences(fh.read())
        for target in _LINK.findall(text):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:
                continue
            base, _, frag = target.partition("#")
            if base:
                dest = os.path.normpath(
                    os.path.join(os.path.dirname(path), base))
                if not os.path.exists(dest):
                    errors.append(f"{rel}: broken link -> {target}")
                    continue
            else:
                dest = path                                 # same-file #frag
            if frag and dest.endswith(".md"):
                if frag.lower() not in _anchors(dest):
                    errors.append(f"{rel}: missing anchor -> {target}")
    return errors


def smoke_blocks(path: str) -> list[str]:
    """Fenced blocks immediately preceded by a ``<!-- smoke -->`` line."""
    with open(path, encoding="utf-8") as fh:
        lines = fh.read().splitlines()
    blocks, i = [], 0
    while i < len(lines):
        if _SMOKE.search(lines[i]):
            j = i + 1
            while j < len(lines) and not lines[j].strip():
                j += 1
            if j < len(lines) and _FENCE.match(lines[j].strip()):
                body, j = [], j + 1
                while j < len(lines) and not _FENCE.match(lines[j].strip()):
                    body.append(lines[j])
                    j += 1
                blocks.append("\n".join(body))
                i = j
        i += 1
    return blocks


def run_smoke() -> list[str]:
    path = os.path.join(REPO, "docs", "benchmarks.md")
    blocks = smoke_blocks(path)
    if not blocks:
        return [f"{os.path.relpath(path, REPO)}: no smoke-tagged blocks "
                f"found — the quickstart stopped being executed"]
    errors = []
    env = dict(os.environ, PYTHONPATH="src")
    for n, block in enumerate(blocks, 1):
        print(f"--- smoke block {n}/{len(blocks)} ---")
        print(block)
        proc = subprocess.run(["bash", "-e", "-c", block], cwd=REPO,
                              env=env)
        if proc.returncode != 0:
            errors.append(f"docs/benchmarks.md smoke block {n} exited "
                          f"{proc.returncode}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-smoke", action="store_true",
                    help="execute the smoke-tagged fenced blocks in "
                         "docs/benchmarks.md")
    args = ap.parse_args(argv)

    errors = check_links()
    n_files = len(_doc_files())
    if args.run_smoke:
        errors += run_smoke()
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"check_docs: {n_files} file(s), {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
