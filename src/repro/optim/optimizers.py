"""First-order optimizers for subspace learning (paper §4.1: AdamW on Σ).

Pure-pytree implementation (no external deps): fp32 master state over
possibly-bf16 params, per-leaf trainability masking (only Σ and the
electronic leaves — embeddings, norms, routers — receive updates; frozen
U/V bases are masked out), global-norm clipping, decoupled weight decay.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig", "SGDConfig", "OptState", "init_opt_state",
    "apply_updates", "clip_by_global_norm", "global_norm",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 2e-3                # paper: 0.002 for SL-from-scratch
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01      # paper: 0.01
    grad_clip: float | None = 1.0

    kind: str = dataclasses.field(default="adamw", init=False)


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 0.0
    grad_clip: float | None = None

    kind: str = dataclasses.field(default="sgd", init=False)


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree        # first moment / momentum (fp32)
    nu: PyTree        # second moment (fp32; zeros pytree for SGD)
    master: PyTree    # fp32 master params (same pytree as params)


def _f32(t: PyTree) -> PyTree:
    return jax.tree.map(lambda a: a.astype(jnp.float32), t)


def init_opt_state(params: PyTree, trainable: PyTree | None = None
                   ) -> OptState:
    """``trainable`` False leaves get scalar placeholders — frozen U/V
    bases carry NO optimizer state (2/3 of an LM's params)."""
    if trainable is None:
        trainable = jax.tree.map(lambda _: True, params)

    def z(a, tr):
        return jnp.zeros(a.shape if tr else (), jnp.float32)

    def m(a, tr):
        return a.astype(jnp.float32) if tr else jnp.zeros((), jnp.float32)

    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(z, params, trainable),
                    nu=jax.tree.map(z, params, trainable),
                    master=jax.tree.map(m, params, trainable))


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.zeros(())


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(params: PyTree, grads: PyTree, state: OptState,
                  cfg: AdamWConfig | SGDConfig,
                  lr_scale: jax.Array | float = 1.0,
                  trainable: PyTree | None = None,
                  ) -> tuple[PyTree, OptState, jax.Array]:
    """One optimizer step.  ``trainable``: bool pytree (same structure);
    False leaves are passed through untouched (frozen U/V bases).
    Returns (new_params, new_state, grad_norm)."""
    if cfg.grad_clip is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = cfg.lr * lr_scale

    if trainable is None:
        trainable = jax.tree.map(lambda _: True, params)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        if cfg.kind == "adamw":
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
            mhat = m / (1 - cfg.b1 ** step)
            vhat = v / (1 - cfg.b2 ** step)
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        else:
            m = cfg.momentum * m + g
            delta = m + cfg.weight_decay * p
        return p - lr * delta, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_master = treedef.flatten_up_to(state.master)
    flat_tr = treedef.flatten_up_to(trainable)

    new_master, new_m, new_v, new_p = [], [], [], []
    for g, m, v, pm, p, tr in zip(flat_g, flat_m, flat_v, flat_master,
                                  flat_p, flat_tr):
        if not tr:
            new_master.append(pm)
            new_m.append(m)
            new_v.append(v)
            new_p.append(p)
            continue
        pm2, m2, v2 = upd(g, m, v, pm)
        new_master.append(pm2)
        new_m.append(m2)
        new_v.append(v2)
        new_p.append(pm2.astype(p.dtype))

    new_params = treedef.unflatten(new_p)
    new_state = OptState(step=step, mu=treedef.unflatten(new_m),
                         nu=treedef.unflatten(new_v),
                         master=treedef.unflatten(new_master))
    return new_params, new_state, gnorm
