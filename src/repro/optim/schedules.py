"""Learning-rate schedules (paper uses cosine annealing for SL)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_schedule", "linear_warmup_cosine", "exponential_decay"]


def cosine_schedule(step, total_steps: int, final_frac: float = 0.0):
    t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return final_frac + (1.0 - final_frac) * cos


def linear_warmup_cosine(step, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.0):
    warm = jnp.clip(step / max(warmup_steps, 1), 0.0, 1.0)
    t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return warm * (final_frac + (1.0 - final_frac) * cos)


def exponential_decay(step, decay: float = 0.99, period: int = 1):
    """IC/PM schedule: lr ← lr·decay every epoch (paper Appendix E)."""
    return decay ** (step // period)
