"""Zeroth-order optimizers for hardware-restricted phase tuning.

The paper's IC/PM stages cannot observe phase gradients, only end-to-end
transfer-matrix losses; they use ZO search (Fig. 4 / Algorithm 1):

* ``zcd`` — zeroth-order coordinate descent: draw a coordinate, probe
  ``L(φ+δφ)`` vs ``L(φ)``, step ±δφ (always moves — Algorithm 1);
  supports the PM *alternate* schedule (even steps probe Φ^U coords, odd
  steps Φ^V) via ``alt_split``.
* ``ztp`` — stochastic three-point: random direction ``u``, move to the
  best of {φ, φ+δu, φ−δu}.
* ``zgd`` — antithetic two-point gradient estimate with momentum.

All methods record the BEST solution seen (the "-B" variants in Fig. 4)
and decay the step size ``δφ ← max(δφ/β, δφ_l)`` every ``inner`` steps,
with δφ bounded by the phase-control resolution (Algorithm 1's
``δφ_u = 2π/(2^min(b_l,b)−1)``).

Everything is a pure ``lax.scan`` so the whole per-block search is
``jax.vmap``-able across the thousands of k×k blocks that IC/PM optimize
in parallel — the paper's key scalability trick ("partitioning a
large-scale regression into a batch of sub-tasks").
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ZOConfig", "zo_minimize"]


class ZOConfig(NamedTuple):
    steps: int = 400            # total probe steps
    inner: int = 20             # step-size decay period (Algorithm 1's S)
    delta0: float = 0.1         # initial step δφ_u
    decay: float = 1.05         # β
    delta_min: float = 2 * np.pi / 255.0  # δφ_l (8-bit phase resolution)
    lr0: float = 1.0            # zgd learning rate
    momentum: float = 0.9       # zgd momentum
    record_every: int = 10      # best-loss history stride


class ZOResult(NamedTuple):
    x: jax.Array        # best solution recorded
    f: jax.Array        # best loss
    history: jax.Array  # best-loss trace, (steps // record_every,)


def zo_minimize(loss_fn: Callable[[jax.Array], jax.Array], x0: jax.Array,
                key: jax.Array, cfg: ZOConfig, method: str = "zcd",
                alt_split: int | None = None) -> ZOResult:
    """Minimize ``loss_fn`` from ``x0`` with a ZO search.

    ``loss_fn`` maps a flat parameter vector to a scalar; it embodies one
    physical loss measurement (PTC probe + electronic comparison).
    ``alt_split``: if set, coordinates [0, alt_split) and [alt_split, n)
    are probed on alternating steps (PM's alternate Φ^U / Φ^V schedule).
    """
    n = x0.shape[-1]
    if method == "zcd":
        step_fn = _zcd_step(loss_fn, n, alt_split)
    elif method == "ztp":
        step_fn = _ztp_step(loss_fn, n)
    elif method == "zgd":
        step_fn = _zgd_step(loss_fn, n, cfg)
    else:
        raise ValueError(f"unknown ZO method: {method!r}")

    f0 = loss_fn(x0)
    carry0 = dict(x=x0, f=f0, best_x=x0, best_f=f0,
                  delta=jnp.asarray(cfg.delta0),
                  m=jnp.zeros_like(x0), t=jnp.asarray(0))

    def body(carry, key_t):
        carry = step_fn(carry, key_t)
        better = carry["f"] < carry["best_f"]
        carry["best_f"] = jnp.where(better, carry["f"], carry["best_f"])
        carry["best_x"] = jnp.where(better, carry["x"], carry["best_x"])
        t = carry["t"] + 1
        carry["t"] = t
        decay_now = (t % cfg.inner) == 0
        carry["delta"] = jnp.where(
            decay_now, jnp.maximum(carry["delta"] / cfg.decay, cfg.delta_min),
            carry["delta"])
        return carry, carry["best_f"]

    keys = jax.random.split(key, cfg.steps)
    carry, trace = jax.lax.scan(body, carry0, keys)
    history = trace[cfg.record_every - 1:: cfg.record_every]
    return ZOResult(x=carry["best_x"], f=carry["best_f"], history=history)


def _zcd_step(loss_fn, n, alt_split):
    def step(carry, key_t):
        x, f, delta, t = carry["x"], carry["f"], carry["delta"], carry["t"]
        if alt_split is None:
            i = jax.random.randint(key_t, (), 0, n)
        else:
            # alternate: even steps sample [0, split), odd [split, n)
            lo = jnp.where(t % 2 == 0, 0, alt_split)
            hi = jnp.where(t % 2 == 0, alt_split, n)
            i = lo + jax.random.randint(key_t, (), 0, 1 << 30) % (hi - lo)
        f_plus = loss_fn(x.at[i].add(delta))
        # Algorithm 1: always move; +δ if it improves on the current loss
        sign = jnp.where(f_plus < f, 1.0, -1.0)
        x_new = x.at[i].add(sign * delta)
        carry["x"] = x_new
        carry["f"] = jnp.where(f_plus < f, f_plus, loss_fn(x_new))
        return carry
    return step


def _ztp_step(loss_fn, n):
    def step(carry, key_t):
        x, f, delta = carry["x"], carry["f"], carry["delta"]
        u = jax.random.normal(key_t, (n,))
        u = u / (jnp.linalg.norm(u) + 1e-12)
        xp, xn = x + delta * u, x - delta * u
        fp, fn_ = loss_fn(xp), loss_fn(xn)
        cands_f = jnp.stack([f, fp, fn_])
        best = jnp.argmin(cands_f)
        carry["x"] = jnp.stack([x, xp, xn])[best]
        carry["f"] = cands_f[best]
        return carry
    return step


def _zgd_step(loss_fn, n, cfg: ZOConfig):
    def step(carry, key_t):
        x, delta, m, t = carry["x"], carry["delta"], carry["m"], carry["t"]
        u = jax.random.normal(key_t, (n,))
        u = u / (jnp.linalg.norm(u) + 1e-12)
        g = (loss_fn(x + delta * u) - loss_fn(x - delta * u)) / (2 * delta) * u
        m = cfg.momentum * m + g
        lr = cfg.lr0 * (0.999 ** t)
        x = x - lr * m
        carry["x"], carry["m"] = x, m
        carry["f"] = loss_fn(x)
        return carry
    return step
