"""Optimizers: first-order (AdamW/SGD, fp32-master), zeroth-order (ZCD/ZTP/
ZGD with best-recording), LR schedules, and gradient compression."""

from .zo import ZOConfig, zo_minimize  # noqa: F401
from .optimizers import (  # noqa: F401
    AdamWConfig, SGDConfig, OptState, init_opt_state, apply_updates,
    clip_by_global_norm,
)
from .schedules import cosine_schedule, linear_warmup_cosine  # noqa: F401
