"""int8 error-feedback gradient compression for the DP all-reduce.

At 1000+-node scale the Σ-gradient all-reduce across the ``("pod","data")``
axes dominates step latency for small-per-chip workloads.  We compress
each leaf to int8 with a per-leaf scale before the psum and keep the
quantization residual locally (error feedback), which preserves
convergence (signSGD/EF theory [3] in the paper's related work).

Used inside a ``shard_map``-ped train step: ``compress → psum(int8 as
int32 accum) → decompress``.  The error buffer is part of the training
state and is checkpointed.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionState", "init_compression", "compress_decompress",
           "psum_compressed"]

PyTree = Any


class CompressionState(NamedTuple):
    error: PyTree  # residual feedback buffers, same structure as grads


def init_compression(grads_like: PyTree) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                           grads_like))


def _quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(g: jax.Array, err: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    """Quantize (g + err) to int8; return (dequantized, new_err)."""
    t = g.astype(jnp.float32) + err
    q, scale = _quantize(t)
    deq = q.astype(jnp.float32) * scale
    return deq, t - deq


def psum_compressed(grads: PyTree, state: CompressionState, axis_name,
                    ) -> tuple[PyTree, CompressionState]:
    """Error-feedback int8 all-reduce of a gradient pytree over ``axis_name``.

    Communicates int8 payloads (4× less ICI traffic than fp32); the int32
    accumulation and rescale happen on-chip.  Must run inside shard_map.
    """
    def one(g, err):
        t = g.astype(jnp.float32) + err
        q, scale = _quantize(t)
        deq_local = q.astype(jnp.float32) * scale
        new_err = t - deq_local
        # communicate int8 (widened to int32 for the additive collective)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        smax = jax.lax.pmax(scale, axis_name)  # shared conservative scale
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (acc.astype(jnp.float32) * smax / n).astype(g.dtype), new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_e = treedef.unflatten([o[1] for o in outs])
    return new_g, CompressionState(error=new_e)
