"""Deterministic synthetic data pipelines (offline container: no external
datasets; tasks are constructed to be LEARNABLE so end-to-end training
demonstrations show real loss curves)."""

from .synthetic import (  # noqa: F401
    lm_batch, lm_batch_stream, synthetic_vision, vision_stream,
    vowel_stream, transfer_vision,
)
