"""Synthetic, deterministic, learnable datasets.

* ``lm_batch`` — an order-2 Markov token stream with a fixed random
  transition table: next-token entropy ≪ uniform, so an LM that learns
  shows a clearly falling loss (used by examples/train_lm.py).
* ``synthetic_vision`` — class-templated images + noise (stand-ins for
  MNIST/FashionMNIST/CIFAR in the paper-reproduction experiments).
  ``transfer_vision`` derives a second task from rotated templates for
  the Fig-14 transfer experiments.
* ``vowel_stream`` — 8-feature 4-class Gaussian blobs (the Vowel MLP).

Everything is a pure function of (seed, step) — restart-safe: the data
pipeline needs no checkpoint state beyond the step counter, which is the
fault-tolerance-friendly design (any worker can regenerate any batch).
"""

from __future__ import annotations

import numpy as np

__all__ = ["lm_batch", "lm_batch_stream", "synthetic_vision",
           "vision_stream", "vowel_stream", "transfer_vision"]


def _markov_table(vocab: int, seed: int = 0, branch: int = 4) -> np.ndarray:
    """(vocab, vocab) table: each context allows `branch` next tokens."""
    rng = np.random.default_rng(seed)
    table = np.zeros((vocab, branch), dtype=np.int64)
    for c in range(vocab):
        table[c] = rng.choice(vocab, size=branch, replace=False)
    return table


_TABLES: dict = {}


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int
             ) -> dict[str, np.ndarray]:
    """One (tokens, labels) LM batch — order-1 Markov with 4-way branching."""
    key = (vocab, seed)
    if key not in _TABLES:
        _TABLES[key] = _markov_table(vocab, seed)
    table = _TABLES[key]
    rng = np.random.default_rng((seed + 1) * 1_000_003 + step)
    toks = np.empty((batch, seq + 1), dtype=np.int32)
    toks[:, 0] = rng.integers(0, vocab, batch)
    choices = rng.integers(0, table.shape[1], (batch, seq))
    for t in range(seq):
        toks[:, t + 1] = table[toks[:, t], choices[:, t]]
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def lm_batch_stream(seed: int, batch: int, seq: int, vocab: int, steps: int):
    for step in range(steps):
        yield lm_batch(seed, step, batch, seq, vocab)


def _templates(n_classes: int, shape: tuple, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n_classes,) + shape).astype(np.float32)


def synthetic_vision(seed: int, step: int, batch: int, shape: tuple,
                     n_classes: int, noise: float = 1.0,
                     rot_classes: bool = False) -> dict[str, np.ndarray]:
    """Class-template + Gaussian-noise images; ``rot_classes`` derives a
    RELATED transfer task: the class templates are permuted and
    perturbed, so the feature subspace is shared but the readout must be
    re-learned (the CIFAR-100→CIFAR-10 analogue of Fig. 14)."""
    tpl = _templates(n_classes, shape, seed)
    if rot_classes:
        # task B's classes are linear mixes of task A's templates — the
        # FEATURE SUBSPACE is shared (as in CIFAR-100→10), only the
        # class readout differs, which is what Σ-only adaptation can do
        rng_t = np.random.default_rng(seed + 77)
        mix = rng_t.standard_normal((n_classes, n_classes)).astype(
            np.float32)
        mix, _ = np.linalg.qr(mix)
        flat = tpl.reshape(n_classes, -1)
        tpl = (mix @ flat).reshape(tpl.shape) * 1.0
    rng = np.random.default_rng((seed + 2) * 999_983 + step)
    y = rng.integers(0, n_classes, batch).astype(np.int32)
    x = tpl[y] + noise * rng.standard_normal((batch,) + shape).astype(
        np.float32)
    return {"x": x, "y": y}


def vision_stream(seed: int, batch: int, shape: tuple, n_classes: int,
                  steps: int, **kw):
    for step in range(steps):
        yield synthetic_vision(seed, step, batch, shape, n_classes, **kw)


def transfer_vision(seed: int, step: int, batch: int, shape: tuple,
                    n_classes: int, noise: float = 1.0):
    return synthetic_vision(seed, step, batch, shape, n_classes, noise,
                            rot_classes=True)


def vowel_stream(seed: int, batch: int, steps: int):
    """8-feature 4-class Gaussian blobs (the paper's Vowel MLP task)."""
    for step in range(steps):
        yield synthetic_vision(seed, step, batch, (8,), 4, noise=0.6)
