"""Jittable step builders shared by train/serve drivers and the dry-run."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..core.sparsity import SparsityConfig
from ..models.lm import (ArchConfig, build_train_step, build_serve_step,
                         forward, model_trainable_mask)
from ..optim.optimizers import (AdamWConfig, SGDConfig, init_opt_state,
                                apply_updates)
from ..optim.compression import psum_compressed

__all__ = ["build_update_step", "build_prefill_step", "build_serve_step",
           "init_train_state"]


def init_train_state(key, cfg: ArchConfig):
    from ..models.lm import init_model
    params = init_model(key, cfg)
    opt = init_opt_state(params, model_trainable_mask(params))
    return params, opt


def build_update_step(cfg: ArchConfig, ocfg: AdamWConfig | SGDConfig,
                      sparsity: SparsityConfig | None = None,
                      lr_schedule=None):
    """(params, opt_state, batch, key) → (params, opt_state, loss, gnorm).

    The full production step: sampled in-situ gradients → (optional
    schedule) → AdamW on the trainable leaves only (Σ + electronics)."""
    ts = build_train_step(cfg, sparsity)

    def update_step(params, opt_state, batch, key):
        loss, grads = ts(params, batch, key)
        scale = lr_schedule(opt_state.step) if lr_schedule else 1.0
        tr = model_trainable_mask(params)
        params, opt_state, gnorm = apply_updates(
            params, grads, opt_state, ocfg, lr_scale=scale, trainable=tr)
        return params, opt_state, loss, gnorm

    return update_step


def build_prefill_step(cfg: ArchConfig):
    """(params, batch{tokens,…}) → last-position logits (inference
    prefill; the prefill_32k dry-run cell)."""

    def prefill_step(params, batch):
        logits, _ = forward(params, cfg, batch)
        return logits[:, -1]

    return prefill_step
