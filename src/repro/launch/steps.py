"""Jittable step builders shared by train/serve drivers and the dry-run."""

from __future__ import annotations

import contextlib
from typing import Callable

import jax.numpy as jnp
import numpy as np

from ..core.sparsity import SparsityConfig
from ..models.lm import (ArchConfig, build_train_step, build_serve_step,
                         forward, model_trainable_mask)
from ..optim.optimizers import (AdamWConfig, SGDConfig, init_opt_state,
                                apply_updates)

__all__ = ["build_update_step", "build_prefill_step", "build_serve_step",
           "init_train_state", "greedy_decode"]


def init_train_state(key, cfg: ArchConfig):
    from ..models.lm import init_model
    params = init_model(key, cfg)
    opt = init_opt_state(params, model_trainable_mask(params))
    return params, opt


def build_update_step(cfg: ArchConfig, ocfg: AdamWConfig | SGDConfig,
                      sparsity: SparsityConfig | None = None,
                      lr_schedule=None):
    """(params, opt_state, batch, key) → (params, opt_state, loss, gnorm).

    The full production step: sampled in-situ gradients → (optional
    schedule) → AdamW on the trainable leaves only (Σ + electronics)."""
    ts = build_train_step(cfg, sparsity)

    def update_step(params, opt_state, batch, key):
        loss, grads = ts(params, batch, key)
        scale = lr_schedule(opt_state.step) if lr_schedule else 1.0
        tr = model_trainable_mask(params)
        params, opt_state, gnorm = apply_updates(
            params, grads, opt_state, ocfg, lr_scale=scale, trainable=tr)
        return params, opt_state, loss, gnorm

    return update_step


def greedy_decode(serve_step, params, cache, prompt, gen: int,
                  extras: dict | None = None,
                  on_step: Callable[[int], None] | None = None,
                  layer_exec=None,
                  preds_out: list | None = None,
                  logits_out: list | None = None,
                  eos_id: int | None = None):
    """One shared serve path: teacher-forced prefill through the decode
    cache, then greedy generation of ``gen`` tokens.

    ``serve_step`` is a (jitted) ``build_serve_step`` product; ``prompt``
    is (B, prompt_len) int32.  The prompt region streams token-by-token
    so the KV cache fills along the same code path generation uses (no
    separate prefill kernel on this CPU driver).  ``on_step(i)`` is
    invoked after every decode-path step — prefill positions included,
    ``prompt_len + gen − 1`` calls total — since each one is a real pass
    through the serving hardware; the fleet router hooks its
    drift/health clock here, so the CLI and the runtime fleet share one
    loop instead of each reimplementing it.

    ``layer_exec`` plugs a layer-execution plane into the loop
    (:class:`repro.runtime.hw_serve.HwServePlane`): its ``hook`` is
    installed as the PTC executor for the whole decode and every step
    body runs inside ``layer_exec.step(i)`` — the decode-path PTC
    matmuls then run on routed photonic chips, with drift advanced and
    repairs scheduled between steps.  Requires an *unjitted* serve step
    built from an ``unroll=True`` config (under a trace the hook is
    structurally inert and logits would silently stay digital).

    ``preds_out`` / ``logits_out``: optional lists that collect the
    per-step argmax predictions (B,) / raw logits (B, V) for EVERY
    decode-path position, prefill included — the teacher-forced
    accuracy metric and the transport bit-identity gates read these.

    ``eos_id`` enables per-sequence early termination: once a sequence
    *emits* the stop token (generation region only — teacher-forced
    prefill predictions never terminate), it is finished and every
    later column of its row is frozen to ``eos_id`` (and fed back
    frozen, so live sequences decode exactly as they would alone).
    When every sequence has finished the loop exits early — trailing
    hardware passes are never issued.  ``preds_out``/``logits_out``
    keep collecting the *raw* per-step argmax/logits for steps that
    run (the accuracy + bit-identity consumers want the model's
    predictions, not the frozen padding).

    Returns ``(generated, cache)`` with ``generated`` (B, gen) numpy.
    """
    from ..models.layers import ptc_execution

    extras = extras or {}
    prompt_len = prompt.shape[1]
    max_len = prompt_len + gen
    tok = jnp.asarray(prompt[:, :1])
    out_tokens = []
    finished = np.zeros((prompt.shape[0],), bool)
    hook_ctx = (ptc_execution(layer_exec.hook) if layer_exec is not None
                else contextlib.nullcontext())
    with hook_ctx:
        for i in range(max_len - 1):
            batch = {"token": tok, "cache_len": jnp.asarray(i, jnp.int32),
                     **extras}
            step_ctx = (layer_exec.step(i) if layer_exec is not None
                        else contextlib.nullcontext())
            with step_ctx:
                logits, cache = serve_step(params, cache, batch)
            nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            if preds_out is not None:
                preds_out.append(np.asarray(nxt)[:, 0])
            if logits_out is not None:
                logits_out.append(np.asarray(logits))
            if i + 1 < prompt_len:
                tok = jnp.asarray(prompt[:, i + 1: i + 2])  # teacher-forced
            else:
                emitted = np.asarray(nxt)[:, 0]
                if eos_id is not None:
                    emitted = np.where(finished, np.int32(eos_id), emitted)
                    finished |= emitted == eos_id
                    nxt = jnp.asarray(emitted)[:, None]
                tok = nxt
                out_tokens.append(emitted)
            if on_step is not None:
                on_step(i)
            if eos_id is not None and finished.all():
                break
    if not out_tokens:        # gen=0: prefill-only run
        return np.zeros((prompt.shape[0], 0), np.int32), cache
    gen_out = np.stack(out_tokens, axis=1)
    if eos_id is not None and gen_out.shape[1] < gen:
        pad = np.full((gen_out.shape[0], gen - gen_out.shape[1]),
                      eos_id, np.int32)
        gen_out = np.concatenate([gen_out, pad], axis=1)
    return gen_out, cache


def build_prefill_step(cfg: ArchConfig):
    """(params, batch{tokens,…}) → last-position logits (inference
    prefill; the prefill_32k dry-run cell)."""

    def prefill_step(params, batch):
        logits, _ = forward(params, cfg, batch)
        return logits[:, -1]

    return prefill_step
