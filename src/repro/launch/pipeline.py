"""Pipeline parallelism: GPipe microbatching over the "pod" axis.

At multi-pod scale the inter-pod links are the slow dimension; PP turns
them into point-to-point activation hops instead of full DP gradient
reductions.  The period-stacked layer parameters shard NATURALLY over
the pipe axis (leading ``n_periods`` axis → ``n_periods/S`` local
periods per stage), so no parameter surgery is needed.

Schedule: classic GPipe — ``n_micro + S − 1`` ticks; stage ``s``
processes microbatch ``t − s`` at tick ``t``; activations hop stage→
stage+1 via ``jax.lax.ppermute`` each tick.  The backward pipeline falls
out of jax autodiff (ppermute transposes to the reverse hop); per-tick
``jax.checkpoint`` keeps in-flight activation memory to
O(n_micro · microbatch).

Scope: decoder-only single-position-plan archs (olmo/qwen3/chatglm —
``period_plan`` length 1); embedding runs on stage 0, unembed + CE on
the last stage, loss psum'd.  Demonstrated and equivalence-tested in
tests/test_pipeline.py; measured vs the DP baseline in EXPERIMENTS §PP.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..models.lm import (ArchConfig, period_plan, _sublayer_fwd, _apply_norm,
                         embed, softcap, cross_entropy)

Params = dict[str, Any]

__all__ = ["build_pp_loss", "pp_param_specs"]

PIPE_AXIS = "pod"


def pp_param_specs(params: Params) -> Params:
    """shard_map in_specs: layer stacks split over the pipe axis on their
    leading period axis; embed/unembed/norms replicated."""
    def spec_for(path_leaf):
        return None
    specs: Params = {}
    for k, v in params.items():
        if k.startswith("pos"):
            specs[k] = jax.tree.map(
                lambda leaf: P(PIPE_AXIS, *([None] * (leaf.ndim - 1))), v)
        else:
            specs[k] = jax.tree.map(lambda leaf: P(), v)
    return specs


def build_pp_loss(cfg: ArchConfig, n_stages: int, n_micro: int):
    """Returns loss_fn(params, batch) running the GPipe schedule inside a
    shard_map over the pipe axis.  Requires:
    * single-position period plan (plan length 1);
    * n_periods % n_stages == 0; global batch % n_micro == 0."""
    plan, n_periods = period_plan(cfg)
    assert len(plan) == 1, "PP demo supports single-position plans"
    assert n_periods % n_stages == 0

    def stage_stack(stack_local, x, positions):
        """Run this stage's local periods (scan over n_periods/S)."""
        def body(carry, layer_params):
            h, _ = _sublayer_fwd(cfg, plan[0], layer_params, carry,
                                 positions)
            return h, None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, stack_local)
        return x

    def local_fn(params, tokens, labels, stage_ids):
        # tokens/labels: (B_global, S) replicated over the pipe axis;
        # stage_ids: (n_stages,) split over it → this shard's (1,) slice
        # is the stage index.  (An input, not lax.axis_index: axis_index
        # inside partial-manual shard_map lowers to a PartitionId op
        # older XLA SPMD pipelines reject.)
        stage = stage_ids[0]
        b, s = tokens.shape
        mb = b // n_micro
        positions = jnp.broadcast_to(jnp.arange(s)[None], (mb, s))

        def embed_micro(m):
            toks = jax.lax.dynamic_slice_in_dim(tokens, m * mb, mb, 0)
            x = embed(params["embed"], toks)
            return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

        def tail_loss(x, m):
            x = _apply_norm(cfg, params["final_norm"], x)
            logits = x @ (params["embed"]["e"].T if cfg.tie_embed
                          else params["unembed"]["w"].T)
            logits = softcap(logits, cfg.final_softcap)
            lbl = jax.lax.dynamic_slice_in_dim(labels, m * mb, mb, 0)
            return cross_entropy(logits, lbl)

        d = cfg.d_model
        carry_in = jnp.zeros((mb, s, d), params["embed"]["e"].dtype)
        loss_acc = jnp.zeros((), jnp.float32)

        def tick(state, t):
            carry_in, loss_acc = state
            m_here = t - stage                  # microbatch index at stage
            active = (m_here >= 0) & (m_here < n_micro)
            m_safe = jnp.clip(m_here, 0, n_micro - 1)
            # stage 0 ingests a fresh microbatch; others take the hop-in
            x = jnp.where(stage == 0, embed_micro(m_safe), carry_in)
            y = stage_stack(params["stack_local"], x, positions)
            # last stage: CE on its active ticks
            is_last = stage == n_stages - 1
            lm = tail_loss(y, m_safe)
            loss_acc = loss_acc + jnp.where(
                active & is_last, lm, 0.0)
            # hop activations to the next stage
            carry_out = jax.lax.ppermute(
                y, PIPE_AXIS,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (carry_out, loss_acc), None

        (carry_in, loss_acc), _ = jax.lax.scan(
            tick, (carry_in, loss_acc), jnp.arange(n_micro + n_stages - 1))
        # every stage returns the same global mean loss
        total = jax.lax.psum(loss_acc, PIPE_AXIS)
        return total / n_micro

    def loss_fn(params, batch, mesh):
        # split the layer stack over the pipe axis; rest replicated
        stack = params["pos0"]
        other = {k: v for k, v in params.items() if k != "pos0"}
        in_specs = (
            {**{k: jax.tree.map(lambda _: P(), v) for k, v in other.items()},
             "stack_local": jax.tree.map(
                 lambda leaf: P(PIPE_AXIS, *([None] * (leaf.ndim - 1))),
                 stack)},
            P(), P(), P(PIPE_AXIS))
        # manual ONLY over the pipe axis — data/model stay under the
        # partitioner (the inner stage compute keeps its DP/TP sharding)
        fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=P(), check_vma=False,
                       axis_names=frozenset({PIPE_AXIS}))
        return fn({**other, "stack_local": stack},
                  batch["tokens"], batch["labels"],
                  jnp.arange(n_stages, dtype=jnp.int32))

    return loss_fn
