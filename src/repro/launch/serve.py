"""Batched serving driver: greedy decode against a KV cache.

Runnable on this CPU container with smoke configs::

    PYTHONPATH=src python -m repro.launch.serve --arch smoke:qwen3-4b \
        --batch 4 --prompt-len 16 --gen 32

With ``--fleet N`` the decode loop is dispatched through the closed-loop
photonic runtime (``repro.runtime``): N virtual chip instances with
independent device realizations back the serving plane, health probes
run out-of-band, and (with ``--drift``) thermal phase drift degrades
chips until the router schedules recalibration around live traffic.
With ``--fleet-tenants T`` every chip is time-multiplexed across T
mapped layers (per-layer Σ banks), and each decode step's PTC traffic
is routed to a (chip, tenant) slot — step ``i`` exercises tenant
``i mod T``, the round-robin a T-layer model would drive — so a single
drifted layer triggers *partial* recalibration of its own blocks only.
In this mode the LM math itself stays on the digital twin; the fleet
models the photonic boards' device state, health, and routing.

``--hw-logits`` goes the rest of the way: the served model's own PTC
layers deploy onto the fleet chips (one tenant per layer, via
``core.mapping.parallel_map(block_range=)``), each decode step routes
the *whole forward pass* to one chip, and every PTC matmul executes
through ``driver.forward_layer`` against that chip's realized
(drifted!) transfer — the logits ARE what the photonic hardware
computes, so accuracy-vs-drift is measurable end to end
(``benchmarks/e2e_accuracy.py``).  Sibling projections sharing one
input (q/k/v, gate/up) ship as one v3 ``batch`` frame.  ``--hw-shadow``
deploys identically but applies the deployment-time readback transfer
digitally — the twin-path reference that is token-identical to
``--hw-logits`` at σ_drift = 0 (a conformance gate across all three
driver transports).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..data import lm_batch
from ..models.lm import (ArchConfig, init_model, init_decode_cache,
                         build_serve_step)
from .steps import greedy_decode
from .train import parse_arch


def add_autopilot_args(ap: argparse.ArgumentParser) -> None:
    """Fleet scheduling/routing knobs shared by ``launch.serve`` and
    ``serving.gateway`` (both build their fleet via
    :func:`_hw_runtime_config`)."""
    ap.add_argument("--autopilot", action="store_true",
                    help="forecast-driven fleet maintenance: proactive "
                         "recals before predicted alarm crossings, "
                         "degradation-rate repair priority, trough-"
                         "scheduled via the gateway's occupancy signal")
    ap.add_argument("--ap-horizon", type=int, default=40,
                    help="autopilot: proactive window (ticks)")
    ap.add_argument("--ap-trough", type=float, default=0.5,
                    help="autopilot: load forecast at/below this "
                         "fraction of capacity counts as a trough")
    ap.add_argument("--ap-budget", type=float, default=None,
                    help="autopilot: recal PTC-call envelope per window "
                         "(default unlimited)")
    ap.add_argument("--ap-window", type=int, default=200,
                    help="autopilot: budget window (ticks)")
    ap.add_argument("--fleet-policy", default=None,
                    choices=["drift_aware", "accuracy_aware",
                             "least_served"],
                    help="dispatch ranking policy (default: the demo "
                         "config's drift_aware)")


def _apply_fleet_policy(args, cfg):
    """Fold the shared CLI scheduling knobs into a RuntimeConfig."""
    policy = getattr(args, "fleet_policy", None)
    if policy:
        cfg = dataclasses.replace(cfg, router_policy=policy)
    if getattr(args, "autopilot", False):
        from ..runtime.autopilot import AutopilotConfig
        budget = getattr(args, "ap_budget", None)
        cfg = dataclasses.replace(cfg, autopilot=AutopilotConfig(
            horizon=getattr(args, "ap_horizon", 40),
            trough_load=getattr(args, "ap_trough", 0.5),
            budget_calls=float("inf") if budget is None else budget,
            budget_window=getattr(args, "ap_window", 200)))
    return cfg


def _build_fleet(args):
    from ..runtime.demo import default_runtime_config, _make_weights
    from ..runtime.fleet import make_fleet, make_router

    sigma = args.drift_sigma if args.drift else 0.0
    cfg = default_runtime_config(k=args.fleet_k, sigma_drift=sigma,
                                 probe_every=args.probe_every,
                                 driver_kind=args.fleet_driver)
    cfg = _apply_fleet_policy(args, cfg)
    kw, kf = jax.random.split(jax.random.PRNGKey(args.seed + 17))
    dim = args.fleet_dim
    tenants = max(1, args.fleet_tenants)
    weights = _make_weights(kw, dim, tenants)
    chips = make_fleet(kf, args.fleet,
                       weights if tenants > 1 else weights[0], cfg)
    return make_router(chips, cfg, seed=args.seed), dim, tenants


def _hw_runtime_config(args):
    """Fleet policy for the hw-logits plane: explicit override via
    ``args.runtime_cfg`` (the accuracy benchmark tunes thresholds), else
    the demo defaults at the CLI-selected drift/probe cadence, with the
    shared scheduling knobs (--autopilot, --fleet-policy) folded in."""
    from ..runtime.demo import default_runtime_config

    cfg = getattr(args, "runtime_cfg", None)
    if cfg is None:
        sigma = args.drift_sigma if args.drift else 0.0
        cfg = default_runtime_config(k=args.fleet_k, sigma_drift=sigma,
                                     probe_every=args.probe_every,
                                     driver_kind=args.fleet_driver)
        cfg = _apply_fleet_policy(args, cfg)
    if getattr(args, "deploy_zo", False):
        cfg = dataclasses.replace(cfg, deploy_zo=True)
    return cfg


def _build_hw_plane(args, cfg, params, serve_fn, extras, mode: str):
    """Enumerate the model's decode-path PTC layers (one dry digital
    step) and deploy them — one tenant per layer — onto a fresh fleet."""
    from ..runtime.hw_serve import record_ptc_layers, HwServePlane

    cache0 = init_decode_cache(cfg, args.batch, 2)
    batch0 = {"token": jnp.zeros((args.batch, 1), jnp.int32),
              "cache_len": jnp.asarray(0, jnp.int32), **extras}
    layers = record_ptc_layers(serve_fn, params, cache0, batch0)
    kf = jax.random.split(jax.random.PRNGKey(args.seed + 17))[1]
    return HwServePlane(kf, layers, _hw_runtime_config(args), args.fleet,
                        mode=mode, seed=args.seed,
                        recal_enabled=not getattr(args, "no_recal", False))


def run(args) -> dict:
    """Serve ``args.gen`` tokens (optionally through the fleet runtime)
    and return the outcome: generated tokens, per-step argmax
    predictions, plus the router's report — the seeded-regression
    surface the e2e tests lock down.

    With ``--gateway`` the whole run is delegated to the continuous-
    batching gateway (``repro.serving``): the workload becomes an
    open-loop request stream instead of one lockstep batch, and the
    returned dict is the gateway report."""
    if getattr(args, "gateway", False):
        from ..serving.gateway import run as run_gateway
        return run_gateway(args)
    cfg = (args.arch if isinstance(args.arch, ArchConfig)
           else parse_arch(args.arch))
    hw_mode = None
    if getattr(args, "hw_logits", False):
        hw_mode = "route"
    if getattr(args, "hw_shadow", False):
        if hw_mode is not None:
            raise ValueError("--hw-logits and --hw-shadow are exclusive")
        hw_mode = "shadow"
    if hw_mode is not None:
        if args.fleet <= 0:
            raise ValueError("--hw-logits/--hw-shadow need --fleet N chips")
        if cfg.n_experts > 0:
            # expert FFNs execute under jax.vmap, where the layer hook
            # is structurally inert (tracer guard) — serving them would
            # silently leave the dominant FFN compute digital while
            # claiming hardware logits.  Refuse until stacked-factor
            # tenants land (ROADMAP: hw-logits for MoE experts).
            raise ValueError(
                f"--hw-logits/--hw-shadow do not support MoE archs yet "
                f"({cfg.name}: {cfg.n_experts} experts run under vmap, "
                f"unreachable by the PTC execution hook)")
        # the layer-execution hook needs concrete activations: run the
        # decode body as an unjitted python loop over periods
        cfg = dataclasses.replace(cfg, unroll=True, remat=False)

    params = getattr(args, "params_override", None)
    if params is None:
        params = init_model(jax.random.PRNGKey(args.seed), cfg)

    prompt = getattr(args, "prompt_tokens", None)
    if prompt is None:
        prompt = lm_batch(args.seed, 0, args.batch, args.prompt_len,
                          cfg.vocab)["tokens"]
    else:
        prompt = np.asarray(prompt, np.int32)
    prompt_len = int(prompt.shape[1])
    max_len = prompt_len + args.gen
    cache = init_decode_cache(cfg, args.batch, max_len)
    serve_fn = build_serve_step(cfg)
    serve = serve_fn if hw_mode is not None else jax.jit(serve_fn)

    extras = {}
    if cfg.family == "vlm":
        extras["img"] = 0.1 * jnp.ones(
            (args.batch, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        extras["enc_out"] = 0.1 * jnp.ones(
            (args.batch, prompt_len, cfg.d_model), jnp.float32)

    on_step = None
    router = None
    plane = None
    report = None
    if hw_mode is not None:
        plane = _build_hw_plane(args, cfg, params, serve_fn, extras, hw_mode)
    elif args.fleet > 0:
        router, fleet_dim, tenants = _build_fleet(args)
        kx = jax.random.PRNGKey(args.seed + 23)

        def on_step(i):
            # every serve-path step (prefill included) runs on one
            # routed (drifted) board, on the step's (chip, tenant) slot
            x = jax.random.normal(jax.random.fold_in(kx, i),
                                  (args.batch, fleet_dim))
            router.serve(x, tenant=i % tenants)
            router.tick()

    preds: list = []
    logits_trace: list | None = \
        [] if getattr(args, "trace_logits", False) else None
    try:
        t0 = time.time()
        gen, cache = greedy_decode(serve, params, cache, prompt, args.gen,
                                   extras=extras, on_step=on_step,
                                   layer_exec=plane, preds_out=preds,
                                   logits_out=logits_trace)
        dt = time.time() - t0
        if plane is not None:
            report = plane.report()
        elif router is not None:
            report = router.report()
    finally:
        if plane is not None:
            plane.close()
        if router is not None:
            router.close()
    out = dict(gen=np.asarray(gen), wall_s=dt, report=report,
               preds=np.stack(preds, axis=1) if preds else
               np.zeros((args.batch, 0), np.int32))
    if logits_trace is not None:
        out["logits"] = np.stack(logits_trace, axis=0)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fleet", type=int, default=0,
                    help="route decode steps through N virtual chips")
    ap.add_argument("--drift", action="store_true",
                    help="enable thermal phase drift on the fleet")
    ap.add_argument("--drift-sigma", type=float, default=0.015)
    ap.add_argument("--probe-every", type=int, default=10)
    ap.add_argument("--fleet-k", type=int, default=6)
    ap.add_argument("--fleet-dim", type=int, default=18)
    ap.add_argument("--fleet-tenants", type=int, default=1,
                    help="mapped layers time-sharing each chip; decode "
                         "step i routes to tenant i %% T (synthetic-"
                         "traffic mode; --hw-logits derives tenants from "
                         "the model instead)")
    ap.add_argument("--fleet-driver", default="twin",
                    choices=["twin", "subprocess", "socket"],
                    help="photonic device transport behind the fleet")
    ap.add_argument("--hw-logits", action="store_true",
                    help="deploy the model's PTC layers onto the fleet "
                         "(one tenant per layer) and execute every "
                         "decode-path matmul through the routed chip's "
                         "realized transfer — logits come from the "
                         "(drifting) hardware, not the digital twin")
    ap.add_argument("--hw-shadow", action="store_true",
                    help="deploy like --hw-logits but serve from the "
                         "deployment-time readback transfer digitally "
                         "(the σ=0 token-identity reference path)")
    ap.add_argument("--deploy-zo", action="store_true",
                    help="run PM's alternate-ZCD stage at deployment "
                         "(lower mapping floor for accuracy studies)")
    ap.add_argument("--no-recal", action="store_true",
                    help="open loop: alarms fire, nothing recovers")
    add_autopilot_args(ap)
    ap.add_argument("--gateway", action="store_true",
                    help="serve an open-loop request stream through the "
                         "continuous-batching gateway (repro.serving) "
                         "instead of one lockstep batch; --gw-* flags "
                         "configure it")
    from ..serving.gateway import add_gateway_args
    add_gateway_args(ap)
    args = ap.parse_args(argv)

    if args.gateway:
        rep = run(args)
        c = rep["config"]
        lat = rep["latency_steps"]
        print(f"gateway [{c['hw_mode']}] {c['arch']}: {c['n_requests']} "
              f"requests, {rep['tokens_out']} tokens in "
              f"{rep['wall_s']:.1f}s ({rep['tokens_per_s']:.1f} tok/s), "
              f"latency p50={lat['p50']:.0f} p99={lat['p99']:.0f} steps")
        return 0

    out = run(args)
    gen = out["gen"]
    print(f"generated {gen.shape} tokens in {out['wall_s']:.1f}s "
          f"({gen.size / out['wall_s']:.1f} tok/s)")
    print("sample:", gen[0][:24])

    rep = out["report"]
    if rep is not None:
        alarms = sum(c["alarms"] for c in rep["chips"])
        recals = sum(c["recals"] for c in rep["chips"])
        n_tenants = len(rep["chips"][0]["tenants"])
        print(f"fleet: {args.fleet} chips x {n_tenants} "
              f"tenant(s), {rep['ticks']} ticks, "
              f"{rep['dropped']} dropped, {alarms} alarms, "
              f"{recals} recals")
        hw = rep.get("hw")
        if hw is not None:
            print(f"hw-logits [{hw['mode']}]: {len(hw['layers'])} PTC "
                  f"layers as tenants, {hw['frames']} driver frames over "
                  f"{hw['steps']} steps "
                  f"({hw['frames_per_step']:.1f} frames/step), "
                  f"{hw['hw_calls']} hw matmuls, "
                  f"{hw['shadow_calls']} shadow matmuls, "
                  f"{hw['dropped_passes']} dropped passes")
        for c in rep["chips"]:
            print(f"  chip {c['chip']}: {c['status']:<13} "
                  f"served={c['served']:4d} d̂={c['distance']:.4f} "
                  f"alarms={c['alarms']} recals={c['recals']}")
            if n_tenants > 1:
                for t in c["tenants"]:
                    print(f"    tenant {t['tenant']} "
                          f"blocks{t['block_range']}: "
                          f"served={t['served']:4d} d̂={t['distance']:.4f} "
                          f"alarms={t['alarms']} recals={t['recals']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
