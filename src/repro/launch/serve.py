"""Batched serving driver: greedy decode against a KV cache.

Runnable on this CPU container with smoke configs::

    PYTHONPATH=src python -m repro.launch.serve --arch smoke:qwen3-4b \
        --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..data import lm_batch
from ..models.lm import (init_model, init_decode_cache, build_serve_step)
from .train import parse_arch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = parse_arch(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_model(key, cfg)
    max_len = args.prompt_len + args.gen
    cache = init_decode_cache(cfg, args.batch, max_len)
    serve = jax.jit(build_serve_step(cfg))

    prompt = lm_batch(args.seed, 0, args.batch, args.prompt_len,
                      cfg.vocab)["tokens"]
    extras = {}
    if cfg.family == "vlm":
        extras["img"] = 0.1 * jnp.ones(
            (args.batch, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        extras["enc_out"] = 0.1 * jnp.ones(
            (args.batch, args.prompt_len, cfg.d_model), jnp.float32)

    # prefill by streaming the prompt through the decode path (cache fills)
    tok = jnp.asarray(prompt[:, :1])
    t0 = time.time()
    out_tokens = []
    for i in range(max_len - 1):
        batch = {"token": tok, "cache_len": jnp.asarray(i, jnp.int32),
                 **extras}
        logits, cache = serve(params, cache, batch)
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        if i + 1 < args.prompt_len:
            tok = jnp.asarray(prompt[:, i + 1: i + 2])   # teacher-forced
        else:
            tok = nxt
            out_tokens.append(np.asarray(nxt)[:, 0])
    dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"generated {gen.shape} tokens in {dt:.1f}s "
          f"({gen.size / dt:.1f} tok/s)")
    print("sample:", gen[0][:24])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
