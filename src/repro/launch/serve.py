"""Batched serving driver: greedy decode against a KV cache.

Runnable on this CPU container with smoke configs::

    PYTHONPATH=src python -m repro.launch.serve --arch smoke:qwen3-4b \
        --batch 4 --prompt-len 16 --gen 32

With ``--fleet N`` the decode loop is dispatched through the closed-loop
photonic runtime (``repro.runtime``): N virtual chip instances with
independent device realizations back the serving plane, health probes
run out-of-band, and (with ``--drift``) thermal phase drift degrades
chips until the router schedules recalibration around live traffic.
With ``--fleet-tenants T`` every chip is time-multiplexed across T
mapped layers (per-layer Σ banks), and each decode step's PTC traffic
is routed to a (chip, tenant) slot — step ``i`` exercises tenant
``i mod T``, the round-robin a T-layer model would drive — so a single
drifted layer triggers *partial* recalibration of its own blocks only.
The LM math itself stays on the digital twin; the fleet models the
photonic boards' device state, health, and routing — every decode step
is routed through one chip's *drifted* transfer function and accounted.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..data import lm_batch
from ..models.lm import init_model, init_decode_cache, build_serve_step
from .steps import greedy_decode
from .train import parse_arch


def _build_fleet(args):
    from ..runtime.demo import default_runtime_config, _make_weights
    from ..runtime.fleet import make_fleet, FleetRouter

    sigma = args.drift_sigma if args.drift else 0.0
    cfg = default_runtime_config(k=args.fleet_k, sigma_drift=sigma,
                                 probe_every=args.probe_every,
                                 driver_kind=args.fleet_driver)
    kw, kf = jax.random.split(jax.random.PRNGKey(args.seed + 17))
    dim = args.fleet_dim
    tenants = max(1, args.fleet_tenants)
    weights = _make_weights(kw, dim, tenants)
    chips = make_fleet(kf, args.fleet,
                       weights if tenants > 1 else weights[0], cfg)
    return FleetRouter(chips, cfg, seed=args.seed), dim, tenants


def run(args) -> dict:
    """Serve ``args.gen`` tokens (optionally through the fleet runtime)
    and return the outcome: generated tokens plus the router's report —
    the seeded-regression surface the e2e test locks down."""
    cfg = parse_arch(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_model(key, cfg)
    max_len = args.prompt_len + args.gen
    cache = init_decode_cache(cfg, args.batch, max_len)
    serve = jax.jit(build_serve_step(cfg))

    prompt = lm_batch(args.seed, 0, args.batch, args.prompt_len,
                      cfg.vocab)["tokens"]
    extras = {}
    if cfg.family == "vlm":
        extras["img"] = 0.1 * jnp.ones(
            (args.batch, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        extras["enc_out"] = 0.1 * jnp.ones(
            (args.batch, args.prompt_len, cfg.d_model), jnp.float32)

    on_step = None
    router = None
    report = None
    if args.fleet > 0:
        router, fleet_dim, tenants = _build_fleet(args)
        kx = jax.random.PRNGKey(args.seed + 23)

        def on_step(i):
            # every serve-path step (prefill included) runs on one
            # routed (drifted) board, on the step's (chip, tenant) slot
            x = jax.random.normal(jax.random.fold_in(kx, i),
                                  (args.batch, fleet_dim))
            router.serve(x, tenant=i % tenants)
            router.tick()

    try:
        t0 = time.time()
        gen, cache = greedy_decode(serve, params, cache, prompt, args.gen,
                                   extras=extras, on_step=on_step)
        dt = time.time() - t0
        if router is not None:
            report = router.report()
    finally:
        if router is not None:
            router.close()
    return dict(gen=np.asarray(gen), wall_s=dt, report=report)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fleet", type=int, default=0,
                    help="route decode steps through N virtual chips")
    ap.add_argument("--drift", action="store_true",
                    help="enable thermal phase drift on the fleet")
    ap.add_argument("--drift-sigma", type=float, default=0.015)
    ap.add_argument("--probe-every", type=int, default=10)
    ap.add_argument("--fleet-k", type=int, default=6)
    ap.add_argument("--fleet-dim", type=int, default=18)
    ap.add_argument("--fleet-tenants", type=int, default=1,
                    help="mapped layers time-sharing each chip; decode "
                         "step i routes to tenant i %% T")
    ap.add_argument("--fleet-driver", default="twin",
                    choices=["twin", "subprocess", "socket"],
                    help="photonic device transport behind the fleet")
    args = ap.parse_args(argv)

    out = run(args)
    gen = out["gen"]
    print(f"generated {gen.shape} tokens in {out['wall_s']:.1f}s "
          f"({gen.size / out['wall_s']:.1f} tok/s)")
    print("sample:", gen[0][:24])

    rep = out["report"]
    if rep is not None:
        alarms = sum(c["alarms"] for c in rep["chips"])
        recals = sum(c["recals"] for c in rep["chips"])
        print(f"fleet: {args.fleet} chips x {max(1, args.fleet_tenants)} "
              f"tenant(s), {rep['ticks']} ticks, "
              f"{rep['dropped']} dropped, {alarms} alarms, "
              f"{recals} recals")
        for c in rep["chips"]:
            print(f"  chip {c['chip']}: {c['status']:<13} "
                  f"served={c['served']:4d} d̂={c['distance']:.4f} "
                  f"alarms={c['alarms']} recals={c['recals']}")
            if args.fleet_tenants > 1:
                for t in c["tenants"]:
                    print(f"    tenant {t['tenant']} "
                          f"blocks{t['block_range']}: "
                          f"served={t['served']:4d} d̂={t['distance']:.4f} "
                          f"alarms={t['alarms']} recals={t['recals']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
