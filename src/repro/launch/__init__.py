"""Launch layer: production meshes, sharding rules, dry-run, drivers."""
