import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape) cell, on the single-pod 16×16
mesh AND the 2×16×16 multi-pod mesh:

    lowered  = jax.jit(step, in_shardings=…).lower(**input_specs)
    compiled = lowered.compile()
    compiled.memory_analysis() / cost_analysis()

Success = the jit lowers, SPMD-partitions over all 512 placeholder
devices, and compiles without sharding mismatches or OOM.  Each cell's
FLOPs / bytes / per-collective byte counts are written to
``bench_artifacts/dryrun/<arch>__<shape>__<mesh>.json`` — the roofline
analysis (benchmarks/roofline.py, EXPERIMENTS.md §Roofline) reads them.

NOTE the XLA_FLAGS line above MUST precede any jax import (device count
locks on first init); smoke tests / benches see 1 device because only
this module sets it.
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import (get_config, smoke_config, ARCH_NAMES, SHAPES,
                       input_specs, shape_applicable)
from ..models.lm import (init_model, init_decode_cache,
                         model_trainable_mask)
from ..optim.optimizers import AdamWConfig, init_opt_state
from .mesh import make_production_mesh
from .sharding import (param_shardings, batch_shardings, cache_shardings,
                       opt_state_shardings, replicated)
from .steps import build_update_step, build_prefill_step
from ..models.lm import build_serve_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "bench_artifacts", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-op RESULT bytes from the (per-device SPMD) HLO.

    For all-reduce / all-to-all / collective-permute the result size is
    the per-device payload; all-gather's result is the gathered size
    (≈ bytes moved per device over a ring); reduce-scatter's payload is
    its input ≈ result × world — we approximate with the declared
    operand type where present on the def line.
    """
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^[%\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s/]+?)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)", s)
        if not m:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
        out["count"] += 1
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             smoke: bool = False, periods: int | None = None,
             unroll: bool = False, cfg_override=None) -> dict:
    """One dry-run cell.  ``periods``: override the layer-stack depth to
    this many periods (same widths) — used by the roofline driver's
    2-point extrapolation (``unroll=True`` replaces the lax.scan with an
    unrolled stack so cost_analysis counts every layer; full-depth
    FLOPs are then f(L) = f(1) + (L−1)·(f(2)−f(1)))."""
    import dataclasses as _dc
    from ..models.lm import period_plan
    cfg = cfg_override if cfg_override is not None else (
        smoke_config(arch) if smoke else get_config(arch))
    if periods is not None:
        plan, n_periods = period_plan(cfg)
        cfg = _dc.replace(
            cfg, n_layers=len(plan) * periods,
            n_enc_layers=periods if cfg.n_enc_layers else 0)
    if unroll:
        cfg = _dc.replace(cfg, unroll=True)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    batch = input_specs(cfg, shape)
    pshapes = jax.eval_shape(lambda k: init_model(k, cfg),
                             jax.random.PRNGKey(0))
    pshard = param_shardings(mesh, pshapes)
    bshard = batch_shardings(mesh, batch)
    rep = replicated(mesh)

    with mesh:
        if shape.kind == "train":
            step = build_update_step(cfg, AdamWConfig())
            oshapes = jax.eval_shape(
                lambda p: init_opt_state(p, model_trainable_mask(p)), pshapes)
            oshard = opt_state_shardings(mesh, oshapes, pshard)
            lowered = jax.jit(
                step, in_shardings=(pshard, oshard, bshard, rep),
                donate_argnums=(0, 1)).lower(pshapes, oshapes, batch, key)
        elif shape.kind == "prefill":
            step = build_prefill_step(cfg)
            lowered = jax.jit(step, in_shardings=(pshard, bshard)
                              ).lower(pshapes, batch)
        else:   # decode
            step = build_serve_step(cfg)
            cshapes = jax.eval_shape(
                lambda: init_decode_cache(cfg, shape.global_batch,
                                          shape.seq_len))
            cshard = cache_shardings(mesh, cshapes, shape.global_batch)
            lowered = jax.jit(
                step, in_shardings=(pshard, cshard, bshard),
                donate_argnums=(1,)).lower(pshapes, cshapes, batch)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "mesh_shape": dict(mesh.shape), "n_devices": n_dev,
        "status": "ok",
        "kind": shape.kind,
        "compile_s": round(time.time() - t0, 1),
        "flops_per_device": ca.get("flops", 0.0),
        "bytes_per_device": ca.get("bytes accessed", 0.0),
        "collectives": coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
        } if mem is not None else None,
    }
    return rec


def cell_list(archs, shapes):
    cells = []
    for a in archs:
        for s in shapes:
            cells.append((a, s))
    return cells


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--smoke", action="store_true",
                    help="use reduced configs (debug)")
    ap.add_argument("--out", default=ART_DIR)
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch, shape in cell_list(archs, shapes):
        for mp in meshes:
            tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
            try:
                rec = run_cell(arch, shape, mp, smoke=args.smoke)
            except Exception as e:   # a failure here is a bug in our system
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if mp else "single",
                       "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
            st = rec["status"]
            n_ok += st == "ok"
            n_skip += st == "skipped"
            n_fail += st == "FAIL"
            extra = ""
            if st == "ok":
                extra = (f" flops/dev={rec['flops_per_device']:.3g}"
                         f" coll={rec['collectives']['count']}"
                         f" t={rec['compile_s']}s")
            elif st == "FAIL":
                extra = " " + rec["error"][:160]
            print(f"[{st:7s}] {tag}{extra}", flush=True)
    print(f"\ndry-run summary: ok={n_ok} skipped={n_skip} FAILED={n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
