"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state): 16×16 = 256 chips per pod on ("data",
"model"); the multi-pod variant adds a leading "pod" axis (2×16×16 =
512 chips).  DP runs over ("pod", "data"); TP/EP over "model"; the pod
axis is the slow (DCN-ish) dimension — only DP gradient reductions
cross it.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "dp_axes", "MODEL_AXIS"]

MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The data-parallel axes of a production mesh."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)
