"""Logical → mesh sharding rules for every architecture (DESIGN §4).

Rules (all divisibility-guarded — an axis whose size does not divide the
mesh axis falls back to replication, which automatically handles GQA
kv-heads < TP and whisper's small dims = DP-only):

* PTC linears: "out-projections" (wq/wk/wv/gate/up/in_proj/dt_proj)
  shard the P (out-block) axis on "model"; "in-projections"
  (wo/down/out_proj/x_proj) shard the Q (in-block) axis — the Megatron
  pairing, one reduction per block pair.
* MoE experts: the E axis shards on "model" (EP); router replicated.
* Embedding / unembedding: vocab axis on "model" (sharded logits + CE).
* Mamba electronics (conv, A, D): d_inner axis on "model".
* Norms / small biases: replicated.
* Batch axes: ("pod", "data").
* Σ optimizer state inherits the Σ sharding (handled by mirroring the
  param tree).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import dp_axes, MODEL_AXIS

__all__ = ["param_shardings", "batch_shardings", "cache_shardings",
           "named", "replicated"]

PyTree = Any

# role classification by the enclosing linear's name
_OUT_SHARD = {"wq", "wk", "wv", "gate", "up", "in_proj", "dt_proj"}
_IN_SHARD = {"wo", "down", "out_proj", "x_proj"}


def _path_names(path) -> list[str]:
    out = []
    for e in path:
        n = getattr(e, "key", getattr(e, "name", None))
        if isinstance(n, str):
            out.append(n)
    return out


def _guard(dim: int, axis_size: int) -> bool:
    return dim % axis_size == 0 and dim >= axis_size


def _ptc_spec(names: list[str], leaf, model_size: int, expert: bool):
    """Spec for one u/s/v/b/w leaf of a PTC (or dense-mode) linear."""
    kind = names[-1]
    role_out = any(n in _OUT_SHARD for n in names)
    role_in = any(n in _IN_SHARD for n in names)
    shape = leaf.shape
    # leading stack axes (period, experts): everything before the block grid
    if kind in ("u", "v"):
        grid_start = len(shape) - 4
    elif kind == "s":
        grid_start = len(shape) - 3
    elif kind in ("b",):
        grid_start = len(shape) - 1
    elif kind == "w":      # dense-baseline (d_out, d_in)
        grid_start = len(shape) - 2
    else:
        return P()
    spec: list = [None] * len(shape)
    if expert:
        # experts axis: first stacked axis after the period axis (or axis 0)
        e_axis = grid_start - 1
        if e_axis >= 0 and _guard(shape[e_axis], model_size):
            spec[e_axis] = MODEL_AXIS
            return P(*spec)
        return P(*spec)
    if kind == "w":
        if role_out and _guard(shape[grid_start], model_size):
            spec[grid_start] = MODEL_AXIS
        elif role_in and _guard(shape[grid_start + 1], model_size):
            spec[grid_start + 1] = MODEL_AXIS
        return P(*spec)
    if kind == "b":
        if role_out and _guard(shape[-1], model_size):
            spec[-1] = MODEL_AXIS
        return P(*spec)
    # u/s/v: block grid (P, Q, ...) starts at grid_start
    if role_out and _guard(shape[grid_start], model_size):
        spec[grid_start] = MODEL_AXIS
    elif role_in and _guard(shape[grid_start + 1], model_size):
        spec[grid_start + 1] = MODEL_AXIS
    return P(*spec)


def _leaf_spec(path, leaf, model_size: int) -> P:
    names = _path_names(path)
    kind = names[-1] if names else ""
    expert = "experts" in names
    if kind in ("u", "s", "v", "b", "w") and len(names) >= 2:
        if names[-2] == "embed" or "unembed" in names or kind == "e":
            pass
        else:
            return _ptc_spec(names, leaf, model_size, expert)
    if kind == "e" or "unembed" in names:        # (…, vocab, d)
        spec: list = [None] * len(leaf.shape)
        if _guard(leaf.shape[-2], model_size):
            spec[-2] = MODEL_AXIS
        return P(*spec)
    if kind == "router":                          # (L, E, d) — replicated
        return P(*([None] * len(leaf.shape)))
    if kind in ("conv_w", "conv_b"):              # (L, W, din) / (L, din)
        spec = [None] * len(leaf.shape)
        if _guard(leaf.shape[-1], model_size):
            spec[-1] = MODEL_AXIS
        return P(*spec)
    if kind in ("a_log", "d") and "mamba" in names:
        spec = [None] * len(leaf.shape)
        ax = len(leaf.shape) - (2 if kind == "a_log" else 1)
        if _guard(leaf.shape[ax], model_size):
            spec[ax] = MODEL_AXIS
        return P(*spec)
    return P(*([None] * len(leaf.shape)))         # norms etc.: replicated


def param_shardings(mesh: Mesh, params: PyTree) -> PyTree:
    model_size = mesh.shape[MODEL_AXIS]

    def f(path, leaf):
        return NamedSharding(mesh, _leaf_spec(path, leaf, model_size))

    return jax.tree_util.tree_map_with_path(f, params)


def batch_shardings(mesh: Mesh, batch: PyTree) -> PyTree:
    """Token/label batches: leading batch axis over the DP axes.
    Scalars (cache_len) replicated."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def f(path, leaf):
        if leaf.ndim == 0 or not _guard(leaf.shape[0], dp_size):
            return NamedSharding(mesh, P(*([None] * leaf.ndim)))
        return NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map_with_path(f, batch)


def cache_shardings(mesh: Mesh, cache: PyTree, batch_size: int) -> PyTree:
    """KV caches (Lp, B, S, H, D) / SSM states (Lp, B, …).

    Batch shards over DP when divisible; for global_batch too small
    (long_500k B=1) the KV SEQUENCE axis shards over "data" instead —
    the long-context memory-scaling plan (DESIGN §4)."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    data_size = mesh.shape["data"]
    model_size = mesh.shape[MODEL_AXIS]

    def f(path, leaf):
        names = _path_names(path)
        spec: list = [None] * leaf.ndim
        if leaf.ndim >= 2 and _guard(leaf.shape[1], dp_size):
            spec[1] = dp                       # (Lp, B, ...) batch over DP
        elif names[-1] in ("k", "v") and leaf.ndim == 5 \
                and _guard(leaf.shape[2], data_size):
            spec[2] = "data"                   # long-context: shard S
        if names[-1] == "h" and leaf.ndim == 4 \
                and _guard(leaf.shape[2], model_size) and spec[1] is None:
            spec[2] = MODEL_AXIS               # SSM state d_inner over TP
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, cache)


def named(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def opt_state_shardings(mesh: Mesh, opt_state, p_shard: PyTree):
    """Optimizer state mirrors params; scalar placeholders replicated."""
    from ..optim.optimizers import OptState

    def mirror(tree):
        flat_p, treedef = jax.tree_util.tree_flatten(p_shard)
        flat_t = treedef.flatten_up_to(tree)
        out = []
        for sh, leaf in zip(flat_p, flat_t):
            if getattr(leaf, "ndim", 0) == 0:
                out.append(replicated(mesh))
            else:
                out.append(sh)
        return treedef.unflatten(out)

    return OptState(step=replicated(mesh), mu=mirror(opt_state.mu),
                    nu=mirror(opt_state.nu), master=mirror(opt_state.master))
