"""End-to-end training driver (the paper's full three-stage flow at LM
scale, with production fault-tolerance).

Runnable on this CPU container with smoke configs::

    PYTHONPATH=src python -m repro.launch.train --arch smoke:olmo-1b \
        --steps 50 --batch 8 --seq 64

Features (DESIGN §5):
* periodic + SIGTERM-preemption checkpoints, auto-resume from latest;
* mesh-independent checkpoints → elastic restart on a different device
  count;
* SMD data sampling (the paper's iteration-skip knob, α_D);
* per-step wall-clock deadline with skip-and-log (straggler mitigation);
* multi-level sparsity flags (α_W feedback / α_C column sampling);
* optional int8 error-feedback gradient compression for the DP
  all-reduce (--compress-grads; shard_map path, multi-device meshes).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_config
from ..core.sparsity import SparsityConfig, smd_keep_iteration
from ..checkpoint import CheckpointManager
from ..data import lm_batch
from ..optim.optimizers import AdamWConfig
from ..optim.schedules import linear_warmup_cosine
from .steps import build_update_step, init_train_state


def parse_arch(name: str):
    if name.startswith("smoke:"):
        return smoke_config(name.split(":", 1)[1])
    return get_config(name)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="arch id, or smoke:<id> for the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--alpha-w", type=float, default=1.0)
    ap.add_argument("--alpha-c", type=float, default=1.0)
    ap.add_argument("--alpha-d", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-step deadline; late steps are logged")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = parse_arch(args.arch)
    scfg = SparsityConfig(alpha_w=args.alpha_w, alpha_c=args.alpha_c,
                          alpha_d=args.alpha_d)
    ocfg = AdamWConfig(lr=args.lr)
    sched = lambda step: linear_warmup_cosine(step, 10, args.steps)

    key = jax.random.PRNGKey(args.seed)
    params, opt_state = init_train_state(key, cfg)
    step0 = 0

    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
        restored, meta = mgr.restore_or_none((params, opt_state))
        if restored is not None:
            params, opt_state = restored
            step0 = int(meta["step"]) + 1
            print(f"resumed from step {meta['step']}")

    update = jax.jit(build_update_step(cfg, ocfg, scfg, sched))

    losses = []
    t_train0 = time.time()
    for step in range(step0, args.steps):
        kstep = jax.random.fold_in(key, step)
        # SMD: data-level sparsity — skip the whole iteration w.p. α_D
        if scfg.alpha_d > 0 and not bool(smd_keep_iteration(kstep, scfg)):
            continue
        batch_np = lm_batch(args.seed, step, args.batch, args.seq, cfg.vocab)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.time()
        params, opt_state, loss, gnorm = update(params, opt_state, batch,
                                                kstep)
        loss = float(loss)
        dt = (time.time() - t0) * 1e3
        if args.deadline_ms and dt > args.deadline_ms:
            print(f"step {step}: DEADLINE exceeded ({dt:.0f}ms "
                  f"> {args.deadline_ms}ms) — straggler logged")
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step}: loss={loss:.4f} gnorm={float(gnorm):.3f} "
                  f"({dt:.0f}ms)", flush=True)
        if mgr is not None:
            saved = mgr.maybe_save(step, (params, opt_state),
                                   {"loss": loss})
            if mgr.preempted:
                print(f"SIGTERM: checkpointed at step {step}, exiting")
                return 0
    print(f"done: first-10 mean loss {np.mean(losses[:10]):.4f} → "
          f"last-10 mean {np.mean(losses[-10:]):.4f} "
          f"({time.time()-t_train0:.0f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
