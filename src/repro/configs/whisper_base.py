"""whisper-base: 6L enc + 6L dec, d=512 8H(kv=8) d_ff=2048 vocab 51865 —
enc-dec; conv/audio frontend is a STUB (input_specs provides precomputed
frame embeddings).  [arXiv:2212.04356]

Adaptations (DESIGN §3): sinusoidal positions → rotary; k=64 PTC blocks
(d=512); DP-only sharding on the production mesh (dims < k·TP, the
divisibility guard replicates automatically)."""
from ..models.lm import ArchConfig
from ..models.layers import PTCLinearCfg

ARCH = ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    head_dim=64, d_ff=2048, vocab=51865,
    norm="layernorm", act="gelu", tie_embed=True,
    ptc=PTCLinearCfg(k=64),
    attn_chunk=2048,
)
