"""falcon-mamba-7b: 64L d=4096 attention-free Mamba-1, ssm_state=16,
vocab 65024.  [arXiv:2410.05355]

The selective-scan recurrence has no dense matrix → the paper's PTC
technique applies to the in/x/dt/out projections (>95% of params), not
the recurrence itself (DESIGN §Arch-applicability)."""
from ..models.lm import ArchConfig

ARCH = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024, ssm_state=16, tie_embed=True,
)
