"""moonshot-v1-16b-a3b (Moonlight-16B-A3B): 48L d=2048 16H(kv=16) MoE 64e
top-6, expert d_ff=1408, vocab 163840.  [hf:moonshotai/Moonlight-16B-A3B]"""
from ..models.lm import ArchConfig

ARCH = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=163840, n_experts=64, top_k=6,
    rope_theta=50000.0, tie_embed=False,
    attn_chunk=2048,
    moe_dispatch="a2a",   # shard_map all_to_all EP (see EXPERIMENTS §Perf)
)
