"""Config registry: the 10 assigned architectures + the paper's own
MLP/CNN models, each with smoke reductions and input-shape specs."""

from __future__ import annotations

import importlib

from .common import (  # noqa: F401
    smoke_reduce, SHAPES, ShapeSpec, input_specs, shape_applicable,
)

_ARCH_MODULES = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "gemma2-27b": "gemma2_27b",
    "chatglm3-6b": "chatglm3_6b",
    "olmo-1b": "olmo_1b",
    "qwen3-4b": "qwen3_4b",
    "whisper-base": "whisper_base",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
}

ARCH_NAMES = list(_ARCH_MODULES)


def get_config(name: str):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f".{_ARCH_MODULES[name]}", __package__)
    return mod.ARCH


def smoke_config(name: str):
    return smoke_reduce(get_config(name))
