"""Shared config utilities: smoke reductions and input-shape specs."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.lm import ArchConfig, period_plan
from ..models.layers import PTCLinearCfg

__all__ = ["smoke_reduce", "SHAPES", "ShapeSpec", "input_specs",
           "shape_applicable"]


def smoke_reduce(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config: small widths, few layers/experts, tiny
    vocab, k=8 PTC — runs a real fwd/train step on CPU in seconds."""
    plan, _ = period_plan(cfg)
    period_len = len(plan)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=period_len * 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 2,
        head_dim=16,
        d_ff=96,
        vocab=256,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        n_img_tokens=8 if cfg.n_img_tokens else 0,
        sliding_window=8 if cfg.sliding_window else None,
        attn_chunk=None,
        remat=False,
        ptc=PTCLinearCfg(k=8, mode=cfg.ptc.mode, base_dtype=jnp.float32),
    )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# modality-stub lengths (precomputed frame/patch embeddings)
ENC_FRAMES_DECODE = 1024     # whisper encoder length in decode shapes


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention: run for SSM/hybrid only
    (DESIGN §Arch-applicability); all other (arch × shape) cells run."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("pure full-attention arch: 512k dense-softmax KV "
                       "is out of spec (DESIGN §4)")
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.bfloat16
    sd = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sd((b, s), i32)}
        if shape.kind == "train":
            batch["labels"] = sd((b, s), i32)
        if cfg.family == "encdec":
            batch["frames"] = sd((b, s, cfg.d_model), f)
        if cfg.family == "vlm":
            batch["img"] = sd((b, cfg.n_img_tokens, cfg.d_model), f)
        return batch
    # decode: one new token against a cache of length seq_len
    batch = {"token": sd((b, 1), i32),
             "cache_len": sd((), i32)}
    if cfg.family == "encdec":
        batch["enc_out"] = sd((b, ENC_FRAMES_DECODE, cfg.d_model), f)
    if cfg.family == "vlm":
        batch["img"] = sd((b, cfg.n_img_tokens, cfg.d_model), f)
    return batch
