"""chatglm3-6b: 28L d=4096 32H(kv=2) d_ff=13696 vocab 65024 — 2d-RoPE
(half-dim rotary), qkv bias, GQA kv=2.  [arXiv:2406.12793]

PTC padding: d_ff 13696 → 14336 (112 blocks of k=128, divisible by TP=16;
+4.7% FFN FLOPs — without it the MLP replicates and costs 16× per device).
"""
from ..models.lm import ArchConfig

ARCH = ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    # d_ff 13696 padded to 112 k=128 blocks (TP16; +4.7% FFN FLOPs)
    d_ff=14336, vocab=65024,
    rope_frac=0.5, qkv_bias=True, tie_embed=False,
    attn_chunk=2048,
)
