"""llama-3.2-vision-11b: 40L d=4096 32H(kv=8) d_ff=14336 vocab 128256 —
cross-attention image layers every 5th layer; the vision tower is a STUB
(input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from ..models.lm import ArchConfig

ARCH = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256,
    cross_attn_period=5, n_img_tokens=1024,
    rope_theta=500000.0, tie_embed=False,
    attn_chunk=2048,
)
