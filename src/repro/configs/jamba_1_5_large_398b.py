"""jamba-1.5-large-398b: 72L d=8192 64H(kv=8) — Mamba+attention 1:7
interleave (1 attn per 8-layer period), MoE 16e top-2 every other layer,
expert d_ff=24576, vocab 65536, ssm_state=16.  [arXiv:2403.19887]"""
from ..models.lm import ArchConfig

ARCH = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536,
    n_experts=16, top_k=2, attn_period=8, moe_period=2,
    ssm_state=16, tie_embed=False,
    attn_chunk=2048,
    moe_dispatch="a2a",
    ssm_chunk=128,       # measured best (EXPERIMENTS §Perf pair 3)   # shard_map all_to_all EP (see EXPERIMENTS §Perf)
)
