"""qwen3-4b: 36L d=2560 32H(kv=8) d_ff=9728 vocab 151936 — qk-norm, GQA.
[hf:Qwen/Qwen3-4B]

PTC padding: d_ff 9728 → 10240 (80 blocks of k=128, divisible by TP=16;
+5.3% FFN FLOPs — without it the MLP replicates and costs 16× per device)."""
from ..models.lm import ArchConfig

ARCH = ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    # d_ff 9728 padded to 80 k=128 blocks (TP16; +5.3% FFN FLOPs)
    d_ff=10240, vocab=151936,
    qk_norm=True, rope_theta=1000000.0, tie_embed=True,
    attn_chunk=2048,
)
