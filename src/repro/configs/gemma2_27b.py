"""gemma2-27b: 46L d=4608 32H(kv=16) d_ff=36864 vocab 256000 — alternating
local(4096-window)/global attention, attn+final logit soft-caps, sandwich
norms, GeGLU.  [arXiv:2408.00118]"""
from ..models.lm import ArchConfig

ARCH = ArchConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab=256000,
    local_global=True, sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0, post_norm=True,
    act="gelu", tie_embed=True,
    attn_chunk=2048,
)
