"""olmo-1b: 16L d=2048 16H(kv=16) d_ff=8192 vocab 50304 — non-parametric
LayerNorm, SwiGLU.  [arXiv:2402.00838]"""
from ..models.lm import ArchConfig

ARCH = ArchConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=8192, vocab=50304,
    norm="nonparam", tie_embed=True,
    attn_chunk=2048,
)
