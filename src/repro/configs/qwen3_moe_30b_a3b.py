"""qwen3-moe-30b-a3b: 48L d=2048 32H(kv=4) MoE 128e top-8, expert
d_ff=768, vocab 151936, qk-norm.  [hf:Qwen/Qwen3-30B-A3B]"""
from ..models.lm import ArchConfig

ARCH = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936, n_experts=128, top_k=8,
    qk_norm=True, rope_theta=1000000.0, tie_embed=False,
    attn_chunk=2048,
    moe_dispatch="a2a",   # shard_map all_to_all EP (see EXPERIMENTS §Perf)
)
