"""Atomic, mesh-independent checkpointing for 1000+-node fault tolerance.

Design (DESIGN §5):

* **Atomicity** — write to ``<dir>/tmp.<step>`` then ``os.replace`` to
  ``<dir>/step_<n>``; a crash mid-write never corrupts the latest
  checkpoint, and restore only ever sees fully-renamed directories.
* **Mesh independence** — arrays are saved as full logical (host-gathered
  numpy) tensors with the pytree structure flattened to key-paths.  A
  restart on a DIFFERENT mesh (elastic rescale, e.g. 512→256 chips)
  simply re-``device_put``s with the new sharding; nothing in the format
  encodes the old device layout.
* **Keep-last-k** — bounded disk usage under long runs.
* **Preemption** — :class:`CheckpointManager` installs a SIGTERM handler
  that requests a final save at the next step boundary (the standard
  TPU-pod preemption contract).

Format: one ``.npz`` per checkpoint + a small JSON metadata file (step,
config digest, save-unix-time).  No external checkpoint libs needed.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import signal
import time
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]

PyTree = Any
_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    metadata: dict | None = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    meta = {"step": step, "time": time.time(), **(metadata or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # the atomic commit point
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, "meta.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like: PyTree, step: int | None = None,
                       shardings: PyTree | None = None
                       ) -> tuple[PyTree, dict]:
    """Restore into the structure of ``like``; optionally re-shard
    (elastic restart onto a different mesh)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    for (p, leaf), shard in zip(paths, shard_leaves):
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                       for e in p)
        arr = data[key]
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype)
                          if hasattr(leaf, "dtype") else arr)
    return treedef.unflatten(leaves), meta


class CheckpointManager:
    """Keep-last-k manager with SIGTERM-triggered preemption saves and
    periodic cadence.  Usage::

        mgr = CheckpointManager(dir, every=100)
        for step in ...:
            ...
            mgr.maybe_save(step, state)      # periodic + preemption
    """

    def __init__(self, directory: str, every: int = 100, keep: int = 3,
                 install_sigterm: bool = True):
        self.directory = directory
        self.every = every
        self.keep = keep
        self._preempted = False
        if install_sigterm:
            try:
                signal.signal(signal.SIGTERM, self._on_sigterm)
            except ValueError:
                pass    # non-main thread (tests)

    def _on_sigterm(self, signum, frame):
        self._preempted = True

    @property
    def preempted(self) -> bool:
        return self._preempted

    def maybe_save(self, step: int, tree: PyTree,
                   metadata: dict | None = None) -> bool:
        due = (step % self.every == 0) or self._preempted
        if due:
            save_checkpoint(self.directory, step, tree, metadata, self.keep)
        return due

    def restore_or_none(self, like: PyTree, shardings: PyTree | None = None):
        if latest_step(self.directory) is None:
            return None, None
        return restore_checkpoint(self.directory, like, shardings=shardings)
