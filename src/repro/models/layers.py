"""Shared neural-net layers over the PTC substrate.

Every projection in every arch is a PTC linear — blockwise (U, Σ, V*)
factors with Σ the only first-order-trainable hardware leaf — unless
``mode="dense"`` selects the full-space electronic baseline the paper
compares against.  Embeddings, norms and routers are dense-trainable
(the paper likewise trains the non-photonic electronics normally).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ptc import PTCParams, random_factorize
from ..core.subspace import ptc_linear, SubspaceMasks

__all__ = [
    "PTCLinearCfg", "init_ptc_linear", "apply_ptc_linear", "is_ptc_leaf",
    "ptc_execution", "ptc_scope", "ptc_scope_name",
    "init_rmsnorm", "rmsnorm", "layernorm_np", "init_layernorm", "layernorm",
    "rotary_cache", "apply_rotary", "softcap", "init_embedding", "embed",
    "trainable_mask", "maybe_constraint",
]


# -- layer-execution hook ----------------------------------------------------
#
# Hardware-in-the-loop serving substitutes a PTC linear's digital matmul
# with the *realized* transfer of a routed photonic chip
# (``runtime/hw_serve.py``).  The substitution point is here: while a
# hook is installed (``ptc_execution``), every *named* factored PTC
# linear offers its call to the hook first — `hook(name, p, x, cfg,
# d_out)` returns the layer output ``(..., m)`` computed elsewhere, or
# ``None`` to fall back to the digital path.  Names are qualified by the
# enclosing ``ptc_scope`` stack (the serve decode loop pushes
# ``p{period}.s{sublayer}.attn`` etc.), so one model forward yields a
# stable, enumerable layer naming that hw tenant placement keys on.
#
# The hook only ever fires on concrete (non-traced) inputs: under
# jit/scan/vmap the call sees tracers and silently stays digital, so a
# hooked serve loop must run unjitted + unrolled (launch/serve.py does).

_PTC_EXEC_HOOK: Callable | None = None
_PTC_SCOPE: list[str] = []


@contextlib.contextmanager
def ptc_execution(hook: Callable):
    """Install ``hook(name, p, x, cfg, d_out) -> y | None`` as the active
    PTC layer executor for the dynamic extent of the block.

    Never install this inside a function that jax traces (jit / scan /
    vmap bodies): dispatch is tracer-guarded, so under trace every PTC
    call silently stays digital and hardware-in-the-loop serving
    degrades to a simulation without an error.  repro-lint flags such
    installs statically (``python -m repro.analysis.lint --explain
    RPL302``); the legal pattern is installing around an unjitted,
    unrolled decode loop as ``launch/serve.py`` does."""
    global _PTC_EXEC_HOOK
    prev, _PTC_EXEC_HOOK = _PTC_EXEC_HOOK, hook
    try:
        yield
    finally:
        _PTC_EXEC_HOOK = prev


@contextlib.contextmanager
def ptc_scope(name: str):
    """Push a qualifier onto the PTC layer-name scope stack."""
    _PTC_SCOPE.append(name)
    try:
        yield
    finally:
        _PTC_SCOPE.pop()


def ptc_scope_name(leaf: str) -> str:
    """Qualified layer name for ``leaf`` under the current scope."""
    return ".".join((*_PTC_SCOPE, leaf))


def _hook_dispatch(p: Params, x: jax.Array, cfg: "PTCLinearCfg",
                   d_out: int | None, name: str | None):
    """Offer this call to the active execution hook; None = stay digital."""
    if (_PTC_EXEC_HOOK is None or name is None or cfg.mode == "dense"
            or "u" not in p or p["u"].ndim != 4):
        return None
    if isinstance(x, jax.core.Tracer):    # jit/vmap/scan context: digital
        return None
    return _PTC_EXEC_HOOK(ptc_scope_name(name), p, x, cfg, d_out)


def maybe_constraint(x: jax.Array, *spec) -> jax.Array:
    """Mesh-aware ``with_sharding_constraint``: entries are ``"dp"`` (all
    non-model axes), ``"model"``, or None.  Degrades to a no-op outside a
    mesh context (single-device tests) — used to steer the MoE G↔E
    reshard into an all-to-all instead of buffer replication."""
    from jax._src import mesh as _mesh_lib
    m = _mesh_lib.thread_resources.env.physical_mesh
    if m.empty:
        return x
    dp = tuple(a for a in m.axis_names if a != "model")
    resolved = []
    for e in spec:
        if e == "dp":
            resolved.append(dp if dp else None)
        elif e == "model":
            resolved.append("model" if "model" in m.axis_names else None)
        else:
            resolved.append(None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*resolved))

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class PTCLinearCfg:
    """Static policy for every PTC linear in a model."""

    k: int = 128               # block size (MXU-aligned default; 9 = paper)
    mode: str = "fused"        # fused | blocked | dense
    base_dtype: Any = jnp.bfloat16   # frozen U/V storage dtype
    sigma_dtype: Any = jnp.float32   # trainable Σ dtype


def init_ptc_linear(key: jax.Array, d_in: int, d_out: int,
                    cfg: PTCLinearCfg, bias: bool = False) -> Params:
    if cfg.mode == "dense":
        scale = float(np.sqrt(2.0 / (d_in + d_out)))
        p: Params = {"w": scale * jax.random.normal(
            key, (d_out, d_in), cfg.base_dtype)}
    else:
        f = random_factorize(key, d_out, d_in, cfg.k)
        p = {"u": f.u.astype(cfg.base_dtype),
             "s": f.s.astype(cfg.sigma_dtype),
             "v": f.v.astype(cfg.base_dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def is_ptc_leaf(path: tuple) -> bool:
    """True for the trainable Σ leaf of a PTC linear ('s' key)."""
    last = path[-1]
    name = getattr(last, "key", getattr(last, "name", None))
    return name == "s"


def apply_ptc_linear(p: Params, x: jax.Array, cfg: PTCLinearCfg,
                     masks: SubspaceMasks | None = None,
                     d_out: int | None = None,
                     name: str | None = None) -> jax.Array:
    """y = x @ Wᵀ (+b).  Handles k-padding on both sides.

    ``name`` identifies the layer to an installed :func:`ptc_execution`
    hook (hardware-in-the-loop serving); unnamed calls never leave the
    digital path."""
    y = _hook_dispatch(p, x, cfg, d_out, name)
    if y is not None:
        if "b" in p:
            y = y + p["b"].astype(y.dtype)
        return y
    if cfg.mode == "dense":
        w = p["w"]
        y = x.astype(w.dtype) @ w.T
        if d_out is not None and d_out != w.shape[0]:
            y = y[..., :d_out]
    else:
        if masks is None and ("fb" in p or "col" in p):
            # masks injected into the param tree (lm.inject_masks) so that
            # scan/vmap slicing distributes them per layer/expert
            masks = SubspaceMasks(feedback=p.get("fb"), column=p.get("col"))
        params = PTCParams(u=p["u"], s=p["s"].astype(p["u"].dtype), v=p["v"])
        pp, qq = params.grid
        k = params.k
        n = x.shape[-1]
        if qq * k != n:
            x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, qq * k - n)])
        lead = x.shape[:-1]
        y = ptc_linear(x.reshape(-1, qq * k).astype(params.u.dtype), params,
                       masks, mode=cfg.mode)
        y = y.reshape(lead + (pp * k,))
        if d_out is not None and d_out != pp * k:
            y = y[..., :d_out]
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def partition(params: Params, mask: Params) -> tuple[Params, Params]:
    """Split a pytree into (selected, rest) by a bool mask pytree; the
    non-selected side holds scalar-zero placeholders so both sides keep
    the full tree structure (cheap, DCE-able).

    Used to take gradients ONLY w.r.t. trainable leaves: differentiating
    through the frozen U/V bases would otherwise materialize ~2/3 of the
    param footprint as zero-gradient accumulators inside the scan
    backward (measured: 4.3 GB/device/layer on qwen3-moe)."""
    ph = lambda p: jnp.zeros((), p.dtype if hasattr(p, "dtype") else None)
    sel = jax.tree.map(lambda p, m: p if m else ph(p), params, mask)
    rest = jax.tree.map(lambda p, m: ph(p) if m else p, params, mask)
    return sel, rest


def combine(sel: Params, rest: Params, mask: Params) -> Params:
    return jax.tree.map(lambda a, b, m: a if m else b, sel, rest, mask)


def trainable_mask(params: Params) -> Params:
    """Bool pytree: True = optimizer updates this leaf.

    Trainable: Σ ('s'), biases, norms, embeddings, routers — everything
    EXCEPT the frozen U/V bases (and dense-baseline 'w' stays trainable:
    that is the paper's full-space reference)."""
    def f(path, leaf):
        name = None
        for e in reversed(path):
            name = getattr(e, "key", getattr(e, "name", None))
            if isinstance(name, str):
                break
        return name not in ("u", "v")
    return jax.tree_util.tree_map_with_path(f, params)


# -- norms -------------------------------------------------------------------


def init_rmsnorm(d: int) -> Params:
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nrm * p["g"]).astype(x.dtype)


def init_layernorm(d: int) -> Params:
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"] + p["b"]).astype(x.dtype)


def layernorm_np(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo's non-parametric LayerNorm (no affine params)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# -- rotary ------------------------------------------------------------------


def rotary_cache(positions: jax.Array, head_dim: int,
                 theta: float = 10000.0, frac: float = 1.0
                 ) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables, (..., rot_dim/2).  ``frac`` < 1 = partial rotary
    (chatglm's 2d-RoPE rotates half the head dim)."""
    rot = int(head_dim * frac) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D); cos/sin: (..., S, rot/2) broadcast over H."""
    rot2 = cos.shape[-1]
    xr, xp = x[..., : 2 * rot2], x[..., 2 * rot2:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    c, s = cos[..., None, :], sin[..., None, :]
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1).astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    """Gemma-2 logit soft-capping: cap·tanh(x/cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# -- embedding ---------------------------------------------------------------


def init_embedding(key: jax.Array, vocab: int, d: int,
                   dtype=jnp.bfloat16) -> Params:
    return {"e": (jax.random.normal(key, (vocab, d), jnp.float32)
                  * (d ** -0.5)).astype(dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["e"], tokens, axis=0)
