"""Feed-forward layers: gated MLP and top-k MoE with ragged expert dispatch.

MoE dispatch is sort-based (EP-native): assignments are sorted by expert
id, scattered into a capacity-bounded (E, C, d) buffer, expert FFNs run
as E-batched PTC matmuls (the E axis is what EP shards over "model"),
and results gather-combine back with the router gates.  No O(T·E·C)
one-hot dispatch tensors are ever materialized.

Every expert matrix is PTC-factorized (E-leading-axis factors); the
paper's feedback sampling composes naturally — only activated experts
contribute feedback blocks (DESIGN §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..compat import shard_map
from .layers import (PTCLinearCfg, init_ptc_linear, apply_ptc_linear,
                     maybe_constraint)

__all__ = ["FFNCfg", "init_mlp", "mlp", "MoECfg", "init_moe", "moe"]

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class FFNCfg:
    d_model: int
    d_ff: int
    act: str = "silu"      # silu | gelu


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


def init_mlp(key: jax.Array, cfg: FFNCfg, lin: PTCLinearCfg) -> Params:
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "gate": init_ptc_linear(kg, cfg.d_model, cfg.d_ff, lin),
        "up": init_ptc_linear(ku, cfg.d_model, cfg.d_ff, lin),
        "down": init_ptc_linear(kd, cfg.d_ff, cfg.d_model, lin),
    }


def mlp(p: Params, cfg: FFNCfg, lin: PTCLinearCfg, x: jax.Array) -> jax.Array:
    g = apply_ptc_linear(p["gate"], x, lin, d_out=cfg.d_ff, name="gate")
    u = apply_ptc_linear(p["up"], x, lin, d_out=cfg.d_ff, name="up")
    return apply_ptc_linear(p["down"], _act(cfg.act, g) * u, lin,
                            d_out=cfg.d_model, name="down")


# -- MoE ---------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    d_ff: int               # per-expert hidden dim
    n_experts: int
    top_k: int
    act: str = "silu"
    capacity_factor: float = 1.25
    balance_coeff: float = 0.01
    dispatch: str = "pjit"  # pjit (partitioner-driven) | a2a (shard_map
    #                         with explicit all_to_all — the EP fast path)


def init_moe(key: jax.Array, cfg: MoECfg, lin: PTCLinearCfg) -> Params:
    kr, ke = jax.random.split(key)
    ekeys = jax.random.split(ke, cfg.n_experts)
    expert = jax.vmap(lambda k: init_mlp(
        k, FFNCfg(cfg.d_model, cfg.d_ff, cfg.act), lin))(ekeys)
    router = (jax.random.normal(kr, (cfg.n_experts, cfg.d_model), jnp.float32)
              * (cfg.d_model ** -0.5))
    return {"router": router, "experts": expert}


def _local_dispatch(xf, router, e, k, cap, balance_coeff):
    """Per-device routing + slot assignment (shared by both paths).

    xf: (T, d) local tokens → (buf (E, cap, d), combine-side indices)."""
    t, d = xf.shape
    logits = xf.astype(jnp.float32) @ router.T                 # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    frac = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = balance_coeff * e * jnp.sum(frac * probs.mean(0))

    flat_e = idx.reshape(t * k)
    order = jnp.argsort(flat_e)
    tok = order // k
    sorted_e = flat_e[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos = jnp.arange(t * k) - group_start[sorted_e]
    valid = pos < cap
    slot = jnp.where(valid, sorted_e * cap + pos, e * cap)

    inv = jnp.full((e * cap,), t * k, jnp.int32)
    inv = inv.at[slot].set(jnp.arange(t * k, dtype=jnp.int32), mode="drop")
    tok_pad = jnp.concatenate([tok, jnp.zeros((1,), tok.dtype)])
    src = jnp.take(tok_pad, jnp.minimum(inv, t * k))
    slot_valid = (inv < t * k)[:, None]
    buf = jnp.take(xf, src, axis=0) * slot_valid.astype(xf.dtype)

    inv_order = jnp.argsort(order)
    slot_tok = jnp.take(jnp.minimum(slot, e * cap - 1), inv_order)
    valid_tok = jnp.take(valid, inv_order)
    return buf.reshape(e, cap, d), gates, slot_tok, valid_tok, aux


def _moe_a2a(p: Params, cfg: MoECfg, lin: PTCLinearCfg, x: jax.Array,
             mesh) -> tuple[jax.Array, jax.Array]:
    """EP fast path: shard_map with explicit all_to_all over "model".

    Each device routes ITS tokens, exchanges exactly the routed slots
    with the expert owners (two all_to_alls per layer), computes its
    E/world experts, and combines locally — the collective payload is
    tokens·K·d instead of the partitioner's buffer all-gathers
    (measured 825 GB → ~40 GB per device per step on qwen3-moe)."""
    from jax.sharding import PartitionSpec as P
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    dp = tuple(a for a in mesh.axis_names if a != "model")
    world = mesh.shape["model"]
    e_loc = e // world

    def local_fn(router, experts, xl):
        b_loc = xl.shape[0]
        t = b_loc * s
        cap = min(t, max(1, int(t * k / e * cfg.capacity_factor)))
        xf = xl.reshape(t, d)
        buf, gates, slot_tok, valid_tok, aux = _local_dispatch(
            xf, router, e, k, cap, cfg.balance_coeff)
        # dispatch a2a (symmetric split=concat axis — its transpose is
        # well-defined for the backward): axis 0 switches meaning from
        # "destination expert-owner" to "source token-owner"
        recv = jax.lax.all_to_all(
            buf.reshape(world, e_loc, cap, d), "model",
            split_axis=0, concat_axis=0, tiled=False)    # (world, e_loc, …)
        recv = jnp.swapaxes(recv, 0, 1).reshape(e_loc, world * cap, d)
        ffn_cfg = FFNCfg(cfg.d_model, cfg.d_ff, cfg.act)
        out = jax.vmap(lambda ep, xb: mlp(ep, ffn_cfg, lin, xb))(
            experts, recv)                               # (e_loc, world·cap, d)
        # combine a2a: back to expert-major (E, cap, d) on the token owner
        out = jnp.swapaxes(out.reshape(e_loc, world, cap, d), 0, 1)
        back = jax.lax.all_to_all(
            out, "model", split_axis=0, concat_axis=0, tiled=False)
        got = jnp.take(back.reshape(e * cap, d), slot_tok, axis=0)
        got = got * valid_tok[:, None].astype(got.dtype)
        got = got.reshape(t, k, d) * gates.reshape(t, k, 1).astype(got.dtype)
        y = got.sum(1).reshape(b_loc, s, d).astype(xl.dtype)
        aux = jax.lax.pmean(aux, dp + ("model",))
        return y, aux

    espec = jax.tree.map(lambda _: P("model"), p["experts"])
    # tokens shard over ALL devices (dp × model); experts over model —
    # the 2D EP layout (tokens dp-only would replicate routing + expert
    # work 16× across the model axis)
    tok_axes = dp + ("model",)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), espec, P(tok_axes, None, None)),
        out_specs=(P(tok_axes, None, None), P()),
        check_vma=False)
    return fn(p["router"], p["experts"], x)


def moe(p: Params, cfg: MoECfg, lin: PTCLinearCfg, x: jax.Array
        ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (y, aux_balance_loss).

    GROUP-WISE ragged dispatch: each batch row is a dispatch group, so
    routing/sort/scatter are batched ops sharded over the DP axes; only
    the (B, E, C, d) expert buffer crosses the G↔E sharding boundary —
    the explicit constraints below turn that reshard into the EP
    all-to-all instead of letting the partitioner replicate the buffer
    (the difference between ~1 GB and ~40 GB per device at train_4k)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    if cfg.dispatch == "a2a":
        from jax._src import mesh as _mesh_lib
        m = _mesh_lib.thread_resources.env.physical_mesh
        if not m.empty and "model" in m.axis_names:
            n_dev = 1
            for a in m.axis_names:
                n_dev *= m.shape[a]
            if (e % m.shape["model"] == 0 and b % n_dev == 0):
                return _moe_a2a(p, cfg, lin, x, m)
        # fall through to the pjit path (no mesh / indivisible)
    cap = min(s * k, max(1, int(s * k / e * cfg.capacity_factor)))

    # -- routing (per token)
    logits = (x.astype(jnp.float32) @ p["router"].T)           # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                       # (B, S, K)
    gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)

    # -- load-balance aux (Switch-style)
    frac = jnp.mean(jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32),
                    axis=(0, 1))
    aux = cfg.balance_coeff * e * jnp.sum(frac * probs.mean((0, 1)))

    # -- per-group sort → slot assignment (all index shapes (B, S·K); the
    # index plumbing is int32 — only ONE (B, E·C, d) gather and ONE
    # (B, S·K, d) gather touch activations, so the backward is exactly
    # two scatter-adds (the naive gather+scatter formulation costs ~38 GB
    # of live backward buffers per device at train_4k; this costs ~8 GB)
    flat_e = idx.reshape(b, s * k)
    order = jnp.argsort(flat_e, axis=-1)                       # stable
    tok = order // k                                           # source token
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    group_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e)))(sorted_e)  # (B, E)
    pos = jnp.arange(s * k)[None] - jnp.take_along_axis(
        group_start, sorted_e, axis=-1)                        # rank in expert
    valid = pos < cap
    slot = jnp.where(valid, sorted_e * cap + pos, e * cap)     # drop overflow

    # inverse table: which assignment fills each buffer slot
    sk = s * k
    inv = jnp.full((b, e * cap), sk, jnp.int32)
    inv = jax.vmap(lambda ii, sl: ii.at[sl].set(
        jnp.arange(sk, dtype=jnp.int32), mode="drop"))(inv, slot)
    tok_pad = jnp.concatenate(
        [tok, jnp.zeros((b, 1), tok.dtype)], axis=1)
    src = jnp.take_along_axis(tok_pad, inv, axis=1)            # (B, E·C)
    slot_valid = (inv < sk)[..., None]

    # -- gather into the per-group expert buffer (G-sharded)
    buf = jnp.take_along_axis(x, src[..., None], axis=1) \
        * slot_valid.astype(x.dtype)
    buf = buf.reshape(b, e, cap, d)
    buf = maybe_constraint(buf, "dp", None, None, None)

    # -- reshard E over "model" KEEPING groups sharded over dp: expert
    # compute is (dp × model)-parallel — 256-way, not 16-way (leaving the
    # group axis unsharded was measured as 16× redundant expert FLOPs
    # AND 16× the all-to-all payload per device)
    buf = maybe_constraint(buf, "dp", "model", None, None)
    ffn_cfg = FFNCfg(cfg.d_model, cfg.d_ff, cfg.act)
    out = jax.vmap(lambda ep, xb: mlp(ep, ffn_cfg, lin, xb),
                   in_axes=(0, 1), out_axes=1)(p["experts"], buf)
    out = maybe_constraint(out, "dp", "model", None, None)
    # -- reshard E→G and gather-combine in token order
    out = maybe_constraint(out, "dp", None, None, None)
    out = out.reshape(b, e * cap, d)

    inv_order = jnp.argsort(order, axis=-1)                    # token order
    slot_tok = jnp.take_along_axis(
        jnp.minimum(slot, e * cap - 1), inv_order, axis=-1)    # (B, S·K)
    valid_tok = jnp.take_along_axis(valid, inv_order, axis=-1)
    got = jnp.take_along_axis(out, slot_tok[..., None], axis=1)
    got = got * valid_tok[..., None].astype(got.dtype)
    got = got.reshape(b, s, k, d) * gates[..., None].astype(got.dtype)
    return got.sum(2).astype(x.dtype), aux
