"""The paper's own evaluation models: MLP / CNN-S / CNN-L / VGG-8 (§4.1).

Convolutions are im2col'd and fed through k=9 PTC linears — exactly the
paper's "fully parallel 9×9-blocking matrix multiplication" engine; the
im2col columns are what Column Sampling drops (§3.4.2 / Fig. 9).  These
models carry the paper-reproduction experiments (Tables 2-5, Figs 5/8/
11-14) on synthetic datasets; the large-scale LM zoo lives in ``lm.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..core.sparsity import SparsityConfig
from .layers import PTCLinearCfg, init_ptc_linear, apply_ptc_linear


__all__ = ["ConvSpec", "FCSpec", "PoolSpec", "CNNConfig", "init_cnn",
           "cnn_forward", "build_cnn_train_step", "MLP_VOWEL", "CNN_S",
           "CNN_L", "VGG8"]

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    c_out: int
    ksize: int = 3
    stride: int = 1
    pad: str = "SAME"


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    size: int
    kind: str = "avg"    # avg | max


@dataclasses.dataclass(frozen=True)
class FCSpec:
    d_out: int


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    layers: tuple
    in_shape: tuple          # (H, W, C) images or (D,) flat features
    n_classes: int
    ptc: PTCLinearCfg = dataclasses.field(
        default_factory=lambda: PTCLinearCfg(k=9, mode="blocked",
                                             base_dtype=jnp.float32))


# paper §4.1 model zoo
MLP_VOWEL = CNNConfig("mlp-vowel", (FCSpec(16), FCSpec(16), FCSpec(4)),
                      in_shape=(8,), n_classes=4)
CNN_S = CNNConfig("cnn-s", (ConvSpec(8, 3, 2), ConvSpec(6, 3, 2), FCSpec(10)),
                  in_shape=(28, 28, 1), n_classes=10)
CNN_L = CNNConfig("cnn-l", (ConvSpec(64), ConvSpec(64), ConvSpec(64),
                            PoolSpec(5), FCSpec(10)),
                  in_shape=(28, 28, 1), n_classes=10)
VGG8 = CNNConfig("vgg8", (ConvSpec(64), ConvSpec(64), PoolSpec(2),
                          ConvSpec(128), ConvSpec(128), PoolSpec(2),
                          ConvSpec(256), ConvSpec(256), PoolSpec(2),
                          FCSpec(512), FCSpec(10)),
                 in_shape=(32, 32, 3), n_classes=10)


def _im2col(x: jax.Array, ksize: int, stride: int, pad: str) -> jax.Array:
    """(B, H, W, C) → (B, H', W', C·K·K) patches (NHWC)."""
    patches = jax.lax.conv_general_dilated_patches(
        x, (ksize, ksize), (stride, stride), pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return patches


def init_cnn(key: jax.Array, cfg: CNNConfig) -> Params:
    params: Params = {}
    shape = cfg.in_shape
    keys = jax.random.split(key, len(cfg.layers))
    for i, (spec, k) in enumerate(zip(cfg.layers, keys)):
        if isinstance(spec, ConvSpec):
            h, w, c = shape
            d_in = c * spec.ksize * spec.ksize
            params[f"l{i}"] = init_ptc_linear(k, d_in, spec.c_out, cfg.ptc,
                                              bias=True)
            s = spec.stride
            if spec.pad == "SAME":
                h, w = -(-h // s), -(-w // s)
            else:
                h, w = (h - spec.ksize) // s + 1, (w - spec.ksize) // s + 1
            shape = (h, w, spec.c_out)
        elif isinstance(spec, PoolSpec):
            h, w, c = shape
            shape = (h // spec.size, w // spec.size, c)
        elif isinstance(spec, FCSpec):
            d_in = int(jnp.prod(jnp.asarray(shape)))
            params[f"l{i}"] = init_ptc_linear(k, d_in, spec.d_out, cfg.ptc,
                                              bias=True)
            shape = (spec.d_out,)
    return params


def _layer_masks(p, key, sparsity, n_cols):
    """Per-layer feedback + column masks, sized to THIS layer's grid and
    THIS layer's im2col column count (the paper's CS is per-layer)."""
    from ..core.sparsity import feedback_mask, column_mask
    from ..core.subspace import SubspaceMasks
    if sparsity is None or not sparsity.enabled or "s" not in p:
        return None
    kf, kc = jax.random.split(key)
    s = jax.lax.stop_gradient(p["s"]).astype(jnp.float32)
    energy = jnp.sum(s * s, axis=-1)
    fb = feedback_mask(kf, energy, sparsity) if sparsity.alpha_w < 1.0 else None
    col = column_mask(kc, n_cols, sparsity) if sparsity.alpha_c < 1.0 else None
    return SubspaceMasks(feedback=fb, column=col)


def cnn_forward(params: Params, cfg: CNNConfig, x: jax.Array,
                key: jax.Array | None = None,
                sparsity: SparsityConfig | None = None) -> jax.Array:
    """x: (B, H, W, C) or (B, D) → logits (B, n_classes)."""
    n = len(cfg.layers)
    for i, spec in enumerate(cfg.layers):
        lk = jax.random.fold_in(key, i) if key is not None else None
        if isinstance(spec, ConvSpec):
            cols = _im2col(x, spec.ksize, spec.stride, spec.pad)
            b, h, w, d = cols.shape
            m = _layer_masks(params[f"l{i}"], lk, sparsity,
                             b * h * w) if lk is not None else None
            y = apply_ptc_linear(params[f"l{i}"], cols.reshape(b, h * w, d),
                                 cfg.ptc, masks=m, d_out=spec.c_out)
            x = y.reshape(b, h, w, spec.c_out)
            x = jax.nn.relu(x)
        elif isinstance(spec, PoolSpec):
            b, h, w, c = x.shape
            s = spec.size
            xr = x[:, : h // s * s, : w // s * s].reshape(
                b, h // s, s, w // s, s, c)
            x = xr.max((2, 4)) if spec.kind == "max" else xr.mean((2, 4))
        elif isinstance(spec, FCSpec):
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            m = _layer_masks(params[f"l{i}"], lk, sparsity,
                             x.shape[0]) if lk is not None else None
            x = apply_ptc_linear(params[f"l{i}"], x, cfg.ptc, masks=m,
                                 d_out=spec.d_out)
            if i < n - 1:
                x = jax.nn.relu(x)
    return x


def build_cnn_train_step(cfg: CNNConfig,
                         sparsity: SparsityConfig | None = None):
    """train_step(params, batch{x, y}, key) → (loss, grads) with the
    paper's multi-level sampled in-situ gradients."""

    def loss_fn(params, batch, key):
        logits = cnn_forward(params, cfg, batch["x"], key=key,
                             sparsity=sparsity)
        labels = batch["y"]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(logits.astype(jnp.float32),
                                   labels[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)

    def train_step(params, batch, key):
        return jax.value_and_grad(loss_fn)(params, batch, key)

    return train_step
