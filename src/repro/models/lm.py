"""Model assembly: decoder-only / enc-dec / VLM LMs from PTC layers.

Architectures are described by :class:`ArchConfig` and composed as
``n_periods`` repetitions of a static *period plan* — a short list of
sub-layers (attn / mamba, each with mlp / moe) — so heterogeneous stacks
(gemma2's local/global alternation, jamba's 1-attn:7-mamba interleave
with MoE every other layer, llama-vision's cross-attn every 5th layer)
still scan as homogeneous ``lax.scan`` stacks: per-position parameters
are stacked over the period axis and sliced inside the scan body.

The paper's multi-level sparsity is first-class here: ``inject_masks``
adds per-step feedback/column masks as leaves *inside* the PTC param
dicts (so scan slicing distributes them layer-wise automatically) and
``apply_ptc_linear`` picks them up — the in-situ custom_vjp then
computes exactly the sampled estimator the photonic chip would.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..core.sparsity import SparsityConfig, feedback_mask, column_mask
from .layers import (PTCLinearCfg,                      init_rmsnorm, rmsnorm, init_layernorm, layernorm,
                     layernorm_np, init_embedding, embed, softcap,
                     trainable_mask, partition, combine, maybe_constraint,
                     ptc_scope)
from .attention import (AttnCfg, init_attention, attention, decode_attention,
                        decode_attention_paged,
                        decode_attention_paged_chunked, init_kv_cache)
from .ffn import FFNCfg, MoECfg, init_mlp, mlp, init_moe, moe
from .ssm import SSMCfg, init_mamba, mamba, mamba_decode, init_ssm_state

__all__ = ["ArchConfig", "SubLayerPlan", "init_model", "forward",
           "build_train_step", "build_serve_step", "build_gateway_step",
           "build_gateway_prefill_step", "init_decode_cache",
           "model_trainable_mask", "inject_masks"]

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    # attention flavour
    rope_theta: float = 10000.0
    rope_frac: float = 1.0
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    sliding_window: int | None = None
    local_global: bool = False      # gemma2: alternate local/global layers
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_period: int = 1             # MoE every `moe_period`-th sub-layer
    moe_dispatch: str = "pjit"      # pjit | a2a (shard_map all_to_all EP)
    # ssm / hybrid
    ssm_state: int = 0
    ssm_chunk: int = 256            # associative-scan chunk length
    attn_period: int = 0            # jamba: 1 attn per `attn_period` layers
    # enc-dec / vlm
    n_enc_layers: int = 0
    cross_attn_period: int = 0      # cross-attn every N-th layer
    n_img_tokens: int = 0
    # norms / activations / embeddings
    norm: str = "rmsnorm"           # rmsnorm | layernorm | nonparam
    act: str = "silu"
    post_norm: bool = False         # gemma2 sandwich norm
    tie_embed: bool = True
    # substrate policy
    ptc: PTCLinearCfg = dataclasses.field(default_factory=PTCLinearCfg)
    remat: bool = True
    remat_policy: str = "full"      # full | dots (save matmul outputs) |
    #                                 none — the memory/recompute knob
    attn_chunk: int | None = None   # chunked-softmax threshold (prefill)
    unroll: bool = False            # python-loop the stack instead of scan
    # (the roofline driver unrolls reduced-depth compiles: cost_analysis
    # counts a lax.scan body once, an unrolled stack exactly)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def attn_cfg(self, window=None, causal=True) -> AttnCfg:
        return AttnCfg(d_model=self.d_model, n_heads=self.n_heads,
                       n_kv_heads=self.n_kv_heads, head_dim=self.hd,
                       rope_theta=self.rope_theta, rope_frac=self.rope_frac,
                       qk_norm=self.qk_norm, attn_softcap=self.attn_softcap,
                       qkv_bias=self.qkv_bias, causal=causal, window=window)

    def moe_cfg(self) -> MoECfg:
        return MoECfg(d_model=self.d_model, d_ff=self.d_ff,
                      n_experts=self.n_experts, top_k=self.top_k,
                      act=self.act, dispatch=self.moe_dispatch)

    def ffn_cfg(self) -> FFNCfg:
        return FFNCfg(d_model=self.d_model, d_ff=self.d_ff, act=self.act)

    def ssm_cfg(self) -> SSMCfg:
        return SSMCfg(d_model=self.d_model, d_state=self.ssm_state,
                      chunk=self.ssm_chunk)


@dataclasses.dataclass(frozen=True)
class SubLayerPlan:
    kind: str                       # attn | mamba
    ffn: str                        # mlp | moe
    window: int | None = None
    cross: bool = False             # extra cross-attention block
    causal: bool = True             # False for encoder stacks


def period_plan(cfg: ArchConfig) -> tuple[list[SubLayerPlan], int]:
    """(plan, n_periods): the static per-period sub-layer schedule."""
    ffn = "moe" if (cfg.n_experts > 0 and cfg.attn_period == 0) else "mlp"
    if cfg.family == "encdec":
        # the DECODER stack (self-attn + cross-attn); encoder is separate
        return [SubLayerPlan("attn", ffn, cross=True)], cfg.n_layers
    if cfg.family in ("dense", "moe"):
        if cfg.local_global:
            plan = [SubLayerPlan("attn", ffn, window=cfg.sliding_window),
                    SubLayerPlan("attn", ffn, window=None)]
            assert cfg.n_layers % 2 == 0
            return plan, cfg.n_layers // 2
        return [SubLayerPlan("attn", ffn)], cfg.n_layers
    if cfg.family == "ssm":
        return [SubLayerPlan("mamba", "none")], cfg.n_layers
    if cfg.family == "hybrid":
        # jamba: period of `attn_period` layers — 1 attention + rest mamba,
        # MoE on every `moe_period`-th position
        ap = cfg.attn_period
        plan = []
        for i in range(ap):
            kind = "attn" if i == 0 else "mamba"
            f = "moe" if (cfg.n_experts and i % cfg.moe_period == 1) else "mlp"
            plan.append(SubLayerPlan(kind, f))
        assert cfg.n_layers % ap == 0
        return plan, cfg.n_layers // ap
    if cfg.family == "vlm":
        cp = cfg.cross_attn_period
        plan = [SubLayerPlan("attn", "mlp", cross=(i == cp - 1))
                for i in range(cp)]
        assert cfg.n_layers % cp == 0
        return plan, cfg.n_layers // cp
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_norm(cfg: ArchConfig) -> Params:
    if cfg.norm == "rmsnorm":
        return init_rmsnorm(cfg.d_model)
    if cfg.norm == "layernorm":
        return init_layernorm(cfg.d_model)
    return {}   # nonparam


def _apply_norm(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rmsnorm(p, x)
    if cfg.norm == "layernorm":
        return layernorm(p, x)
    return layernorm_np(x)


def _init_sublayer(key: jax.Array, cfg: ArchConfig, plan: SubLayerPlan
                   ) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": _init_norm(cfg)}
    if plan.kind == "attn":
        p["attn"] = init_attention(ks[0], cfg.attn_cfg(plan.window), cfg.ptc)
    else:
        p["mamba"] = init_mamba(ks[0], cfg.ssm_cfg(), cfg.ptc)
    if cfg.post_norm:
        p["pn1"] = _init_norm(cfg)
    if plan.cross:
        p["lnx"] = _init_norm(cfg)
        p["cross"] = init_attention(
            ks[1], cfg.attn_cfg(causal=False), cfg.ptc)
    if plan.ffn != "none":
        p["ln2"] = _init_norm(cfg)
        if plan.ffn == "moe":
            p["moe"] = init_moe(ks[2], cfg.moe_cfg(), cfg.ptc)
        else:
            p["mlp"] = init_mlp(ks[2], cfg.ffn_cfg(), cfg.ptc)
        if cfg.post_norm:
            p["pn2"] = _init_norm(cfg)
    return p


def init_model(key: jax.Array, cfg: ArchConfig) -> Params:
    plan, n_periods = period_plan(cfg)
    keys = jax.random.split(key, len(plan) + 4)
    params: Params = {
        "embed": init_embedding(keys[0], cfg.vocab, cfg.d_model,
                                cfg.ptc.base_dtype),
        "final_norm": _init_norm(cfg),
    }
    if not cfg.tie_embed:
        params["unembed"] = {
            "w": (jax.random.normal(keys[1], (cfg.vocab, cfg.d_model),
                                    jnp.float32)
                  * (cfg.d_model ** -0.5)).astype(cfg.ptc.base_dtype)}
    for i, sub in enumerate(plan):
        pk = jax.random.split(keys[2 + i], n_periods)
        params[f"pos{i}"] = jax.vmap(
            lambda k: _init_sublayer(k, cfg, sub))(pk)
    if cfg.family == "encdec":
        ek = jax.random.split(keys[-1], cfg.n_enc_layers)
        enc_plan = SubLayerPlan("attn", "mlp", causal=False)
        params["enc"] = jax.vmap(
            lambda k: _init_sublayer(k, cfg, enc_plan))(ek)
        params["enc_norm"] = _init_norm(cfg)
    return params


def model_trainable_mask(params: Params) -> Params:
    return trainable_mask(params)


# ---------------------------------------------------------------------------
# sampling-mask injection (paper §3.4.2, LM-scale)
# ---------------------------------------------------------------------------


def inject_masks(params: Params, key: jax.Array, scfg: SparsityConfig,
                 n_tokens: int) -> Params:
    """Return a copy of ``params`` with per-PTC ``fb``/``col`` mask leaves.

    Masks are sampled from stop-gradient block energies; stacked leading
    axes (period, experts, …) are vmapped over so scan/vmap slicing
    distributes the right mask to the right physical block grid."""
    if not scfg.enabled:
        return params
    counter = [0]

    def walk(p):
        if isinstance(p, dict):
            if "u" in p and "s" in p and "v" in p:
                out = dict(p)
                s = jax.lax.stop_gradient(p["s"]).astype(jnp.float32)
                energy = jnp.sum(s * s, axis=-1)        # (..., P, Q)
                k = jax.random.fold_in(key, counter[0])
                counter[0] += 1
                lead = energy.shape[:-2]
                if scfg.alpha_w < 1.0:
                    e2 = energy.reshape((-1,) + energy.shape[-2:])
                    ks = jax.random.split(k, e2.shape[0])
                    fb = jax.vmap(lambda kk, ee: feedback_mask(kk, ee, scfg)
                                  )(ks, e2)
                    out["fb"] = fb.reshape(lead + fb.shape[1:])
                if scfg.alpha_c < 1.0:
                    kc = jax.random.fold_in(k, 1)
                    if lead:
                        kcs = jax.random.split(kc, int(jnp.prod(
                            jnp.asarray(lead))))
                        col = jax.vmap(lambda kk: column_mask(
                            kk, n_tokens, scfg))(kcs)
                        out["col"] = col.reshape(lead + (n_tokens,))
                    else:
                        out["col"] = column_mask(kc, n_tokens, scfg)
                return out
            return {k2: walk(v) for k2, v in p.items()}
        return p

    return walk(params)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _sublayer_fwd(cfg: ArchConfig, plan: SubLayerPlan, p: Params, x, positions,
                  cross_kv=None):
    """One sub-layer (train/prefill path).  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _apply_norm(cfg, p["ln1"], x)
    if plan.kind == "attn":
        h = attention(p["attn"], cfg.attn_cfg(plan.window, plan.causal),
                      cfg.ptc, h, positions, chunk=cfg.attn_chunk)
    else:
        h = mamba(p["mamba"], cfg.ssm_cfg(), cfg.ptc, h)
    if cfg.post_norm:
        h = _apply_norm(cfg, p["pn1"], h)
    x = x + h
    if plan.cross:
        h = _apply_norm(cfg, p["lnx"], x)
        h = attention(p["cross"], cfg.attn_cfg(causal=False), cfg.ptc, h,
                      None, kv_x=cross_kv)
        x = x + h
    if plan.ffn != "none":
        h = _apply_norm(cfg, p["ln2"], x)
        if plan.ffn == "moe":
            h, a = moe(p["moe"], cfg.moe_cfg(), cfg.ptc, h)
            aux = aux + a
        else:
            h = mlp(p["mlp"], cfg.ffn_cfg(), cfg.ptc, h)
        if cfg.post_norm:
            h = _apply_norm(cfg, p["pn2"], h)
        x = x + h
    return x, aux


def _run_stack(cfg: ArchConfig, plan, stacked: list[Params], x, positions,
               cross_kv=None):
    """Scan the period stack.  ``stacked[i]`` has leading period axis."""
    def body(carry, layer_params):
        x, aux = carry
        for i, sub in enumerate(plan):
            x, a = _sublayer_fwd(cfg, sub, layer_params[i], x, positions,
                                 cross_kv)
            aux = aux + a
        return (x, aux), None

    if cfg.remat and cfg.remat_policy != "none":
        policy = None
        if cfg.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(body, policy=policy)
    if cfg.unroll:
        n_periods = jax.tree.leaves(stacked[0])[0].shape[0]
        carry = (x, jnp.zeros((), jnp.float32))
        for pi in range(n_periods):
            layer = [jax.tree.map(lambda a: a[pi], st) for st in stacked]
            carry, _ = body(carry, layer)
        return carry
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               stacked)
    return x, aux


def forward(params: Params, cfg: ArchConfig, batch: dict[str, jax.Array],
            ) -> tuple[jax.Array, jax.Array]:
    """Token logits for a full sequence.  Returns (logits, aux_loss)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = embed(params["embed"], tokens)
    if cfg.family != "ssm":
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    cross_kv = None
    if cfg.family == "encdec":
        enc = batch["frames"].astype(x.dtype)       # stubbed audio frontend
        enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1])[None],
                                   (b, enc.shape[1]))
        enc_out, _ = _run_stack(
            cfg, [SubLayerPlan("attn", "mlp", causal=False)],
            [params["enc"]], enc, enc_pos)
        cross_kv = _apply_norm(cfg, params["enc_norm"], enc_out)
    if cfg.family == "vlm":
        cross_kv = batch["img"].astype(x.dtype)     # stubbed vision tower

    plan, _ = period_plan(cfg)
    stacked = [params[f"pos{i}"] for i in range(len(plan))]
    x, aux = _run_stack(cfg, plan, stacked, x, positions, cross_kv)
    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embed:
        logits = x @ params["embed"]["e"].T
    else:
        logits = x @ params["unembed"]["w"].T
    # keep the (B, S, vocab) logits vocab-sharded — replicated logits are
    # ~20 GB/device at 152k vocab (measured); CE reduces over the shard
    logits = maybe_constraint(logits, "dp", None, "model")
    logits = softcap(logits, cfg.final_softcap)
    return logits, aux


@jax.custom_vjp
def _ce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Memory-lean softmax CE: the (B, S, V) tensor is never upcast to
    f32 (only the reduced max/denoms are) and the backward materializes
    a single bf16 softmax instead of f32 logit copies — at 256k vocab
    this is ~8 GB/device less live memory than the naive form."""
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.exp(logits - m)                       # stays in logits dtype
    denom = jnp.sum(p.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0].astype(jnp.float32)
    lse = m[..., 0].astype(jnp.float32) + jnp.log(denom)
    return jnp.mean(lse - gold)


def _ce_fwd(logits, labels):
    return _ce(logits, labels), (logits, labels)


def _ce_bwd(res, g):
    logits, labels = res
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
    soft = (p / denom.astype(p.dtype))
    onehot = (labels[..., None] == jnp.arange(
        logits.shape[-1], dtype=labels.dtype)).astype(soft.dtype)
    n = 1
    for d in labels.shape:
        n *= d
    dl = (soft - onehot) * jnp.asarray(g / n, soft.dtype)
    return dl, None


_ce.defvjp(_ce_fwd, _ce_bwd)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return _ce(logits, labels)


def build_train_step(cfg: ArchConfig, sparsity: SparsityConfig | None = None):
    """Returns train_step(params, batch, key) → (loss, grads).

    Gradients are taken ONLY w.r.t. the trainable partition (Σ +
    electronics); frozen U/V bases ride along as non-differentiated
    constants, so no zero-grad accumulators are ever materialized.
    Frozen positions in the returned grads tree are scalar-zero
    placeholders (the optimizer skips them via the same mask)."""
    scfg = sparsity

    def loss_fn(tr, fr, mask, batch, key):
        params = combine(tr, fr, mask)
        if scfg is not None and scfg.enabled:
            n_tokens = batch["tokens"].shape[0] * batch["tokens"].shape[1]
            params = inject_masks(params, key, scfg, n_tokens)
        logits, aux = forward(params, cfg, batch)
        return cross_entropy(logits, batch["labels"]) + aux

    def train_step(params, batch, key):
        mask = trainable_mask(params)
        tr, fr = partition(params, mask)
        loss, grads = jax.value_and_grad(loss_fn)(tr, fr, mask, batch, key)
        return loss, grads

    return train_step


# ---------------------------------------------------------------------------
# serve (decode) path
# ---------------------------------------------------------------------------


def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    plan, n_periods = period_plan(cfg)
    cache: Params = {}
    for i, sub in enumerate(plan):
        if sub.kind == "attn":
            one = init_kv_cache(batch, max_len, cfg.attn_cfg(sub.window))
        else:
            one = init_ssm_state(batch, cfg.ssm_cfg())
        cache[f"pos{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_periods,) + a.shape), one)
    return cache


def build_serve_step(cfg: ArchConfig):
    """Returns serve_step(params, cache, batch) → (logits, new_cache).

    ``batch``: {"token": (B,1) int32, "cache_len": () int32,
    ["img"/"frames" for vlm/encdec]}.  One new token against a KV cache
    of length ``cache_len`` (the decode_* / long_* dry-run shapes)."""
    plan, n_periods = period_plan(cfg)

    def serve_step(params, cache, batch):
        tok = batch["token"]
        b = tok.shape[0]
        cache_len = batch["cache_len"]
        x = embed(params["embed"], tok)
        if cfg.family != "ssm":
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        cross_kv = None
        if cfg.family == "vlm":
            cross_kv = batch["img"].astype(x.dtype)
        if cfg.family == "encdec":
            cross_kv = batch["enc_out"].astype(x.dtype)

        def body(x, per):
            # PTC layers are name-scoped (``p{period}.s{sublayer}.<module>``)
            # so the hardware-in-the-loop executor (models.layers.
            # ptc_execution) can key tenant placement on a stable layer id;
            # under lax.scan the scopes only run at trace time and the hook
            # stays inert (tracer guard), so naming costs nothing there.
            layer_params, layer_cache = per
            new_cache = {}
            for i, sub in enumerate(plan):
                p = layer_params[f"pos{i}"]
                c = layer_cache[f"pos{i}"]
                h = _apply_norm(cfg, p["ln1"], x)
                if sub.kind == "attn":
                    with ptc_scope(f"s{i}.attn"):
                        h, c = decode_attention(p["attn"],
                                                cfg.attn_cfg(sub.window),
                                                cfg.ptc, h, c, cache_len)
                else:
                    with ptc_scope(f"s{i}.mamba"):
                        h, c = mamba_decode(p["mamba"], cfg.ssm_cfg(),
                                            cfg.ptc, h, c)
                if cfg.post_norm:
                    h = _apply_norm(cfg, p["pn1"], h)
                x = x + h
                if sub.cross:
                    h = _apply_norm(cfg, p["lnx"], x)
                    with ptc_scope(f"s{i}.cross"):
                        h = attention(p["cross"], cfg.attn_cfg(causal=False),
                                      cfg.ptc, h, None, kv_x=cross_kv)
                    x = x + h
                if sub.ffn != "none":
                    h = _apply_norm(cfg, p["ln2"], x)
                    if sub.ffn == "moe":
                        h, _ = moe(p["moe"], cfg.moe_cfg(), cfg.ptc, h)
                    else:
                        with ptc_scope(f"s{i}.mlp"):
                            h = mlp(p["mlp"], cfg.ffn_cfg(), cfg.ptc, h)
                    if cfg.post_norm:
                        h = _apply_norm(cfg, p["pn2"], h)
                    x = x + h
                new_cache[f"pos{i}"] = c
            return x, new_cache

        layer_stack = {f"pos{i}": params[f"pos{i}"] for i in range(len(plan))}
        if cfg.unroll:
            outs = []
            for pi in range(n_periods):
                lp = jax.tree.map(lambda a: a[pi], layer_stack)
                lc = jax.tree.map(lambda a: a[pi], cache)
                with ptc_scope(f"p{pi}"):
                    x, c = body(x, (lp, lc))
                outs.append(c)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            x, new_cache = jax.lax.scan(body, x, (layer_stack, cache))
        x = _apply_norm(cfg, params["final_norm"], x)
        if cfg.tie_embed:
            logits = x @ params["embed"]["e"].T
        else:
            logits = x @ params["unembed"]["w"].T
        return softcap(logits, cfg.final_softcap)[:, 0], new_cache

    return serve_step


def build_gateway_step(cfg: ArchConfig):
    """Returns gateway_step(params, views, batch) → (logits, new_kv):
    the continuous-batching decode step over *page-assembled* KV views
    with per-sequence cache lengths (``repro.serving.engine``).

    ``batch``: {"token": (B, 1) int32, "lens": (B,) int32} — B is the
    gateway's slot count, each slot at its own decode position.
    ``views`` mirrors :func:`init_decode_cache`'s tree: per sub-layer
    position either ``{"k","v"}`` views (n_periods, B, S_max, Hkv, Dh)
    gathered from the page pool, or an SSM state.  Unlike the dense
    serve step the views are step-scratch: the returned ``new_kv``
    holds only each attention layer's NEW (n_periods, B, 1, Hkv, Dh)
    rows (the engine scatters them into the pool) plus full replacement
    SSM states.

    PTC scope names are identical to :func:`build_serve_step`'s
    (``p{period}.s{sub}.attn.wq`` …), so a hardware-in-the-loop
    deployment recorded off the solo serve path routes the gateway's
    coalesced frames onto the same tenants."""
    plan, n_periods = period_plan(cfg)
    if cfg.family in ("vlm", "encdec"):
        raise ValueError(
            f"gateway decode does not support {cfg.family} archs "
            f"(per-request cross-attention streams are not paged yet)")
    if cfg.n_experts > 0:
        raise ValueError("gateway decode does not support MoE archs yet")

    def gateway_step(params, views, batch):
        tok = batch["token"]
        lens = batch["lens"]
        x = embed(params["embed"], tok)
        if cfg.family != "ssm":
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

        def body(x, per):
            layer_params, layer_views = per
            new = {}
            for i, sub in enumerate(plan):
                p = layer_params[f"pos{i}"]
                c = layer_views[f"pos{i}"]
                h = _apply_norm(cfg, p["ln1"], x)
                if sub.kind == "attn":
                    with ptc_scope(f"s{i}.attn"):
                        h, k_new, v_new = decode_attention_paged(
                            p["attn"], cfg.attn_cfg(sub.window), cfg.ptc,
                            h, c["k"], c["v"], lens)
                    new[f"pos{i}"] = {"k": k_new, "v": v_new}
                else:
                    with ptc_scope(f"s{i}.mamba"):
                        h, st = mamba_decode(p["mamba"], cfg.ssm_cfg(),
                                             cfg.ptc, h, c)
                    new[f"pos{i}"] = st
                if cfg.post_norm:
                    h = _apply_norm(cfg, p["pn1"], h)
                x = x + h
                if sub.ffn != "none":
                    h = _apply_norm(cfg, p["ln2"], x)
                    with ptc_scope(f"s{i}.mlp"):
                        h = mlp(p["mlp"], cfg.ffn_cfg(), cfg.ptc, h)
                    if cfg.post_norm:
                        h = _apply_norm(cfg, p["pn2"], h)
                    x = x + h
            return x, new

        layer_stack = {f"pos{i}": params[f"pos{i}"] for i in range(len(plan))}
        if cfg.unroll:
            outs = []
            for pi in range(n_periods):
                lp = jax.tree.map(lambda a: a[pi], layer_stack)
                lv = jax.tree.map(lambda a: a[pi], views)
                with ptc_scope(f"p{pi}"):
                    x, nk = body(x, (lp, lv))
                outs.append(nk)
            new_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            x, new_kv = jax.lax.scan(body, x, (layer_stack, views))
        x = _apply_norm(cfg, params["final_norm"], x)
        if cfg.tie_embed:
            logits = x @ params["embed"]["e"].T
        else:
            logits = x @ params["unembed"]["w"].T
        return softcap(logits, cfg.final_softcap)[:, 0], new_kv

    return gateway_step


def build_gateway_prefill_step(cfg: ArchConfig, kv_block: int | None = None):
    """Returns prefill_step(params, views, batch) → (logits, new_kv):
    the chunked-prefill gateway step — every slot advances up to C
    tokens per call instead of one.

    ``batch``: {"token": (B, C) int32, "lens": (B,) int32,
    "n_valid": (B,) int32} — slot b's next ``n_valid[b]`` tokens sit in
    columns 0..n_valid-1 at absolute positions ``lens[b] + c`` (decode
    slots ride along with n_valid == 1; padding columns are arbitrary
    and masked).  ``views`` is :func:`build_gateway_step`'s tree; the
    returned ``new_kv`` holds (n_periods, B, C, Hkv, Dh) rows per
    attention position, of which the engine scatters the first
    ``n_valid[b]`` per slot.  Logits are taken at column
    ``n_valid[b]-1`` — the prediction after the slot's last real token
    — so the return shape matches the one-token step: (B, vocab).

    PTC scope names are IDENTICAL to :func:`build_gateway_step`
    (``p{period}.s{sub}.attn.wq`` …): a hardware deployment recorded
    off the solo serve path routes the wide (B·C-column) prefill frames
    onto the same tenants untouched.  ``kv_block`` sets the Pallas
    kernel's KV block size (None = whole view per block).

    Attention-only: ssm/hybrid recurrences are inherently sequential in
    tokens, and vlm/encdec/MoE are not paged at all — those archs keep
    the one-token path."""
    plan, n_periods = period_plan(cfg)
    if cfg.family in ("vlm", "encdec"):
        raise ValueError(
            f"gateway decode does not support {cfg.family} archs "
            f"(per-request cross-attention streams are not paged yet)")
    if cfg.n_experts > 0:
        raise ValueError("gateway decode does not support MoE archs yet")
    if any(sub.kind != "attn" for sub in plan):
        raise ValueError(
            "chunked prefill supports attention-only archs; ssm/hybrid "
            "token recurrences are sequential — use prefill_chunk=1")

    def prefill_step(params, views, batch):
        tok = batch["token"]
        lens = batch["lens"]
        n_valid = batch["n_valid"].astype(jnp.int32)
        x = embed(params["embed"], tok)
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

        def body(x, per):
            layer_params, layer_views = per
            new = {}
            for i, sub in enumerate(plan):
                p = layer_params[f"pos{i}"]
                c = layer_views[f"pos{i}"]
                h = _apply_norm(cfg, p["ln1"], x)
                with ptc_scope(f"s{i}.attn"):
                    h, k_new, v_new = decode_attention_paged_chunked(
                        p["attn"], cfg.attn_cfg(sub.window), cfg.ptc,
                        h, c["k"], c["v"], lens, kv_block=kv_block)
                new[f"pos{i}"] = {"k": k_new, "v": v_new}
                if cfg.post_norm:
                    h = _apply_norm(cfg, p["pn1"], h)
                x = x + h
                if sub.ffn != "none":
                    h = _apply_norm(cfg, p["ln2"], x)
                    with ptc_scope(f"s{i}.mlp"):
                        h = mlp(p["mlp"], cfg.ffn_cfg(), cfg.ptc, h)
                    if cfg.post_norm:
                        h = _apply_norm(cfg, p["pn2"], h)
                    x = x + h
            return x, new

        layer_stack = {f"pos{i}": params[f"pos{i}"] for i in range(len(plan))}
        if cfg.unroll:
            outs = []
            for pi in range(n_periods):
                lp = jax.tree.map(lambda a: a[pi], layer_stack)
                lv = jax.tree.map(lambda a: a[pi], views)
                with ptc_scope(f"p{pi}"):
                    x, nk = body(x, (lp, lv))
                outs.append(nk)
            new_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            x, new_kv = jax.lax.scan(body, x, (layer_stack, views))
        x = _apply_norm(cfg, params["final_norm"], x)
        if cfg.tie_embed:
            logits = x @ params["embed"]["e"].T
        else:
            logits = x @ params["unembed"]["w"].T
        logits = softcap(logits, cfg.final_softcap)      # (B, C, V)
        last = jnp.take_along_axis(logits, (n_valid - 1)[:, None, None],
                                   axis=1)
        return last[:, 0], new_kv

    return prefill_step
