"""Model zoo: every assigned architecture built from PTC-factorized linears.

* ``layers``      — PTCLinear wrapper, norms, rotary variants, softcap
* ``attention``   — GQA self/cross attention (chunked-softmax prefill,
                    KV-cache decode)
* ``moe``         — top-k MoE with sort-based ragged expert dispatch (EP)
* ``ssm``         — Mamba-1 selective scan (falcon-mamba, jamba)
* ``lm``          — decoder-only / enc-dec / VLM assembly + train & serve
                    step builders
* ``cnn``         — the paper's own MLP/CNN models (k=9 PTC, im2col conv)
"""

from .layers import PTCLinearCfg, init_ptc_linear, apply_ptc_linear  # noqa: F401
from .lm import build_train_step, build_serve_step, init_model  # noqa: F401
