"""GQA self/cross attention over PTC-factorized projections.

Features needed across the assigned archs: grouped KV heads (all),
qk-norm (qwen3), attention/logit soft-capping (gemma2), sliding-window
local layers (gemma2 alternates local/global), partial/2d rotary
(chatglm), cross-attention (whisper decoder, llama-vision), KV-cache
decode (serve path), and chunked-softmax attention for long prefill
(online softmax over KV blocks — memory O(S·chunk) instead of O(S²)).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..kernels.ops import prefill_attention
from .layers import (PTCLinearCfg, init_ptc_linear, apply_ptc_linear,
                     init_rmsnorm, rmsnorm, rotary_cache, apply_rotary,
                     softcap)

__all__ = ["AttnCfg", "init_attention", "attention", "decode_attention",
           "decode_attention_paged", "decode_attention_paged_chunked",
           "init_kv_cache"]

Params = dict[str, Any]
NEG_INF = -2.0 ** 30


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    rope_frac: float = 1.0          # <1 = partial rotary (chatglm 2d-RoPE)
    qk_norm: bool = False           # qwen3
    attn_softcap: float | None = None   # gemma2
    qkv_bias: bool = False          # chatglm3
    causal: bool = True             # False for encoder / cross-attn
    window: int | None = None       # sliding window (gemma2 local layers)


def init_attention(key: jax.Array, cfg: AttnCfg, lin: PTCLinearCfg) -> Params:
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    d, hd = cfg.d_model, cfg.head_dim
    p: Params = {
        "wq": init_ptc_linear(kq, d, cfg.n_heads * hd, lin, bias=cfg.qkv_bias),
        "wk": init_ptc_linear(kk, d, cfg.n_kv_heads * hd, lin,
                              bias=cfg.qkv_bias),
        "wv": init_ptc_linear(kv, d, cfg.n_kv_heads * hd, lin,
                              bias=cfg.qkv_bias),
        "wo": init_ptc_linear(ko, cfg.n_heads * hd, d, lin),
    }
    if cfg.qk_norm:
        p["qn"] = init_rmsnorm(hd)
        p["kn"] = init_rmsnorm(hd)
    return p


def _project_qkv(p: Params, cfg: AttnCfg, lin: PTCLinearCfg, x, positions,
                 kv_x=None):
    """Project (and rope/norm) q from x, k/v from kv_x (defaults to x)."""
    b = x.shape[0]
    kv_x = x if kv_x is None else kv_x
    q = apply_ptc_linear(p["wq"], x, lin, d_out=cfg.n_heads * cfg.head_dim,
                         name="wq")
    k = apply_ptc_linear(p["wk"], kv_x, lin,
                         d_out=cfg.n_kv_heads * cfg.head_dim, name="wk")
    v = apply_ptc_linear(p["wv"], kv_x, lin,
                         d_out=cfg.n_kv_heads * cfg.head_dim, name="wv")
    q = q.reshape(b, x.shape[1], cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, kv_x.shape[1], cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, kv_x.shape[1], cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["qn"], q)
        k = rmsnorm(p["kn"], k)
    if cfg.rope_frac > 0 and positions is not None:
        cos, sin = rotary_cache(positions, cfg.head_dim, cfg.rope_theta,
                                cfg.rope_frac)
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
    return q, k, v


def _mask_bias(sq, sk, causal, window, q_offset=0, dtype=jnp.float32):
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok = ok & (ki <= qi)
    if window is not None:
        ok = ok & (ki > qi - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(dtype)


def _sdpa(q, k, v, cfg: AttnCfg, q_offset=0):
    """Materialized-scores attention (training / short prefill)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    rep = h // k.shape[2]
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) * scale
    logits = softcap(logits, cfg.attn_softcap)
    logits = logits + _mask_bias(sq, sk, cfg.causal, cfg.window, q_offset)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, vr)


def _sdpa_chunked(q, k, v, cfg: AttnCfg, chunk: int):
    """Online-softmax attention over KV chunks: O(S·chunk) memory.

    The long-prefill path; mathematically identical to _sdpa."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    assert sk % chunk == 0, (sk, chunk)
    rep = h // k.shape[2]
    scale = hd ** -0.5
    kc = k.reshape(b, sk // chunk, chunk, k.shape[2], hd)
    vc = v.reshape(b, sk // chunk, chunk, v.shape[2], hd)
    qi = jnp.arange(sq)[:, None]

    def body(carry, ckv):
        acc, m, denom, ci = carry
        kb, vb = ckv
        kb = jnp.repeat(kb, rep, axis=2)
        vb = jnp.repeat(vb, rep, axis=2)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        logits = softcap(logits, cfg.attn_softcap)
        ki = ci * chunk + jnp.arange(chunk)[None, :]
        ok = jnp.ones((sq, chunk), bool)
        if cfg.causal:
            ok = ok & (ki <= qi)
        if cfg.window is not None:
            ok = ok & (ki > qi - cfg.window)
        logits = logits + jnp.where(ok, 0.0, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(logits - m_new[..., None])
        denom = denom * alpha + pexp.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", pexp.astype(q.dtype), vb).astype(jnp.float32)
        return (acc, m_new, denom, ci + 1), None

    acc0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    d0 = jnp.zeros((b, h, sq), jnp.float32)
    # checkpoint per KV chunk: backward recomputes each chunk's logits
    # instead of saving (B, H, S, S_k) — peak memory O(S·chunk)
    (acc, _, denom, _), _ = jax.lax.scan(
        jax.checkpoint(body), (acc0, m0, d0, jnp.asarray(0)),
        (jnp.swapaxes(kc, 0, 1), jnp.swapaxes(vc, 0, 1)))
    out = acc / denom[..., None]
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)


def attention(p: Params, cfg: AttnCfg, lin: PTCLinearCfg, x, positions,
              kv_x=None, chunk: int | None = None):
    """Full attention layer: project → attend → output projection."""
    q, k, v = _project_qkv(p, cfg, lin, x, positions, kv_x)
    if chunk is not None and k.shape[1] > chunk:
        o = _sdpa_chunked(q, k, v, cfg, chunk)
    else:
        o = _sdpa(q, k, v, cfg)
    b, s = x.shape[0], x.shape[1]
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return apply_ptc_linear(p["wo"], o, lin, d_out=cfg.d_model, name="wo")


# -- decode (serve path) -----------------------------------------------------


def init_kv_cache(batch: int, max_len: int, cfg: AttnCfg, dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(p: Params, cfg: AttnCfg, lin: PTCLinearCfg, x, cache,
                     cache_len):
    """One-token decode against a populated KV cache.

    x: (B, 1, d); cache k/v: (B, S, Hkv, Dh); cache_len: scalar/ (B,) —
    number of valid cache entries.  Returns (out, updated_cache)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), cache_len, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, lin, x, positions)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), cache_len, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), cache_len, axis=1)
    sk = k.shape[1]
    rep = cfg.n_heads // cfg.n_kv_heads
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32)
    logits = logits * (cfg.head_dim ** -0.5)
    logits = softcap(logits, cfg.attn_softcap)
    ki = jnp.arange(sk)[None, None, None, :]
    ok = ki <= cache_len
    if cfg.window is not None:
        ok = ok & (ki > cache_len - cfg.window)
    logits = jnp.where(ok, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, vr)
    o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    out = apply_ptc_linear(p["wo"], o, lin, d_out=cfg.d_model, name="wo")
    return out, {"k": k, "v": v}


def decode_attention_paged(p: Params, cfg: AttnCfg, lin: PTCLinearCfg, x,
                           k_view, v_view, lens):
    """One-token decode against page-assembled per-slot KV views with
    *per-sequence* cache lengths (the continuous-batching gateway path).

    x: (B, 1, d); k_view/v_view: (B, S_max, Hkv, Dh) contiguous views
    gathered from the page pool (position ``lens[b]`` is within slot
    b's reservation); lens: (B,) int32 valid lengths — heterogeneous
    across the batch, unlike :func:`decode_attention`'s shared scalar.

    Returns ``(out, k_new, v_new)``: the caller persists the new
    (B, 1, Hkv, Dh) rows into the page pool (``kernels.paged_scatter``);
    the assembled views are step-scratch and never written back.
    """
    b = x.shape[0]
    lens = lens.astype(jnp.int32)
    positions = lens[:, None]
    q, k_new, v_new = _project_qkv(p, cfg, lin, x, positions)
    # splice each slot's new row in at its own write position
    ins = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
        c, n, i, axis=0))
    k = ins(k_view, k_new.astype(k_view.dtype), lens)
    v = ins(v_view, v_new.astype(v_view.dtype), lens)
    sk = k.shape[1]
    rep = cfg.n_heads // cfg.n_kv_heads
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32)
    logits = logits * (cfg.head_dim ** -0.5)
    logits = softcap(logits, cfg.attn_softcap)
    ki = jnp.arange(sk)[None, None, None, :]
    ln = lens[:, None, None, None]
    ok = ki <= ln
    if cfg.window is not None:
        ok = ok & (ki > ln - cfg.window)
    logits = jnp.where(ok, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, vr)
    o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    out = apply_ptc_linear(p["wo"], o, lin, d_out=cfg.d_model, name="wo")
    return out, k_new, v_new


def decode_attention_paged_chunked(p: Params, cfg: AttnCfg,
                                   lin: PTCLinearCfg, x, k_view, v_view,
                                   lens, kv_block: int | None = None):
    """C-token chunked prefill against page-assembled per-slot views.

    x: (B, C, d) — each slot's next C tokens (padding columns past the
    slot's ``n_valid`` are arbitrary: the causal mask plus the caller's
    length bookkeeping keep them out of every surviving value); lens:
    (B,) int32 cache lengths, so chunk column c sits at absolute
    position ``lens[b] + c``.  Attention runs through the Pallas
    online-softmax kernel (``kernels.prefill_attention``) over the view
    with the chunk's own K/V rows spliced in, ``kv_block`` keys at a
    time.

    The splice deliberately avoids ``dynamic_update_slice`` — its start
    index CLAMPS, so a slot near the end of its reservation would slide
    the chunk backwards over valid history.  Instead each view row
    selects by absolute position: rows ``lens[b]+c`` take chunk column
    c, all others keep the pool value.

    Returns ``(out, k_new, v_new)`` with out (B, C, d) and k_new/v_new
    (B, C, Hkv, Dh) for the caller's multi-row page scatter.
    """
    b, c = x.shape[0], x.shape[1]
    lens = lens.astype(jnp.int32)
    positions = lens[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    q, k_new, v_new = _project_qkv(p, cfg, lin, x, positions)
    s = k_view.shape[1]
    rel = jnp.arange(s, dtype=jnp.int32)[None, :] - lens[:, None]  # (B, S)
    in_chunk = (rel >= 0) & (rel < c)
    sel = jnp.clip(rel, 0, c - 1)[:, :, None, None]

    def splice(view, new):
        g = jnp.take_along_axis(new.astype(view.dtype), sel, axis=1)
        return jnp.where(in_chunk[:, :, None, None], g, view)

    o = prefill_attention(lens, q, splice(k_view, k_new),
                          splice(v_view, v_new), blk=kv_block,
                          window=cfg.window, cap=cfg.attn_softcap)
    o = o.reshape(b, c, cfg.n_heads * cfg.head_dim)
    out = apply_ptc_linear(p["wo"], o, lin, d_out=cfg.d_model, name="wo")
    return out, k_new, v_new
