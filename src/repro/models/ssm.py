"""Mamba-1 selective SSM (falcon-mamba; jamba's mamba layers).

All dense projections (in/x/dt/out) are PTC-factorized; the selective
recurrence itself is elementwise/diagonal — no dense matrix exists, so
the paper's technique is *not applicable to the recurrence* (DESIGN
§Arch-applicability) and its small parameters (A, D, conv, dt bias) stay
electronic-trainable.

TPU adaptation of the CUDA selective-scan kernel: a CHUNKED associative
scan — ``lax.associative_scan`` inside fixed-size sequence chunks
(materializing (B, c, d_inner, N) only per chunk), with the SSM state
carried across chunks by an outer ``lax.scan``.  This is the
memory-hierarchy rethink the hardware-adaptation mandate asks for: VMEM
holds one chunk's states, HBM holds one chunk's activations, never the
full (B, S, d_inner, N) tensor.  Decode is the exact single-step
recurrence against a carried (h, conv) state.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import (PTCLinearCfg, init_ptc_linear, apply_ptc_linear,
                     )

__all__ = ["SSMCfg", "init_mamba", "mamba", "mamba_decode", "init_ssm_state"]

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_model: int
    d_state: int = 16
    expand: int = 2
    conv_width: int = 4
    dt_rank: int | None = None      # default d_model/16
    chunk: int = 256                # associative-scan chunk length

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def rank(self) -> int:
        return self.dt_rank if self.dt_rank is not None else max(
            1, self.d_model // 16)


def init_mamba(key: jax.Array, cfg: SSMCfg, lin: PTCLinearCfg) -> Params:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    din, n, r = cfg.d_inner, cfg.d_state, cfg.rank
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (din, 1))
    return {
        "in_proj": init_ptc_linear(k1, cfg.d_model, 2 * din, lin),
        "conv_w": 0.1 * jax.random.normal(k2, (cfg.conv_width, din),
                                          jnp.float32),
        "conv_b": jnp.zeros((din,), jnp.float32),
        "x_proj": init_ptc_linear(k3, din, r + 2 * n, lin),
        "dt_proj": init_ptc_linear(k4, r, din, lin, bias=True),
        "a_log": jnp.log(a),            # A = −exp(a_log) (stability)
        "d": jnp.ones((din,), jnp.float32),
        "out_proj": init_ptc_linear(k5, din, cfg.d_model, lin),
    }


def _causal_depthwise_conv(x, w, b, init_state=None):
    """x: (B, S, D); w: (W, D) depthwise taps → causal conv, silu'd.

    ``init_state``: (B, W-1, D) carry-in from previous tokens (decode)."""
    width = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu(out + b), xp[:, -(width - 1):]


def _ssm_params(p: Params, cfg: SSMCfg, lin: PTCLinearCfg, xc):
    """Input-dependent Δ, B, C from the conv'd activations xc (B,S,din)."""
    n, r = cfg.d_state, cfg.rank
    proj = apply_ptc_linear(p["x_proj"], xc, lin, d_out=r + 2 * n,
                            name="x_proj")
    dt, b_ssm, c_ssm = jnp.split(proj, [r, r + n], axis=-1)
    dt = apply_ptc_linear(p["dt_proj"], dt, lin, d_out=cfg.d_inner,
                          name="dt_proj")
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    return dt, b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32)


def mamba(p: Params, cfg: SSMCfg, lin: PTCLinearCfg, x: jax.Array,
          ) -> jax.Array:
    """Training / prefill path: chunked associative selective scan."""
    bsz, s, _ = x.shape
    din, n = cfg.d_inner, cfg.d_state
    xz = apply_ptc_linear(p["in_proj"], x, lin, d_out=2 * din,
                          name="in_proj")
    x_in, z = jnp.split(xz, 2, axis=-1)
    # NOTE (§Perf pair 3): explicit d_inner sharding constraints here
    # (outer or per-chunk) were each measured to REGRESS the jamba
    # roofline (0.382 → 0.283) — the partitioner's propagated layout
    # beats the hand-forced one; left to propagation deliberately.
    xc, _ = _causal_depthwise_conv(x_in, p["conv_w"], p["conv_b"])
    dt, b_ssm, c_ssm = _ssm_params(p, cfg, lin, xc)
    a = -jnp.exp(p["a_log"])                                  # (din, N)

    chunk = min(cfg.chunk, s)
    assert s % chunk == 0, (s, chunk)
    nchunks = s // chunk

    def scan_chunk(h0, inputs):
        xc_c, dt_c, b_c, c_c = inputs                         # (B, c, ·)
        abar = jnp.exp(dt_c[..., None] * a)                   # (B,c,din,N)
        bx = (dt_c * xc_c.astype(jnp.float32))[..., None] * b_c[..., None, :]
        # NOTE: constraining abar/bx here was measured to REGRESS (the
        # partitioner reshards per chunk); outer dt/xc constraints are
        # kept, the scan interior is left to propagation (§Perf pair 3)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        # prepend carry-in as a pseudo-step: h0 enters through b
        a_all = jnp.concatenate(
            [jnp.ones((bsz, 1, din, n), abar.dtype), abar], axis=1)
        b_all = jnp.concatenate([h0[:, None], bx], axis=1)
        _, h_all = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
        h = h_all[:, 1:]                                      # (B,c,din,N)
        y = jnp.einsum("bcdn,bcn->bcd", h, c_c)
        return h[:, -1], y

    resh = lambda t: t.reshape(bsz, nchunks, chunk, *t.shape[2:]).swapaxes(0, 1)
    h0 = jnp.zeros((bsz, din, n), jnp.float32)
    _, ys = jax.lax.scan(scan_chunk, h0,
                         (resh(xc), resh(dt), resh(b_ssm), resh(c_ssm)))
    y = ys.swapaxes(0, 1).reshape(bsz, s, din)
    y = y + p["d"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return apply_ptc_linear(p["out_proj"], y, lin, d_out=cfg.d_model,
                            name="out_proj")


# -- decode ------------------------------------------------------------------


def init_ssm_state(batch: int, cfg: SSMCfg) -> Params:
    return {"h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner),
                              jnp.bfloat16)}


def mamba_decode(p: Params, cfg: SSMCfg, lin: PTCLinearCfg, x: jax.Array,
                 state: Params) -> tuple[jax.Array, Params]:
    """Single-token recurrence.  x: (B, 1, d) → (y, new_state)."""
    din, n = cfg.d_inner, cfg.d_state
    xz = apply_ptc_linear(p["in_proj"], x, lin, d_out=2 * din,
                          name="in_proj")
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, conv_new = _causal_depthwise_conv(x_in, p["conv_w"], p["conv_b"],
                                          init_state=state["conv"])
    dt, b_ssm, c_ssm = _ssm_params(p, cfg, lin, xc)
    a = -jnp.exp(p["a_log"])
    abar = jnp.exp(dt[:, 0, :, None] * a)                     # (B,din,N)
    bx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] \
        * b_ssm[:, 0, None, :]
    h = abar * state["h"] + bx
    y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0])[:, None]
    y = y + p["d"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = apply_ptc_linear(p["out_proj"], y, lin, d_out=cfg.d_model,
                           name="out_proj")
    return out, {"h": h, "conv": conv_new.astype(state["conv"].dtype)}
