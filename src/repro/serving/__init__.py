"""Continuous-batching serving gateway over the photonic fleet.

The request-level layer between real traffic and the hardware-in-the-
loop runtime (``repro.runtime``): a FIFO admission scheduler
(``scheduler``) continuously batches many concurrent decode streams
into one model forward per step, a paged KV cache (``kv_pages`` +
``kernels.paged_kv``) replaces the dense per-request cache so slots
admit/evict without reshaping state, and every PTC layer's matmul for
*all* in-flight requests ships to the routed chip as ONE coalesced
driver frame (``engine``) — the chip round-trip that used to serve one
user's layer now serves every user's.

    python -m repro.serving.gateway --arch smoke:qwen3-4b --slots 4 \
        --requests 8 --rate 1.0 --fleet 2 --fleet-k 8 --hw-logits

DESIGN
------
* **Lockstep continuous batching.**  One virtual step = one batched
  single-token forward over every active slot.  A request admitted into
  a slot streams its prompt token-by-token through the same decode path
  generation uses (prefill-then-decode slotting: the KV cache fills
  along the serving path, as ``launch/steps.greedy_decode`` does), so a
  gateway-served request is *token-identical* to a solo ``serve`` run
  at σ_drift = 0 — the conformance gate ``tests/test_serving_gateway.py``
  and ``benchmarks/serving_gateway.py`` lock on twin and socket
  transports.
* **Reserve-at-admission paging.**  A request is admitted only when a
  slot AND enough free pages for its whole lifetime
  (``ceil((prompt+max_new)/page_size)``) are available — admission is
  strict FIFO (no bypass, hence starvation-free) and a running request
  can never hit pool exhaustion mid-flight, so no preemption machinery
  is needed.  Eviction returns pages to the free list for reuse.
* **Cross-request PTC frame coalescing.**  The gateway's step function
  carries the full slot batch through every PTC layer, so the
  ``HwServePlane`` hook sees ONE (slots, 1, n) activation per layer and
  ships ONE ``forward_layer`` op per layer group per step — B users'
  matmuls per chip round-trip instead of one.
"""

from .kv_pages import PageConfig, PagedKVPool
from .scheduler import Request, Scheduler, poisson_workload
from .engine import GatewayConfig, ServingGateway

__all__ = ["PageConfig", "PagedKVPool", "Request", "Scheduler",
           "poisson_workload", "GatewayConfig", "ServingGateway"]
