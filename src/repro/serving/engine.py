"""Continuous-batching gateway engine: lockstep decode over slot batches.

One virtual step = one batched single-token forward over every active
slot (``models.lm.build_gateway_step``): page-assembled KV views in,
logits + new KV rows out, rows scattered back into the page pool
(``kernels.paged_gather`` / ``paged_scatter``).  Admission, eviction
and paging policy live in ``scheduler``/``kv_pages``; hardware-in-the-
loop execution rides the existing :class:`~repro.runtime.hw_serve.
HwServePlane` — the gateway installs the plane's PTC hook around its
loop, so each layer's matmul for ALL in-flight requests ships as one
coalesced driver frame to the routed chip.

Digital mode jits the step (static shapes: slot count, view lengths and
pool geometry never change — only table/length *contents* do).
Hardware mode runs it unjitted over an ``unroll=True`` config, exactly
like ``serve --hw-logits`` (the hook needs concrete activations).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ops import paged_gather, paged_scatter, paged_scatter_rows
from ..models.lm import (ArchConfig, build_gateway_prefill_step,
                         build_gateway_step, build_serve_step,
                         init_decode_cache, period_plan)
from ..models.ssm import init_ssm_state
from .kv_pages import PageConfig, PagedKVPool
from .scheduler import (Request, Scheduler, FINISH_EOS, FINISH_MAX_NEW)

__all__ = ["GatewayConfig", "ServingGateway", "build_gateway_hw_plane"]


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    """Static gateway geometry/policy."""

    slots: int = 4               # concurrent decode streams
    pages: PageConfig = PageConfig()
    max_steps: int = 100_000     # hard stop for the run loop
    # chunked prefill: each prefilling slot ingests up to prefill_chunk
    # prompt tokens per virtual step through the (B, C)-wide prefill
    # step while decode slots ride along producing one token each.
    # 1 = the original one-token-per-step path, bit-for-bit.
    prefill_chunk: int = 1
    # test/debug knob: cap tokens *advanced* per step below the padded
    # width C.  stride s at width C is bitwise-identical in KV and
    # tokens to stride C at width C (row-position invariance at fixed
    # shape) — the property tests' comparison lever.  None = C.
    prefill_stride: int | None = None
    kv_block: int | None = None  # prefill kernel KV block (None = whole view)


def build_gateway_hw_plane(key, cfg: ArchConfig, params, runtime_cfg,
                           n_chips: int, *, slots: int, mode: str = "route",
                           seed: int = 0, recal_enabled: bool = True):
    """Deploy the model's decode-path PTC layers onto a fresh fleet for
    gateway serving (one tenant per layer, exactly the ``serve
    --hw-logits`` deployment).  ``cfg`` must be the unrolled config the
    gateway step will run; layer enumeration uses the *solo* serve step,
    whose scope names the gateway step reproduces."""
    from ..runtime.hw_serve import HwServePlane, record_ptc_layers

    serve_fn = build_serve_step(cfg)
    cache0 = init_decode_cache(cfg, slots, 2)
    batch0 = {"token": jnp.zeros((slots, 1), jnp.int32),
              "cache_len": jnp.asarray(0, jnp.int32)}
    layers = record_ptc_layers(serve_fn, params, cache0, batch0)
    return HwServePlane(key, layers, runtime_cfg, n_chips, mode=mode,
                        seed=seed, recal_enabled=recal_enabled)


class ServingGateway:
    """The request-level serving loop over one model + optional fleet."""

    def __init__(self, cfg: ArchConfig, params, gcfg: GatewayConfig,
                 hw_plane=None):
        if hw_plane is not None and not cfg.unroll:
            raise ValueError("hardware-in-the-loop gateway needs an "
                             "unroll=True config (the PTC hook is inert "
                             "under jit/scan)")
        self.cfg = cfg
        self.gcfg = gcfg
        self.params = params
        self.hw = hw_plane
        self.plan, self.n_periods = period_plan(cfg)
        self.pool = PagedKVPool(gcfg.pages, gcfg.slots)
        self.chunk = max(1, int(gcfg.prefill_chunk))
        self.stride = (self.chunk if gcfg.prefill_stride is None
                       else max(1, min(int(gcfg.prefill_stride), self.chunk)))
        if self.chunk > 1:
            self._step_fn = build_gateway_prefill_step(
                cfg, kv_block=gcfg.kv_block)
        else:
            self._step_fn = build_gateway_step(cfg)
        if hw_plane is None:
            self._step_fn = jax.jit(self._step_fn)

        # tensor pools: one (P·(n_pages+1), page_size, Hkv·Dh) pair per
        # attention sub-layer position — all periods share the slot page
        # table (token t lives at the same page/offset in every layer),
        # each period's pages offset by its stripe.  The +1 page per
        # stripe is the scratch page idle slots scatter into.
        ps = gcfg.pages.page_size
        self._stripe = gcfg.pages.n_pages + 1
        self._scratch = gcfg.pages.n_pages      # id of the scratch page
        self._kv_dims: dict[str, tuple[int, int]] = {}
        self._pools: dict[str, dict[str, jax.Array]] = {}
        self._ssm0: dict[str, dict] = {}
        self._ssm: dict[str, dict] = {}
        kv_dtype = jnp.bfloat16
        for i, sub in enumerate(self.plan):
            name = f"pos{i}"
            if sub.kind == "attn":
                acfg = cfg.attn_cfg(sub.window)
                hk, hd = acfg.n_kv_heads, acfg.head_dim
                self._kv_dims[name] = (hk, hd)
                shape = (self.n_periods * self._stripe, ps, hk * hd)
                self._pools[name] = {"k": jnp.zeros(shape, kv_dtype),
                                     "v": jnp.zeros(shape, kv_dtype)}
            else:
                one = init_ssm_state(gcfg.slots, cfg.ssm_cfg())
                stacked = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (self.n_periods,) + a.shape), one)
                self._ssm0[name] = stacked
                self._ssm[name] = stacked

        # counters
        self.step_count = 0
        self.busy_steps = 0
        self.slot_steps = 0          # Σ active slots over busy steps
        self.tokens_out = 0

    # -- paged-pool plumbing -------------------------------------------------

    def _period_table(self) -> np.ndarray:
        """(P·B, J) page table with per-period stripe offsets."""
        t = self.pool.table
        return np.concatenate(
            [t + p * self._stripe for p in range(self.n_periods)], axis=0)

    def _gather_views(self) -> dict:
        """Assemble every attention position's (P, B, S_max, Hkv, Dh)
        views from the pools; SSM positions pass their dense states."""
        b = self.gcfg.slots
        jps = self.gcfg.pages.max_pages_per_slot * self.gcfg.pages.page_size
        table = jnp.asarray(self._period_table())
        views = {}
        for name, pools in self._pools.items():
            hk, hd = self._kv_dims[name]
            views[name] = {
                kk: paged_gather(table, pools[kk]).reshape(
                    self.n_periods, b, jps, hk, hd)
                for kk in ("k", "v")}
        for name, st in self._ssm.items():
            views[name] = st
        return views

    def _scatter_new(self, new_kv: dict, active: Sequence[int]) -> None:
        """Persist each active slot's new KV row at its write position;
        idle slots land on the scratch page.  SSM replacement states are
        adopted wholesale (idle slots' states are reset on admit)."""
        b = self.gcfg.slots
        idx = np.zeros((b, 2), np.int32)
        idx[:, 0] = self._scratch
        for slot in active:
            pid, off = self.pool.write_pos(slot)
            idx[slot] = (pid, off)
        full_idx = np.concatenate(
            [idx + np.asarray([[p * self._stripe, 0]], np.int32)
             for p in range(self.n_periods)], axis=0)
        full_idx = jnp.asarray(full_idx)
        for name, pools in self._pools.items():
            hk, hd = self._kv_dims[name]
            rows = new_kv[name]     # {"k","v"}: (P, B, 1, Hkv, Dh)
            for kk in ("k", "v"):
                flat = rows[kk].reshape(self.n_periods * b, hk * hd)
                pools[kk] = paged_scatter(
                    full_idx, flat.astype(pools[kk].dtype), pools[kk])
        for name in self._ssm:
            self._ssm[name] = new_kv[name]

    def _scatter_chunk(self, new_kv: dict, act: np.ndarray,
                       take: np.ndarray) -> None:
        """Persist each active slot's first ``take[slot]`` new KV rows
        at its consecutive write positions — chunks crossing page
        boundaries are split host-side by ``PagedKVPool.write_span`` —
        through ONE aliased multi-row scatter per pool tensor.  Padding
        columns and idle slots land on the scratch page (the scatter
        grid is sequential, so the duplicate scratch writes resolve
        deterministically)."""
        b, c = self.gcfg.slots, self.chunk
        idx = np.zeros((b, c, 2), np.int32)
        idx[:, :, 0] = self._scratch
        for slot in np.flatnonzero(act):
            n = int(take[slot])
            if n:
                idx[slot, :n] = self.pool.write_span(slot, n)
        full_idx = np.concatenate(
            [idx.reshape(b * c, 2)
             + np.asarray([[p * self._stripe, 0]], np.int32)
             for p in range(self.n_periods)], axis=0)
        full_idx = jnp.asarray(full_idx)
        for name, pools in self._pools.items():
            hk, hd = self._kv_dims[name]
            rows = new_kv[name]     # {"k","v"}: (P, B, C, Hkv, Dh)
            for kk in ("k", "v"):
                flat = rows[kk].reshape(self.n_periods * b * c, hk * hd)
                pools[kk] = paged_scatter_rows(
                    full_idx, flat.astype(pools[kk].dtype), pools[kk])

    def _reset_slot(self, slot: int) -> None:
        """Zero an admitted slot's SSM state (pages need no reset: the
        slot writes before it reads, and attention masks by length)."""
        for name, st in self._ssm.items():
            self._ssm[name] = jax.tree.map(
                lambda a: a.at[:, slot].set(0), st)

    # -- the loop ------------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> dict:
        """Serve ``requests`` (arrival steps respected — the open-loop
        process) to completion; returns the report dict.

        Token staging is vectorized: per-slot prompt buffers, lengths
        and cursors live in NumPy arrays refreshed at admission /
        emission, so each step's (B, C) token block is pure fancy
        indexing — no per-slot scalar writes on the hot path.  With
        ``prefill_chunk`` C > 1 a prefilling slot ingests up to
        min(prefill_stride, remaining) prompt tokens per step while
        decode slots produce one token each (n_valid == 1), all through
        one (B, C)-wide forward."""
        sched = Scheduler(self.pool)
        todo = sorted(requests, key=lambda r: (r.arrival, r.rid))
        next_arrival = 0
        from ..models.layers import ptc_execution
        hook_ctx = (ptc_execution(self.hw.hook) if self.hw is not None
                    else contextlib.nullcontext())
        b, chunk, stride = self.gcfg.slots, self.chunk, self.stride
        buf_len = self.gcfg.pages.max_tokens_per_slot
        prompt_buf = np.zeros((b, buf_len), np.int32)
        plen = np.zeros((b,), np.int32)      # prompt length per slot
        slot_pos = np.zeros((b,), np.int32)  # decode position per slot
        last_tok = np.zeros((b,), np.int32)  # last emitted token per slot
        arange_b = np.arange(b)
        arange_c = np.arange(chunk)
        t0 = time.time()
        with hook_ctx:
            while self.step_count < self.gcfg.max_steps:
                step = self.step_count
                while (next_arrival < len(todo)
                       and todo[next_arrival].arrival <= step):
                    sched.submit(todo[next_arrival], step)
                    next_arrival += 1
                for slot, req in sched.admit(step):
                    slot_pos[slot] = 0
                    plen[slot] = req.prompt_len
                    prompt_buf[slot, :req.prompt_len] = req.prompt
                    self._reset_slot(slot)
                if sched.idle:
                    if next_arrival >= len(todo):
                        break                      # drained
                    # open-loop gap: virtual time still passes (drift
                    # walks, probes/repairs run) while no one is here —
                    # and the autopilot sees the trough (zero occupancy)
                    if self.hw is not None:
                        self.hw.observe_load(0.0)
                        self.hw.router.tick()
                    self.step_count += 1
                    continue

                act = np.asarray([r is not None for r in sched.running])
                if self.hw is not None:
                    # occupancy signal for the autopilot's load forecast:
                    # active slots plus queued requests, over capacity
                    # (>1 = over-subscribed)
                    self.hw.observe_load(
                        (int(act.sum()) + len(sched.pending)) / b)
                pre = act & (slot_pos < plen)
                dec = act & ~pre
                # tokens each slot ingests this step (idle slots: none)
                take = np.where(pre, np.minimum(stride, plen - slot_pos),
                                act.astype(np.int32))
                cols = slot_pos[:, None] + arange_c[None, :]     # (B, C)
                valid = arange_c[None, :] < take[:, None]
                tok = np.where(
                    pre[:, None] & valid,
                    prompt_buf[arange_b[:, None],
                               np.minimum(cols, buf_len - 1)],
                    0).astype(np.int32)
                tok[dec, 0] = last_tok[dec]
                batch = {"token": jnp.asarray(tok),
                         "lens": jnp.asarray(self.pool.lens)}
                if chunk > 1:
                    batch["n_valid"] = jnp.asarray(
                        np.maximum(take, 1).astype(np.int32))
                views = self._gather_views()
                step_ctx = (self.hw.step(step,
                                         valid=valid if chunk > 1 else None)
                            if self.hw is not None
                            else contextlib.nullcontext())
                with step_ctx:
                    logits, new_kv = self._step_fn(self.params, views, batch)
                if chunk > 1:
                    self._scatter_chunk(new_kv, act, take)
                else:
                    self._scatter_new(new_kv, list(np.flatnonzero(act)))
                preds = np.asarray(jnp.argmax(logits, axis=-1))
                for slot in np.flatnonzero(act):
                    req = sched.running[slot]
                    n = int(take[slot])
                    self.pool.advance(slot, n)
                    pos = slot_pos[slot] = slot_pos[slot] + n
                    if pos < plen[slot]:
                        continue                             # still prefilling
                    nxt = int(preds[slot])
                    req.out_tokens.append(nxt)
                    last_tok[slot] = nxt
                    self.tokens_out += 1
                    if req.first_token_step < 0:
                        req.first_token_step = step
                    if req.eos_id is not None and nxt == req.eos_id:
                        sched.finish(slot, step, FINISH_EOS)
                    elif len(req.out_tokens) >= req.max_new:
                        sched.finish(slot, step, FINISH_MAX_NEW)
                self.busy_steps += 1
                self.slot_steps += int(act.sum())
                self.step_count += 1
        wall = time.time() - t0
        if not sched.idle:
            raise RuntimeError(
                f"gateway hit max_steps={self.gcfg.max_steps} with "
                f"{len(sched.pending)} queued / {sched.n_active} running "
                f"requests unfinished")
        return self._report(sched, wall)

    # -- reporting -----------------------------------------------------------

    def _report(self, sched: Scheduler, wall: float) -> dict:
        reqs = sorted(sched.finished, key=lambda r: r.rid)
        lats = np.asarray([r.latency() for r in reqs], np.float64)
        waits = np.asarray([r.admitted_step - r.arrival for r in reqs],
                           np.float64)
        ttfts = np.asarray([r.ttft() for r in reqs], np.float64)
        rep = dict(
            requests=[dict(rid=r.rid, prompt_len=r.prompt_len,
                           max_new=r.max_new, arrival=r.arrival,
                           admitted=r.admitted_step,
                           first_token=r.first_token_step,
                           finished=r.finished_step,
                           finish_reason=r.finish_reason,
                           n_out=len(r.out_tokens),
                           tokens=list(map(int, r.out_tokens)))
                      for r in reqs],
            steps=self.step_count, busy_steps=self.busy_steps,
            occupancy=(self.slot_steps / self.busy_steps
                       if self.busy_steps else 0.0),
            tokens_out=self.tokens_out, wall_s=wall,
            tokens_per_s=self.tokens_out / wall if wall > 0 else 0.0,
            latency_steps=dict(
                p50=float(np.percentile(lats, 50)) if len(lats) else 0.0,
                p99=float(np.percentile(lats, 99)) if len(lats) else 0.0,
                mean=float(lats.mean()) if len(lats) else 0.0),
            ttft_steps=dict(
                p50=float(np.percentile(ttfts, 50)) if len(ttfts) else 0.0,
                p99=float(np.percentile(ttfts, 99)) if len(ttfts) else 0.0,
                mean=float(ttfts.mean()) if len(ttfts) else 0.0),
            admission_wait_steps=dict(
                p50=float(np.percentile(waits, 50)) if len(waits) else 0.0,
                p99=float(np.percentile(waits, 99)) if len(waits) else 0.0),
            schedule_trace=list(sched.trace),
        )
        if self.hw is not None:
            rep["fleet"] = self.hw.report()
        return rep

    def close(self) -> None:
        if self.hw is not None:
            self.hw.close()
