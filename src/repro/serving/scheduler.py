"""Request queue + continuous-batching admission scheduler.

The scheduler owns *which request runs in which slot when*; the engine
owns the math.  Policy (see the package docstring's DESIGN note):

* **Strict FIFO admission** — requests are admitted in submission
  order, never bypassed.  A large request at the queue head blocks
  later small ones until capacity frees up; in exchange no request can
  starve (the property suite locks this).
* **Reserve-at-admission** — admission requires a free slot AND the
  request's whole-lifetime page reservation
  (``ceil((prompt+max_new)/page_size)``), so an admitted request never
  preempts or OOMs mid-flight.
* **Evict-on-completion** — a request leaves its slot the step it
  finishes (EOS emitted, or ``max_new`` reached); pages return to the
  free list the same step and the next queued request can take the
  slot on the *next* admission scan.

Everything is deterministic given the submission order: the event
``trace`` reproduces bit-for-bit under a fixed seed (property-tested).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

from .kv_pages import PagedKVPool

__all__ = ["Request", "Scheduler", "poisson_workload"]

FINISH_EOS = "eos"
FINISH_MAX_NEW = "max_new"


@dataclasses.dataclass
class Request:
    """One user request: a prompt to prefill, then greedy decode."""

    rid: int
    prompt: np.ndarray            # (L,) int32 token ids
    max_new: int                  # decode budget
    arrival: int = 0              # virtual step the request enters the queue
    eos_id: Optional[int] = None  # stop token (emitted token ends decode)

    # lifecycle (filled by the scheduler/engine)
    out_tokens: list = dataclasses.field(default_factory=list)
    submitted_step: int = -1
    admitted_step: int = -1
    first_token_step: int = -1
    finished_step: int = -1
    finish_reason: str = ""

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_tokens(self) -> int:
        """Cache positions a full-budget run writes."""
        return self.prompt_len + self.max_new

    @property
    def done(self) -> bool:
        return self.finished_step >= 0

    def latency(self) -> int:
        """Sojourn time in virtual steps (arrival → finish)."""
        return self.finished_step - self.arrival

    def ttft(self) -> int:
        """Time to first token in virtual steps (arrival → first
        emission): queueing wait + the whole prefill."""
        return self.first_token_step - self.arrival


def poisson_workload(seed: int, n_requests: int, rate: float, vocab: int,
                     prompt_len: tuple[int, int] = (4, 12),
                     max_new: tuple[int, int] = (4, 12),
                     eos_id: Optional[int] = None) -> list[Request]:
    """Synthetic open-loop arrival process: ``n_requests`` requests with
    exponential(1/rate) inter-arrival gaps (quantized to steps), seeded
    prompt tokens and uniform prompt/decode lengths.  Deterministic for
    a fixed seed — the benchmark's load axis."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n_requests):
        if rid > 0:
            t += rng.exponential(1.0 / max(rate, 1e-9))
        ln = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        mn = int(rng.integers(max_new[0], max_new[1] + 1))
        prompt = rng.integers(0, vocab, size=(ln,)).astype(np.int32)
        out.append(Request(rid=rid, prompt=prompt, max_new=mn,
                           arrival=int(t), eos_id=eos_id))
    return out


class Scheduler:
    """Slot assignment over a :class:`PagedKVPool`."""

    def __init__(self, pool: PagedKVPool):
        self.pool = pool
        self.n_slots = pool.n_slots
        self.pending: collections.deque[Request] = collections.deque()
        self.running: list[Optional[Request]] = [None] * self.n_slots
        self.finished: list[Request] = []
        self.trace: list[tuple] = []   # (step, event, rid, slot)

    # -- queue side ----------------------------------------------------------

    def submit(self, req: Request, step: int) -> None:
        req.submitted_step = step
        self.pending.append(req)
        self.trace.append((step, "submit", req.rid, -1))

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.running)

    @property
    def idle(self) -> bool:
        return not self.pending and self.n_active == 0

    # -- admission / eviction ------------------------------------------------

    def admit(self, step: int) -> list[tuple[int, Request]]:
        """Admit queued requests into free slots, strict FIFO: stop at
        the first request that doesn't fit (slot or pages) — later
        requests never jump the queue."""
        admitted = []
        while self.pending:
            req = self.pending[0]
            if req.total_tokens > self.pool.cfg.max_tokens_per_slot:
                raise ValueError(
                    f"request {req.rid} needs {req.total_tokens} cache "
                    f"positions > slot capacity "
                    f"{self.pool.cfg.max_tokens_per_slot}")
            slot = next((i for i, r in enumerate(self.running)
                         if r is None), None)
            if slot is None or not self.pool.can_reserve(req.total_tokens):
                break
            self.pending.popleft()
            self.pool.reserve(slot, req.total_tokens)
            self.running[slot] = req
            req.admitted_step = step
            admitted.append((slot, req))
            self.trace.append((step, "admit", req.rid, slot))
        return admitted

    def finish(self, slot: int, step: int, reason: str) -> Request:
        req = self.running[slot]
        assert req is not None
        req.finished_step = step
        req.finish_reason = reason
        self.pool.free(slot)
        self.running[slot] = None
        self.finished.append(req)
        self.trace.append((step, "finish", req.rid, slot))
        return req
