"""Serving-gateway CLI: continuous batching over an open-loop workload.

Runnable on this CPU container::

    PYTHONPATH=src python -m repro.serving.gateway --arch smoke:qwen3-4b \
        --slots 4 --requests 12 --rate 0.5

Add ``--fleet N --hw-logits`` to serve every request's PTC matmuls
through routed photonic chips — one *coalesced* driver frame per layer
group per step carries ALL in-flight requests' activations (vs one
frame per request in sequential ``launch.serve``).  ``launch.serve
--gateway`` forwards here, so both entry points share this driver.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from ..models.lm import ArchConfig, init_model
from .engine import GatewayConfig, ServingGateway, build_gateway_hw_plane
from .kv_pages import PageConfig
from .scheduler import poisson_workload

__all__ = ["run", "main", "add_gateway_args"]


def add_gateway_args(ap: argparse.ArgumentParser) -> None:
    """Gateway knobs, shared by this CLI and ``launch.serve --gateway``."""
    ap.add_argument("--slots", "--gw-slots", dest="slots", type=int,
                    default=4, help="concurrent decode streams")
    ap.add_argument("--requests", "--gw-requests", dest="requests",
                    type=int, default=8, help="workload size")
    ap.add_argument("--rate", "--gw-rate", dest="rate", type=float,
                    default=0.5, help="Poisson arrival rate (req/step)")
    ap.add_argument("--page-size", "--gw-page-size", dest="page_size",
                    type=int, default=8, help="tokens per KV page")
    ap.add_argument("--pages", "--gw-pages", dest="pages", type=int,
                    default=64, help="physical pages in the shared pool")
    ap.add_argument("--max-pages-per-slot", "--gw-max-pages-per-slot",
                    dest="max_pages_per_slot", type=int, default=8,
                    help="page-table length per slot")
    ap.add_argument("--max-new", "--gw-max-new", dest="max_new", type=int,
                    nargs=2, default=(4, 12), metavar=("LO", "HI"),
                    help="uniform decode-budget range per request")
    # --gw- prefix only: launch.serve owns a scalar --prompt-len already
    ap.add_argument("--gw-prompt-len", dest="prompt_len_range", type=int,
                    nargs=2, default=(4, 12), metavar=("LO", "HI"),
                    help="uniform prompt-length range per request")
    ap.add_argument("--eos-id", "--gw-eos-id", dest="eos_id", type=int,
                    default=None, help="stop token (early termination)")
    ap.add_argument("--prefill-chunk", "--gw-prefill-chunk",
                    dest="prefill_chunk", type=int, default=1,
                    help="prompt tokens ingested per prefilling slot per "
                         "step (1 = the one-token legacy path; >1 needs "
                         "an attention-only arch)")


def run(args) -> dict:
    """Build the gateway for ``args`` and drive the workload to
    completion; returns the engine report (plus the resolved config).

    Test/benchmark hooks mirror ``launch.serve.run``:
    ``args.params_override`` serves given params instead of seeded
    random init; ``args.requests_override`` replaces the Poisson
    workload with an explicit request list; ``args.runtime_cfg``
    overrides the fleet policy."""
    from ..launch.serve import _hw_runtime_config
    from ..launch.train import parse_arch

    cfg = (args.arch if isinstance(args.arch, ArchConfig)
           else parse_arch(args.arch))
    hw_mode = None
    if getattr(args, "hw_logits", False):
        hw_mode = "route"
    if getattr(args, "hw_shadow", False):
        if hw_mode is not None:
            raise ValueError("--hw-logits and --hw-shadow are exclusive")
        hw_mode = "shadow"
    if hw_mode is not None:
        if getattr(args, "fleet", 0) <= 0:
            raise ValueError("--hw-logits/--hw-shadow need --fleet N chips")
        # concrete activations for the PTC hook: python loop over periods
        cfg = dataclasses.replace(cfg, unroll=True, remat=False)

    params = getattr(args, "params_override", None)
    if params is None:
        params = init_model(jax.random.PRNGKey(args.seed), cfg)

    reqs = getattr(args, "requests_override", None)
    if reqs is None:
        pl = getattr(args, "prompt_len_range", (4, 12))
        reqs = poisson_workload(args.seed, args.requests, args.rate,
                                cfg.vocab, prompt_len=tuple(pl),
                                max_new=tuple(args.max_new),
                                eos_id=args.eos_id)

    gcfg = GatewayConfig(
        slots=args.slots,
        pages=PageConfig(page_size=args.page_size, n_pages=args.pages,
                         max_pages_per_slot=args.max_pages_per_slot),
        prefill_chunk=getattr(args, "prefill_chunk", 1) or 1,
        prefill_stride=getattr(args, "prefill_stride", None),
        kv_block=getattr(args, "kv_block", None))
    plane = None
    if hw_mode is not None:
        kf = jax.random.split(jax.random.PRNGKey(args.seed + 17))[1]
        plane = build_gateway_hw_plane(
            kf, cfg, params, _hw_runtime_config(args), args.fleet,
            slots=args.slots, mode=hw_mode, seed=args.seed,
            recal_enabled=not getattr(args, "no_recal", False))
    gw = ServingGateway(cfg, params, gcfg, hw_plane=plane)
    try:
        rep = gw.run(reqs)
    finally:
        gw.close()
    rep["config"] = dict(arch=cfg.name, slots=args.slots,
                         page_size=args.page_size, pages=args.pages,
                         prefill_chunk=gcfg.prefill_chunk,
                         hw_mode=hw_mode or "digital",
                         n_requests=len(reqs))
    return rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--seed", type=int, default=0)
    add_gateway_args(ap)
    ap.add_argument("--fleet", type=int, default=0,
                    help="photonic chips backing --hw-logits/--hw-shadow")
    ap.add_argument("--drift", action="store_true")
    ap.add_argument("--drift-sigma", type=float, default=0.015)
    ap.add_argument("--probe-every", type=int, default=10)
    ap.add_argument("--fleet-k", type=int, default=6)
    ap.add_argument("--fleet-driver", default="twin",
                    choices=["twin", "subprocess", "socket"])
    ap.add_argument("--hw-logits", action="store_true",
                    help="serve every request's PTC matmuls through the "
                         "routed chips (coalesced frames)")
    ap.add_argument("--hw-shadow", action="store_true")
    ap.add_argument("--deploy-zo", action="store_true")
    ap.add_argument("--no-recal", action="store_true")
    from ..launch.serve import add_autopilot_args
    add_autopilot_args(ap)
    args = ap.parse_args(argv)

    rep = run(args)
    c = rep["config"]
    lat, wait = rep["latency_steps"], rep["admission_wait_steps"]
    ttft = rep["ttft_steps"]
    print(f"gateway [{c['hw_mode']}] {c['arch']}: {c['n_requests']} "
          f"requests over {rep['steps']} steps "
          f"({rep['busy_steps']} busy, occupancy "
          f"{rep['occupancy']:.2f}/{c['slots']}, "
          f"prefill chunk {c['prefill_chunk']})")
    print(f"  {rep['tokens_out']} tokens in {rep['wall_s']:.1f}s "
          f"({rep['tokens_per_s']:.1f} tok/s) | latency steps "
          f"p50={lat['p50']:.0f} p99={lat['p99']:.0f} | ttft steps "
          f"p50={ttft['p50']:.0f} p99={ttft['p99']:.0f} | admission wait "
          f"p50={wait['p50']:.0f} p99={wait['p99']:.0f}")
    fleet = rep.get("fleet")
    if fleet is not None:
        hw = fleet.get("hw") or {}
        alarms = sum(ch["alarms"] for ch in fleet["chips"])
        recals = sum(ch["recals"] for ch in fleet["chips"])
        print(f"  fleet: {len(fleet['chips'])} chips, "
              f"{hw.get('frames', 0)} coalesced frames "
              f"({hw.get('frames_per_step', 0.0):.1f}/step), "
              f"{hw.get('hw_calls', 0)} hw matmuls, "
              f"{alarms} alarms, {recals} recals")
        ap_rep = fleet.get("autopilot")
        if ap_rep is not None:
            print(f"  autopilot: {ap_rep['proactive_recals']} proactive "
                  f"recals, deferred {ap_rep['deferred_trough']} (load) + "
                  f"{ap_rep['deferred_budget']} (budget), load forecast "
                  f"{ap_rep['load_forecast']:.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
