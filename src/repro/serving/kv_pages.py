"""Paged KV-cache bookkeeping: page pool, free list, per-slot tables.

This module is pure allocator state — no arrays, no model.  The tensor
pools (one (n_periods, n_pages, page_size, H·D) pool per attention
sub-layer position) live in ``engine.ServingGateway``; every layer of
every period shares ONE page table per slot, because a request's token
``t`` occupies the same page/offset in every layer's pool (the
head-interleaved fusion idiom: one allocation decision covers the whole
stack).  Keeping the allocator separate lets the scheduler property
tests (``tests/test_serving_gateway.py``) sweep thousands of
admit/evict schedules without touching jax.

Invariants (property-tested):

* a page is owned by at most one slot at a time (never double-allocated);
* ``len(free) + Σ owned == n_pages`` always (never leaked, never
  conjured);
* a slot's reservation is returned *in full* on ``free()`` — eviction
  cannot strand pages;
* allocation order is deterministic: the free list is LIFO, so a fixed
  admit/evict schedule reproduces the same physical page assignment
  bit-for-bit (the gateway's determinism gate rests on this).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["PageConfig", "PagedKVPool"]


@dataclasses.dataclass(frozen=True)
class PageConfig:
    """Static paged-KV geometry for one gateway."""

    page_size: int = 8           # tokens per page
    n_pages: int = 64            # physical pages in the shared pool
    max_pages_per_slot: int = 8  # page-table length (S_max = this · page_size)

    @property
    def max_tokens_per_slot(self) -> int:
        return self.page_size * self.max_pages_per_slot

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache positions."""
        return -(-max(0, n_tokens) // self.page_size)


class PagedKVPool:
    """Free-list page allocator with per-slot page tables.

    ``table`` keeps unallocated entries at 0 — a *valid* physical page
    id — so the gather kernel can assemble every slot unconditionally;
    positions beyond a slot's length are masked by attention, never
    read as data.
    """

    def __init__(self, cfg: PageConfig, n_slots: int):
        self.cfg = cfg
        self.n_slots = n_slots
        # LIFO free list, low ids on top: deterministic reuse order
        self._free = list(range(cfg.n_pages - 1, -1, -1))
        self._owned: list[list[int]] = [[] for _ in range(n_slots)]
        self.table = np.zeros((n_slots, cfg.max_pages_per_slot), np.int32)
        self.lens = np.zeros((n_slots,), np.int32)

    # -- capacity ------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return sum(len(o) for o in self._owned)

    def can_reserve(self, n_tokens: int) -> bool:
        need = self.cfg.pages_for(n_tokens)
        return (need <= len(self._free)
                and need <= self.cfg.max_pages_per_slot)

    # -- slot lifecycle ------------------------------------------------------

    def reserve(self, slot: int, n_tokens: int) -> None:
        """Allocate a slot's whole-lifetime page reservation up front."""
        if self._owned[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        need = self.cfg.pages_for(n_tokens)
        if need > self.cfg.max_pages_per_slot:
            raise ValueError(
                f"request needs {need} pages > table length "
                f"{self.cfg.max_pages_per_slot}")
        if need > len(self._free):
            raise RuntimeError(
                f"pool exhausted: need {need}, free {len(self._free)}")
        pages = [self._free.pop() for _ in range(need)]
        self._owned[slot] = pages
        self.table[slot, :] = 0
        self.table[slot, :need] = pages
        self.lens[slot] = 0

    def free(self, slot: int) -> None:
        """Return the slot's reservation to the free list (reverse
        order, so a LIFO realloc of the same size reuses the same
        pages — deterministic)."""
        for pid in reversed(self._owned[slot]):
            self._free.append(pid)
        self._owned[slot] = []
        self.table[slot, :] = 0
        self.lens[slot] = 0

    # -- per-step write positions --------------------------------------------

    def write_pos(self, slot: int) -> tuple[int, int]:
        """(page_id, offset) where the slot's next token row lands."""
        ln = int(self.lens[slot])
        j, off = divmod(ln, self.cfg.page_size)
        if j >= len(self._owned[slot]):
            raise RuntimeError(
                f"slot {slot} writing past its reservation "
                f"(len {ln}, {len(self._owned[slot])} pages)")
        return int(self.table[slot, j]), off

    def write_span(self, slot: int, n: int) -> np.ndarray:
        """(n, 2) int32 ``(page_id, offset)`` rows for the slot's next
        ``n`` consecutive cache positions — the chunked-prefill write
        path.  A chunk that crosses one or more page boundaries is
        split here, host-side, against the slot's page table; the
        flattened row list feeds ONE aliased multi-row scatter
        (``kernels.paged_scatter_rows``)."""
        ln = int(self.lens[slot])
        pos = ln + np.arange(n)
        j = pos // self.cfg.page_size
        if n and j[-1] >= len(self._owned[slot]):
            raise RuntimeError(
                f"slot {slot} writing past its reservation "
                f"(len {ln} + {n}, {len(self._owned[slot])} pages)")
        return np.stack([self.table[slot, j],
                         pos % self.cfg.page_size], axis=1).astype(np.int32)

    def advance(self, slot: int, n: int = 1) -> None:
        self.lens[slot] += n

    # -- audits (property tests) ---------------------------------------------

    def check_invariants(self) -> None:
        seen: set[int] = set()
        for slot, owned in enumerate(self._owned):
            for pid in owned:
                if pid in seen:
                    raise AssertionError(f"page {pid} double-allocated")
                seen.add(pid)
        if seen & set(self._free):
            raise AssertionError("page simultaneously owned and free")
        total = len(self._free) + len(seen)
        if total != self.cfg.n_pages:
            raise AssertionError(
                f"page leak: {total} accounted != {self.cfg.n_pages}")
