"""Closed-loop photonic device runtime (DESIGN).

The IC → PM → SL pipeline in ``repro.core`` prepares a chip *once*; in
production the chip then lives under time — thermal and aging phase
drift walk Γ/Φ_b away from the state calibration compensated for, which
is precisely why in-situ learnability matters (L2ight §3.2; the
power-aware sparse-ZOO predecessor arXiv:2012.11148 motivates cheap
on-chip re-optimization).  This package closes the loop:

    drift.py        the plant:    seeded OU phase drift on DeviceRealization
    monitor.py      the sensor:   stochastic fidelity probes + hysteretic alarm
    recalibrate.py  the actuator: warm-started ZO + OSP refresh (+ in-situ Σ)
    fleet.py        the plane:    N-chip registry + health-aware router
    demo.py         the driver:   ``python -m repro.runtime.demo``

Closed-loop state machine (one per chip; the router enforces it)::

            ┌────────────────────────────────────────────────┐
            ▼                                                │
        HEALTHY ──probe d̂ > alarm_threshold (×consecutive)──▶ DEGRADED
            ▲                                                │ repair slot
            │ post-recal probe d̂ < clear_threshold           ▼
            └───────────────────────────────────── RECALIBRATING
                      (job: warm ZO → OSP → optional SL; chip unroutable;
                       a probe still above clear re-queues as DEGRADED)

Design invariants:

* **Serving never blocks on maintenance.**  Recalibration is out-of-band:
  at most ``max_concurrent_recals`` chips are in repair at once and the
  router structurally never dispatches to a RECALIBRATING chip.
  DEGRADED chips keep serving (stale beats down).
* **Alarms are hysteretic.**  ``consecutive`` strikes above
  ``alarm_threshold`` raise; recovery must pass the *lower*
  ``clear_threshold`` — no chatter around one boundary.
* **Everything is seeded.**  Drift, probes, and recal searches all
  derive from one PRNG chain, so whole fleet trajectories are exactly
  reproducible (the runtime tests assert bit-equal replays).
* **Costs are accounted.**  Probe and recal budgets are tallied in PTC
  calls with the paper's Appendix-G energy model (``core.profiler``),
  so the closed loop's overhead is measurable, not vibes
  (``benchmarks/drift_recovery.py``).
"""

from .drift import (DriftConfig, DriftState, init_drift, advance,
                    bias_deviation, DEFAULT_DRIFT)  # noqa: F401
from .monitor import (MonitorConfig, HealthState, realized_blocks,
                      aggregate_distance, probe_mapping_distance,
                      probe_identity_distance, true_mapping_distance,
                      update_health, clear_health, probe_ptc_calls)  # noqa: F401
from .recalibrate import RecalConfig, RecalResult, recalibrate  # noqa: F401
from .fleet import (HEALTHY, DEGRADED, RECALIBRATING, RuntimeConfig, Chip,
                    FleetRouter, make_chip, make_fleet)  # noqa: F401
