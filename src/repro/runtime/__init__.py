"""Closed-loop photonic device runtime (DESIGN).

The IC → PM → SL pipeline in ``repro.core`` prepares a chip *once*; in
production the chip then lives under time — thermal and aging phase
drift walk Γ/Φ_b away from the state calibration compensated for, which
is precisely why in-situ learnability matters (L2ight §3.2; the
power-aware sparse-ZOO predecessor arXiv:2012.11148 motivates cheap
on-chip re-optimization).  This package closes the loop, talking to
devices exclusively through the :class:`repro.hw.driver.PhotonicDriver`
control-plane ABC:

    monitor.py      the sensor:   stochastic fidelity probes + hysteretic
                                  alarm, resolved per tenant from one
                                  shared probe stream
    recalibrate.py  the actuator: warm ZO job + OSP refresh (+ in-situ Σ),
                                  budget autotuned from d̂ at alarm time,
                                  scoped to one tenant's block range for
                                  partial recalibration
    fleet.py        the plane:    N-chip registry + tenant slots
                                  (tenant → Σ bank + block range) +
                                  drift-aware (chip, tenant) router
    demo.py         the driver:   ``python -m repro.runtime.demo``

(the plant — OU phase drift on the device realization — lives on the
device side, ``repro.hw.drift``; the runtime only sees it through
``driver.advance`` and probe estimates, exactly as with real hardware)

Closed-loop state machine (one per chip; the router enforces it)::

            ┌────────────────────────────────────────────────┐
            ▼                                                │
        HEALTHY ──probe d̂ > alarm_threshold (×consecutive)──▶ DEGRADED
            ▲                                                │ repair slot
            │ post-recal probe d̂ < clear_threshold           ▼
            └───────────────────────────────────── RECALIBRATING
                      (job: warm ZO → OSP → optional SL; chip unroutable;
                       a probe still above clear re-queues as DEGRADED)

Design invariants:

* **Serving never blocks on maintenance.**  Recalibration is out-of-band:
  at most ``max_concurrent_recals`` chips are in repair at once and the
  router structurally never dispatches to a RECALIBRATING chip.
  DEGRADED chips keep serving (stale beats down).
* **Repairs are tenant-scoped.**  On a multi-tenant chip only the
  alarmed tenant's blocks are re-tuned (warm ZO + OSP over its block
  range); co-resident tenants' commanded phases and Σ banks are
  bit-identical across the job — one noisy layer never costs its
  neighbors their calibration.
* **Alarms are hysteretic.**  ``consecutive`` strikes above
  ``alarm_threshold`` raise; recovery must pass the *lower*
  ``clear_threshold`` — no chatter around one boundary.
* **Everything is seeded.**  Probes and recal searches derive from one
  PRNG chain; each driver owns its drift chain (seeded at construction),
  so whole fleet trajectories are exactly reproducible — and identical
  across the in-process and subprocess transports.
* **Costs are metered at the boundary.**  Every op that touches light is
  tallied in PTC calls by the driver itself (Appendix-G energy model),
  so the closed loop's overhead is measurable, not vibes
  (``benchmarks/drift_recovery.py``, ``benchmarks/driver_overhead.py``).
* **No twin peeking.**  Exact distances / device realizations exist only
  behind ``driver.unsafe_twin()`` (tests and benchmarks); the guard test
  in ``tests/test_driver.py`` keeps runtime code on the legal surface.
"""

from .monitor import (MonitorConfig, HealthState, aggregate_distance,
                      probe_mapping_distance, probe_tenant_distances,
                      readout_mapping_distance,
                      probe_identity_distance, update_health,
                      clear_health)  # noqa: F401
from .recalibrate import (RecalConfig, RecalResult, recalibrate,
                          autotune_zo_steps)  # noqa: F401
from .fleet import (HEALTHY, DEGRADED, RECALIBRATING, RuntimeConfig, Tenant,
                    Chip, FleetRouter, make_chip, make_fleet,
                    predicted_distance)  # noqa: F401
