"""Predictive fleet autopilot: forecast-driven maintenance scheduling.

The reactive loop (``runtime/fleet.py``) waits for hysteretic alarms:
a tenant must be *measured* past the alarm threshold ``consecutive``
times before a repair window is spent on it — by which point served
accuracy has already degraded.  But the router is sitting on two
forecasts it only uses for dispatch ranking:

* the OU relaxation law behind :func:`~repro.runtime.fleet.
  predicted_distance` — distance relaxes toward a stationary level with
  rate ``2θ``; and
* the per-tenant EWMA **degradation rate** the monitor now tracks
  (:class:`~repro.runtime.monitor.HealthState` ``.rate``), which
  calibrates where that stationary level actually sits for *this*
  tenant on *this* chip (the constant-factor-free heuristic's scale is
  tenant-dependent; the measured rate pins it empirically:
  ``d_∞ ≈ d̂ + rate/2θ``, since ``d' = −2θ(d − d_∞)``).

:func:`predicted_crossing` inverts that law: the number of ticks until
a tenant's distance is forecast to cross the alarm threshold.  For
fast-degrading tenants it reduces to the linear extrapolation
``(threshold − d̂)/rate``; for tenants whose empirical stationary level
sits below the threshold it returns ``inf`` — drift that saturates
inside tolerance never earns a repair window, the FLOPS-style
power-aware budgeting shape (Gu et al.): maintenance work sized to the
actual drift state, not to a worst-case schedule.

:class:`AutopilotRouter` plugs into the ``FleetRouter._schedule_repairs``
seam and replaces the reactive chip-order walk with:

1. **a degradation-rate priority queue across chips AND co-resident
   tenants** — alarmed (reactive) jobs first, then proactive
   candidates, each class ordered by measured degradation rate
   (fastest-degrading first), tie-broken by forecast crossing time;
2. **proactive partial recalibration** — a tenant whose crossing is
   forecast within ``horizon`` ticks is repaired *before* the alarm it
   would have tripped, preferring traffic troughs read from the
   :class:`LoadForecast` (fed by the serving gateway's occupancy via
   ``observe_load``); a crossing forecast inside the loop's own
   reaction time (``recal_latency + probe_every``) overrides the trough
   gate — waiting for the trough would lose the race to the alarm;
3. **a PTC-call budget envelope** — proactive work stops when the
   rolling window's *proactive* recal spend hits ``budget_calls``.
   Reactive repairs are never budget-gated (an alarm is already an SLO
   breach) and do not draw the envelope down either: the budget bounds
   the extra maintenance power prediction is allowed to add on top of
   what alarms already force, so an alarm burst cannot starve the
   proactive machinery exactly when forecasting is most valuable.

Everything else — probe cadence, PRNG streams, partial-recal
machinery, repair-slot bandwidth — is inherited bit-identically from
the base router.  ``benchmarks/fleet_autopilot.py`` drives a seeded
diurnal workload (bursty load, correlated drift events, injected chip
outages) through both schedulers and gates autopilot-on ≥ alarm-driven
on accuracy, strictly fewer reactive alarms, and budget compliance.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from ..hw import DriftConfig
from .fleet import Chip, FleetRouter, RECALIBRATING, Tenant, \
    predicted_distance

__all__ = ["AutopilotConfig", "LoadForecast", "AutopilotRouter",
           "predicted_crossing", "logit_sensitivity"]


@dataclasses.dataclass(frozen=True)
class AutopilotConfig:
    """Policy knobs for the forecast-driven scheduler."""

    horizon: int = 40            # proactive window: schedule a repair if
    #                              the alarm crossing is forecast within
    #                              this many ticks
    trough_load: float = 0.5     # load forecast at/below this fraction of
    #                              capacity counts as a trough (proactive
    #                              jobs prefer to run there)
    budget_calls: float = math.inf  # proactive recal PTC-call envelope
    #                              per window; proactive work defers once
    #                              the rolling window's proactive spend
    #                              exceeds it (reactive spend is exempt
    #                              and does not draw it down)
    budget_window: int = 200     # ticks per budget window
    forecast_period: int = 0     # diurnal period hint for the load
    #                              forecast (0 = pure EWMA, no phase bins)
    forecast_alpha: float = 0.2  # EWMA weight for observed load
    cooldown: int = 0            # min ticks between proactive repairs of
    #                              the same tenant (0 = probe cadence
    #                              already paces them)


def predicted_crossing(distance: float, rate: float, threshold: float,
                       drift: DriftConfig) -> float:
    """Ticks until a tenant's distance is forecast to cross
    ``threshold``, by inverting the OU relaxation law with the
    *empirically calibrated* stationary level.

    The law ``d(Δ) = d_∞ + (d̂ − d_∞)·e^{−2θΔ}`` gives
    ``d' = −2θ(d − d_∞)``, so the measured EWMA rate pins
    ``d_∞ = d̂ + rate/2θ``.  Solving ``d(Δ*) = threshold``::

        Δ* = −ln((threshold − d_∞)/(d̂ − d_∞)) / 2θ

    valid when ``d̂ < threshold < d_∞``.  Limits: for ``rate → ∞`` this
    reduces to the linear extrapolation ``(threshold − d̂)/rate``; for
    ``d_∞ ≤ threshold`` (drift saturates inside tolerance) it returns
    ``inf`` — no forecast crossing, no proactive work.  Already-crossed
    estimates return 0.
    """
    d, r, thr = float(distance), float(rate), float(threshold)
    if d >= thr:
        return 0.0
    if r <= 1e-12:
        return math.inf
    two_theta = max(2.0 * drift.theta, 1e-12)
    d_inf = d + r / two_theta
    if d_inf <= thr:
        return math.inf
    return -math.log((thr - d_inf) / (d - d_inf)) / two_theta


def logit_sensitivity(weights: Sequence[np.ndarray]) -> list[float]:
    """Per-tenant logit-sensitivity weights from the served layers'
    effective dense weights, normalized to mean 1.

    For a PTC linear ``y = Wx`` at relative mapping distance ``d``
    (``‖ΔW‖²/‖W‖² = d``), the injected output-energy error is
    ``≈ d·‖W‖²·E‖x‖²/n`` — so within one served model, a layer's
    leverage on downstream logits scales with its Frobenius energy per
    input column.  This is the *prior*; ``benchmarks/fleet_autopilot.py``
    additionally validates the ranking against measured end-to-end
    serve error (the PR-5 e2e harness methodology) before the
    ``accuracy_aware`` policy leans on it.
    """
    energies = [float(np.sum(np.asarray(w, np.float64) ** 2))
                / max(1, np.asarray(w).shape[-1]) for w in weights]
    mean = sum(energies) / len(energies)
    if mean <= 0:
        return [1.0] * len(energies)
    return [e / mean for e in energies]


class LoadForecast:
    """Traffic forecast: periodic (diurnal) profile bins + global EWMA.

    ``observe(load, tick)`` folds one occupancy sample in; ``forecast
    (tick)`` returns the expected load at ``tick``.  With a
    ``period`` hint, each phase bin keeps its own EWMA (the diurnal
    profile), blended toward the global EWMA while a bin is still cold;
    without one, the global EWMA alone is the forecast.  Until any
    sample arrives the forecast is pessimistic (1.0 = full capacity) so
    a cold autopilot never mistakes ignorance for a trough.
    """

    def __init__(self, period: int = 0, alpha: float = 0.2):
        self.period = max(0, int(period))
        self.alpha = float(alpha)
        self.ewma: Optional[float] = None
        self._bins: list[Optional[float]] = [None] * self.period
        self.samples = 0

    def observe(self, load: float, tick: int) -> None:
        load = float(load)
        self.samples += 1
        self.ewma = (load if self.ewma is None
                     else (1.0 - self.alpha) * self.ewma
                     + self.alpha * load)
        if self.period:
            i = tick % self.period
            prev = self._bins[i]
            self._bins[i] = (load if prev is None
                             else (1.0 - self.alpha) * prev
                             + self.alpha * load)

    def forecast(self, tick: int) -> float:
        if self.ewma is None:
            return 1.0
        if self.period:
            b = self._bins[tick % self.period]
            if b is not None:
                return b
        return self.ewma


class AutopilotRouter(FleetRouter):
    """Forecast-driven scheduler on the reactive router's chassis."""

    def __init__(self, chips: list[Chip], cfg, seed: int = 0,
                 recal_enabled: bool = True):
        super().__init__(chips, cfg, seed=seed, recal_enabled=recal_enabled)
        ap = cfg.autopilot if cfg.autopilot is not None else AutopilotConfig()
        self.ap: AutopilotConfig = ap
        self.forecast = LoadForecast(period=ap.forecast_period,
                                     alpha=ap.forecast_alpha)
        self.proactive_recals = 0
        self.deferred_budget = 0     # proactive jobs deferred: envelope
        self.deferred_trough = 0     # proactive jobs deferred: waiting for
        #                              a trough (crossing not yet urgent)
        self.proactive_calls = 0.0   # cumulative proactive recal PTC spend
        self.proactive_windows: list[float] = []  # closed windows' spend
        self._window_start = 0
        self._window_spent = 0.0     # proactive spend, current window
        self._last_proactive: dict[tuple[int, int], int] = {}

    # -- signals -------------------------------------------------------------

    def observe_load(self, load: float) -> None:
        self.forecast.observe(load, self.tick_count)

    def crossing(self, chip: Chip, tenant: Tenant) -> float:
        """Forecast ticks-from-now until this tenant crosses the alarm
        threshold (0 = already past, inf = saturates inside tolerance)."""
        pd = predicted_distance(chip, self.tick_count, self.cfg.drift,
                                tenant)
        return predicted_crossing(pd, tenant.health.rate,
                                  self.cfg.monitor.alarm_threshold,
                                  self.cfg.drift)

    # -- budget window -------------------------------------------------------

    def _roll_budget(self) -> None:
        if self.tick_count - self._window_start >= self.ap.budget_window:
            self.proactive_windows.append(self._window_spent)
            self._window_start = self.tick_count
            self._window_spent = 0.0

    def _finish_recal(self, chip: Chip) -> None:
        proactive = chip.recal_proactive
        before = chip.recal_calls
        super()._finish_recal(chip)
        if proactive:
            spent = chip.recal_calls - before
            self._window_spent += spent
            self.proactive_calls += spent

    # -- the scheduler -------------------------------------------------------

    def _repair_queue(self, pending) -> list[tuple[tuple, Chip, Tenant]]:
        """Build the priority queue over every (chip, tenant) candidate.

        Key (ascending = first served): reactive class before proactive,
        then fastest measured degradation rate, then earliest forecast
        crossing, then (chip, tenant) id for determinism.  Alarmed
        tenants are reactive candidates; unalarmed tenants whose
        crossing is forecast within ``horizon`` are proactive ones.
        """
        queue = []
        for chip, _, _, _ in pending:
            if chip.status == RECALIBRATING or chip.offline:
                continue
            for t in chip.tenants:
                if t.health.alarmed:
                    key = (0, -t.health.rate, 0.0, chip.chip_id,
                           t.tenant_id)
                    queue.append((key, chip, t))
                    continue
                cross = self.crossing(chip, t)
                if cross <= self.ap.horizon:
                    cool = self._last_proactive.get(
                        (chip.chip_id, t.tenant_id))
                    if (cool is not None
                            and self.tick_count - cool < self.ap.cooldown):
                        continue
                    key = (1, -t.health.rate, cross, chip.chip_id,
                           t.tenant_id)
                    queue.append((key, chip, t))
        return sorted(queue, key=lambda e: e[0])

    def _schedule_repairs(self, pending) -> None:
        """Degradation-rate priority queue + trough-gated proactive jobs.

        Repair-slot bandwidth, the one-job-per-chip invariant, and the
        recal machinery are the base router's; only the *choice* of
        which (chip, tenant) gets the next window changes.  A proactive
        job runs when (a) the load forecast says trough, OR (b) its
        crossing is inside the loop's reaction time (waiting would lose
        the race to the alarm anyway) — and never once the window's
        proactive PTC-call spend has reached the envelope.
        """
        if not self.recal_enabled:
            return
        cfg, ap = self.cfg, self.ap
        self._roll_budget()
        occupancy = sum(c.status == RECALIBRATING for c in self.chips)
        free = cfg.max_concurrent_recals - occupancy
        if free <= 0:
            return
        load_now = self.forecast.forecast(self.tick_count)
        in_trough = load_now <= ap.trough_load
        urgent = cfg.recal_latency + cfg.probe_every
        budget_ok = self._window_spent < ap.budget_calls
        taken: set[int] = set()
        for key, chip, tenant in self._repair_queue(pending):
            if free <= 0:
                break
            if chip.chip_id in taken or chip.status == RECALIBRATING:
                continue
            proactive = key[0] == 1
            if proactive:
                if not budget_ok:
                    self.deferred_budget += 1
                    continue
                if not in_trough and key[2] > urgent:
                    self.deferred_trough += 1
                    continue
                self.proactive_recals += 1
                self._last_proactive[(chip.chip_id, tenant.tenant_id)] = \
                    self.tick_count
            self._start_recal(chip, tenant, proactive=proactive)
            taken.add(chip.chip_id)
            free -= 1

    # -- reporting -----------------------------------------------------------

    def report(self) -> dict:
        rep = super().report()
        rep["autopilot"] = dict(
            proactive_recals=self.proactive_recals,
            deferred_budget=self.deferred_budget,
            deferred_trough=self.deferred_trough,
            budget_calls=(None if math.isinf(self.ap.budget_calls)
                          else self.ap.budget_calls),
            budget_window=self.ap.budget_window,
            window_spent=self._window_spent,
            proactive_calls=self.proactive_calls,
            proactive_windows=list(self.proactive_windows),
            horizon=self.ap.horizon, trough_load=self.ap.trough_load,
            load_forecast=(None if self.forecast.ewma is None
                           else self.forecast.forecast(self.tick_count)),
            load_samples=self.forecast.samples)
        return rep
