"""Health probes + alarm logic for a deployed chip.

On chip, the full realized transfer matrix is not observable for free —
reading back all k columns of every block costs P·Q·k PTC calls.  The
monitor instead estimates mapping fidelity *stochastically* from a
handful of forward probes: random Gaussian inputs streamed through the
(drifted) device's :class:`~repro.hw.driver.PhotonicDriver`, compared
electronically against the target response,

    d̂ = Σ_blocks ‖Ŵ x − W x‖² / Σ_blocks ‖W x‖²,

an unbiased Hutchinson-style estimator of the fleet-level aggregate of
``mapping.matrix_distance`` (exact in the limit of many probes; the
full-readout variant is :func:`readout_mapping_distance`, and the twin's
free ground truth lives behind ``driver.unsafe_twin()``).  Chips parked
in the post-IC identity state are probed the same way against ``Ĩ`` via
:func:`probe_identity_distance`, which reduces to
``calibration.identity_mse`` at full readout.

On a multi-tenant chip (several mapped layers time-sharing one block
batch) the same probe stream is scored per tenant:
:func:`probe_tenant_distances` streams one set of Gaussian columns
through the whole chip and slices the response per tenant block range,
so per-tenant health costs no more light than whole-chip health.  Each
tenant then owns its own :class:`HealthState` and its own hysteretic
alarm — one drifted layer never masks (or falsely trips) another.

Alarm logic is hysteretic: ``consecutive`` probe estimates above
``alarm_threshold`` raise the alarm (one noisy estimate never trips
it); after recalibration the alarm clears only once a fresh probe falls
below the *lower* ``clear_threshold``, so the loop cannot chatter
around a single boundary.

Probe overhead is metered by the driver itself (``driver.stats``) in
the paper's Appendix-G energy unit: one probe column through a
P×Q-block layer is P·Q PTC calls.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.calibration import identity_mse
from ..hw.driver import readout_blocks

__all__ = ["MonitorConfig", "HealthState", "aggregate_distance",
           "probe_mapping_distance", "probe_tenant_distances",
           "score_tenant_probes", "readout_mapping_distance",
           "probe_identity_distance", "update_health", "clear_health"]


class MonitorConfig(NamedTuple):
    n_probes: int = 6            # probe columns per health check
    alarm_threshold: float = 0.05  # d̂ above this (repeatedly) raises alarm
    clear_threshold: float = 0.02  # recal must restore d̂ below this
    consecutive: int = 2         # strikes before the alarm fires
    rate_alpha: float = 0.5      # EWMA weight for the degradation-rate track
    #                              (trailing field: configs built positionally
    #                              before this knob existed still parse)


@dataclasses.dataclass
class HealthState:
    """Per-chip monitor state (python-level; the fleet registry owns it)."""

    distance: float = 0.0        # latest probe estimate d̂
    strikes: int = 0             # consecutive probes above alarm_threshold
    alarmed: bool = False
    probes: int = 0              # health checks performed
    rate: float = 0.0            # EWMA of Δd̂/Δt between probes — the
    #                              degradation-rate signal the autopilot's
    #                              repair priority queue and crossing
    #                              forecast consume; 0 until two probes
    #                              have landed (or dt was never supplied)


def aggregate_distance(w_hat: jax.Array, w_blocks: jax.Array) -> jax.Array:
    """Fleet-level scalar: Σ_blocks‖Ŵ−W‖² / Σ_blocks‖W‖² (the aggregate
    of ``mapping.matrix_distance`` over a chip's block batch)."""
    num = jnp.sum((w_hat - w_blocks) ** 2, axis=(-2, -1))
    den = jnp.sum(w_blocks ** 2, axis=(-2, -1)) + 1e-12
    return jnp.sum(num) / jnp.sum(den)


def probe_mapping_distance(key: jax.Array, driver, w_blocks: jax.Array,
                           n_probes: int,
                           block_range: tuple[int, int] | None = None
                           ) -> jax.Array:
    """Stochastic estimate of the aggregate mapping distance from
    ``n_probes`` Gaussian forward probes (shared across blocks).
    ``block_range`` scopes the probe to one tenant's blocks (``w_blocks``
    then carries that tenant's targets only)."""
    k = w_blocks.shape[-1]
    x = jax.random.normal(key, (n_probes, k))
    y_hat = driver.forward(x, category="probe",
                           block_range=block_range)      # (B, n, k)
    y_ref = jnp.einsum("bij,nj->bni", w_blocks, x)
    num = jnp.sum((y_hat - y_ref) ** 2)
    den = jnp.sum(y_ref ** 2) + 1e-12
    return num / den


def probe_tenant_distances(key: jax.Array, driver,
                           tenants: "list[tuple[tuple[int, int], jax.Array]]",
                           n_probes: int) -> list[jax.Array]:
    """Per-tenant distance estimates from ONE shared probe stream.

    ``tenants`` is a list of ``(block_range, w_blocks)`` specs.  The same
    ``n_probes`` Gaussian columns stream through the whole chip once
    (B·n PTC calls — no cheaper way to cover every tenant), and each
    tenant's estimate is scored against its own targets over its own
    block slice, so a fleet health check costs the same as the old
    whole-chip probe while yielding per-tenant resolution.

    Wire cost: ONE batched RPC per chip.  The single ``forward`` is the
    probe stream's only observable op, and on the stream transports it
    auto-flushes any pipelined clock advances / writes ahead of itself
    in the same ``batch`` frame — a fleet health sweep therefore costs
    one round-trip per chip regardless of how many ticks elapsed since
    the last probe.

    The probe splits into issue (draw ``x``, stream it through the
    device) and score (:func:`score_tenant_probes`, pure electronics)
    so an async caller — ``FleetRouter.tick`` — can have every chip's
    probe frame in flight before the first response is scored.
    """
    x = jax.random.normal(key, (n_probes, driver.k))
    y_hat = driver.forward(x, category="probe")            # (B, n, k)
    return score_tenant_probes(x, y_hat, tenants)


def score_tenant_probes(x: jax.Array, y_hat: jax.Array,
                        tenants: "list[tuple[tuple[int, int], jax.Array]]"
                        ) -> list[jax.Array]:
    """Score one shared probe response per tenant (the electronic half
    of :func:`probe_tenant_distances`): ``x`` (n, k) is the probe batch
    that produced the device response ``y_hat`` (B, n, k); each
    tenant's d̂ compares its block slice against its own targets."""
    out = []
    for (start, stop), w_blocks in tenants:
        y_ref = jnp.einsum("bij,nj->bni", w_blocks, x)
        num = jnp.sum((y_hat[start:stop] - y_ref) ** 2)
        out.append(num / (jnp.sum(y_ref ** 2) + 1e-12))
    return out


def readout_mapping_distance(driver, w_blocks: jax.Array,
                             block_range: tuple[int, int] | None = None
                             ) -> jax.Array:
    """Exact aggregate distance from a full Ŵ readout: k unit-vector
    probe columns per block (observability-legal, costs B·k calls)."""
    return aggregate_distance(readout_blocks(driver,
                                             block_range=block_range),
                              w_blocks)


def probe_identity_distance(key: jax.Array, driver,
                            n_probes: int) -> jax.Array:
    """Identity-state health: read back the realized U/V* (reciprocal
    probes, metered by the driver) and score ``n_probes`` random basis
    columns against Ĩ columns (sign-agnostic).  With ``n_probes >= k``
    this equals ``identity_mse`` over both meshes.
    """
    k = driver.k
    if n_probes >= k:
        u, v = driver.readback_bases()
        return (jnp.mean(identity_mse(u)) + jnp.mean(identity_mse(v))) / 2.0
    cols = jax.random.choice(key, k, (n_probes,), replace=False)
    u, v = driver.readback_bases(cols=cols)   # partial: 2·B·n_probes calls
    eye = jnp.eye(k)[:, cols]
    err_u = jnp.mean((jnp.abs(u) - eye) ** 2)
    err_v = jnp.mean((jnp.abs(v) - eye) ** 2)
    return (err_u + err_v) / 2.0


def update_health(h: HealthState, estimate: float,
                  cfg: MonitorConfig, dt: float = 0.0) -> HealthState:
    """Fold one probe estimate into the alarm state (hysteretic).

    ``dt`` is the virtual time since the previous probe of this tenant;
    when positive, the observed growth ``(d̂ − d̂_prev)/dt`` folds into
    the EWMA degradation-rate track (``cfg.rate_alpha``).  Callers that
    omit it (the historical signature) leave the rate untouched, so the
    alarm decision — threshold, strikes, hysteresis — is bit-identical
    with or without rate tracking."""
    est = float(estimate)
    strikes = h.strikes + 1 if est > cfg.alarm_threshold else 0
    alarmed = h.alarmed or strikes >= cfg.consecutive
    rate = h.rate
    if dt > 0:
        obs = (est - h.distance) / float(dt)
        a = cfg.rate_alpha
        rate = obs if h.probes == 0 else (1.0 - a) * h.rate + a * obs
    return HealthState(distance=est, strikes=strikes, alarmed=alarmed,
                       probes=h.probes + 1, rate=rate)


def clear_health(h: HealthState, estimate: float,
                 cfg: MonitorConfig) -> HealthState:
    """Post-recalibration check: clear the alarm only below the lower
    hysteresis threshold; otherwise the alarm stays raised.  The
    degradation-rate track resets — the repair re-anchored the phases,
    so pre-repair growth says nothing about the fresh state."""
    est = float(estimate)
    ok = est < cfg.clear_threshold
    return HealthState(distance=est, strikes=0 if ok else h.strikes,
                       alarmed=not ok if h.alarmed else False,
                       probes=h.probes + 1, rate=0.0)
