"""Closed-loop recalibration: incremental ZO + OSP refresh (+ in-situ Σ).

When the monitor raises an alarm, the runtime does NOT redo the full
cold-start IC→PM flow (hundreds of thousands of probes).  Drift is
small and continuous, so the current commanded phases are an excellent
warm start: a short alternate ZCD search (the same hardware-restricted
search ``optim.zo`` used for IC/PM, §3.2–3.3), requested as an in-situ
``driver.zo_refine`` job, re-absorbs the walked phase biases at a
fraction of the cold-start budget.  The Σ attenuators are then
refreshed analytically with OSP (``mapping.osp``, Claim 1) on the
freshly read-back bases — on chip this is two reciprocal PTC probes
per block and sign flips cancel on the diagonal.

Optionally, a few *subspace-learning* steps follow: stochastic in-situ
gradient descent on Σ against Gaussian forward probes, using exactly
the paper's Eq.-5 reciprocity structure

    ∂L/∂Σ = (Uᵀ r) ⊙ (V* x),   r = Ŵx − Wx,

which approaches the OSP optimum without any full matrix readout — the
fast-adaptation mode for chips whose target is a live training state
rather than a frozen weight.

The ZO budget can be *autotuned* from the probe distance at alarm time
(``RecalConfig.auto_budget``): ``benchmarks/drift_recovery.py`` shows
recovery is ~flat in ZO steps beyond a warm-start-dependent knee, so a
mild excursion gets a short job and only deep drift pays the full
default budget (:func:`autotune_zo_steps`).

On a multi-tenant chip, pass ``block_range`` for *partial*
recalibration: the warm ZO job, the OSP readback, and the Σ write are
all scoped to the alarmed tenant's blocks (the power-aware sparse-ZO
motivation — re-tune only what drifted past tolerance), and
co-resident tenants' commanded phases and Σ banks are bit-identical
before and after the job.  The budget autotunes from *that tenant's*
probe distance, and the PTC bill scales with the tenant's block count,
not the chip's.

Every device interaction goes through the
:class:`~repro.hw.driver.PhotonicDriver` boundary; the job's probe
budget is the driver's metered PTC-call delta.  The whole job is a
*batched* interaction (protocol v3): the meter snapshot, warm ZO job,
Σ read, and OSP basis readback ship as one ``driver.run_batch`` round
trip, and the trailing Σ write rides the stream transports' write
pipeline into the closing meter read — two RPCs end-to-end where the
v2 loop paid seven, with bit-identical results by construction.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core import unitary as un
from ..core.mapping import osp
from ..optim.zo import ZOConfig
from .monitor import aggregate_distance, readout_mapping_distance

__all__ = ["RecalConfig", "RecalResult", "recalibrate", "autotune_zo_steps"]


class RecalConfig(NamedTuple):
    zo_steps: int = 400          # warm-start ZCD probe steps per block (max)
    inner: int | None = None     # decay period (default 2T)
    # gentle schedule: drift biases are ~0.01-0.03 rad, so a 0.05-rad
    # first step overshoots and the fast decay then freezes the search
    # above the deployment floor (measured: 0.05/1.05 plateaus at
    # d≈0.0075 where 0.02/1.02 reaches d≈0.003 from the same warm start)
    delta0: float = 0.02
    decay: float = 1.02
    method: str = "zcd"
    sl_steps: int = 0            # optional in-situ Σ fine-tune steps
    sl_lr: float = 0.2
    sl_probes: int = 8           # probe columns per Σ step
    # -- budget autotuning (drift_recovery knee heuristic) -------------------
    auto_budget: bool = False    # derive the step budget from d̂ at alarm
    auto_target: float = 0.02    # the recovery target (clear threshold)
    # knee-calibrated at the demo geometry (dim=18, k=6, σ=0.015,
    # target 0.02): recovery from d̂∈[0.03, 0.14] knees at 16–96 steps
    # (≈1.0 sweeps of 2T per log₂ excess); 1.5 keeps ~1.5-2x headroom
    # above the measured knee while budgeting 2-3x below the old 400-step
    # cap for typical alarm-depth excursions
    auto_min: int = 64           # floor: one compile quantum of sweep
    auto_coeff: float = 1.5      # knee slope, in units of 2T per log₂ excess
    auto_quantum: int = 64       # round autotuned budgets UP to a multiple
    #                              of this: the hw jobs layer compiles one
    #                              solver per (geometry, ZO budget)
    #                              signature, so a continuum of step counts
    #                              would defeat the compiled-twin cache —
    #                              quantized budgets keep it to a handful


class RecalResult(NamedTuple):
    phi: jax.Array               # refreshed commanded phases, (B, 2T)
    sigma: jax.Array             # refreshed attenuators, (B, k)
    dist_before: jax.Array       # aggregate distance walking in
    dist_after_zo: jax.Array     # ... after the warm ZO stage
    dist_after: jax.Array        # ... after OSP (+ SL) — the recovery point
    ptc_calls: float             # probe budget spent by this job
    zo_steps: int                # ZCD budget actually spent (autotuned)


def autotune_zo_steps(dist: float, cfg: RecalConfig, n_rot: int) -> int:
    """Budget from the probe distance at alarm time.

    The drift-recovery curves knee once the warm ZCD has swept each
    coordinate a handful of times; how many sweeps are needed grows with
    how far the estimate sits above the recovery target, so we spend
    ``auto_coeff`` alternate sweeps (2T probes each) per log₂ of excess,
    floored at ``auto_min`` and capped at the fixed ``zo_steps`` default.
    """
    ratio = max(float(dist), 0.0) / max(cfg.auto_target, 1e-9)
    if ratio <= 1.0:
        return int(cfg.auto_min)
    steps = int(round(cfg.auto_coeff * 2 * n_rot * math.log2(1.0 + ratio)))
    q = max(1, int(cfg.auto_quantum))
    steps = -(-steps // q) * q           # quantize up: bounded compile count
    return int(min(max(steps, cfg.auto_min), cfg.zo_steps))


def recalibrate(key: jax.Array, driver, w_blocks: jax.Array,
                cfg: RecalConfig = RecalConfig(),
                dist_hint: Optional[float] = None,
                block_range: Optional[tuple[int, int]] = None) -> RecalResult:
    """Refresh the driver's commanded ``(phi, sigma)`` against its
    drifted device.

    ``w_blocks``: (B, k, k) mapping targets.  The device is treated as
    frozen for the duration of the job (recal is fast vs. drift).
    ``dist_hint``: the monitor's probe estimate at alarm time, used by
    budget autotuning (defaults to a fresh full readout).
    ``block_range``: partial recalibration — scope every stage to the
    alarmed tenant's ``(start, stop)`` block slice (``w_blocks`` then
    carries that tenant's targets); all other blocks' commanded state
    stays bit-identical.
    """
    k = driver.k
    b = w_blocks.shape[0]
    t = un.mesh_spec(k, driver.kind).n_rot

    # the monitor's estimate at alarm time doubles as dist_before — no
    # point paying a B·k readout just to restate what tripped the alarm
    if dist_hint is not None:
        dist_before = jnp.asarray(float(dist_hint), jnp.float32)
        pre_ops = [("stats", {})]
    else:
        calls0 = driver.stats.total
        dist_before = readout_mapping_distance(driver, w_blocks,
                                               block_range=block_range)
        pre_ops = []

    steps = cfg.zo_steps
    if cfg.auto_budget:
        steps = autotune_zo_steps(float(dist_before), cfg, t)

    # Stage 1 — incremental ZO, warm-started from the current phases
    # (an on-controller job: per-probe round trips would defeat in-situ),
    # batched with the meter snapshot, Σ read, and the OSP basis readback
    # into ONE driver round-trip (the hot-path RPC of the closed loop).
    zo_cfg = ZOConfig(steps=steps, inner=cfg.inner or 2 * t,
                      delta0=cfg.delta0, decay=cfg.decay)
    kz, ks = jax.random.split(key)
    out = driver.run_batch(pre_ops + [
        ("zo_refine", dict(w_blocks=w_blocks, key=kz, cfg=zo_cfg,
                           method=cfg.method, block_range=block_range)),
        ("read_sigma", {}),
        ("readback_bases", dict(block_range=block_range)),
    ])
    if pre_ops:
        calls0 = out[0].total
    res, sigma, (u, v) = out[-3], out[-2], out[-1]
    phi_new = res.phi

    if block_range is not None:
        sigma = sigma[block_range[0]:block_range[1]]
    dist_after_zo = aggregate_distance((u * sigma[..., None, :]) @ v,
                                       w_blocks)

    # Stage 2 — OSP refresh (Claim 1): two reciprocal probes per block
    # (the readback above); Σ_opt is electronic arithmetic on it.
    sigma_new = osp(u, v, w_blocks)

    # Stage 3 — optional in-situ stochastic Σ descent (Eq.-5 structure):
    # each step streams sl_probes Gaussian columns and two reciprocal
    # passes; simulated here on the read-back bases, metered explicitly.
    if cfg.sl_steps > 0:
        def sl_step(s, key_i):
            x = jax.random.normal(key_i, (cfg.sl_probes, k))
            w_hat = (u * s[..., None, :]) @ v
            r = jnp.einsum("bij,nj->bni", w_hat - w_blocks, x)  # residual probes
            ur = jnp.einsum("bji,bnj->bni", u, r)               # Uᵀ r
            vx = jnp.einsum("bij,nj->bni", v, x)                # V* x
            g = jnp.einsum("bni,bni->bi", ur, vx) / cfg.sl_probes
            return s - cfg.sl_lr * g, None

        sigma_new, _ = jax.lax.scan(
            sl_step, sigma_new, jax.random.split(ks, cfg.sl_steps))
        driver.charge("probe", float(cfg.sl_steps * cfg.sl_probes * b * 2))

    driver.write_sigma(sigma_new, block_range=block_range)
    dist_after = aggregate_distance(
        (u * sigma_new[..., None, :]) @ v, w_blocks)
    return RecalResult(phi=phi_new, sigma=sigma_new,
                       dist_before=dist_before, dist_after_zo=dist_after_zo,
                       dist_after=dist_after,
                       ptc_calls=float(driver.stats.total - calls0),
                       zo_steps=steps)
