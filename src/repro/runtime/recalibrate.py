"""Closed-loop recalibration: incremental ZO + OSP refresh (+ in-situ Σ).

When the monitor raises an alarm, the runtime does NOT redo the full
cold-start IC→PM flow (hundreds of thousands of probes).  Drift is
small and continuous, so the current commanded phases are an excellent
warm start: a short alternate ZCD search (the same hardware-restricted
search ``optim.zo`` used for IC/PM, §3.2–3.3) re-absorbs the walked
phase biases at a fraction of the cold-start budget.  The Σ attenuators
are then refreshed analytically with OSP (``mapping.osp``, Claim 1) on
the freshly realized bases — on chip this is two reciprocal PTC probes
per block and sign flips cancel on the diagonal.

Optionally, a few *subspace-learning* steps follow: stochastic in-situ
gradient descent on Σ against Gaussian forward probes, using exactly
the paper's Eq.-5 reciprocity structure

    ∂L/∂Σ = (Uᵀ r) ⊙ (V* x),   r = Ŵx − Wx,

which approaches the OSP optimum without any full matrix readout — the
fast-adaptation mode for chips whose target is a live training state
rather than a frozen weight.

All stages run vmapped across the chip's blocks (independent physical
circuits), mirroring IC/PM's batched-sub-task scalability trick.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import unitary as un
from ..core.calibration import DeviceRealization, realized_unitaries
from ..core.mapping import matrix_distance, osp
from ..core.noise import NoiseModel
from ..optim.zo import ZOConfig, zo_minimize
from .monitor import aggregate_distance, true_mapping_distance

__all__ = ["RecalConfig", "RecalResult", "recalibrate"]


class RecalConfig(NamedTuple):
    zo_steps: int = 400          # warm-start ZCD probe steps per block
    inner: int | None = None     # decay period (default 2T)
    delta0: float = 0.05         # small initial step — we are near-optimal
    decay: float = 1.05
    method: str = "zcd"
    sl_steps: int = 0            # optional in-situ Σ fine-tune steps
    sl_lr: float = 0.2
    sl_probes: int = 8           # probe columns per Σ step


class RecalResult(NamedTuple):
    phi: jax.Array               # refreshed commanded phases, (B, 2T)
    sigma: jax.Array             # refreshed attenuators, (B, k)
    dist_before: jax.Array       # aggregate distance walking in
    dist_after_zo: jax.Array     # ... after the warm ZO stage
    dist_after: jax.Array        # ... after OSP (+ SL) — the recovery point
    ptc_calls: float             # probe budget spent by this job


def recalibrate(key: jax.Array, spec: un.MeshSpec, phi: jax.Array,
                sigma: jax.Array, dev: DeviceRealization, model: NoiseModel,
                w_blocks: jax.Array, cfg: RecalConfig = RecalConfig()
                ) -> RecalResult:
    """Refresh ``(phi, sigma)`` against the drifted ``dev``.

    ``phi``: (B, 2T) commanded phases (U‖V), ``sigma``: (B, k),
    ``w_blocks``: (B, k, k) mapping targets.  The device is treated as
    frozen for the duration of the job (recal is fast vs. drift).
    """
    t = spec.n_rot
    b, k = sigma.shape

    def block_err(ph, dev_b, w_b, s_b):
        u, v = realized_unitaries(spec, ph[:t], ph[t:], dev_b, model)
        return matrix_distance((u * s_b) @ v, w_b)

    dist_before = true_mapping_distance(spec, phi, sigma, dev, model,
                                        w_blocks)

    # Stage 1 — incremental ZO, warm-started from the current phases.
    zo_cfg = ZOConfig(steps=cfg.zo_steps, inner=cfg.inner or 2 * t,
                      delta0=cfg.delta0, decay=cfg.decay)
    kz, ks = jax.random.split(key)
    keys = jax.random.split(kz, b)

    def solve_one(phi_b, key_b, dev_b, w_b, s_b):
        return zo_minimize(lambda ph: block_err(ph, dev_b, w_b, s_b),
                           phi_b, key_b, zo_cfg, method=cfg.method,
                           alt_split=t)

    res = jax.jit(jax.vmap(solve_one))(phi, keys, dev, w_blocks, sigma)
    phi_new = res.x
    # each ZCD step issues ≤2 transfer-matrix evaluations of k columns
    ptc_calls = float(cfg.zo_steps * 2 * b * k)

    u, v = realized_unitaries(spec, phi_new[:, :t], phi_new[:, t:],
                              dev, model)
    dist_after_zo = aggregate_distance((u * sigma[..., None, :]) @ v,
                                       w_blocks)

    # Stage 2 — OSP refresh (Claim 1): two reciprocal probes per block.
    sigma_new = osp(u, v, w_blocks)
    ptc_calls += float(2 * b * k)

    # Stage 3 — optional in-situ stochastic Σ descent (Eq.-5 structure).
    if cfg.sl_steps > 0:
        def sl_step(s, key_i):
            x = jax.random.normal(key_i, (cfg.sl_probes, k))
            w_hat = (u * s[..., None, :]) @ v
            r = jnp.einsum("bij,nj->bni", w_hat - w_blocks, x)  # residual probes
            ur = jnp.einsum("bji,bnj->bni", u, r)               # Uᵀ r
            vx = jnp.einsum("bij,nj->bni", v, x)                # V* x
            g = jnp.einsum("bni,bni->bi", ur, vx) / cfg.sl_probes
            return s - cfg.sl_lr * g, None

        sigma_new, _ = jax.lax.scan(
            sl_step, sigma_new, jax.random.split(ks, cfg.sl_steps))
        ptc_calls += float(cfg.sl_steps * cfg.sl_probes * b * 2)

    dist_after = aggregate_distance(
        (u * sigma_new[..., None, :]) @ v, w_blocks)
    return RecalResult(phi=phi_new, sigma=sigma_new,
                       dist_before=dist_before, dist_after_zo=dist_after_zo,
                       dist_after=dist_after, ptc_calls=ptc_calls)
