"""Fleet registry + health-aware router for many virtual chip instances.

A production ONN deployment is not one chip: it is N boards, each with
an independent manufacturing realization and an independent drift
clock.  This module keeps the registry and routes serve traffic around
unhealthy devices, the scheduler/router idiom of LLM serving stacks
(sglang-style: requests never block on maintenance work; recalibration
runs out-of-band on a bounded number of "repair slots").

Per-chip state machine (see ``runtime/__init__`` for the full DESIGN
note)::

    HEALTHY ──probe d̂ > alarm (×consecutive)──▶ DEGRADED
    DEGRADED ──repair slot free──▶ RECALIBRATING   (not routable)
    RECALIBRATING ──job done, probe d̂ < clear──▶ HEALTHY
                 └─ probe still above clear ──▶ DEGRADED (re-queued)

DEGRADED chips still serve (stale but functional — better than dropping
traffic); RECALIBRATING chips are never dispatched to.  The router
prefers HEALTHY chips and falls back to DEGRADED ones only when no
healthy chip is available, balancing by least-served.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import unitary as un
from ..core.mapping import parallel_map
from ..core.noise import NoiseModel, DEFAULT_NOISE
from ..core.ptc import blockize
from .drift import DriftConfig, DriftState, init_drift, advance, DEFAULT_DRIFT
from .monitor import (MonitorConfig, HealthState, realized_blocks,
                      probe_mapping_distance, true_mapping_distance,
                      update_health, clear_health, probe_ptc_calls)
from .recalibrate import RecalConfig, recalibrate

__all__ = ["HEALTHY", "DEGRADED", "RECALIBRATING", "RuntimeConfig",
           "Chip", "FleetRouter", "make_chip", "make_fleet"]

HEALTHY = "healthy"
DEGRADED = "degraded"
RECALIBRATING = "recalibrating"


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Static policy knobs for one fleet."""

    k: int = 6
    kind: str = "clements"
    # Chips join the fleet after burn-in Identity Calibration, so the
    # serving noise frame is post-IC: the static Φ_b is compensated
    # (Q/Γ/Ω remain) and *drift* walks fresh bias on top of it.
    noise: NoiseModel = DEFAULT_NOISE.post_ic()
    drift: DriftConfig = DEFAULT_DRIFT
    monitor: MonitorConfig = MonitorConfig()
    recal: RecalConfig = RecalConfig()
    probe_every: int = 10        # ticks between health checks per chip
    recal_latency: int = 4       # ticks a recal job occupies the chip
    max_concurrent_recals: int = 1  # repair-slot bandwidth


@dataclasses.dataclass
class Chip:
    """One virtual chip: a mapped weight + its drifting realization."""

    chip_id: int
    m: int
    n: int
    w_blocks: jax.Array          # (B, k, k) mapping targets
    phi: jax.Array               # (B, 2T) commanded phases
    sigma: jax.Array             # (B, k) attenuator settings
    drift: DriftState
    health: HealthState
    status: str = HEALTHY
    recal_ticks_left: int = 0
    # counters
    served: int = 0
    alarms: int = 0
    recals: int = 0
    probe_calls: float = 0.0
    recal_calls: float = 0.0

    @property
    def routable(self) -> bool:
        return self.status != RECALIBRATING


def make_chip(key: jax.Array, chip_id: int, w: jax.Array,
              cfg: RuntimeConfig) -> Chip:
    """Deploy ``w`` onto a fresh device: PM (commanded-SVD + OSP; Σ
    absorbs most of the residual, the cheap large-model mode) and start
    the drift clock."""
    pm = parallel_map(key, w, cfg.k, cfg.noise, kind=cfg.kind, run_zo=False)
    b = pm.phi_u.shape[0]
    phi = jnp.concatenate([pm.phi_u, pm.phi_v], axis=-1)
    sigma = pm.params.s.reshape(b, cfg.k)
    w_blocks = blockize(w, cfg.k).reshape(b, cfg.k, cfg.k)
    health = HealthState(distance=float(np.asarray(pm.err_osp).mean()))
    return Chip(chip_id=chip_id, m=w.shape[0], n=w.shape[1],
                w_blocks=w_blocks, phi=phi, sigma=sigma,
                drift=init_drift(pm.dev), health=health)


def make_fleet(key: jax.Array, n_chips: int, w: jax.Array,
               cfg: RuntimeConfig) -> list[Chip]:
    """N chips serving the same logical weight, each with an independent
    realization (different manufacturing draw + drift path)."""
    keys = jax.random.split(key, n_chips)
    return [make_chip(keys[i], i, w, cfg) for i in range(n_chips)]


class FleetRouter:
    """Dispatches serve traffic; drives drift, probes, and repair jobs.

    The router owns virtual time: one :meth:`tick` = one scheduling
    quantum (drift advances on every chip, due health checks run, repair
    jobs count down / complete).  ``dispatch``/``serve`` picks a chip for
    one batch; RECALIBRATING chips are structurally unroutable.
    """

    def __init__(self, chips: list[Chip], cfg: RuntimeConfig,
                 seed: int = 0, recal_enabled: bool = True):
        if not chips:
            raise ValueError("fleet must contain at least one chip")
        self.chips = chips
        self.cfg = cfg
        self.recal_enabled = recal_enabled
        self.tick_count = 0
        self.dropped = 0             # batches with no routable chip
        self.events: list[dict] = []
        self._key = jax.random.PRNGKey(seed)
        self._spec = un.mesh_spec(cfg.k, cfg.kind)

    # -- key plumbing -------------------------------------------------------

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- routing ------------------------------------------------------------

    def dispatch(self) -> Optional[Chip]:
        """Pick the least-served routable chip, preferring HEALTHY."""
        for pool in (HEALTHY, DEGRADED):
            cands = [c for c in self.chips if c.status == pool]
            if cands:
                return min(cands, key=lambda c: c.served)
        return None

    def serve(self, x: jax.Array) -> tuple[Optional[jax.Array], Optional[int]]:
        """Route one batch ``x`` (..., n) through a chip's realized
        (drifted!) transfer function.  Returns (y, chip_id); (None, None)
        if every chip is mid-recalibration (counted as ``dropped``)."""
        chip = self.dispatch()
        if chip is None:
            self.dropped += 1
            return None, None
        y = _chip_forward(self._spec, chip.phi, chip.sigma,
                          chip.drift.dev, self.cfg.noise, x, chip.m)
        chip.served += 1
        return y, chip.chip_id

    # -- the closed loop ----------------------------------------------------

    def tick(self, dt: float = 1.0) -> None:
        """Advance virtual time: drift every chip, run due probes, fire
        alarms, schedule/complete out-of-band recalibration jobs."""
        cfg = self.cfg
        self.tick_count += 1
        in_repair = sum(c.status == RECALIBRATING for c in self.chips)

        for chip in self.chips:
            chip.drift = advance(chip.drift, dt, self._next_key(), cfg.drift)

            if chip.status == RECALIBRATING:
                chip.recal_ticks_left -= 1
                if chip.recal_ticks_left <= 0:
                    self._finish_recal(chip)
                    in_repair -= 1
                continue

            if self.tick_count % cfg.probe_every == 0:
                self._probe(chip)

            if (chip.health.alarmed and self.recal_enabled
                    and in_repair < cfg.max_concurrent_recals):
                chip.status = RECALIBRATING
                chip.recal_ticks_left = cfg.recal_latency
                in_repair += 1
                self.events.append(dict(tick=self.tick_count, event="recal_start",
                                        chip=chip.chip_id))

    def _probe(self, chip: Chip) -> None:
        cfg = self.cfg
        est = probe_mapping_distance(
            self._next_key(), self._spec, chip.phi, chip.sigma,
            chip.drift.dev, cfg.noise, chip.w_blocks, cfg.monitor.n_probes)
        was_alarmed = chip.health.alarmed
        chip.health = update_health(chip.health, float(est), cfg.monitor)
        chip.probe_calls += probe_ptc_calls(chip.m, chip.n, cfg.k,
                                            cfg.monitor.n_probes)
        if chip.health.alarmed and not was_alarmed:
            chip.alarms += 1
            chip.status = DEGRADED
            self.events.append(dict(tick=self.tick_count, event="alarm",
                                    chip=chip.chip_id,
                                    distance=chip.health.distance))

    def _finish_recal(self, chip: Chip) -> None:
        """The out-of-band job lands: apply its result against the chip's
        current (post-latency) drifted state and re-probe to clear."""
        cfg = self.cfg
        res = recalibrate(self._next_key(), self._spec, chip.phi, chip.sigma,
                          chip.drift.dev, cfg.noise, chip.w_blocks, cfg.recal)
        chip.phi, chip.sigma = res.phi, res.sigma
        chip.recal_calls += res.ptc_calls
        chip.recals += 1
        est = probe_mapping_distance(
            self._next_key(), self._spec, chip.phi, chip.sigma,
            chip.drift.dev, cfg.noise, chip.w_blocks, cfg.monitor.n_probes)
        chip.probe_calls += probe_ptc_calls(chip.m, chip.n, cfg.k,
                                            cfg.monitor.n_probes)
        chip.health = clear_health(chip.health, float(est), cfg.monitor)
        chip.status = HEALTHY if not chip.health.alarmed else DEGRADED
        self.events.append(dict(
            tick=self.tick_count, event="recal_done", chip=chip.chip_id,
            dist_before=float(res.dist_before),
            dist_after=float(res.dist_after), status=chip.status))

    # -- reporting ----------------------------------------------------------

    def true_distances(self) -> list[float]:
        """Exact per-chip mapping distances (simulator read-out)."""
        return [float(true_mapping_distance(
            self._spec, c.phi, c.sigma, c.drift.dev, self.cfg.noise,
            c.w_blocks)) for c in self.chips]

    def report(self) -> dict:
        return dict(
            ticks=self.tick_count,
            dropped=self.dropped,
            chips=[dict(chip=c.chip_id, status=c.status, served=c.served,
                        distance=c.health.distance, alarms=c.alarms,
                        recals=c.recals, probe_ptc_calls=c.probe_calls,
                        recal_ptc_calls=c.recal_calls)
                   for c in self.chips],
            events=self.events,
        )


def _chip_forward(spec, phi, sigma, dev, model, x, out_dim):
    """y = Ŵ x through the drifted realized blocks (paper dataflow:
    per-block V* → Σ → U, electronic accumulation over q is implicit
    here because each chip hosts a flat batch of blocks of one weight)."""
    k = spec.k
    w_hat = realized_blocks(spec, phi, sigma, dev, model)  # (B, k, k)
    b = w_hat.shape[0]
    # reassemble the (P, Q) grid from the flat block batch
    p = -(-out_dim // k)
    q = b // p
    w = w_hat.reshape(p, q, k, k)
    xb = x
    n = q * k
    if x.shape[-1] != n:
        xb = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, n - x.shape[-1])])
    xb = xb.reshape(x.shape[:-1] + (q, k))
    y = jnp.einsum("pqij,...qj->...pi", w, xb)
    y = y.reshape(x.shape[:-1] + (p * k,))
    return y[..., :out_dim]
