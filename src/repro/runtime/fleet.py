"""Fleet registry + health-aware router for many virtual chip instances.

A production ONN deployment is not one chip: it is N boards, each with
an independent manufacturing realization and an independent drift
clock.  This module keeps the registry and routes serve traffic around
unhealthy devices, the scheduler/router idiom of LLM serving stacks
(sglang-style: requests never block on maintenance work; recalibration
runs out-of-band on a bounded number of "repair slots").

Each :class:`Chip` holds a :class:`~repro.hw.driver.PhotonicDriver` —
the router never touches device internals: it serves through
``driver.forward_layer``, probes through the monitor's driver-based
estimators, lets time pass with ``driver.advance``, and reads PTC-call
budgets off ``driver.stats``.  Any transport (in-process twin,
subprocess twin, real hardware) slots in unchanged.

Per-chip state machine (see ``runtime/__init__`` for the full DESIGN
note)::

    HEALTHY ──probe d̂ > alarm (×consecutive)──▶ DEGRADED
    DEGRADED ──repair slot free──▶ RECALIBRATING   (not routable)
    RECALIBRATING ──job done, probe d̂ < clear──▶ HEALTHY
                 └─ probe still above clear ──▶ DEGRADED (re-queued)

DEGRADED chips still serve (stale but functional — better than dropping
traffic); RECALIBRATING chips are never dispatched to.  Routing policy:

* ``"drift_aware"`` (default) — rank dispatch candidates by *predicted*
  fidelity at dispatch time: the last probe estimate extrapolated along
  the OU relaxation law (variance relaxes toward its stationary level
  ``σ_φ²/2θ`` with rate ``2θ``, i.e. half-life ``ln2/2θ`` ticks), so a
  chip probed long ago is charged its forecast drift, not its stale
  estimate.  Ties break by least-served.
* ``"least_served"`` — the plain balancing baseline.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import numpy as np

from ..core.mapping import parallel_map
from ..core.noise import NoiseModel, DEFAULT_NOISE
from ..core.ptc import blockize
from ..hw import make_driver
from ..hw.drift import DriftConfig, DEFAULT_DRIFT
from .monitor import (MonitorConfig, HealthState, probe_mapping_distance,
                      update_health, clear_health)
from .recalibrate import RecalConfig, recalibrate

__all__ = ["HEALTHY", "DEGRADED", "RECALIBRATING", "RuntimeConfig",
           "Chip", "FleetRouter", "make_chip", "make_fleet",
           "predicted_distance"]

HEALTHY = "healthy"
DEGRADED = "degraded"
RECALIBRATING = "recalibrating"


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Static policy knobs for one fleet."""

    k: int = 6
    kind: str = "clements"
    # Chips join the fleet after burn-in Identity Calibration, so the
    # serving noise frame is post-IC: the static Φ_b is compensated
    # (Q/Γ/Ω remain) and *drift* walks fresh bias on top of it.
    noise: NoiseModel = DEFAULT_NOISE.post_ic()
    drift: DriftConfig = DEFAULT_DRIFT
    monitor: MonitorConfig = MonitorConfig()
    recal: RecalConfig = RecalConfig()
    probe_every: int = 10        # ticks between health checks per chip
    recal_latency: int = 4       # ticks a recal job occupies the chip
    max_concurrent_recals: int = 1  # repair-slot bandwidth
    driver_kind: str = "twin"    # "twin" | "subprocess" (hw.make_driver)
    router_policy: str = "drift_aware"  # | "least_served"


@dataclasses.dataclass
class Chip:
    """One virtual chip: a mapped weight behind its control-plane driver."""

    chip_id: int
    m: int
    n: int
    w_blocks: jax.Array          # (B, k, k) mapping targets
    driver: object               # PhotonicDriver (owns phi/sigma/clock/meter)
    health: HealthState
    status: str = HEALTHY
    recal_ticks_left: int = 0
    last_probe_tick: int = 0     # when health.distance was last measured
    # counters
    served: int = 0
    alarms: int = 0
    recals: int = 0
    recal_calls: float = 0.0     # PTC calls spent by recal jobs (job deltas)

    @property
    def routable(self) -> bool:
        return self.status != RECALIBRATING


def make_chip(key: jax.Array, chip_id: int, w: jax.Array,
              cfg: RuntimeConfig, driver=None) -> Chip:
    """Deploy ``w`` onto a fresh device: construct the chip's driver
    (``cfg.driver_kind`` transport), PM it (commanded-SVD + OSP; Σ
    absorbs most of the residual, the cheap large-model mode) — the
    drift clock is the driver's own."""
    m, n = int(w.shape[0]), int(w.shape[1])
    b = (-(-m // cfg.k)) * (-(-n // cfg.k))
    kd, kpm = jax.random.split(key)
    if driver is None:
        driver = make_driver(cfg.driver_kind, kd, b, cfg.k, cfg.noise,
                             cfg.kind, m=m, n=n, drift=cfg.drift)
    pm = parallel_map(kpm, w, cfg.k, cfg.noise, kind=cfg.kind,
                      run_zo=False, driver=driver)
    w_blocks = blockize(w, cfg.k).reshape(b, cfg.k, cfg.k)
    health = HealthState(distance=float(np.asarray(pm.err_osp).mean()))
    return Chip(chip_id=chip_id, m=m, n=n, w_blocks=w_blocks,
                driver=driver, health=health)


def make_fleet(key: jax.Array, n_chips: int, w: jax.Array,
               cfg: RuntimeConfig) -> list[Chip]:
    """N chips serving the same logical weight, each with an independent
    realization (different manufacturing draw + drift path)."""
    keys = jax.random.split(key, n_chips)
    return [make_chip(keys[i], i, w, cfg) for i in range(n_chips)]


def predicted_distance(chip: Chip, now: int, drift: DriftConfig) -> float:
    """Forecast of a chip's mapping distance at tick ``now``.

    Small-angle, the distance tracks the phase-error variance, whose OU
    law relaxes toward the stationary level ``σ_φ²/2θ`` with rate
    ``2θ``::

        d(Δ) ≈ d_∞ + (d̂ − d_∞)·exp(−2θΔ),   d_∞ = σ_φ²/(2θ)

    so a stale low estimate inflates toward the stationary floor while a
    fresh one is trusted as-is.  A heuristic (constant-factor-free), but
    monotone in both the estimate and its staleness — exactly what a
    dispatch *ranking* needs.
    """
    dt = max(0, now - chip.last_probe_tick)
    d_inf = drift.sigma_phase ** 2 / (2.0 * drift.theta + 1e-12)
    decay = math.exp(-2.0 * drift.theta * dt)
    return d_inf + (chip.health.distance - d_inf) * decay


class FleetRouter:
    """Dispatches serve traffic; drives drift, probes, and repair jobs.

    The router owns virtual time: one :meth:`tick` = one scheduling
    quantum (every chip's driver advances its clock, due health checks
    run, repair jobs count down / complete).  ``dispatch``/``serve``
    picks a chip for one batch; RECALIBRATING chips are structurally
    unroutable.
    """

    def __init__(self, chips: list[Chip], cfg: RuntimeConfig,
                 seed: int = 0, recal_enabled: bool = True):
        if not chips:
            raise ValueError("fleet must contain at least one chip")
        self.chips = chips
        self.cfg = cfg
        self.recal_enabled = recal_enabled
        self.tick_count = 0
        self.dropped = 0             # batches with no routable chip
        self.events: list[dict] = []
        self._key = jax.random.PRNGKey(seed)

    # -- key plumbing -------------------------------------------------------

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- routing ------------------------------------------------------------

    def dispatch(self) -> Optional[Chip]:
        """Pick a routable chip, preferring HEALTHY; rank within the pool
        by the configured policy (predicted fidelity decay or plain
        least-served)."""
        for pool in (HEALTHY, DEGRADED):
            cands = [c for c in self.chips if c.status == pool]
            if not cands:
                continue
            if self.cfg.router_policy == "drift_aware":
                return min(cands, key=lambda c: (
                    predicted_distance(c, self.tick_count, self.cfg.drift),
                    c.served, c.chip_id))
            return min(cands, key=lambda c: c.served)
        return None

    def serve(self, x: jax.Array) -> tuple[Optional[jax.Array], Optional[int]]:
        """Route one batch ``x`` (..., n) through a chip's realized
        (drifted!) transfer function.  Returns (y, chip_id); (None, None)
        if every chip is mid-recalibration (counted as ``dropped``)."""
        chip = self.dispatch()
        if chip is None:
            self.dropped += 1
            return None, None
        y = chip.driver.forward_layer(x)
        chip.served += 1
        return y, chip.chip_id

    # -- the closed loop ----------------------------------------------------

    def tick(self, dt: float = 1.0) -> None:
        """Advance virtual time: every chip's clock runs, due probes
        fire, alarms raise, out-of-band recalibration jobs schedule and
        complete."""
        cfg = self.cfg
        self.tick_count += 1
        in_repair = sum(c.status == RECALIBRATING for c in self.chips)

        for chip in self.chips:
            chip.driver.advance(dt)

            if chip.status == RECALIBRATING:
                chip.recal_ticks_left -= 1
                if chip.recal_ticks_left <= 0:
                    self._finish_recal(chip)
                    in_repair -= 1
                continue

            if self.tick_count % cfg.probe_every == 0:
                self._probe(chip)

            if (chip.health.alarmed and self.recal_enabled
                    and in_repair < cfg.max_concurrent_recals):
                chip.status = RECALIBRATING
                chip.recal_ticks_left = cfg.recal_latency
                in_repair += 1
                self.events.append(dict(tick=self.tick_count, event="recal_start",
                                        chip=chip.chip_id))

    def _probe(self, chip: Chip) -> None:
        cfg = self.cfg
        est = probe_mapping_distance(self._next_key(), chip.driver,
                                     chip.w_blocks, cfg.monitor.n_probes)
        was_alarmed = chip.health.alarmed
        chip.health = update_health(chip.health, float(est), cfg.monitor)
        chip.last_probe_tick = self.tick_count
        if chip.health.alarmed and not was_alarmed:
            chip.alarms += 1
            chip.status = DEGRADED
            self.events.append(dict(tick=self.tick_count, event="alarm",
                                    chip=chip.chip_id,
                                    distance=chip.health.distance))

    def _finish_recal(self, chip: Chip) -> None:
        """The out-of-band job lands: run it against the chip's current
        (post-latency) drifted state and re-probe to clear."""
        cfg = self.cfg
        res = recalibrate(self._next_key(), chip.driver, chip.w_blocks,
                          cfg.recal, dist_hint=chip.health.distance)
        chip.recals += 1
        chip.recal_calls += res.ptc_calls
        est = probe_mapping_distance(self._next_key(), chip.driver,
                                     chip.w_blocks, cfg.monitor.n_probes)
        chip.health = clear_health(chip.health, float(est), cfg.monitor)
        chip.last_probe_tick = self.tick_count
        chip.status = HEALTHY if not chip.health.alarmed else DEGRADED
        self.events.append(dict(
            tick=self.tick_count, event="recal_done", chip=chip.chip_id,
            dist_before=float(res.dist_before),
            dist_after=float(res.dist_after), zo_steps=res.zo_steps,
            status=chip.status))

    # -- reporting ----------------------------------------------------------

    def true_distances(self) -> list[float]:
        """Exact per-chip mapping distances — a twin-only readout routed
        through the audited ``driver.unsafe_twin()`` escape hatch
        (benchmark/diagnostic use; raises TwinUnavailable on real HW)."""
        return [c.driver.unsafe_twin().true_mapping_distance(c.w_blocks)
                for c in self.chips]

    def report(self) -> dict:
        chips = []
        for c in self.chips:
            s = c.driver.stats
            # everything the driver metered that is neither serve traffic
            # nor a recal job's delta is monitor probing (incl. the PM
            # deployment readout)
            chips.append(dict(chip=c.chip_id, status=c.status,
                              served=c.served, distance=c.health.distance,
                              alarms=c.alarms, recals=c.recals,
                              probe_ptc_calls=s.total - s.serve - c.recal_calls,
                              recal_ptc_calls=c.recal_calls,
                              serve_ptc_calls=s.serve,
                              ptc_calls=s.as_dict()))
        return dict(ticks=self.tick_count, dropped=self.dropped,
                    chips=chips, events=self.events)

    def close(self) -> None:
        """Release every chip's driver transport (subprocess servers)."""
        for c in self.chips:
            c.driver.close()
