"""Fleet registry + health-aware router for many virtual chip instances.

A production ONN deployment is not one chip: it is N boards, each with
an independent manufacturing realization and an independent drift
clock.  This module keeps the registry and routes serve traffic around
unhealthy devices, the scheduler/router idiom of LLM serving stacks
(sglang-style: requests never block on maintenance work; recalibration
runs out-of-band on a bounded number of "repair slots").

Multi-tenancy: L2ight's premise is that one photonic tensor core is
time-multiplexed across many mapped layers (Bandyopadhyay et al.
demonstrate the multi-layer-on-one-chip shape in hardware).  Each
:class:`Chip` therefore hosts a list of :class:`Tenant` slots — one
mapped layer each, owning a contiguous block range and its Σ bank on
the shared device plus its own :class:`HealthState`.  Health probes
resolve per tenant from one shared probe stream, alarms are per
tenant, and recalibration is *partial*: only the alarmed tenant's
blocks are re-tuned (``recalibrate(..., block_range=...)``), so
co-resident tenants' commanded phases and Σ banks stay bit-identical
through a repair.  A single-tenant chip (one weight spanning every
block) is the degenerate case and behaves exactly as before.

Each :class:`Chip` holds a :class:`~repro.hw.driver.PhotonicDriver` —
the router never touches device internals: it serves through
``driver.forward_layer`` (scoped to the dispatched tenant's block
range), probes through the monitor's driver-based estimators, lets
time pass with ``driver.advance``, and reads PTC-call budgets off
``driver.stats``.  Any transport (in-process twin, subprocess twin,
real hardware) slots in unchanged.

Per-chip state machine (see ``runtime/__init__`` for the full DESIGN
note)::

    HEALTHY ──tenant probe d̂ > alarm (×consecutive)──▶ DEGRADED
    DEGRADED ──repair slot free──▶ RECALIBRATING   (not routable;
                                    partial recal of the worst alarmed
                                    tenant's blocks only)
    RECALIBRATING ──job done, tenant probe d̂ < clear──▶ HEALTHY
                 └─ probe still above clear, or another tenant
                    alarmed ──▶ DEGRADED (re-queued)

DEGRADED chips still serve (stale but functional — better than dropping
traffic); RECALIBRATING chips are never dispatched to.  Routing policy:

* ``"drift_aware"`` (default) — rank dispatch candidates by *predicted*
  fidelity of the requested tenant at dispatch time: the tenant's last
  probe estimate extrapolated along the OU relaxation law (variance
  relaxes toward its stationary level ``σ_φ²/2θ`` with rate ``2θ``,
  i.e. half-life ``ln2/2θ`` ticks), so a tenant probed long ago is
  charged its forecast drift, not its stale estimate.  Ties break by
  least-served.
* ``"accuracy_aware"`` — rank by forecast *logit* fidelity instead of
  raw probe distance: each tenant's predicted drift-induced excess over
  its deployment-time floor, weighted by a per-tenant logit-sensitivity
  calibration (:meth:`FleetRouter.set_sensitivity`; derived from the
  served layers by ``autopilot.logit_sensitivity``).  At σ_drift = 0
  every excess is exactly 0 and the policy reduces to ``drift_aware``
  (property-tested) — the deployment floor is priced into baseline
  accuracy, so only drift-induced excess should steer traffic.
* ``"least_served"`` — the plain balancing baseline.

Scheduling is a seam: the *reactive* repair policy lives in
:meth:`FleetRouter._schedule_repairs` (alarm-driven, FIFO in chip
order); the forecast-driven autopilot (``runtime/autopilot.py``,
:func:`make_router`) overrides exactly that method with a
degradation-rate priority queue plus proactive trough-scheduled
maintenance.  See ``docs/architecture.md``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.mapping import parallel_map
from ..core.noise import NoiseModel, DEFAULT_NOISE
from ..core.ptc import blockize
from ..hw import make_driver, DriftConfig, DEFAULT_DRIFT
from .monitor import (MonitorConfig, HealthState, probe_mapping_distance,
                      score_tenant_probes, update_health, clear_health)
from .recalibrate import RecalConfig, recalibrate

__all__ = ["HEALTHY", "DEGRADED", "RECALIBRATING", "RuntimeConfig",
           "Tenant", "Chip", "FleetRouter", "make_chip", "make_fleet",
           "make_router", "predicted_distance"]

HEALTHY = "healthy"
DEGRADED = "degraded"
RECALIBRATING = "recalibrating"


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Static policy knobs for one fleet."""

    k: int = 6
    kind: str = "clements"
    # Chips join the fleet after burn-in Identity Calibration, so the
    # serving noise frame is post-IC: the static Φ_b is compensated
    # (Q/Γ/Ω remain) and *drift* walks fresh bias on top of it.
    noise: NoiseModel = DEFAULT_NOISE.post_ic()
    drift: DriftConfig = DEFAULT_DRIFT
    monitor: MonitorConfig = MonitorConfig()
    recal: RecalConfig = RecalConfig()
    probe_every: int = 10        # ticks between health checks per chip
    recal_latency: int = 4       # ticks a recal job occupies the chip
    max_concurrent_recals: int = 1  # repair-slot bandwidth
    driver_kind: str = "twin"    # "twin"|"subprocess"|"socket" (make_driver)
    router_policy: str = "drift_aware"  # | "least_served"
    deploy_zo: bool = False      # PM stage 2 (alternate ZCD) at deployment:
    #                              lower mapping floor, dearer onboarding —
    #                              the hw-logits accuracy benchmarks turn it
    #                              on; health/routing studies don't need it
    repair_batch: int = 1        # alarmed tenants re-tuned per repair
    #                              outage (worst-first).  1 = the historical
    #                              one-tenant-per-window policy; hw-logits
    #                              serving raises it so one chip outage
    #                              refreshes every drifted layer at once
    #                              (a model's tenants drift together)
    autopilot: Optional[object] = None  # AutopilotConfig — when set,
    #                              :func:`make_router` builds the
    #                              forecast-driven AutopilotRouter
    #                              (runtime/autopilot.py) instead of the
    #                              reactive FleetRouter.  Typed loosely to
    #                              keep fleet.py import-free of autopilot.


@dataclasses.dataclass
class Tenant:
    """One mapped layer resident on a chip: a Σ bank + block range on
    the shared device, with its own health/alarm state and counters."""

    tenant_id: int
    m: int
    n: int
    block_range: tuple[int, int]   # (start, stop) into the chip's blocks
    w_blocks: jax.Array            # (b_t, k, k) mapping targets
    health: HealthState
    last_probe_tick: int = 0       # when health.distance was last measured
    # counters
    served: int = 0
    alarms: int = 0
    recals: int = 0
    recal_calls: float = 0.0       # PTC calls spent on this tenant's recals

    @property
    def n_blocks(self) -> int:
        return self.block_range[1] - self.block_range[0]


@dataclasses.dataclass
class Chip:
    """One virtual chip: tenant slots behind a control-plane driver."""

    chip_id: int
    driver: object               # PhotonicDriver (owns phi/sigma/clock/meter)
    tenants: list[Tenant]
    status: str = HEALTHY
    recal_ticks_left: int = 0
    recal_tenant: Optional[int] = None   # tenant the pending job re-tunes
    recal_proactive: bool = False        # pending job was forecast-scheduled
    offline_ticks_left: int = 0  # injected outage: board unreachable —
    #                              not routable, not probeable, and any
    #                              in-flight repair job stalls until the
    #                              outage lifts
    # chip-level counters (tenant counters hold the breakdown)
    served: int = 0
    alarms: int = 0
    recals: int = 0
    recal_calls: float = 0.0     # PTC calls spent by recal jobs (job deltas)

    @property
    def offline(self) -> bool:
        return self.offline_ticks_left > 0

    @property
    def routable(self) -> bool:
        return self.status != RECALIBRATING and not self.offline

    @property
    def alarmed(self) -> bool:
        return any(t.health.alarmed for t in self.tenants)

    # -- single-tenant compatibility surface ---------------------------------
    # A chip made from one weight has exactly one tenant spanning every
    # block; these views keep the pre-tenant API working unchanged.

    @property
    def m(self) -> int:
        return self.tenants[0].m

    @property
    def n(self) -> int:
        return self.tenants[0].n

    @property
    def w_blocks(self) -> jax.Array:
        if len(self.tenants) == 1:
            return self.tenants[0].w_blocks
        return jnp.concatenate([t.w_blocks for t in self.tenants], axis=0)

    @property
    def health(self) -> HealthState:
        return self.tenants[0].health

    @health.setter
    def health(self, h: HealthState) -> None:
        self.tenants[0].health = h

    @property
    def last_probe_tick(self) -> int:
        return self.tenants[0].last_probe_tick

    @last_probe_tick.setter
    def last_probe_tick(self, tick: int) -> None:
        self.tenants[0].last_probe_tick = tick


def _tenant_layout(weights: Sequence[jax.Array], k: int
                   ) -> list[tuple[int, int, tuple[int, int]]]:
    """(m, n, block_range) per tenant, packed contiguously in order."""
    out = []
    offset = 0
    for w in weights:
        m, n = int(w.shape[0]), int(w.shape[1])
        b = (-(-m // k)) * (-(-n // k))
        out.append((m, n, (offset, offset + b)))
        offset += b
    return out


def make_chip(key: jax.Array, chip_id: int, w, cfg: RuntimeConfig,
              driver=None) -> Chip:
    """Deploy weight(s) onto a fresh device.

    ``w`` is either one (M, N) array — a single-tenant chip, identical
    to the historical behavior — or a sequence of arrays, one mapped
    layer per tenant, packed into contiguous block ranges of one shared
    device.  Constructs the chip's driver (``cfg.driver_kind``
    transport) sized for the total block count, then PMs each tenant
    onto its range (commanded-SVD + OSP; Σ absorbs most of the
    residual, the cheap large-model mode) — the drift clock is the
    driver's own.
    """
    weights = list(w) if isinstance(w, (list, tuple)) else [w]
    layout = _tenant_layout(weights, cfg.k)
    total_blocks = layout[-1][2][1]
    single = len(weights) == 1
    kd, kpm = jax.random.split(key)
    if driver is None:
        m0, n0 = layout[0][0], layout[0][1]
        driver = make_driver(cfg.driver_kind, kd, total_blocks, cfg.k,
                             cfg.noise, cfg.kind, m=m0, n=n0,
                             drift=cfg.drift)
    tenants = []
    for i, (wi, (m, n, rng)) in enumerate(zip(weights, layout)):
        kt = kpm if i == 0 else jax.random.fold_in(kpm, i)
        pm = parallel_map(kt, wi, cfg.k, cfg.noise, kind=cfg.kind,
                          run_zo=cfg.deploy_zo, driver=driver,
                          block_range=None if single else rng)
        b = rng[1] - rng[0]
        w_blocks = blockize(wi, cfg.k).reshape(b, cfg.k, cfg.k)
        health = HealthState(distance=float(np.asarray(pm.err_osp).mean()))
        tenants.append(Tenant(tenant_id=i, m=m, n=n, block_range=rng,
                              w_blocks=w_blocks, health=health))
    return Chip(chip_id=chip_id, driver=driver, tenants=tenants)


def make_fleet(key: jax.Array, n_chips: int, w,
               cfg: RuntimeConfig) -> list[Chip]:
    """N chips serving the same logical weight(s), each with an
    independent realization (different manufacturing draw + drift
    path).  ``w`` may be a list of weights — every chip then hosts the
    same tenant layout."""
    keys = jax.random.split(key, n_chips)
    return [make_chip(keys[i], i, w, cfg) for i in range(n_chips)]


def make_router(chips: list[Chip], cfg: RuntimeConfig, seed: int = 0,
                recal_enabled: bool = True) -> "FleetRouter":
    """Router factory: the reactive :class:`FleetRouter` by default, or
    the forecast-driven ``AutopilotRouter`` when ``cfg.autopilot`` is
    set (imported lazily — fleet.py never depends on autopilot.py)."""
    if cfg.autopilot is not None:
        from .autopilot import AutopilotRouter
        return AutopilotRouter(chips, cfg, seed=seed,
                               recal_enabled=recal_enabled)
    return FleetRouter(chips, cfg, seed=seed, recal_enabled=recal_enabled)


def predicted_distance(chip: Chip, now: int, drift: DriftConfig,
                       tenant: Optional[Tenant] = None) -> float:
    """Forecast of a tenant's mapping distance at tick ``now``
    (defaults to the chip's first tenant — the whole chip when
    single-tenant).

    Small-angle, the distance tracks the phase-error variance, whose OU
    law relaxes toward the stationary level ``σ_φ²/2θ`` with rate
    ``2θ``::

        d(Δ) ≈ d_∞ + (d̂ − d_∞)·exp(−2θΔ),   d_∞ = σ_φ²/(2θ)

    so a stale low estimate inflates toward the stationary floor while a
    fresh one is trusted as-is.  A heuristic (constant-factor-free), but
    monotone in both the estimate and its staleness — exactly what a
    dispatch *ranking* needs.
    """
    t = tenant if tenant is not None else chip.tenants[0]
    dt = max(0, now - t.last_probe_tick)
    d_inf = drift.sigma_phase ** 2 / (2.0 * drift.theta + 1e-12)
    decay = math.exp(-2.0 * drift.theta * dt)
    return d_inf + (t.health.distance - d_inf) * decay


class FleetRouter:
    """Dispatches serve traffic; drives drift, probes, and repair jobs.

    The router owns virtual time: one :meth:`tick` = one scheduling
    quantum (every chip's driver advances its clock, due health checks
    run, repair jobs count down / complete).  ``dispatch``/``serve``
    picks a chip for one batch of one tenant's traffic; RECALIBRATING
    chips are structurally unroutable.
    """

    def __init__(self, chips: list[Chip], cfg: RuntimeConfig,
                 seed: int = 0, recal_enabled: bool = True):
        if not chips:
            raise ValueError("fleet must contain at least one chip")
        self.chips = chips
        self.cfg = cfg
        self.recal_enabled = recal_enabled
        self.tick_count = 0
        self.dropped = 0             # batches with no routable chip
        self.events: list[dict] = []
        self._key = jax.random.PRNGKey(seed)
        # deployment-time floors: the PM residual each tenant carried at
        # fleet build.  "accuracy_aware" ranks chips by drift-induced
        # EXCESS over this floor (the floor is baked into baseline task
        # accuracy — only the excess degrades served logits).
        self._floor = {c.chip_id: [t.health.distance for t in c.tenants]
                       for c in chips}
        # per-tenant logit-sensitivity weights (uniform until calibrated
        # via set_sensitivity — HwServePlane derives them from the served
        # layers' effective weights; see autopilot.logit_sensitivity)
        self.sensitivity: Optional[list[float]] = None

    def set_sensitivity(self, weights: Sequence[float]) -> None:
        """Install per-tenant logit-sensitivity weights for the
        ``accuracy_aware`` routing policy (one weight per tenant slot;
        every chip hosts the same layout)."""
        n = len(self.chips[0].tenants)
        if len(weights) != n:
            raise ValueError(f"expected {n} tenant weights, "
                             f"got {len(weights)}")
        self.sensitivity = [float(w) for w in weights]

    def _tenant_weight(self, idx: int) -> float:
        return 1.0 if self.sensitivity is None else self.sensitivity[idx]

    def observe_load(self, load: float) -> None:
        """Load-forecast hook: the serving gateway reports its occupancy
        (active slots + queue depth over capacity) here each virtual
        step.  The reactive router ignores it; the autopilot subclass
        folds it into its trough forecast."""

    # -- key plumbing -------------------------------------------------------

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- routing ------------------------------------------------------------

    def dispatch(self, tenant: int = 0) -> Optional[Chip]:
        """Pick a routable chip for ``tenant``'s traffic, preferring
        HEALTHY; rank within the pool by the configured policy
        (predicted per-tenant fidelity decay, forecast logit excess, or
        plain least-served)."""
        for pool in (HEALTHY, DEGRADED):
            cands = [c for c in self.chips
                     if c.status == pool and c.routable
                     and tenant < len(c.tenants)]
            if not cands:
                continue
            if self.cfg.router_policy == "drift_aware":
                return min(cands, key=lambda c: (
                    predicted_distance(c, self.tick_count, self.cfg.drift,
                                       c.tenants[tenant]),
                    c.tenants[tenant].served, c.served, c.chip_id))
            if self.cfg.router_policy == "accuracy_aware":
                return min(cands, key=lambda c:
                           self._accuracy_key(c, tenant))
            return min(cands, key=lambda c: (c.tenants[tenant].served,
                                             c.served, c.chip_id))
        return None

    def _accuracy_key(self, c: Chip, tenant: int) -> tuple:
        """``accuracy_aware`` dispatch key: forecast *logit* infidelity
        first — the tenant's predicted drift-induced excess over its
        deployment floor, weighted by its logit sensitivity — then the
        raw forecast distance (which makes the policy reduce EXACTLY to
        ``drift_aware`` at σ_drift = 0, where every excess is 0: the
        floor error is already priced into baseline accuracy)."""
        pd = predicted_distance(c, self.tick_count, self.cfg.drift,
                                c.tenants[tenant])
        excess = max(0.0, pd - self._floor[c.chip_id][tenant])
        return (self._tenant_weight(tenant) * excess, pd,
                c.tenants[tenant].served, c.served, c.chip_id)

    def serve(self, x: jax.Array, tenant: int = 0
              ) -> tuple[Optional[jax.Array], Optional[int]]:
        """Route one batch ``x`` (..., n_t) of ``tenant``'s traffic
        through a chip's realized (drifted!) transfer function, scoped
        to that tenant's block range.  Returns (y, chip_id);
        (None, None) if every chip is mid-recalibration (counted as
        ``dropped``)."""
        chip = self.dispatch(tenant)
        if chip is None:
            self.dropped += 1
            return None, None
        t = chip.tenants[tenant]
        y = chip.driver.forward_layer(x, block_range=t.block_range,
                                      out_dim=t.m)
        chip.served += 1
        t.served += 1
        return y, chip.chip_id

    def route_pass(self) -> Optional[Chip]:
        """Pick ONE chip for a whole forward pass (every tenant slot of
        the pass lands on the same board — the hardware-in-the-loop
        serving shape, where tenant ``j`` is layer ``j`` of the served
        model and activations flow chip-side layer by layer).  Ranking
        mirrors :meth:`dispatch` but aggregates over all tenants: the
        chip whose *worst* forecast tenant fidelity is best wins."""
        for pool in (HEALTHY, DEGRADED):
            cands = [c for c in self.chips
                     if c.status == pool and c.routable]
            if not cands:
                continue
            if self.cfg.router_policy == "drift_aware":
                return min(cands, key=lambda c: (
                    max(predicted_distance(c, self.tick_count,
                                           self.cfg.drift, t)
                        for t in c.tenants),
                    c.served, c.chip_id))
            if self.cfg.router_policy == "accuracy_aware":
                return min(cands, key=self._accuracy_pass_key)
            return min(cands, key=lambda c: (c.served, c.chip_id))
        return None

    def _accuracy_pass_key(self, c: Chip) -> tuple:
        """Whole-pass ``accuracy_aware`` key: Σ over tenants of
        sensitivity-weighted forecast excess (a pass touches every
        layer, so the chip's aggregate forecast logit error is what the
        served model will see), tie-broken by the worst raw forecast —
        the ``drift_aware`` pass key, to which this reduces at σ = 0."""
        now, drift = self.tick_count, self.cfg.drift
        pds = [predicted_distance(c, now, drift, t) for t in c.tenants]
        floors = self._floor[c.chip_id]
        excess = sum(self._tenant_weight(j) * max(0.0, pd - floors[j])
                     for j, pd in enumerate(pds))
        return (excess, max(pds), c.served, c.chip_id)

    def _pass_ops(self, chip: Chip,
                  items: "Sequence[tuple[int, jax.Array]]") -> list:
        ops = []
        for idx, x in items:
            t = chip.tenants[idx]
            ops.append(("forward_layer", dict(x=x, block_range=t.block_range,
                                              out_dim=t.m)))
        return ops

    def serve_pass(self, chip: Chip, items: "Sequence[tuple[int, jax.Array]]"
                   ) -> list:
        """Execute several tenants' layer matmuls on ``chip`` in ONE
        driver round-trip: ``items`` is ``[(tenant_idx, x), ...]`` and
        the whole list ships as a single ``batch`` frame (any pipelined
        clock advances from :meth:`tick` flush ahead of it in the same
        frame), so a decode step costs O(1) RPCs per (chip,
        layer-group) instead of one per op.  Results are bit-identical
        to per-op ``forward_layer`` calls by the batch frame's
        construction; serve counters update per tenant."""
        ys = chip.driver.run_batch(self._pass_ops(chip, items))
        for idx, _ in items:
            chip.tenants[idx].served += 1
        chip.served += len(items)   # chip total stays Σ tenant counters
        return ys

    def serve_pass_async(self, chip: Chip,
                         items: "Sequence[tuple[int, jax.Array]]"):
        """:meth:`serve_pass`, split at the wire: issue the batch frame
        now, return a future whose ``.result()`` is exactly
        :meth:`serve_pass`'s response list.  A caller holding passes
        for several chips issues them all, then collects — the frames
        overlap across chips instead of serializing round-trips.
        Counters update at issue time (the frame is committed to the
        wire once this returns); results are bit-identical to the
        blocking path by :meth:`~repro.hw.driver.PhotonicDriver.
        run_batch_async`'s contract."""
        fut = chip.driver.run_batch_async(self._pass_ops(chip, items))
        for idx, _ in items:
            chip.tenants[idx].served += 1
        chip.served += len(items)   # chip total stays Σ tenant counters
        return fut

    # -- the closed loop ----------------------------------------------------

    def tick(self, dt: float = 1.0) -> None:
        """Advance virtual time: every chip's clock runs, due probes
        fire, alarms raise, out-of-band recalibration jobs schedule and
        complete.

        The tick is two-phase.  The *issue* phase walks chips in order:
        clocks advance (result-less, so stream transports pipeline them
        client-side — a tick with no due probe costs zero round-trips),
        finished repair jobs land, and every due probe's batch frame
        goes out via ``driver.run_batch_async`` WITHOUT waiting for its
        response — a fleet health sweep has every chip's frame in
        flight at once.  The *collect* phase resolves responses in the
        same chip order, scores them electronically
        (:func:`~repro.runtime.monitor.score_tenant_probes`), and runs
        alarm/repair scheduling against the repair-slot occupancy each
        chip would have observed in the sequential walk — PRNG draws,
        health decisions, and results are bit-identical to the
        serialized tick; only the wall-clock overlap changes."""
        cfg = self.cfg
        self.tick_count += 1
        in_repair = sum(c.status == RECALIBRATING for c in self.chips)
        probe_due = self.tick_count % cfg.probe_every == 0

        # issue phase.  Probe keys and _finish_recal's keys draw at the
        # chip's position in the walk, exactly as the sequential loop
        # drew them.  `pending` records every schedulable chip with the
        # repair-slot occupancy at its walk position.
        pending = []
        for chip in self.chips:
            chip.driver.advance(dt)

            if chip.offline:
                # injected outage: the board is unreachable — drift
                # still walks (the clock above is physical time), but no
                # probe frame can go out and an in-flight repair job
                # stalls where it stood until the outage lifts
                chip.offline_ticks_left -= 1
                if not chip.offline:
                    self.events.append(dict(tick=self.tick_count,
                                            event="outage_end",
                                            chip=chip.chip_id))
                continue

            if chip.status == RECALIBRATING:
                chip.recal_ticks_left -= 1
                if chip.recal_ticks_left <= 0:
                    self._finish_recal(chip)
                    in_repair -= 1
                continue

            x = fut = None
            if probe_due:
                x = jax.random.normal(self._next_key(),
                                      (cfg.monitor.n_probes, chip.driver.k))
                fut = chip.driver.run_batch_async(
                    [("forward", dict(x=x, category="probe"))])
            pending.append((chip, in_repair, x, fut))

        # collect phase: resolve + score in issue order, then run repair
        # scheduling over the scored fleet.  Scoring only mutates the
        # scored chip's own health and scheduling draws no PRNG keys, so
        # splitting the two sub-phases keeps PRNG streams, health
        # decisions, and repair choices bit-identical to the historical
        # interleaved walk — and hands subclasses a fleet-wide view
        # (every probe landed) to schedule against.
        for chip, _, x, fut in pending:
            if fut is not None:
                self._score_probe(chip, x, fut.result()[0])
        self._schedule_repairs(pending)

    def _schedule_repairs(
            self, pending: "list[tuple[Chip, int, object, object]]") -> None:
        """Reactive (alarm-driven) repair scheduling — the policy seam
        the autopilot overrides.  Walks chips in issue order; a chip's
        decision replays the sequential walk's slot count (its
        issue-phase occupancy plus repairs scheduled ahead of it), and
        the worst alarmed tenant wins the chip's repair window."""
        cfg = self.cfg
        scheduled = 0
        for chip, base_repair, _, _ in pending:
            if (chip.alarmed and self.recal_enabled
                    and base_repair + scheduled < cfg.max_concurrent_recals):
                # repair the worst alarmed tenant; others re-queue after
                alarmed = [t for t in chip.tenants if t.health.alarmed]
                worst = max(alarmed, key=lambda t: t.health.distance)
                self._start_recal(chip, worst)
                scheduled += 1

    def _start_recal(self, chip: Chip, tenant: Tenant,
                     proactive: bool = False) -> None:
        """Commit one repair window: the chip leaves the routable pool
        for ``cfg.recal_latency`` ticks, after which ``_finish_recal``
        re-tunes ``tenant`` (plus up to ``repair_batch − 1`` other
        alarmed co-tenants)."""
        chip.status = RECALIBRATING
        chip.recal_tenant = tenant.tenant_id
        chip.recal_proactive = proactive
        chip.recal_ticks_left = self.cfg.recal_latency
        ev = dict(tick=self.tick_count, event="recal_start",
                  chip=chip.chip_id, tenant=tenant.tenant_id)
        if proactive:
            ev["proactive"] = True
        self.events.append(ev)

    def inject_outage(self, chip_id: int, ticks: int) -> None:
        """Fault injection (benchmark/chaos use): take one chip off the
        network for ``ticks`` ticks — unroutable, unprobeable, repairs
        stalled; drift keeps walking underneath."""
        chip = next(c for c in self.chips if c.chip_id == chip_id)
        chip.offline_ticks_left = max(chip.offline_ticks_left, int(ticks))
        self.events.append(dict(tick=self.tick_count, event="outage",
                                chip=chip_id, ticks=int(ticks)))

    def _score_probe(self, chip: Chip, x: jax.Array, y_hat) -> None:
        """Fold one resolved probe response into tenant health: the
        shared stream ``x`` is scored per tenant (B·n_probes PTC calls
        total, charged at issue — same light as a whole-chip check).
        On stream transports the issued frame was ONE batched RPC: the
        probe forward flushed the pipelined clock advances queued by
        :meth:`tick` in the same wire frame."""
        cfg = self.cfg
        ests = score_tenant_probes(
            x, y_hat, [(t.block_range, t.w_blocks) for t in chip.tenants])
        for ten, est in zip(chip.tenants, ests):
            was_alarmed = ten.health.alarmed
            # dt feeds the EWMA degradation-rate track only — alarm
            # decisions are bit-identical to the dt-less signature
            ten.health = update_health(ten.health, float(est), cfg.monitor,
                                       dt=self.tick_count
                                       - ten.last_probe_tick)
            ten.last_probe_tick = self.tick_count
            if ten.health.alarmed and not was_alarmed:
                ten.alarms += 1
                chip.alarms += 1
                chip.status = DEGRADED
                self.events.append(dict(tick=self.tick_count, event="alarm",
                                        chip=chip.chip_id,
                                        tenant=ten.tenant_id,
                                        distance=ten.health.distance))

    def _finish_recal(self, chip: Chip) -> None:
        """The out-of-band job lands: partial recalibration of the
        alarmed tenant's block range against the chip's current
        (post-latency) drifted state, then a scoped re-probe to clear.
        Co-resident tenants' commanded state is untouched.

        With ``cfg.repair_batch > 1`` the outage is amortized: up to
        that many *currently alarmed* tenants (worst probe distance
        first, the scheduled tenant always included) are re-tuned
        before the chip returns to service — one chip outage refreshes
        every drifted layer of a served model instead of cycling
        through 14 separate repair windows while the rest keep
        drifting."""
        cfg = self.cfg
        first = chip.tenants[chip.recal_tenant or 0]
        others = sorted((t for t in chip.tenants
                         if t.health.alarmed and t is not first),
                        key=lambda t: -t.health.distance)
        for ten in (first, *others[:max(0, cfg.repair_batch - 1)]):
            res = recalibrate(self._next_key(), chip.driver, ten.w_blocks,
                              cfg.recal, dist_hint=ten.health.distance,
                              block_range=ten.block_range)
            ten.recals += 1
            chip.recals += 1
            ten.recal_calls += res.ptc_calls
            chip.recal_calls += res.ptc_calls
            est = probe_mapping_distance(self._next_key(), chip.driver,
                                         ten.w_blocks, cfg.monitor.n_probes,
                                         block_range=ten.block_range)
            ten.health = clear_health(ten.health, float(est), cfg.monitor)
            ten.last_probe_tick = self.tick_count
            ev = dict(
                tick=self.tick_count, event="recal_done", chip=chip.chip_id,
                tenant=ten.tenant_id,
                dist_before=float(res.dist_before),
                dist_after=float(res.dist_after), zo_steps=res.zo_steps,
                status=RECALIBRATING)
            if chip.recal_proactive:
                ev["proactive"] = True
            self.events.append(ev)
        chip.status = HEALTHY if not chip.alarmed else DEGRADED
        chip.recal_tenant = None
        chip.recal_proactive = False
        self.events[-1]["status"] = chip.status

    # -- reporting ----------------------------------------------------------

    def true_distances(self) -> list[float]:
        """Exact per-chip mapping distances (all tenants aggregated) — a
        twin-only readout routed through the audited
        ``driver.unsafe_twin()`` escape hatch (benchmark/diagnostic use;
        raises TwinUnavailable on real HW)."""
        return [c.driver.unsafe_twin().true_mapping_distance(c.w_blocks)
                for c in self.chips]

    def true_tenant_distances(self) -> list[list[float]]:
        """Exact per-(chip, tenant) mapping distances — twin-only, same
        escape hatch as :meth:`true_distances`."""
        return [[c.driver.unsafe_twin().true_mapping_distance(t.w_blocks, t.block_range)
                 for t in c.tenants] for c in self.chips]

    def report(self) -> dict:
        chips = []
        for c in self.chips:
            s = c.driver.stats
            # everything the driver metered that is neither serve traffic
            # nor a recal job's delta is monitor probing (incl. the PM
            # deployment readout)
            chips.append(dict(
                chip=c.chip_id, status=c.status, offline=c.offline,
                served=c.served,
                distance=max(t.health.distance for t in c.tenants),
                alarms=c.alarms, recals=c.recals,
                probe_ptc_calls=s.total - s.serve - c.recal_calls,
                recal_ptc_calls=c.recal_calls,
                serve_ptc_calls=s.serve,
                ptc_calls=s.as_dict(),
                tenants=[dict(tenant=t.tenant_id,
                              block_range=list(t.block_range),
                              m=t.m, n=t.n, served=t.served,
                              distance=t.health.distance,
                              alarmed=t.health.alarmed,
                              alarms=t.alarms, recals=t.recals,
                              recal_ptc_calls=t.recal_calls)
                         for t in c.tenants]))
        return dict(ticks=self.tick_count, dropped=self.dropped,
                    chips=chips, events=self.events)

    def close(self) -> None:
        """Release every chip's driver transport (subprocess servers).

        Every handle is closed even if one raises — chips parked
        mid-recalibration (or whose transport errors on shutdown) must
        not leak their server processes; failures are collected and
        re-raised once all handles have been attempted."""
        errors = []
        for c in self.chips:
            try:
                c.driver.close()
            except Exception as e:  # noqa: BLE001 - collect, close the rest
                errors.append(f"chip {c.chip_id}: {e!r}")
        if errors:
            raise RuntimeError("fleet close failed for " + "; ".join(errors))
