"""Closed-loop runtime demo: drift → alarm → recalibrate → recover.

    PYTHONPATH=src python -m repro.runtime.demo --chips 4 --steps 200
    PYTHONPATH=src python -m repro.runtime.demo --driver subprocess
    PYTHONPATH=src python -m repro.runtime.demo --tenants 3

Builds a fleet of N virtual chips (independent manufacturing draws of
the same mapped weight(s)), then runs the serving loop under phase
drift: every tick one batch is routed to a healthy chip while the
monitor probes fidelity out-of-band; alarms trigger warm-started
recalibration jobs that the router schedules around.  Prints the event
timeline and a summary showing (a) fidelity degrading under drift,
(b) alarms firing, (c) recalibration restoring the mapping distance
below the clear threshold, and (d) serving throughput uninterrupted
throughout.

``--tenants T`` time-multiplexes every chip across T mapped layers
(per-layer Σ banks on contiguous block ranges of one shared device).
Health is tracked per tenant, traffic round-robins across tenants, and
repair jobs are *partial*: only the alarmed tenant's blocks are
re-tuned, so the summary additionally shows co-resident tenants riding
through a neighbor's recalibration untouched.

``--driver subprocess`` runs every device out-of-process behind the
JSON-over-pipe :class:`~repro.hw.subprocess_driver.SubprocessDriver` —
the hardware-in-the-loop topology — and the same loop closes unchanged.

``simulate`` is the library entry point ``benchmarks/drift_recovery.py``
reuses for the closed- vs. open-loop recovery curves.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..core.noise import DEFAULT_NOISE
from ..hw import DriftConfig
from .monitor import MonitorConfig
from .recalibrate import RecalConfig
from .fleet import RuntimeConfig, make_fleet, make_router, RECALIBRATING

__all__ = ["simulate", "default_runtime_config", "main"]


def default_runtime_config(k: int = 6, sigma_drift: float = 0.015,
                           probe_every: int = 10,
                           zo_steps: int = 400,
                           driver_kind: str = "twin",
                           auto_budget: bool = False,
                           router_policy: str = "drift_aware",
                           autopilot=None) -> RuntimeConfig:
    """Demo-scale policy: drift crosses the alarm threshold within a few
    probe periods; a short warm-started recal restores ~initial error.
    ``autopilot``: an :class:`~repro.runtime.autopilot.AutopilotConfig`
    switches the fleet to forecast-driven maintenance scheduling."""
    monitor = MonitorConfig(n_probes=6, alarm_threshold=0.05,
                            clear_threshold=0.02, consecutive=2)
    return RuntimeConfig(
        k=k,
        noise=DEFAULT_NOISE.post_ic(),
        drift=DriftConfig(sigma_phase=sigma_drift, theta=0.01),
        monitor=monitor,
        # the historical 0.05/1.05 schedule, pinned: the demo/benchmark
        # artifacts (BENCH_drift_recovery et al.) are seeded against it;
        # RecalConfig's own default moved to the gentler 0.02/1.02
        recal=RecalConfig(zo_steps=zo_steps, delta0=0.05, decay=1.05,
                          auto_budget=auto_budget,
                          auto_target=monitor.clear_threshold),
        probe_every=probe_every,
        recal_latency=4,
        max_concurrent_recals=1,
        driver_kind=driver_kind,
        router_policy=router_policy,
        autopilot=autopilot,
    )


def _make_weights(key: jax.Array, dim: int, tenants: int) -> list[jax.Array]:
    """Per-tenant logical weights (one for the single-tenant case, the
    historical seed path)."""
    scale = jnp.sqrt(jnp.asarray(dim, jnp.float32))
    if tenants == 1:
        return [jax.random.normal(key, (dim, dim)) / scale]
    return [jax.random.normal(jax.random.fold_in(key, i), (dim, dim)) / scale
            for i in range(tenants)]


def simulate(n_chips: int, steps: int, *, dim: int = 18, batch: int = 8,
             seed: int = 0, cfg: RuntimeConfig | None = None,
             tenants: int = 1, recal_enabled: bool = True,
             verbose: bool = False) -> dict:
    """Run the closed (or open) loop and record the trajectory.

    Returns a dict with per-tick traces (``t``, ``max_dist``,
    ``mean_dist``, ``serve_err``, ``n_recalibrating``, plus
    ``tenant_dist`` — per-(chip, tenant) true distances) and the
    router's final report — everything the recovery benchmarks need.
    Traffic round-robins across tenants: tick ``t`` serves tenant
    ``t % tenants``.
    """
    cfg = cfg or default_runtime_config()
    kw, kf, kx = jax.random.split(jax.random.PRNGKey(seed), 3)
    weights = _make_weights(kw, dim, tenants)
    chips = make_fleet(kf, n_chips, weights if tenants > 1 else weights[0],
                       cfg)
    router = make_router(chips, cfg, seed=seed + 1,
                         recal_enabled=recal_enabled)

    trace = dict(t=[], max_dist=[], mean_dist=[], serve_err=[],
                 n_recalibrating=[], served_chip=[], served_tenant=[],
                 tenant_dist=[])
    n_events = 0
    try:
        for t in range(1, steps + 1):
            tenant = (t - 1) % tenants
            x = jax.random.normal(jax.random.fold_in(kx, t), (batch, dim))
            y, chip_id = router.serve(x, tenant=tenant)
            if y is not None:
                y_ref = x @ weights[tenant].T
                err = float(jnp.sum((y - y_ref) ** 2) /
                            (jnp.sum(y_ref ** 2) + 1e-12))
            else:
                err = float("nan")
            router.tick()

            dists = router.true_distances()
            trace["t"].append(t)
            trace["max_dist"].append(max(dists))
            trace["mean_dist"].append(sum(dists) / len(dists))
            trace["serve_err"].append(err)
            trace["n_recalibrating"].append(
                sum(c.status == RECALIBRATING for c in router.chips))
            trace["served_chip"].append(-1 if chip_id is None else chip_id)
            trace["served_tenant"].append(tenant)
            # single-tenant: the per-chip readout above IS the tenant
            # readout — don't pay (or RPC) the same exact readout twice
            trace["tenant_dist"].append(
                [[d] for d in dists] if tenants == 1
                else router.true_tenant_distances())

            if verbose:
                for ev in router.events[n_events:]:
                    print(f"[t={ev['tick']:4d}] {_fmt_event(ev)}")
                n_events = len(router.events)

        report = router.report()
    finally:
        router.close()
    return dict(trace=trace, report=report, config=dict(
        chips=n_chips, steps=steps, dim=dim, batch=batch, seed=seed,
        tenants=tenants, recal_enabled=recal_enabled, k=cfg.k,
        alarm_threshold=cfg.monitor.alarm_threshold,
        clear_threshold=cfg.monitor.clear_threshold,
        sigma_drift=cfg.drift.sigma_phase,
        driver=cfg.driver_kind, router_policy=cfg.router_policy,
        auto_budget=cfg.recal.auto_budget))


def cotenant_shifts(trace: dict, events: list[dict],
                    recal_latency: int) -> list[dict]:
    """For each completed recal, how far every co-resident tenant's TRUE
    distance moved across the repair window (job start → job done).

    The partial-recal invariant says co-tenants' commanded state is
    untouched; their true distance can still move by natural drift over
    the window, so the shift should sit within the per-window drift
    noise — this is the quantity the multi-tenant benchmark bounds.
    """
    out = []
    td = trace["tenant_dist"]
    for ev in events:
        if ev["event"] != "recal_done":
            continue
        t_done = ev["tick"] - 1                      # trace index of done
        t_start = max(0, t_done - recal_latency)     # ≈ job-start index
        chip = ev["chip"]
        n_tenants = len(td[t_done][chip])
        for j in range(n_tenants):
            if j == ev.get("tenant", 0):
                continue
            out.append(dict(
                tick=ev["tick"], chip=chip, recal_tenant=ev.get("tenant", 0),
                cotenant=j, dist_pre=td[t_start][chip][j],
                dist_post=td[t_done][chip][j],
                shift=td[t_done][chip][j] - td[t_start][chip][j]))
    return out


def isolation_band(noise: float, fallback: float) -> float:
    """Co-tenant shift tolerance from the empirical drift noise: both
    the worst co-tenant shift and the worst repair-free shift are maxima
    of the same drift distribution, so allow 2× headroom; fall back to
    ``fallback`` when no repair-free window existed to estimate from."""
    return 2.0 * noise + 1e-3 if noise > 0 else fallback


def drift_noise_band(trace: dict, events: list[dict],
                     recal_latency: int) -> float:
    """Largest |Δ true distance| over any repair-free window of
    ``recal_latency`` ticks, across every (chip, tenant) — the natural
    per-window drift scale co-tenant shifts are judged against."""
    td = trace["tenant_dist"]
    done = {(ev["chip"], ev["tick"]) for ev in events
            if ev["event"] == "recal_done"}
    worst = 0.0
    for t_start in range(0, len(td) - recal_latency):
        t_done = t_start + recal_latency
        for chip in range(len(td[0])):
            if any((chip, tk) in done
                   for tk in range(t_start + 2, t_done + 2)):
                continue        # a repair landed on this chip this window
            for j in range(len(td[t_start][chip])):
                shift = abs(td[t_done][chip][j] - td[t_start][chip][j])
                worst = max(worst, shift)
    return worst


def _fmt_event(ev: dict) -> str:
    ten = f".t{ev['tenant']}" if ev.get("tenant") is not None else ""
    if ev["event"] == "alarm":
        return (f"ALARM chip {ev['chip']}{ten}: probe distance "
                f"{ev['distance']:.4f} above threshold")
    if ev["event"] == "outage":
        return f"OUTAGE chip {ev['chip']}: offline for {ev['ticks']} ticks"
    if ev["event"] == "outage_end":
        return f"OUTAGE chip {ev['chip']}: back online"
    if ev["event"] == "recal_start":
        kind = "proactive" if ev.get("proactive") else "partial"
        return (f"RECAL chip {ev['chip']}{ten}: {kind} job scheduled "
                f"(chip unroutable)")
    kind = " (proactive)" if ev.get("proactive") else ""
    return (f"RECAL chip {ev['chip']}{ten} done{kind}: distance "
            f"{ev['dist_before']:.4f} → {ev['dist_after']:.4f} "
            f"({ev['zo_steps']} ZO steps) [{ev['status']}]")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--chips", type=int, default=4)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=18)
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sigma-drift", type=float, default=0.015)
    ap.add_argument("--probe-every", type=int, default=10)
    ap.add_argument("--zo-steps", type=int, default=400)
    ap.add_argument("--tenants", type=int, default=1,
                    help="mapped layers time-sharing each chip "
                         "(per-layer Σ banks + partial recalibration)")
    ap.add_argument("--driver", default="twin",
                    choices=["twin", "subprocess", "socket"],
                    help="device transport: in-process twin, "
                         "JSON-over-pipe out-of-process twin (HIL "
                         "shape), or the same protocol over TCP")
    ap.add_argument("--policy", default="drift_aware",
                    choices=["drift_aware", "accuracy_aware",
                             "least_served"],
                    help="dispatch ranking policy")
    ap.add_argument("--auto-budget", action="store_true",
                    help="autotune recal ZO steps from d̂ at alarm time")
    ap.add_argument("--no-recal", action="store_true",
                    help="open-loop baseline: alarms fire, nothing recovers")
    ap.add_argument("--autopilot", action="store_true",
                    help="forecast-driven maintenance: proactive recals "
                         "before predicted alarm crossings, degradation-"
                         "rate repair priority (runtime/autopilot.py)")
    ap.add_argument("--ap-horizon", type=int, default=40,
                    help="autopilot: proactive window in ticks")
    ap.add_argument("--ap-trough", type=float, default=0.5,
                    help="autopilot: load forecast at/below this counts "
                         "as a trough")
    ap.add_argument("--ap-budget", type=float, default=None,
                    help="autopilot: recal PTC-call envelope per window "
                         "(default: unlimited)")
    ap.add_argument("--ap-window", type=int, default=200,
                    help="autopilot: budget window in ticks")
    args = ap.parse_args(argv)

    autopilot = None
    if args.autopilot:
        from .autopilot import AutopilotConfig
        autopilot = AutopilotConfig(
            horizon=args.ap_horizon, trough_load=args.ap_trough,
            budget_calls=(float("inf") if args.ap_budget is None
                          else args.ap_budget),
            budget_window=args.ap_window)
    cfg = default_runtime_config(k=args.k, sigma_drift=args.sigma_drift,
                                 probe_every=args.probe_every,
                                 zo_steps=args.zo_steps,
                                 driver_kind=args.driver,
                                 auto_budget=args.auto_budget,
                                 router_policy=args.policy,
                                 autopilot=autopilot)
    out = simulate(args.chips, args.steps, dim=args.dim, batch=args.batch,
                   seed=args.seed, cfg=cfg, tenants=args.tenants,
                   recal_enabled=not args.no_recal, verbose=True)
    trace, report = out["trace"], out["report"]

    peak = max(trace["max_dist"])
    final = trace["max_dist"][-1]
    alarms = sum(c["alarms"] for c in report["chips"])
    recals = sum(c["recals"] for c in report["chips"])
    recovered = [ev for ev in report["events"]
                 if ev["event"] == "recal_done"
                 and ev["dist_after"] < cfg.monitor.clear_threshold]
    served = sum(1 for c in trace["served_chip"] if c >= 0)
    probe_calls = sum(c["probe_ptc_calls"] for c in report["chips"])
    recal_calls = sum(c["recal_ptc_calls"] for c in report["chips"])
    serve_calls = sum(c["serve_ptc_calls"] for c in report["chips"])

    print(f"\n--- closed-loop summary ({args.driver} driver, "
          f"{args.tenants} tenant(s)/chip) ---")
    print(f"fidelity degraded under drift : peak distance {peak:.4f} "
          f"(alarm threshold {cfg.monitor.alarm_threshold})")
    print(f"alarms fired                  : {alarms} "
          f"(recal jobs completed: {recals})")
    print(f"recalibration recovered       : "
          f"{len(recovered)}/{recals} jobs below clear threshold "
          f"{cfg.monitor.clear_threshold}; final fleet max {final:.4f}")
    print(f"throughput uninterrupted      : {served}/{args.steps} batches "
          f"served, {report['dropped']} dropped")
    print(f"probe overhead                : {probe_calls:.0f} PTC calls "
          f"({100 * probe_calls / max(serve_calls, 1):.2f}% of serve path)")
    print(f"recal overhead (out-of-band)  : {recal_calls:.0f} PTC calls")
    ap_rep = report.get("autopilot")
    if ap_rep is not None:
        print(f"autopilot                     : "
              f"{ap_rep['proactive_recals']} proactive recals, "
              f"{ap_rep['deferred_trough']} deferred to troughs, "
              f"{ap_rep['deferred_budget']} deferred on budget")
    for c in report["chips"]:
        print(f"  chip {c['chip']}: {c['status']:<8} served={c['served']:4d} "
              f"d̂={c['distance']:.4f} alarms={c['alarms']} "
              f"recals={c['recals']}")
        if args.tenants > 1:
            for t in c["tenants"]:
                print(f"    tenant {t['tenant']} blocks"
                      f"{t['block_range']}: served={t['served']:4d} "
                      f"d̂={t['distance']:.4f} alarms={t['alarms']} "
                      f"recals={t['recals']}")

    cotenants_ok = True
    if args.tenants > 1 and not args.no_recal:
        shifts = cotenant_shifts(trace, report["events"], cfg.recal_latency)
        if shifts:
            worst = max(abs(s["shift"]) for s in shifts)
            # a partial recal must not cost co-tenants more than their
            # own per-window drift scale (they were never touched)
            noise = drift_noise_band(trace, report["events"],
                                     cfg.recal_latency)
            band = isolation_band(noise, cfg.monitor.clear_threshold)
            cotenants_ok = worst <= band
            print(f"partial-recal isolation       : {len(shifts)} co-tenant "
                  f"windows, worst |Δd| {worst:.4f} "
                  f"({'within' if cotenants_ok else 'OUTSIDE'} drift band "
                  f"{band:.4f})")

    degraded = peak > cfg.monitor.alarm_threshold
    if args.no_recal:
        ok = degraded and served == args.steps
    elif args.autopilot:
        # proactive maintenance may legitimately prevent every alarm —
        # require the loop to have *worked* (jobs ran and recovered),
        # not that it waited for the damage first
        ok = (recals > 0 and len(recovered) > 0
              and served == args.steps and cotenants_ok)
    else:
        ok = (degraded and alarms > 0 and recals > 0
              and len(recovered) > 0 and served == args.steps
              and cotenants_ok)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
