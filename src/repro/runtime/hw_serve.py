"""Hardware-in-the-loop serving: LM logits through routed chips' realized transfer.

Until now ``launch/serve.py --fleet`` used the photonic fleet for
health/routing *accounting* only — decode steps drove synthetic probe
traffic through a chip while the LM logits came from the pristine
digital model.  This module closes the gap the paper actually cares
about: the *served model's* PTC layers execute on the (drifting)
photonic hardware, so task accuracy — not just mapping distance — is
what the closed drift→alarm→recalibrate loop protects.

Shape of the plane
------------------
* **One tenant per PTC layer.**  :func:`record_ptc_layers` runs one
  digital decode step under a recording :func:`~repro.models.layers.
  ptc_execution` hook and enumerates every named PTC linear of the
  served model in call order (``p0.s0.attn.wq`` …), together with its
  effective dense weight ``W = U·diag(Σ)·V*`` (cropped to the true
  ``(m, n)``).  :class:`HwServePlane` then deploys that whole layer
  list onto each fleet chip via ``core.mapping.parallel_map(
  block_range=)`` — the existing multi-tenant machinery: layer *j* is
  tenant *j*, owning a contiguous block range and its Σ bank, with its
  own health/alarm state and *partial* recalibration.
* **Whole-pass routing.**  Each decode step is routed as one unit:
  ``FleetRouter.route_pass`` picks a single chip for all tenant slots
  (ranked by the worst forecast tenant fidelity), drift advances
  between steps, and health probes / repair jobs run out-of-band
  exactly as before.  While a chip is mid-recalibration the pass fails
  over to another chip; if *no* chip is routable the step falls back to
  the deployment-time shadow transfer (counted, never silent).
* **Batched execution.**  Sibling projections that consume the same
  activations (``wq``/``wk``/``wv``; ``gate``/``up``) ship as ONE v3
  ``batch`` frame via ``FleetRouter.serve_pass`` — with the pipelined
  clock advances flushing ahead inside the same frame, a decode step
  costs O(1) round-trips per (chip, layer-group) on every transport.
* **Shadow twin.**  At deployment the plane reads back each tenant's
  realized transfer through the observability-legal driver surface
  (``readback_bases`` + commanded Σ) and keeps the assembled dense
  ``Ŵ_j``.  ``mode="shadow"`` serves from these digitally — the
  "digital twin of the deployed chip" reference path: at σ_drift = 0
  the routed and shadow paths apply the *same* realized transfer (the
  device never moves), so greedy decode is token-identical — the
  conformance gate ``tests/test_hw_serve.py`` locks across all three
  transports (whose routed logits are mutually bit-identical).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ptc import PTCParams, compose_weight, unblockize
from .fleet import RuntimeConfig, make_fleet, make_router

__all__ = ["PTCLayerSpec", "record_ptc_layers", "HwServePlane"]


@dataclasses.dataclass
class PTCLayerSpec:
    """One PTC linear of the served model = one fleet tenant."""

    index: int                 # tenant index (call order within a step)
    name: str                  # qualified scope name, e.g. "p0.s0.attn.wq"
    m: int                     # output dim the call site consumes
    n: int                     # input dim the call site supplies
    w: np.ndarray              # effective dense weight (m, n), float32
    group: Optional[str] = None   # sibling group sharing one input


def _effective_weight(p: dict, x, d_out: int | None) -> tuple[int, int, np.ndarray]:
    """(m, n, W) for a factored PTC param dict at one call site.

    ``W`` is exactly the matrix the digital path applies: the composed
    ``U·diag(Σ)·V*`` blocks (Σ cast to the bases' dtype, as
    ``apply_ptc_linear`` does), cropped to the call's true output dim
    and the un-padded input dim — the zero-padded rows/cols the block
    grid carries never touch data."""
    params = PTCParams(u=p["u"], s=p["s"].astype(p["u"].dtype), v=p["v"])
    w_full = unblockize(compose_weight(params))
    n = int(x.shape[-1])
    m = int(d_out) if d_out is not None else int(w_full.shape[0])
    return m, n, np.asarray(w_full[:m, :n], np.float32)


def _sibling_group(name: str) -> Optional[str]:
    """Sibling-group id for layers that consume the same activations.

    Self-attention's q/k/v projections all read the same normed hidden
    state, as do the MLP gate/up pair — those execute as one batched
    driver frame.  Cross-attention is the exception: ``wq`` reads the
    decoder state while ``wk``/``wv`` read the encoder/vision stream,
    so only the k/v pair groups there."""
    scope, _, leaf = name.rpartition(".")
    cross = scope.endswith(".cross")
    if leaf in ("wq", "wk", "wv") and not cross:
        return f"{scope}.qkv"
    if leaf in ("wk", "wv") and cross:
        return f"{scope}.kv"
    if leaf in ("gate", "up"):
        return f"{scope}.gateup"
    return None


def record_ptc_layers(serve_step, params, cache, batch) -> list[PTCLayerSpec]:
    """Enumerate the decode path's PTC layers by running ONE digital
    step under a recording hook.  Call order is deterministic (the
    decode body is a static python loop when unrolled), so the returned
    indices double as tenant indices."""
    from ..models.layers import ptc_execution

    recorded: list[PTCLayerSpec] = []
    seen: dict[str, int] = {}

    def recorder(name, p, x, cfg, d_out):
        if name in seen:               # decode calls each layer once/step
            raise RuntimeError(
                f"PTC layer {name!r} executed twice in one decode step — "
                f"layer names must be unique for tenant placement")
        seen[name] = len(recorded)
        m, n, w = _effective_weight(p, x, d_out)
        recorded.append(PTCLayerSpec(index=len(recorded), name=name,
                                     m=m, n=n, w=w,
                                     group=_sibling_group(name)))
        return None                    # stay digital: this is a dry pass

    with ptc_execution(recorder):
        serve_step(params, cache, batch)
    if not recorded:
        raise ValueError(
            "served model exposes no named PTC layers on its decode path "
            "(dense mode, or an un-scoped architecture)")
    return recorded


class HwServePlane:
    """The serving-side execution plane: model PTC layers on fleet chips.

    Install :attr:`hook` with ``models.layers.ptc_execution`` around the
    decode loop and wrap each step in :meth:`step` (``launch/steps.
    greedy_decode(layer_exec=...)`` does both).  ``mode``:

    * ``"route"``  — layer matmuls execute on the routed chip's realized
      (drifted) transfer via ``driver.forward_layer``;
    * ``"shadow"`` — same deployment, but matmuls apply the deployment-
      time readback ``Ŵ_j`` digitally: the twin-path reference the
      σ_drift = 0 token-identity gate compares against.
    """

    def __init__(self, key: jax.Array, layers: Sequence[PTCLayerSpec],
                 cfg: RuntimeConfig, n_chips: int, *, mode: str = "route",
                 seed: int = 0, recal_enabled: bool = True):
        if mode not in ("route", "shadow"):
            raise ValueError(f"unknown hw serve mode: {mode!r}")
        self.mode = mode
        self.layers = list(layers)
        self._by_name = {s.name: s for s in self.layers}
        self._groups: dict[str, list[PTCLayerSpec]] = {}
        for s in self.layers:
            if s.group is not None:
                self._groups.setdefault(s.group, []).append(s)
        chips = make_fleet(key, n_chips, [s.w for s in self.layers], cfg)
        # factory seam: cfg.autopilot selects the forecast-driven
        # AutopilotRouter; with it unset this IS the historical
        # FleetRouter, bit-identical
        self.router = make_router(chips, cfg, seed=seed,
                                  recal_enabled=recal_enabled)
        if cfg.router_policy == "accuracy_aware":
            from .autopilot import logit_sensitivity
            self.router.set_sensitivity(
                logit_sensitivity([s.w for s in self.layers]))
        # deployment-time shadow: the realized transfer of the reference
        # chip, read back through the observability-legal surface — one
        # commanded-Σ read plus ONE batch frame of per-tenant basis
        # readbacks (not 2 round-trips per layer)
        sigma = np.asarray(chips[0].driver.read_sigma())
        bases = chips[0].driver.run_batch(
            [("readback_bases", dict(block_range=t.block_range))
             for t in chips[0].tenants])
        self._shadow = [
            self._assemble_transfer(spec, u, v,
                                    sigma[t.block_range[0]:t.block_range[1]],
                                    chips[0].driver.k)
            for spec, t, (u, v) in zip(self.layers, chips[0].tenants, bases)]
        # per-step state
        self._chip = None
        self._valid: Optional[np.ndarray] = None
        self._group_cache: dict[tuple[str, str], tuple[np.ndarray, jax.Array]] = {}
        self.steps = 0
        self.frames = 0            # driver round-trips spent on layer math
        self.frame_cols = 0        # Σ activation columns shipped in frames
        self.hw_calls = 0          # layer matmuls served by a chip
        self.shadow_calls = 0      # layer matmuls served by the shadow
        self.dropped_passes = 0    # steps with no routable chip

    @staticmethod
    def _assemble_transfer(spec: PTCLayerSpec, u, v, sigma: np.ndarray,
                           k: int) -> np.ndarray:
        """Dense realized ``Ŵ`` of one tenant: reciprocal basis readback
        × commanded Σ, assembled and cropped like the digital weight."""
        wb = (np.asarray(u) * sigma[:, None, :]) @ np.asarray(v)   # (b, k, k)
        p = -(-spec.m // k)
        q = wb.shape[0] // p
        grid = wb.reshape(p, q, k, k)
        dense = grid.transpose(0, 2, 1, 3).reshape(p * k, q * k)
        return np.asarray(dense[:spec.m, :spec.n], np.float32)

    def observe_load(self, load: float) -> None:
        """Forward the serving gateway's occupancy signal (active slots
        plus queue depth, over slot capacity) to the router's load
        forecast — the autopilot schedules proactive maintenance into
        the troughs this traces out; the reactive router ignores it."""
        self.router.observe_load(load)

    # -- decode-loop surface -------------------------------------------------

    @contextlib.contextmanager
    def step(self, i: int, valid: Optional[np.ndarray] = None):
        """One decode step: route the whole pass to one chip, serve it,
        then let virtual time pass (drift advances, probes/repairs run
        out-of-band).  With no routable chip the step's layers fall
        back to the shadow transfer and the pass counts as dropped.

        ``valid`` (chunked prefill): a (B, C) bool mask of real
        activation columns in this step's (B, C, d) wide frames.  The
        hook ships only the valid columns to the chip — decode_batch +
        Σ chunk_lens rows per frame instead of B·C — and scatters the
        results back, zero-filling the padding columns (which per-column
        sublayers and the position-masked attention never read)."""
        self._group_cache.clear()
        self._chip = None
        self._valid = (np.asarray(valid, bool) if valid is not None
                       else None)
        if self.mode == "route":
            self._chip = self.router.route_pass()
            if self._chip is None:
                self.dropped_passes += 1
        try:
            yield
        finally:
            self._group_cache.clear()
            self._chip = None
            self._valid = None
            self.router.tick()
            self.steps += 1

    def hook(self, name: str, p, x, cfg, d_out):
        """``models.layers.ptc_execution`` hook: execute one PTC layer
        on the plane.  Unknown names stay digital (return None)."""
        spec = self._by_name.get(name)
        if spec is None:
            return None
        if self._chip is None:         # shadow mode, or no routable chip
            self.shadow_calls += 1
            w = jnp.asarray(self._shadow[spec.index])
            return (x.astype(jnp.float32) @ w.T).astype(x.dtype)
        if spec.group is not None:
            hit = self._group_cache.pop((spec.group, name), None)
            if hit is not None:
                x_ref, y = hit
                if np.array_equal(x_ref, np.asarray(x)):
                    return y
                # speculative sibling result computed on different
                # activations: drop the whole group, execute singly
                for s in self._groups[spec.group]:
                    self._group_cache.pop((spec.group, s.name), None)
        members = [spec]
        if spec.group is not None and not any(
                (spec.group, s.name) in self._group_cache
                for s in self._groups[spec.group]):
            members = self._groups[spec.group]
        x_np = np.asarray(x)
        xs, mask = x, None
        if (self._valid is not None and x_np.ndim == 3
                and x_np.shape[:2] == self._valid.shape):
            # wide prefill frame: ship only the real activation columns
            mask = self._valid.reshape(-1)
            xs = jnp.asarray(x_np.reshape(-1, x_np.shape[-1])[mask])
        ys = self.router.serve_pass(self._chip,
                                    [(s.index, xs) for s in members])
        self.frames += 1
        self.frame_cols += int(np.prod(np.asarray(xs.shape[:-1])))
        self.hw_calls += len(members)
        out = None
        for s, y in zip(members, ys):
            y = jnp.asarray(y).astype(x.dtype)
            if mask is not None:
                full = jnp.zeros((mask.size, y.shape[-1]), y.dtype)
                full = full.at[jnp.asarray(np.flatnonzero(mask))].set(y)
                y = full.reshape(x_np.shape[0], x_np.shape[1], y.shape[-1])
            if s.name == name:
                out = y
            else:
                self._group_cache[(spec.group, s.name)] = (x_np, y)
        return out

    # -- reporting / lifecycle -----------------------------------------------

    def report(self) -> dict:
        rep = self.router.report()
        rep["hw"] = dict(
            mode=self.mode,
            layers=[dict(tenant=s.index, name=s.name, m=s.m, n=s.n,
                         group=s.group) for s in self.layers],
            steps=self.steps, frames=self.frames,
            frames_per_step=self.frames / max(1, self.steps),
            frame_cols=self.frame_cols,
            cols_per_frame=self.frame_cols / max(1, self.frames),
            hw_calls=self.hw_calls, shadow_calls=self.shadow_calls,
            dropped_passes=self.dropped_passes)
        return rep

    def close(self) -> None:
        self.router.close()
