"""``--self-test``: prove every rule fires, and stays quiet when clean.

Mirrors ``benchmarks/check_regression.py --self-test``: a gate that
cannot demonstrate it would catch the failure it exists for is not a
gate.  For each rule code we materialize a minimal fixture tree with
exactly one injected violation, run the engine over it, and require the
code to fire there — and *not* to fire on the corresponding clean twin.
CI runs this before linting the real tree, so a rule silently broken by
refactoring fails the build even when the tree itself is clean.
"""

from __future__ import annotations

import dataclasses
import os
import tempfile

from .engine import all_rules, run_lint

__all__ = ["CASES", "run_self_test"]


@dataclasses.dataclass(frozen=True)
class Case:
    code: str
    bad: dict      # rel path -> source with exactly one violation
    clean: dict    # rel path -> source that must not fire the code


# --- wire-protocol fixture trio -------------------------------------
# The RPL2xx rules locate repro.hw.{driver,server,stream_driver} by
# module name inside the corpus, so fixtures carry the same layout.

def _trio(ops, server_ops, client_ops, server_extra="", pipelined=(),
          merge_ops=(), wire_internal=()):
    """A minimal protocol trio.  ``server_ops``/``client_ops`` map
    op -> payload keys (server: read hard; client: encoded).  Ops in
    ``merge_ops`` emit via the v4 handshake idiom — a ``base =
    dict(...)`` payload re-sent as ``self._exec(op, dict(base, v=4))``
    — so the self-test proves RPL204 sees through the merge form (the
    base keys count as sent, and a key dropped from the base is still
    caught)."""
    driver = ("BATCHABLE_OPS = frozenset(%r)\n"
              "PIPELINED_OPS = frozenset(%r)\n"
              "WIRE_INTERNAL_OPS = frozenset(%r)\n"
              % (sorted(ops), sorted(pipelined), sorted(wire_internal)))
    branches = "".join(
        "    if op == %r:\n        return {%s}\n" % (
            op, ", ".join("%r: kw[%r]" % (k, k) for k in keys) or "'ok': 1")
        for op, keys in server_ops.items())
    server = ("def _dispatch(driver, op, kw):\n"
              + branches + server_extra
              + "    raise ValueError(op)\n")
    methods = "".join(
        ("    def %s(self, **kw):\n"
         "        base = dict(%s)\n"
         "        return self._exec(%r, dict(base, v=4))\n" % (
             op.replace("/", "_"),
             ", ".join("%s=kw[%r]" % (k, k) for k in keys), op))
        if op in merge_ops else
        ("    def %s(self, **kw):\n"
         "        return self._exec(%r, dict(%s))\n" % (
             op.replace("/", "_"), op,
             ", ".join("%s=kw[%r]" % (k, k) for k in keys)))
        for op, keys in client_ops.items())
    client = ("class StreamDriver:\n"
              "    def _exec(self, op, kw):\n"
              "        return (op, kw)\n" + methods)
    return {"repro/hw/driver.py": driver,
            "repro/hw/server.py": server,
            "repro/hw/stream_driver.py": client}


_WIRED = _trio({"ping"}, {"ping": ["x"]}, {"ping": ["x"]})
# v4-emitter twin: the client sends ping's payload through the
# dict(base, v=4) merge form; must lint exactly as clean as _WIRED
_WIRED_V4 = _trio({"ping"}, {"ping": ["x", "v"]}, {"ping": ["x"]},
                  merge_ops={"ping"})

CASES = [
    Case(
        "RPL101",
        bad={"repro/runtime/ctrl.py":
             "from ..hw.twin import make_twin\n"
             "def boot():\n    return make_twin\n"},
        clean={"repro/runtime/ctrl.py":
               "from ..hw import make_twin\n"
               "def boot():\n    return make_twin\n"},
    ),
    Case(
        "RPL102",
        bad={"repro/core/opt.py":
             "def probe(driver):\n    return driver.unsafe_twin()\n"},
        clean={"tests/test_opt.py":
               "def probe(driver):\n    return driver.unsafe_twin()\n"},
    ),
    Case(
        "RPL103",
        bad={"repro/core/opt.py":
             "def peek(hw):\n    return hw.realized_unitaries\n"},
        clean={"repro/core/opt.py":
               "def peek(driver):\n    return driver.read_phases()\n"},
    ),
    Case(
        "RPL201",
        bad=_trio({"ping", "ghost"}, {"ping": ["x"]},
                  {"ping": ["x"], "ghost": ["x"]}),
        clean=_WIRED,
    ),
    Case(
        "RPL202",
        bad=_trio({"ping", "ghost"}, {"ping": ["x"], "ghost": ["x"]},
                  {"ping": ["x"]}),
        clean=_WIRED,
    ),
    Case(
        "RPL203",
        bad=_trio({"ping"}, {"ping": ["x"], "rogue": []},
                  {"ping": ["x"], "rogue": []}),
        clean=_WIRED,
    ),
    Case(
        # RPL203 on the WIRE_INTERNAL_OPS surface: a declared
        # client-coalesced rewrite (v4's forward_many shape) is clean
        # when both the client emitter and server branch exist, and
        # caught when only one end is wired
        "RPL203",
        bad=_trio({"ping"}, {"ping": ["x"], "merged": ["xs"]},
                  {"ping": ["x"]}, wire_internal={"merged"}),
        clean=_trio({"ping"}, {"ping": ["x"], "merged": ["xs"]},
                    {"ping": ["x"], "merged": ["xs"]},
                    wire_internal={"merged"}),
    ),
    Case(
        "RPL204",
        bad=_trio({"ping"}, {"ping": ["x", "y"]}, {"ping": ["x"]}),
        clean=_WIRED,
    ),
    Case(
        # RPL204 through the v4 dict(base, ...) merge emitter: the base
        # payload's keys count as sent (clean twin), and a hard server
        # key missing from the base is still caught (bad twin)
        "RPL204",
        bad=_trio({"ping"}, {"ping": ["x", "y", "v"]}, {"ping": ["x"]},
                  merge_ops={"ping"}),
        clean=_WIRED_V4,
    ),
    Case(
        "RPL301",
        bad={"repro/runtime/step.py":
             "import time\nimport jax\n"
             "def f(x):\n    return x + time.time()\n"
             "g = jax.jit(f)\n"},
        clean={"repro/runtime/step.py":
               "import time\nimport jax\n"
               "def f(x):\n    return x * 2\n"
               "g = jax.jit(f)\n"
               "t0 = time.time()\n"},
    ),
    Case(
        "RPL302",
        bad={"repro/runtime/step.py":
             "import jax\n"
             "from ..models.layers import ptc_execution\n"
             "def decode(m, x, driver):\n"
             "    with ptc_execution(m, driver):\n"
             "        return m(x)\n"
             "g = jax.jit(decode)\n"},
        clean={"repro/runtime/step.py":
               "import jax\n"
               "from ..models.layers import ptc_execution\n"
               "def decode(m, x, driver):\n"
               "    step = jax.jit(m)\n"
               "    with ptc_execution(m, driver):\n"
               "        return step(x)\n"},
    ),
    Case(
        "RPL401",
        bad={"repro/kernels/k.py":
             "import jax.experimental.pallas as pl\n"
             "def _kern(a_ref, o_ref):\n"
             "    o_ref[...] = a_ref[...]\n"
             "def run(a, b, s):\n"
             "    return pl.pallas_call(\n"
             "        _kern, grid=(4,),\n"
             "        in_specs=[pl.BlockSpec((8,), lambda i: i),\n"
             "                  pl.BlockSpec((8,), lambda i: i)],\n"
             "        out_specs=pl.BlockSpec((8,), lambda i: i),\n"
             "        out_shape=s)(a, b)\n"},
        clean={"repro/kernels/k.py":
               "import jax.experimental.pallas as pl\n"
               "def _kern(a_ref, b_ref, o_ref):\n"
               "    o_ref[...] = a_ref[...] + b_ref[...]\n"
               "def run(a, b, s):\n"
               "    return pl.pallas_call(\n"
               "        _kern, grid=(4,),\n"
               "        in_specs=[pl.BlockSpec((8,), lambda i: i),\n"
               "                  pl.BlockSpec((8,), lambda i: i)],\n"
               "        out_specs=pl.BlockSpec((8,), lambda i: i),\n"
               "        out_shape=s)(a, b)\n"},
    ),
    Case(
        # RPL401 on the chunked-prefill call shape: scalar-prefetch grid
        # spec + VMEM scratch operands.  The kernel's positional arity
        # must count prefetch refs + inputs + outputs + scratch refs;
        # the bad twin drops the scratch ref (the exact miswiring a
        # refactor of kernels/prefill_attn.py would introduce).
        "RPL401",
        bad={"repro/kernels/k.py":
             "import jax\n"
             "import jax.experimental.pallas as pl\n"
             "from jax.experimental.pallas import tpu as pltpu\n"
             "def _kern(lens_ref, q_ref, o_ref):\n"
             "    o_ref[...] = q_ref[...]\n"
             "def run(lens, q, s):\n"
             "    return pl.pallas_call(\n"
             "        _kern,\n"
             "        grid_spec=pltpu.PrefetchScalarGridSpec(\n"
             "            num_scalar_prefetch=1, grid=(2, 4),\n"
             "            in_specs=[pl.BlockSpec((1, 8),\n"
             "                                   lambda b, j, t: (b, 0))],\n"
             "            out_specs=pl.BlockSpec((1, 8),\n"
             "                                   lambda b, j, t: (b, 0)),\n"
             "            scratch_shapes=[pltpu.VMEM((8,),\n"
             "                                       jax.numpy.float32)]),\n"
             "        out_shape=s)(lens, q)\n"},
        clean={"repro/kernels/k.py":
               "import jax\n"
               "import jax.experimental.pallas as pl\n"
               "from jax.experimental.pallas import tpu as pltpu\n"
               "def _kern(lens_ref, q_ref, o_ref, acc_ref):\n"
               "    acc_ref[...] = q_ref[...]\n"
               "    o_ref[...] = acc_ref[...]\n"
               "def run(lens, q, s):\n"
               "    return pl.pallas_call(\n"
               "        _kern,\n"
               "        grid_spec=pltpu.PrefetchScalarGridSpec(\n"
               "            num_scalar_prefetch=1, grid=(2, 4),\n"
               "            in_specs=[pl.BlockSpec((1, 8),\n"
               "                                   lambda b, j, t: (b, 0))],\n"
               "            out_specs=pl.BlockSpec((1, 8),\n"
               "                                   lambda b, j, t: (b, 0)),\n"
               "            scratch_shapes=[pltpu.VMEM((8,),\n"
               "                                       jax.numpy.float32)]),\n"
               "        out_shape=s)(lens, q)\n"},
    ),
    Case(
        "RPL402",
        bad={"repro/kernels/k.py":
             "import jax.experimental.pallas as pl\n"
             "def _kern(a_ref, o_ref):\n"
             "    o_ref[...] = a_ref[...]\n"
             "def run(a, s):\n"
             "    return pl.pallas_call(\n"
             "        _kern, grid=(4, 4),\n"
             "        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],\n"
             "        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),\n"
             "        out_shape=s)(a)\n"},
        clean={"repro/kernels/k.py":
               "import jax.experimental.pallas as pl\n"
               "def _kern(a_ref, o_ref):\n"
               "    o_ref[...] = a_ref[...]\n"
               "def run(a, s):\n"
               "    return pl.pallas_call(\n"
               "        _kern, grid=(4, 4),\n"
               "        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, 0))],\n"
               "        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),\n"
               "        out_shape=s)(a)\n"},
    ),
    Case(
        "RPL403",
        bad={"repro/kernels/k.py":
             "import jax.experimental.pallas as pl\n"
             "def _kern(a_ref, o_ref):\n"
             "    o_ref[...] = a_ref[...]\n"
             "def run(a, s):\n"
             "    return pl.pallas_call(\n"
             "        _kern, grid=(4,),\n"
             "        in_specs=[pl.BlockSpec((8,), lambda i: i)],\n"
             "        out_specs=pl.BlockSpec((8,), lambda i: i),\n"
             "        out_shape=s,\n"
             "        input_output_aliases={3: 0})(a)\n"},
        clean={"repro/kernels/k.py":
               "import jax.experimental.pallas as pl\n"
               "def _kern(a_ref, o_ref):\n"
               "    o_ref[...] = a_ref[...]\n"
               "def run(a, s):\n"
               "    return pl.pallas_call(\n"
               "        _kern, grid=(4,),\n"
               "        in_specs=[pl.BlockSpec((8,), lambda i: i)],\n"
               "        out_specs=pl.BlockSpec((8,), lambda i: i),\n"
               "        out_shape=s,\n"
               "        input_output_aliases={0: 0})(a)\n"},
    ),
    Case(
        "RPL501",
        bad={"repro/runtime/seed.py":
             "import time\nimport numpy as np\n"
             "def make_rng():\n"
             "    return np.random.default_rng(int(time.time()))\n"},
        clean={"repro/runtime/seed.py":
               "import numpy as np\n"
               "def make_rng(seed):\n"
               "    return np.random.default_rng(seed)\n"},
    ),
    Case(
        "RPL502",
        bad={"repro/hw/frames.py":
             "def build(encode):\n"
             "    return [encode(op) for op in {'advance', 'charge'}]\n"},
        clean={"repro/hw/frames.py":
               "def build(encode):\n"
               "    return [encode(op)\n"
               "            for op in sorted({'advance', 'charge'})]\n"},
    ),
]


def _materialize(root: str, files: dict) -> None:
    for rel, text in files.items():
        full = os.path.join(root, rel)
        os.makedirs(os.path.dirname(full), exist_ok=True)
        with open(full, "w", encoding="utf-8") as fh:
            fh.write(text)


def _codes(result) -> set:
    return {f.code for f in result.findings}


def run_self_test(emit=print) -> bool:
    """Inject one violation per rule; return True iff every rule fired
    on its bad fixture and stayed quiet on its clean twin."""
    covered = {c.code for c in CASES}
    known = {r.code for r in all_rules()}
    ok = True
    for missing in sorted(known - covered):
        emit(f"FAIL {missing}: no self-test fixture for this rule")
        ok = False
    for case in CASES:
        if case.code not in known:
            emit(f"FAIL {case.code}: fixture for unknown rule")
            ok = False
            continue
        with tempfile.TemporaryDirectory(prefix="repro-lint-self-") as tmp:
            bad_root = os.path.join(tmp, "bad", "fixture")
            clean_root = os.path.join(tmp, "clean", "fixture")
            _materialize(bad_root, case.bad)
            _materialize(clean_root, case.clean)
            fired = case.code in _codes(run_lint([bad_root]))
            quiet = case.code not in _codes(run_lint([clean_root]))
        if fired and quiet:
            emit(f"ok   {case.code}: fires on injected violation, "
                 f"quiet on clean twin")
        else:
            detail = []
            if not fired:
                detail.append("did NOT fire on the injected violation")
            if not quiet:
                detail.append("fired on the clean twin")
            emit(f"FAIL {case.code}: " + "; ".join(detail))
            ok = False
    return ok
