"""RPL3xx — tracer-safety analyzers.

jax traces a function once and replays the recorded graph; anything
that is not a jax op executes at *trace time only* and its result is
baked into the graph as a constant.  A ``time.time()`` or global
``np.random.*`` draw inside a jitted function therefore "works" while
silently freezing one sample forever; ``.item()`` / ``float()`` on a
traced array raises a ConcretizationTypeError at best.

The sharpest instance in this repo is the ``ptc_execution`` hook
(``models/layers.py``): the hook dispatch is tracer-guarded, so a
hooked model called under jit/scan/vmap *silently stays digital* — the
exact failure mode that would turn "hardware-in-the-loop" serving into
a digital simulation while reporting success.  Installing the hook
inside traced code is therefore always a bug.

These rules are lexical: they look at functions that are *somewhere in
this module* passed to ``jax.jit`` / ``lax.scan`` / ``jax.vmap`` /
``pl.pallas_call`` etc. (or decorated with jit), and flag host-side
effects inside their bodies.  Like all of repro-lint they are
best-effort static checks, not a dynamic proof — which is exactly why
the runtime guard in ``_hook_dispatch`` also exists.
"""

from __future__ import annotations

import ast
from typing import Iterator

from .astutil import SourceFile, call_name, dotted, line_at
from .findings import Finding, Rule

__all__ = ["RULES"]

# callee leaf names that trace their function argument(s); value = the
# positional indices of the traced callables
TRACING_CALLS = {
    "jit": (0,), "pjit": (0,), "vmap": (0,), "pmap": (0,),
    "grad": (0,), "value_and_grad": (0,), "checkpoint": (0,),
    "remat": (0,), "custom_jvp": (0,), "custom_vjp": (0,),
    "scan": (0,), "while_loop": (0, 1), "fori_loop": (2,),
    "cond": (1, 2), "switch": None,     # switch: every arg after 0
    "pallas_call": (0,),
}

# decorator spellings that mark a def as traced
_JIT_DECOS = frozenset(["jit", "pjit"])

# host-side effect callees (dotted suffixes) that must not run under
# trace — wall clock, global RNG state, entropy
HOST_EFFECTS = (
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.sleep",
    "datetime.now", "datetime.utcnow", "os.urandom",
)
# module-global RNG state (jax.random is keyed and fine; stdlib
# `random` is excluded to avoid colliding with `from jax import random`)
HOST_EFFECT_PREFIXES = ("np.random.", "numpy.random.")


def _is_jit_decorated(fn: ast.FunctionDef) -> bool:
    for deco in fn.decorator_list:
        if isinstance(deco, ast.Call):
            name = call_name(deco)
            # functools.partial(jax.jit, ...) — first arg is the jit
            if name is not None and name.rsplit(".", 1)[-1] == "partial" \
                    and deco.args:
                iname = dotted(deco.args[0])
                if iname is not None \
                        and iname.rsplit(".", 1)[-1] in _JIT_DECOS:
                    return True
        else:
            name = dotted(deco)
        if name is not None and name.rsplit(".", 1)[-1] in _JIT_DECOS:
            return True
    return False


def _traced_callables(sf: SourceFile):
    """(node, reason) for every FunctionDef/Lambda in the module that is
    traced: jit-decorated, or passed by name/position to a tracing
    transform anywhere in the module."""
    defs_by_name: dict[str, list[ast.AST]] = {}
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs_by_name.setdefault(node.name, []).append(node)

    traced: dict[ast.AST, str] = {}
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and _is_jit_decorated(node):
            traced.setdefault(node, "decorated with jax.jit")
        if not isinstance(node, ast.Call):
            continue
        fn = call_name(node)
        if fn is None:
            continue
        leaf = fn.rsplit(".", 1)[-1]
        if leaf not in TRACING_CALLS:
            continue
        idxs = TRACING_CALLS[leaf]
        if idxs is None:                       # lax.switch: branches 1..n
            idxs = tuple(range(1, len(node.args)))
        for i in idxs:
            if i >= len(node.args):
                continue
            arg = node.args[i]
            reason = f"passed to {fn}"
            if isinstance(arg, ast.Lambda):
                traced.setdefault(arg, reason)
            elif isinstance(arg, ast.Name):
                for d in defs_by_name.get(arg.id, []):
                    traced.setdefault(d, reason)
    return traced


def _body_params(fn: ast.AST) -> set:
    a = fn.args
    return {p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)}


def _host_effect(fn_name: str) -> bool:
    if any(fn_name == s or fn_name.endswith("." + s) for s in HOST_EFFECTS):
        return True
    return any(fn_name.startswith(p) for p in HOST_EFFECT_PREFIXES)


def check_host_effects(corpus) -> Iterator[Finding]:
    for sf in corpus:
        for fn, reason in _traced_callables(sf).items():
            params = _body_params(fn)
            body = fn.body if isinstance(fn.body, list) else [fn.body]
            for stmt in body:
                for node in ast.walk(stmt):
                    if not isinstance(node, ast.Call):
                        continue
                    name = call_name(node)
                    if name is not None and _host_effect(name):
                        yield Finding(
                            "RPL301", sf.rel, node.lineno, node.col_offset,
                            f"host-side effect {name}() inside a traced "
                            f"function ({reason}) — executes at trace "
                            f"time only and bakes a constant into the "
                            f"compiled graph",
                            line_at(sf, node))
                    elif (isinstance(node.func, ast.Attribute)
                          and node.func.attr == "item"
                          and not node.args):
                        yield Finding(
                            "RPL301", sf.rel, node.lineno, node.col_offset,
                            f".item() inside a traced function ({reason}) "
                            f"— concretizes a tracer "
                            f"(ConcretizationTypeError at best, a baked "
                            f"constant at worst)",
                            line_at(sf, node))
                    elif (isinstance(node.func, ast.Name)
                          and node.func.id == "float" and node.args
                          and _param_derived(node.args[0], params)):
                        yield Finding(
                            "RPL301", sf.rel, node.lineno, node.col_offset,
                            f"float() on a traced argument inside a "
                            f"traced function ({reason}) — concretizes "
                            f"the tracer",
                            line_at(sf, node))


def _param_derived(node: ast.AST, params: set) -> bool:
    """The expression is rooted at a function parameter (so, under
    trace, almost certainly a tracer)."""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        node = node.value
    return isinstance(node, ast.Name) and node.id in params


def check_hook_install(corpus) -> Iterator[Finding]:
    for sf in corpus:
        traced = _traced_callables(sf)
        for fn, reason in traced.items():
            body = fn.body if isinstance(fn.body, list) else [fn.body]
            for stmt in body:
                for node in ast.walk(stmt):
                    if (isinstance(node, ast.Call)
                            and (name := call_name(node)) is not None
                            and name.rsplit(".", 1)[-1] == "ptc_execution"):
                        yield Finding(
                            "RPL302", sf.rel, node.lineno, node.col_offset,
                            f"ptc_execution(...) hook installed inside a "
                            f"traced function ({reason}) — the hook only "
                            f"fires on concrete inputs, so under "
                            f"jit/scan/vmap every PTC call silently "
                            f"stays digital and 'hardware-in-the-loop' "
                            f"becomes a simulation",
                            line_at(sf, node))


RULES = [
    Rule(
        "RPL301", "no host effects under trace", check_host_effects,
        "Functions passed to jax.jit / lax.scan / jax.vmap / "
        "lax.fori_loop / pl.pallas_call (or decorated with jit) must "
        "not call wall-clock (`time.time`), global-state RNG "
        "(`np.random.*`, stdlib `random.*`), entropy (`os.urandom`), "
        "or concretize tracers (`.item()`, `float()` on a parameter-"
        "derived value).\n\n"
        "Why: jax traces once and replays the graph — host effects run "
        "at trace time only, freezing one sample/timestamp into the "
        "compiled computation.  A drift step that drew `np.random` "
        "inside a scanned body would replay the identical 'random' walk "
        "every step while looking correct in eager tests.\n\n"
        "Fix: thread `jax.random` keys (split per step), take "
        "timestamps outside the traced region, and keep concretization "
        "(`float`, `.item`) on already-materialized outputs."),
    Rule(
        "RPL302", "no ptc_execution install under trace",
        check_hook_install,
        "`ptc_execution(...)` (models/layers.py) must never be "
        "installed inside a function that jax traces.\n\n"
        "Why: the hook dispatch is tracer-guarded — under jit/scan/vmap "
        "a hooked PTC linear sees tracers and silently falls back to "
        "the digital matmul.  Installing the hook under trace therefore "
        "*succeeds* while every layer quietly bypasses the routed "
        "chip: serving reports hardware-in-the-loop results that never "
        "touched the (simulated) hardware.  This is the failure mode "
        "in-situ protocols are warned about (power-aware sparse-ZO, "
        "Gu et al.) — the measurement path degrading to the model "
        "path without an error.\n\n"
        "Fix: install the hook around an *unjitted, unrolled* decode "
        "loop (launch/serve.py does), never inside jit/scan/vmap "
        "bodies; runtime/hw_serve.py documents the legal pattern."),
]
