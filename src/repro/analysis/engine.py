"""Corpus walking, rule execution, ``noqa`` and baseline filtering.

Pure stdlib.  The engine parses every ``.py`` file under the requested
paths once into :class:`~repro.analysis.astutil.SourceFile` objects and
hands the whole corpus to each rule — cross-file rules (the RPL2xx wire
checks) need the full set, and single-file rules just iterate it.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterable

from .astutil import SourceFile
from .findings import Finding, Rule, fingerprint, noqa_codes
from . import (rules_determinism, rules_pallas, rules_tracer, rules_twin,
               rules_wire)

__all__ = ["LintResult", "all_rules", "rule_by_code", "run_lint",
           "load_corpus", "load_baseline", "baseline_payload"]

_RULE_MODULES = (rules_twin, rules_wire, rules_tracer, rules_pallas,
                 rules_determinism)

_SKIP_DIRS = frozenset(["__pycache__", ".git", ".venv", "node_modules",
                        "build", "dist", ".mypy_cache", ".ruff_cache"])


def all_rules() -> list[Rule]:
    rules: list[Rule] = []
    for mod in _RULE_MODULES:
        rules.extend(mod.RULES)
    return sorted(rules, key=lambda r: r.code)


def rule_by_code(code: str) -> Rule | None:
    for rule in all_rules():
        if rule.code == code:
            return rule
    return None


def _rel(path: str, root: str) -> str:
    """Invocation-relative display path.  Prefer cwd-relative (so repo-
    root runs produce the stable ``src/repro/...`` paths the committed
    baseline fingerprints); fall back to root-relative for corpora
    outside the cwd (fixture trees in tests)."""
    rel = os.path.relpath(path)
    if not rel.startswith(".."):
        return rel
    root = os.path.abspath(root)
    base = os.path.dirname(root)
    return os.path.relpath(path, base)


def load_corpus(paths: Iterable[str]):
    """Parse every .py under ``paths`` (files or directories).

    Returns ``(corpus, errors)`` where errors are ``(path, message)``
    for unparseable files — reported, never silently skipped.
    """
    corpus: list[SourceFile] = []
    errors: list[tuple[str, str]] = []
    seen: set[str] = set()
    for root in paths:
        files: list[tuple[str, str]] = []
        if os.path.isfile(root):
            files.append((root, _rel(root, root)))
        else:
            for dirpath, dirnames, filenames in os.walk(root):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in _SKIP_DIRS
                                     and not d.startswith("."))
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        full = os.path.join(dirpath, fn)
                        files.append((full, _rel(full, root)))
        for full, rel in files:
            key = os.path.abspath(full)
            if key in seen:
                continue
            seen.add(key)
            try:
                with open(full, encoding="utf-8") as fh:
                    text = fh.read()
                corpus.append(SourceFile(full, rel, text))
            except (SyntaxError, UnicodeDecodeError, OSError) as exc:
                errors.append((rel, f"{type(exc).__name__}: {exc}"))
    return corpus, errors


@dataclasses.dataclass
class LintResult:
    """Outcome of one lint run, after suppression filtering."""

    findings: list          # active Finding objects, sorted
    noqa_suppressed: list   # Finding objects silenced by `# repro: noqa`
    baseline_suppressed: list   # Finding objects matched by the baseline
    stale_baseline: list    # baseline fingerprints that matched nothing
    errors: list            # (path, message) parse failures

    @property
    def ok(self) -> bool:
        return not self.findings and not self.errors

    def as_dict(self) -> dict:
        return {
            "findings": [f.as_dict() for f in self.findings],
            "suppressed": {
                "noqa": [f.as_dict() for f in self.noqa_suppressed],
                "baseline": [f.as_dict() for f in self.baseline_suppressed],
            },
            "stale_baseline": list(self.stale_baseline),
            "errors": [{"path": p, "message": m} for p, m in self.errors],
            "ok": self.ok,
        }


def _sort_key(f: Finding):
    return (f.path, f.line, f.col, f.code)


def run_lint(paths: Iterable[str],
             baseline: Iterable[str] = (),
             codes: Iterable[str] | None = None) -> LintResult:
    """Lint ``paths`` with every rule (or just ``codes``), applying
    per-line ``# repro: noqa[...]`` suppressions and the grandfathered
    ``baseline`` fingerprints."""
    corpus, errors = load_corpus(paths)
    by_rel = {sf.rel: sf for sf in corpus}
    wanted = set(codes) if codes is not None else None

    raw: dict[tuple, Finding] = {}
    for rule in all_rules():
        if wanted is not None and rule.code not in wanted:
            continue
        for f in rule.check(corpus):
            raw.setdefault((f.code, f.path, f.line, f.col, f.message), f)

    baseline_fps = set(baseline)
    active: list[Finding] = []
    noqa_hits: list[Finding] = []
    baseline_hits: list[Finding] = []
    matched_fps: set[str] = set()
    for f in sorted(raw.values(), key=_sort_key):
        sf = by_rel.get(f.path)
        line = sf.line_text(f.line) if sf is not None else f.snippet
        codes_off = noqa_codes(line)
        if codes_off is not None and (not codes_off or f.code in codes_off):
            noqa_hits.append(f)
            continue
        fp = fingerprint(f)
        if fp in baseline_fps:
            matched_fps.add(fp)
            baseline_hits.append(f)
            continue
        active.append(f)
    return LintResult(active, noqa_hits, baseline_hits,
                      sorted(baseline_fps - matched_fps), errors)


def load_baseline(path: str) -> set[str]:
    """Fingerprints from a baseline file; empty set if absent."""
    if not os.path.exists(path):
        return set()
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    return {e["fingerprint"] for e in data.get("findings", [])}


def baseline_payload(findings: Iterable[Finding]) -> dict:
    """Serializable baseline for the currently-active findings.  Stale
    entries are dropped by construction: only findings observed in this
    run are written."""
    entries = [{
        "fingerprint": fingerprint(f),
        "code": f.code,
        "path": f.path,
        "snippet": f.snippet.strip(),
        "message": f.message,
    } for f in sorted(set(findings), key=_sort_key)]
    return {"version": 1, "findings": entries}
