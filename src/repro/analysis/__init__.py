"""`repro-lint`: repo-specific static analysis for the repro stack.

The stack's correctness rests on invariants no general-purpose linter
knows about, and that are otherwise enforced only by tribal knowledge:

* **Twin boundary (RPL1xx)** — the paper's premise (§3.2) is that
  in-situ learning sees only observable chip state.  Twin-internal
  ground truth (`hw.twin` / `hw.device` / `hw.drift` internals) must
  stay quarantined behind ``driver.unsafe_twin()``, whose call sites
  are themselves restricted to an explicit diagnostic allowlist.
* **Wire protocol (RPL2xx)** — the v3 op-stream protocol is defined in
  three places that must agree: ``BATCHABLE_OPS`` (the whitelist),
  ``hw/server.py:_dispatch`` (the server), and the ``StreamDriver``
  client emitters, including the payload keywords each side
  encodes/reads.  A new op must ship fully wired or not at all.
* **Tracer safety (RPL3xx)** — host-side effects inside functions
  handed to ``jax.jit`` / ``lax.scan`` / ``jax.vmap`` or used as Pallas
  kernel bodies silently bake trace-time constants (or, for the
  ``ptc_execution`` hook, silently turn hardware-in-the-loop serving
  into a digital simulation).
* **Pallas call sites (RPL4xx)** — kernel arity vs in/out/scratch
  specs, ``index_map`` arity vs grid rank (+ scalar prefetch), and
  ``input_output_aliases`` index validity.
* **Determinism (RPL5xx)** — seeds derive from configuration, never
  wall-clock; set iteration never feeds wire-frame construction.

Run it::

    python -m repro.analysis.lint src benchmarks        # lint
    python -m repro.analysis.lint --explain RPL201      # rule docs
    python -m repro.analysis.lint --self-test           # prove rules fire

Findings are suppressed per line with ``# repro: noqa[CODE]`` or
grandfathered in the committed ``repro-lint-baseline.json``.  The
package is pure stdlib (``ast``) — it never imports jax and is safe to
run in any environment.
"""

from .findings import Finding  # noqa: F401
from .engine import run_lint, all_rules, LintResult  # noqa: F401

__all__ = ["Finding", "run_lint", "all_rules", "LintResult"]
