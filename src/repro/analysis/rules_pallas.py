"""RPL4xx — Pallas call-site analyzers.

``pl.pallas_call`` wires a kernel body to its operands positionally:
the kernel receives ``num_scalar_prefetch`` scalar refs, then one ref
per ``in_specs`` entry, then one ref per output, then one ref per
``scratch_shapes`` entry.  Every ``BlockSpec`` index map receives the
grid indices (plus, under ``PrefetchScalarGridSpec``, the scalar-
prefetch refs).  ``input_output_aliases`` maps *call-operand* indices
(scalar-prefetch operands included) to output indices.

None of this is checked until the kernel actually runs — and
``interpret=True`` (the default off-TPU here) reports arity mismatches
with notoriously indirect errors, while on a real TPU backend Mosaic
fails at compile time inside a jit trace.  These analyzers validate the
counts statically at the call site, where the fix is obvious.

Checked call sites in-tree: ``kernels/paged_kv.py``,
``kernels/ptc_block_matmul.py``, ``kernels/mesh_apply.py``,
``kernels/sigma_grad.py``, ``kernels/feedback_matmul.py`` — and any
future ``pallas_call`` anywhere in the linted paths.
"""

from __future__ import annotations

import ast
from typing import Iterator

from .astutil import (SourceFile, call_name, func_arity, lambda_arity,
                      line_at, resolve_local)
from .findings import Finding, Rule

__all__ = ["RULES"]


def _kwarg(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _enclosing_scopes(sf: SourceFile, target: ast.AST):
    """Module + function scopes lexically containing ``target``."""
    scopes = [sf.tree]

    def visit(node):
        for child in ast.iter_child_nodes(node):
            if child is target or any(n is target for n in ast.walk(child)):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    scopes.append(child)
                visit(child)
                return
    visit(sf.tree)
    return scopes


def _resolve(sf: SourceFile, site: ast.Call, node: ast.AST):
    """Follow one level of `name = <expr>` indirection near the site."""
    if isinstance(node, ast.Name):
        for scope in reversed(_enclosing_scopes(sf, site)):
            hit = resolve_local(scope, node.id)
            if hit is not None:
                return hit
    return node


def _seq_len(node: ast.AST) -> int | None:
    if isinstance(node, (ast.List, ast.Tuple)):
        return len(node.elts)
    return None


class CallSite:
    """Statically-extracted facts about one pallas_call site."""

    def __init__(self, sf: SourceFile, call: ast.Call):
        self.sf, self.call = sf, call
        grid_src = call
        self.prefetch = 0
        spec = _kwarg(call, "grid_spec")
        if isinstance(spec, ast.Call):
            grid_src = spec
            name = call_name(spec) or ""
            if name.rsplit(".", 1)[-1] == "PrefetchScalarGridSpec":
                n = _kwarg(spec, "num_scalar_prefetch")
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    self.prefetch = n.value
        self.grid = _resolve(sf, call, _kwarg(grid_src, "grid"))
        self.grid_rank = _seq_len(self.grid) if self.grid is not None else None
        if self.grid is not None and self.grid_rank is None \
                and not isinstance(self.grid, ast.Name):
            self.grid_rank = 1 if not isinstance(
                self.grid, (ast.List, ast.Tuple)) else None
        ins = _resolve(sf, call, _kwarg(grid_src, "in_specs"))
        self.in_specs = ins.elts if isinstance(ins, (ast.List, ast.Tuple)) \
            else None
        outs = _kwarg(grid_src, "out_specs")
        if isinstance(outs, (ast.List, ast.Tuple)):
            self.out_specs = list(outs.elts)
        elif outs is not None:
            self.out_specs = [outs]
        else:
            # fall back to out_shape arity (single struct = one output)
            osh = _kwarg(call, "out_shape")
            self.out_specs = (list(osh.elts)
                              if isinstance(osh, (ast.List, ast.Tuple))
                              else [osh] if osh is not None else None)
        scr = _resolve(sf, call, _kwarg(grid_src, "scratch_shapes"))
        self.n_scratch = _seq_len(scr) if scr is not None else 0
        self.aliases = _kwarg(call, "input_output_aliases")
        # kernel: first positional arg, possibly through functools.partial
        self.kernel = call.args[0] if call.args else None
        self.bound = 0
        if isinstance(self.kernel, ast.Call):
            kname = call_name(self.kernel) or ""
            if kname.rsplit(".", 1)[-1] == "partial":
                self.bound = len(self.kernel.args) - 1
                self.kernel = self.kernel.args[0] if self.kernel.args else None

    def kernel_def(self):
        if not isinstance(self.kernel, ast.Name):
            return None
        hit = None
        for node in ast.walk(self.sf.tree):
            if isinstance(node, ast.FunctionDef) \
                    and node.name == self.kernel.id:
                hit = node
        return hit


def _sites(corpus) -> Iterator[CallSite]:
    for sf in corpus:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                name = call_name(node)
                if name is not None \
                        and name.rsplit(".", 1)[-1] == "pallas_call":
                    yield CallSite(sf, node)


def check_kernel_arity(corpus) -> Iterator[Finding]:
    for site in _sites(corpus):
        kdef = site.kernel_def()
        if kdef is None or site.in_specs is None or site.out_specs is None:
            continue
        arity = func_arity(kdef)
        if arity is None:
            continue
        want = (site.prefetch + len(site.in_specs) + len(site.out_specs)
                + (site.n_scratch or 0))
        have = arity + site.bound
        if have != want:
            yield Finding(
                "RPL401", site.sf.rel, site.call.lineno,
                site.call.col_offset,
                f"kernel {kdef.name!r} takes {arity} ref(s)"
                + (f" (+{site.bound} partial-bound)" if site.bound else "")
                + f" but the call wires {want}: {site.prefetch} scalar-"
                f"prefetch + {len(site.in_specs)} in_specs + "
                f"{len(site.out_specs)} output(s) + "
                f"{site.n_scratch or 0} scratch",
                line_at(site.sf, site.call))


def check_index_map_arity(corpus) -> Iterator[Finding]:
    for site in _sites(corpus):
        if site.grid_rank is None:
            continue
        want = site.grid_rank + site.prefetch
        specs = list(site.in_specs or [])
        if site.out_specs:
            specs += [s for s in site.out_specs
                      if isinstance(s, ast.Call)]
        for spec in specs:
            if not isinstance(spec, ast.Call):
                continue
            sname = call_name(spec) or ""
            if sname.rsplit(".", 1)[-1] != "BlockSpec":
                continue
            imap = _kwarg(spec, "index_map")
            if imap is None and len(spec.args) >= 2:
                imap = spec.args[1]
            if not isinstance(imap, ast.Lambda):
                continue
            arity = lambda_arity(imap)
            if arity is not None and arity != want:
                yield Finding(
                    "RPL402", site.sf.rel, imap.lineno, imap.col_offset,
                    f"index_map takes {arity} arg(s) but the grid has "
                    f"rank {site.grid_rank}"
                    + (f" plus {site.prefetch} scalar-prefetch ref(s)"
                       if site.prefetch else "")
                    + f" = {want} expected",
                    line_at(site.sf, imap))


def check_io_aliases(corpus) -> Iterator[Finding]:
    for site in _sites(corpus):
        if not isinstance(site.aliases, ast.Dict):
            continue
        n_in = (site.prefetch + len(site.in_specs)
                if site.in_specs is not None else None)
        n_out = len(site.out_specs) if site.out_specs is not None else None
        for k, v in zip(site.aliases.keys, site.aliases.values):
            ki = k.value if isinstance(k, ast.Constant) \
                and isinstance(k.value, int) else None
            vi = v.value if isinstance(v, ast.Constant) \
                and isinstance(v.value, int) else None
            if ki is not None and n_in is not None \
                    and not (0 <= ki < n_in):
                yield Finding(
                    "RPL403", site.sf.rel, site.aliases.lineno,
                    site.aliases.col_offset,
                    f"input_output_aliases input index {ki} out of range "
                    f"for {n_in} call operand(s) (scalar-prefetch "
                    f"operands count)",
                    line_at(site.sf, site.aliases))
            if vi is not None and n_out is not None \
                    and not (0 <= vi < n_out):
                yield Finding(
                    "RPL403", site.sf.rel, site.aliases.lineno,
                    site.aliases.col_offset,
                    f"input_output_aliases output index {vi} out of "
                    f"range for {n_out} output(s)",
                    line_at(site.sf, site.aliases))


RULES = [
    Rule(
        "RPL401", "pallas kernel arity", check_kernel_arity,
        "A pallas kernel's parameter count must equal "
        "num_scalar_prefetch + len(in_specs) + number of outputs + "
        "len(scratch_shapes) (minus any functools.partial-bound "
        "leading args).\n\n"
        "Why: the wiring is positional and unchecked until runtime; "
        "interpret=True (the off-TPU default in kernels/ops.py) "
        "surfaces a mismatch as an opaque shape error deep inside the "
        "interpreter, and Mosaic fails at jit-trace time on TPU.  The "
        "static count makes the mistake a one-line lint message at the "
        "call site.\n\n"
        "Fix: add/remove the kernel ref parameter, or fix the spec "
        "lists."),
    Rule(
        "RPL402", "index_map arity vs grid rank", check_index_map_arity,
        "Every BlockSpec index_map lambda must take exactly "
        "len(grid) arguments — plus num_scalar_prefetch trailing "
        "scalar-ref arguments under PrefetchScalarGridSpec (e.g. "
        "`lambda bb, jj, t` for grid rank 2 + 1 prefetched table).\n\n"
        "Why: a wrong-arity index map is a TypeError at trace time on "
        "TPU, but in interpret mode some arities *run* with silently "
        "shifted block indexing — the kernel reads the wrong tiles and "
        "produces plausible garbage.\n\n"
        "Fix: match the lambda to the grid (and prefetch count) at the "
        "call site."),
    Rule(
        "RPL403", "input_output_aliases validity", check_io_aliases,
        "input_output_aliases keys index the pallas_call's positional "
        "operands (scalar-prefetch operands INCLUDED, e.g. pages is "
        "operand 2 in paged_scatter(idx, new, pages)); values index "
        "its outputs.  Both must be in range.\n\n"
        "Why: an out-of-range or off-by-one alias either fails deep in "
        "jax's donation machinery or aliases the WRONG buffer — an "
        "in-place scatter into a live input is silent data corruption "
        "of the shared KV pool.\n\n"
        "Fix: count operands including the prefetched scalars; alias "
        "the intended buffer only."),
]
