"""CLI for repro-lint: ``python -m repro.analysis.lint [paths...]``.

Exit status: 0 when no active findings (suppressed/baselined findings
do not fail), 1 on findings, parse errors, or a failed ``--self-test``,
2 on usage errors.
"""

from __future__ import annotations

import argparse
import json
import sys

from .engine import (all_rules, baseline_payload, load_baseline,
                     rule_by_code, run_lint)

DEFAULT_BASELINE = "repro-lint-baseline.json"


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Repo-specific AST lint: twin boundary (RPL1xx), "
                    "wire protocol (RPL2xx), tracer safety (RPL3xx), "
                    "Pallas call sites (RPL4xx), determinism (RPL5xx).")
    p.add_argument("paths", nargs="*", default=["src", "benchmarks"],
                   help="files/directories to lint "
                        "(default: src benchmarks)")
    p.add_argument("--json", metavar="FILE", default=None,
                   help="also write machine-readable findings to FILE "
                        "('-' for stdout)")
    p.add_argument("--baseline", metavar="FILE", default=DEFAULT_BASELINE,
                   help=f"baseline of grandfathered finding fingerprints "
                        f"(default: {DEFAULT_BASELINE}; absent = empty)")
    p.add_argument("--update-baseline", action="store_true",
                   help="rewrite the baseline from the currently active "
                        "findings (stale entries are dropped) and exit 0")
    p.add_argument("--select", metavar="CODES", default=None,
                   help="comma-separated rule codes to run (default: all)")
    p.add_argument("--explain", metavar="CODE", default=None,
                   help="print the invariant behind a rule code and exit")
    p.add_argument("--list-rules", action="store_true",
                   help="list all rule codes and exit")
    p.add_argument("--self-test", action="store_true",
                   help="inject one violation per rule into fixture trees "
                        "and verify every rule fires (and stays quiet on "
                        "clean twins)")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="suppress the summary line")
    return p


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.code}  {rule.name}")
        return 0

    if args.explain is not None:
        rule = rule_by_code(args.explain.strip().upper())
        if rule is None:
            print(f"unknown rule code {args.explain!r}; known codes:",
                  ", ".join(r.code for r in all_rules()), file=sys.stderr)
            return 2
        print(f"{rule.code} — {rule.name}\n")
        print(rule.explain)
        print(f"\nDocs: docs/repro-lint.md#{rule.code.lower()}")
        return 0

    if args.self_test:
        from .selftest import run_self_test
        return 0 if run_self_test() else 1

    codes = None
    if args.select is not None:
        codes = [c.strip().upper() for c in args.select.split(",")
                 if c.strip()]
        known = {r.code for r in all_rules()}
        bad = sorted(set(codes) - known)
        if bad:
            print(f"unknown rule code(s): {', '.join(bad)}",
                  file=sys.stderr)
            return 2

    baseline = load_baseline(args.baseline)
    result = run_lint(args.paths, baseline=baseline, codes=codes)

    if args.update_baseline:
        grandfathered = result.findings + result.baseline_suppressed
        payload = baseline_payload(grandfathered)
        with open(args.baseline, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"{args.baseline}: {len(payload['findings'])} finding(s) "
              f"baselined ({len(result.stale_baseline)} stale entries "
              f"dropped)")
        return 0

    if args.json is not None:
        text = json.dumps(result.as_dict(), indent=2)
        if args.json == "-":
            print(text)
        else:
            with open(args.json, "w", encoding="utf-8") as fh:
                fh.write(text + "\n")

    for path, msg in result.errors:
        print(f"{path}: parse error: {msg}", file=sys.stderr)
    for f in result.findings:
        print(f.format())
    for fp in result.stale_baseline:
        print(f"warning: stale baseline entry {fp} matched nothing "
              f"(run --update-baseline to drop it)", file=sys.stderr)

    if not args.quiet:
        n = len(result.findings)
        parts = [f"{n} finding(s)"]
        if result.noqa_suppressed:
            parts.append(f"{len(result.noqa_suppressed)} noqa-suppressed")
        if result.baseline_suppressed:
            parts.append(f"{len(result.baseline_suppressed)} baselined")
        if result.errors:
            parts.append(f"{len(result.errors)} parse error(s)")
        status = "clean" if result.ok else "FAILED"
        print(f"repro-lint: {status} — " + ", ".join(parts))
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
