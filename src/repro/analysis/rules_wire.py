"""RPL2xx — wire-protocol consistency analyzers.

The driver wire protocol (v3 JSON lines, v4 binary frames — the
framing differs, the op surface is the same) is defined in three
places that nothing (until now) forced to agree:

* ``repro/hw/driver.py`` — ``BATCHABLE_OPS``, the op whitelist every
  transport enforces symmetrically;
* ``repro/hw/server.py:_dispatch`` — the server's ``op == "..."``
  branches and the payload keys each branch reads (``kw["x"]`` /
  ``kw.get("x")`` / ``_rng(kw)``);
* ``repro/hw/stream_driver.py`` — the client emitters
  (``self._exec(op, ...)`` / ``self._queue(op, ...)``) and the payload
  keys they encode (``self._wire_kw(op, dict(...))``).

A new op added to one side but not the others ships *half-wired*: it
either round-trips to an "unknown op" error, silently drops payload
keys the server never reads, or dies inside a batch frame on exactly
one transport.  These analyzers cross-check all three definitions
statically, so the failure is a lint error at commit time instead of a
runtime surprise on the transport the author didn't test.

The analyzers locate the three files *within the linted corpus* by
module name (``repro.hw.driver`` etc.), so they run unchanged against
the real tree, a test fixture tree, or a deliberately broken copy.
"""

from __future__ import annotations

import ast
from typing import Iterator

from .astutil import SourceFile, call_name, const_str, line_at
from .findings import Finding, Rule

__all__ = ["RULES", "WireModel", "extract_wire_model"]

# ops that are session control, not data plane: dispatched outside the
# whitelist on purpose
CONTROL_OPS = frozenset(["init", "shutdown", "batch", "meta"])


class WireModel:
    """Everything the three protocol files statically declare."""

    def __init__(self):
        self.batchable: set[str] = set()
        self.batchable_node = None          # (sf, node) anchor
        self.pipelined: set[str] = set()
        self.wire_internal: set[str] = set()
        self.server_ops: dict[str, tuple] = {}       # op -> (sf, node)
        self.server_reads: dict[str, dict] = {}      # op -> {key: "hard"|"soft"}
        self.client_ops: dict[str, tuple] = {}       # op -> (sf, node)
        self.client_keys: dict[str, dict] = {}       # op -> {key: (sf, node)}
        self.found = set()                  # which of the three files exist


def _collect_str_elts(node: ast.AST) -> list[str]:
    """String constants inside frozenset([...]) / {...} / [...] / (...)."""
    if isinstance(node, ast.Call) and node.args:
        return _collect_str_elts(node.args[0])
    if isinstance(node, (ast.List, ast.Tuple, ast.Set)):
        return [s for e in node.elts if (s := const_str(e)) is not None]
    return []


def _scan_driver(model: WireModel, sf: SourceFile) -> None:
    model.found.add("driver")
    for node in sf.tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "BATCHABLE_OPS":
                    model.batchable = set(_collect_str_elts(node.value))
                    model.batchable_node = (sf, node)
                elif (isinstance(tgt, ast.Name)
                        and tgt.id == "WIRE_INTERNAL_OPS"):
                    model.wire_internal = set(_collect_str_elts(node.value))


def _kw_reads(body_nodes, reads: dict) -> None:
    """Collect ``kw["k"]`` (hard), ``kw.get("k")`` / ``_rng(kw)`` /
    ``_build_driver(kw)`` (soft / delegated) reads from a branch body."""
    for stmt in body_nodes:
        for node in ast.walk(stmt):
            if (isinstance(node, ast.Subscript)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "kw"
                    and (k := const_str(node.slice)) is not None):
                reads[k] = "hard"
            elif isinstance(node, ast.Call):
                fn = call_name(node)
                if (fn is not None and fn.endswith("kw.get") and node.args
                        and (k := const_str(node.args[0])) is not None):
                    reads.setdefault(k, "soft")
                elif fn == "_rng" and node.args:
                    reads.setdefault("block_range", "soft")


def _scan_server(model: WireModel, sf: SourceFile) -> None:
    model.found.add("server")
    dispatch = build = None
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.FunctionDef):
            if node.name == "_dispatch":
                dispatch = node
            elif node.name == "_build_driver":
                build = node
    if dispatch is None:
        return
    for node in ast.walk(dispatch):
        if not isinstance(node, ast.If):
            continue
        t = node.test
        if (isinstance(t, ast.Compare) and isinstance(t.left, ast.Name)
                and t.left.id == "op" and len(t.ops) == 1
                and isinstance(t.ops[0], ast.Eq)
                and (op := const_str(t.comparators[0])) is not None):
            model.server_ops[op] = (sf, node)
            reads: dict = {}
            if op != "batch":       # batch bodies read entry dicts, not kw
                _kw_reads(node.body, reads)
            model.server_reads[op] = reads
    # `init` is handled in serve() by delegating kw to _build_driver
    if build is not None:
        reads: dict = {}
        _kw_reads(build.body, reads)
        model.server_ops.setdefault("init", (sf, build))
        model.server_reads["init"] = reads


def _payload_keys(node: ast.AST, env: dict | None = None) -> dict | None:
    """Keys of a ``dict(...)`` call or ``{...}`` literal payload.

    ``env`` maps local names to payload dicts already resolved from
    simple assignments, so the v4 handshake's re-offer idiom —
    ``base = dict(key=..., ...)`` then ``_exec("init", dict(base,
    v=want))`` — resolves to base's keys plus the overrides instead of
    hiding the base payload from RPL204."""
    if isinstance(node, ast.Call) and call_name(node) == "dict":
        if any(kw.arg is None for kw in node.keywords):
            return None                       # **expansion: unknown
        out: dict = {}
        for arg in node.args:                 # dict(base, ...) merge form
            inner = _payload_keys(arg, env)
            if inner is None and isinstance(arg, ast.Name):
                inner = (env or {}).get(arg.id)
            if inner is None:
                return None                   # opaque positional: unknown
            out.update(inner)
        for kw in node.keywords:
            out[kw.arg] = kw.value
        return out
    if isinstance(node, ast.Dict):
        out = {}
        for k, v in zip(node.keys, node.values):
            ks = const_str(k) if k is not None else None
            if ks is None:
                return None
            out[ks] = v
        return out
    return None


def _local_payloads(sf: SourceFile) -> dict:
    """name → payload keys for every simple ``name = dict(...)`` /
    ``name = {...}`` assignment in the file (the client's base-payload
    variables; collisions across scopes keep the first binding, which
    is enough for a static cross-check)."""
    env: dict = {}
    for node in ast.walk(sf.tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            keys = _payload_keys(node.value, env)
            if keys is not None:
                env.setdefault(node.targets[0].id, keys)
    return env


def _scan_client(model: WireModel, sf: SourceFile) -> None:
    model.found.add("client")
    env = _local_payloads(sf)
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = call_name(node)
        if fn is None:
            continue
        leaf = fn.rsplit(".", 1)[-1]
        if leaf in ("_exec", "_queue") and node.args:
            op = const_str(node.args[0])
            if op is None:
                continue
            model.client_ops.setdefault(op, (sf, node))
            if len(node.args) > 1:
                keys = _payload_keys(node.args[1], env)
                if keys:
                    dst = model.client_keys.setdefault(op, {})
                    for k in keys:
                        dst.setdefault(k, (sf, node))
        elif leaf == "_wire_kw" and len(node.args) >= 2:
            op = const_str(node.args[0])
            keys = _payload_keys(node.args[1], env)
            if op is not None:
                model.client_ops.setdefault(op, (sf, node))
                if keys:
                    dst = model.client_keys.setdefault(op, {})
                    for k in keys:
                        dst.setdefault(k, (sf, node))
    # PIPELINED_OPS must stay a subset of BATCHABLE_OPS (they flush
    # inside batch frames)
    for stmt in sf.tree.body:
        if isinstance(stmt, ast.Assign):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "PIPELINED_OPS":
                    model.pipelined = set(_collect_str_elts(stmt.value))


def extract_wire_model(corpus) -> WireModel:
    model = WireModel()
    for sf in corpus:
        if sf.module == "repro.hw.driver":
            _scan_driver(model, sf)
        elif sf.module == "repro.hw.server":
            _scan_server(model, sf)
        elif sf.module == "repro.hw.stream_driver":
            _scan_client(model, sf)
    return model


def _complete(model: WireModel) -> bool:
    """Cross-file checks only fire when the whole trio was linted —
    linting a subtree (e.g. just benchmarks) must not report the
    protocol as half-wired because two of its files are out of scope."""
    return model.found >= {"driver", "server", "client"}


def _anchor(model: WireModel, op: str):
    if op in model.server_ops:
        return model.server_ops[op]
    if op in model.client_ops:
        return model.client_ops[op]
    return model.batchable_node


def check_server_coverage(corpus) -> Iterator[Finding]:
    model = extract_wire_model(corpus)
    if not _complete(model):
        return
    sf, node = model.batchable_node or (None, None)
    for op in sorted(model.batchable - set(model.server_ops)):
        yield Finding(
            "RPL201", sf.rel, node.lineno, node.col_offset,
            f"op {op!r} is in BATCHABLE_OPS but hw/server.py:_dispatch "
            f"has no `op == {op!r}` branch — a wire peer batching it "
            f"gets 'unknown op' after the whitelist admitted it",
            line_at(sf, node))


def check_client_coverage(corpus) -> Iterator[Finding]:
    model = extract_wire_model(corpus)
    if not _complete(model):
        return
    sf, node = model.batchable_node or (None, None)
    for op in sorted(model.batchable - set(model.client_ops)):
        yield Finding(
            "RPL202", sf.rel, node.lineno, node.col_offset,
            f"op {op!r} is in BATCHABLE_OPS but the StreamDriver client "
            f"never emits it (no _exec/_queue/_wire_kw site) — the op "
            f"is unreachable over the wire and its server branch is "
            f"dead code",
            line_at(sf, node))


def check_whitelist_membership(corpus) -> Iterator[Finding]:
    model = extract_wire_model(corpus)
    if not _complete(model):
        return
    for op, (sf, node) in sorted(model.server_ops.items()):
        if (op not in model.batchable and op not in CONTROL_OPS
                and op not in model.wire_internal
                and not op.startswith("unsafe/")):
            yield Finding(
                "RPL203", sf.rel, node.lineno, node.col_offset,
                f"server dispatches op {op!r} which is neither in "
                f"BATCHABLE_OPS, WIRE_INTERNAL_OPS, nor a control/"
                f"unsafe op — in-process run_batch would reject a "
                f"list the wire accepts (transport asymmetry)",
                line_at(sf, node))
    for op, (sf, node) in sorted(model.client_ops.items()):
        if (op not in model.batchable and op not in CONTROL_OPS
                and op not in model.wire_internal
                and not op.startswith("unsafe/")):
            yield Finding(
                "RPL203", sf.rel, node.lineno, node.col_offset,
                f"client emits op {op!r} which is neither in "
                f"BATCHABLE_OPS, WIRE_INTERNAL_OPS, nor a control/"
                f"unsafe op — it can never travel inside a batch "
                f"frame, breaking pipelined flush ordering",
                line_at(sf, node))
    # a wire-internal op is a client-rewrite + server-branch PAIR: one
    # half alone is either an op the server can never see or a frame
    # the server cannot answer
    for op in sorted(model.wire_internal):
        missing = [side for side, where in
                   (("server branch", model.server_ops),
                    ("client emitter", model.client_ops))
                   if op not in where]
        if missing and model.batchable_node is not None:
            sf, node = model.batchable_node
            yield Finding(
                "RPL203", sf.rel, node.lineno, node.col_offset,
                f"WIRE_INTERNAL_OPS contains {op!r} but it has no "
                f"{' or '.join(missing)} — the wire-internal rewrite "
                f"must be wired on both ends in the same commit",
                line_at(sf, node))
    if model.pipelined - model.batchable:
        sf, node = model.batchable_node
        for op in sorted(model.pipelined - model.batchable):
            yield Finding(
                "RPL203", sf.rel, node.lineno, node.col_offset,
                f"PIPELINED_OPS contains {op!r} which is not in "
                f"BATCHABLE_OPS — queued writes flush inside batch "
                f"frames, so every pipelined op must be batchable",
                line_at(sf, node))


def check_payload_keywords(corpus) -> Iterator[Finding]:
    model = extract_wire_model(corpus)
    if not _complete(model):
        return
    for op in sorted(set(model.server_reads) & set(model.client_ops)):
        if op in ("batch", "meta"):
            continue
        reads = model.server_reads.get(op, {})
        sent = model.client_keys.get(op, {})
        hard = {k for k, kind in reads.items() if kind == "hard"}
        for k in sorted(hard - set(sent)):
            sf, node = model.server_ops[op]
            yield Finding(
                "RPL204", sf.rel, node.lineno, node.col_offset,
                f"server op {op!r} reads kw[{k!r}] unconditionally but "
                f"the client encoder never sends {k!r} — every wire "
                f"call of this op raises KeyError server-side",
                line_at(sf, node))
        for k in sorted(set(sent) - set(reads)):
            sf, node = sent[k]
            yield Finding(
                "RPL204", sf.rel, node.lineno, node.col_offset,
                f"client encodes payload key {k!r} for op {op!r} but "
                f"the server branch never reads it — the value is "
                f"silently dropped on the wire",
                line_at(sf, node))


RULES = [
    Rule(
        "RPL201", "batchable op has a server branch", check_server_coverage,
        "Every op in BATCHABLE_OPS (repro/hw/driver.py) must have a "
        "matching `op == \"...\"` branch in hw/server.py:_dispatch.\n\n"
        "Why: BATCHABLE_OPS is enforced symmetrically on every "
        "transport — the whitelist admitting an op the server cannot "
        "dispatch means a client-validated batch frame dies mid-list "
        "server-side, after earlier ops already applied.\n\n"
        "Fix: add the dispatch branch (and its payload decode) in the "
        "same commit that extends BATCHABLE_OPS."),
    Rule(
        "RPL202", "batchable op has a client emitter", check_client_coverage,
        "Every op in BATCHABLE_OPS must be emitted somewhere by the "
        "StreamDriver client (`self._exec(op, ...)`, `self._queue(op, "
        "...)`, or a `self._wire_kw(op, dict(...))` encode site).\n\n"
        "Why: an op only the server knows is dead protocol surface — "
        "it rots unreviewed and suggests the client half of a feature "
        "was never shipped.\n\n"
        "Fix: implement the client method, or remove the op from "
        "BATCHABLE_OPS and the server."),
    Rule(
        "RPL203", "wire op whitelist symmetry", check_whitelist_membership,
        "Ops dispatched by the server or emitted by the client must be "
        "in BATCHABLE_OPS, a control op (init/shutdown/batch/meta), a "
        "declared WIRE_INTERNAL_OPS rewrite (client-coalesced forms "
        "like `forward_many`, which must then be wired on BOTH ends), "
        "or an `unsafe/*` twin-debug op; and PIPELINED_OPS must be a "
        "subset of BATCHABLE_OPS.\n\n"
        "Why: PR 4's post-review hardening made the whitelist "
        "symmetric — an op accepted over the wire but rejected by "
        "in-process run_batch (or vice versa) makes batched ≡ "
        "sequential bit-identity transport-dependent, which is exactly "
        "the bug class the conformance suite exists to prevent.  "
        "Pipelined writes flush *inside* batch frames, so a pipelined "
        "op outside the whitelist would poison every later frame.\n\n"
        "Fix: add the op to BATCHABLE_OPS, or mark it control/unsafe "
        "by design."),
    Rule(
        "RPL204", "payload keyword agreement", check_payload_keywords,
        "For every op the client emits and the server dispatches, the "
        "payload keywords must agree: a key the server reads as "
        "`kw[\"k\"]` (no default) must be encoded by the client, and "
        "every key the client encodes must be read (as `kw[\"k\"]`, "
        "`kw.get(\"k\")`, or `_rng(kw)` for block_range) by the server "
        "branch.\n\n"
        "Why: a missing hard key is a guaranteed server-side KeyError "
        "on every call; an unread client key is silent payload loss — "
        "e.g. a `block_range` the server ignores would make a scoped "
        "write land on the whole chip, corrupting co-resident "
        "tenants.\n\n"
        "Fix: wire the keyword through both sides (encode in "
        "_wire_kw / the _exec payload, read in the _dispatch branch)."),
]
