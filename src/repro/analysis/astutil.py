"""Shared AST helpers for the repro-lint analyzers (stdlib only)."""

from __future__ import annotations

import ast

__all__ = ["SourceFile", "dotted", "const_str", "line_at", "call_name",
           "resolve_local", "lambda_arity", "func_arity"]


class SourceFile:
    """One parsed python file plus its classification inside the repo.

    ``module`` is the best-effort dotted module path: files under a
    ``repro`` package directory become ``repro.x.y``; files under a
    top-level ``tests`` / ``benchmarks`` / ``examples`` directory keep
    that prefix (``tests.test_x``).  Classification is purely
    path-based so the analyzers work identically on the real tree and
    on fixture trees in tests.
    """

    def __init__(self, path: str, rel: str, text: str):
        self.path = path
        self.rel = rel.replace("\\", "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        self.module = self._module_name()

    def _module_name(self) -> str:
        parts = self.rel.split("/")
        stem = [p[:-3] if p.endswith(".py") else p for p in parts]
        for anchor in ("repro", "tests", "benchmarks", "examples"):
            if anchor in stem:
                mod = stem[stem.index(anchor):]
                if mod[-1] == "__init__":
                    mod = mod[:-1]
                return ".".join(mod)
        return ".".join(stem)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def in_package(self, prefix: str) -> bool:
        return self.module == prefix or self.module.startswith(prefix + ".")


def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> str | None:
    """Dotted name of a call's callee, else None."""
    return dotted(node.func)


def const_str(node: ast.AST) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def line_at(sf: SourceFile, node: ast.AST) -> str:
    return sf.line_text(getattr(node, "lineno", 0)).strip()


def resolve_local(scope: ast.AST, name: str) -> ast.AST | None:
    """Last plain ``name = <expr>`` assignment in ``scope`` (a module or
    function body), for resolving e.g. ``grid = (a, b)`` before a
    ``pallas_call(grid=grid)``.  Shallow on purpose: only direct body
    statements, no dataflow."""
    found = None
    for stmt in ast.walk(scope):
        if isinstance(stmt, ast.Assign):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name) and tgt.id == name:
                    found = stmt.value
    return found


def lambda_arity(node: ast.Lambda) -> int | None:
    a = node.args
    if a.vararg is not None or a.kwarg is not None:
        return None
    return len(a.posonlyargs) + len(a.args)


def func_arity(node: ast.FunctionDef) -> int | None:
    a = node.args
    if a.vararg is not None or a.kwarg is not None:
        return None
    return len(a.posonlyargs) + len(a.args)
