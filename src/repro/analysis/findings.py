"""Finding/Rule data model, fingerprints, and ``noqa`` suppression."""

from __future__ import annotations

import dataclasses
import hashlib
import re

__all__ = ["Finding", "Rule", "fingerprint", "noqa_codes"]

# `# repro: noqa` (suppress everything on the line) or
# `# repro: noqa[RPL101]` / `# repro: noqa[RPL101, RPL203]`
_NOQA = re.compile(r"#\s*repro:\s*noqa(?:\[([A-Z0-9,\s]+)\])?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source line."""

    code: str          # e.g. "RPL101"
    path: str          # repo-relative (or invocation-relative) posix path
    line: int          # 1-based
    col: int           # 0-based
    message: str
    snippet: str = ""  # the offending source line, stripped

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: {self.code} {self.message}"

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = fingerprint(self)
        return d


def fingerprint(f: Finding) -> str:
    """Stable identity for baselining: survives line-number drift (the
    line content, not the line number, is hashed) but changes when the
    offending code itself changes — so a baselined finding resurfaces
    the moment the grandfathered line is edited."""
    h = hashlib.sha1()
    h.update(f.path.encode())
    h.update(b"\0")
    h.update(f.code.encode())
    h.update(b"\0")
    h.update(f.snippet.strip().encode())
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class Rule:
    """One analyzer: a code, a human name, a checker over the parsed
    corpus (``check(corpus) -> Iterator[Finding]``), and the long
    explanation ``--explain CODE`` prints."""

    code: str
    name: str
    check: object
    explain: str


def noqa_codes(line: str) -> frozenset | None:
    """Codes suppressed on ``line``: None = no noqa, empty set = all."""
    m = _NOQA.search(line)
    if m is None:
        return None
    if m.group(1) is None:
        return frozenset()
    return frozenset(c.strip() for c in m.group(1).split(",") if c.strip())
