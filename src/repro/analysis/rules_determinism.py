"""RPL5xx — determinism analyzers.

The repo's strongest invariant is *bit-identity across transports and
reruns*: the conformance suite, the serving-gateway token-identity
gates, and the benchmark regression gate all assert that equal seeds
give equal bits.  Two things statically destroy that:

* **wall-clock seeds** — a PRNG seeded from ``time.time()`` /
  ``os.urandom`` makes every run its own baseline, so the bit-identity
  gates stop gating anything;
* **set iteration feeding wire frames** — python set order depends on
  insertion history and hash randomization; a batch frame built by
  iterating a set ships ops in a different order per process, which
  executes *different physics* (ops are stateful) on one transport and
  breaks batched ≡ sequential identity.
"""

from __future__ import annotations

import ast
from typing import Iterator

from .astutil import SourceFile, call_name, line_at
from .findings import Finding, Rule

__all__ = ["RULES"]

# callees that consume a seed / construct a generator
_SEEDERS = frozenset(["PRNGKey", "key", "default_rng", "seed", "RandomState",
                      "Generator"])
# entropy sources that must never feed a seed
_ENTROPY = ("time.time", "time.time_ns", "perf_counter", "monotonic",
            "datetime.now", "datetime.utcnow", "os.urandom", "os.getpid",
            "uuid.uuid4")

# packages whose functions assemble wire frames / op lists: set-order
# nondeterminism here changes the op stream itself
_WIRE_PACKAGES = ("repro.hw", "repro.serving")


def _entropy_inside(node: ast.AST) -> str | None:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = call_name(sub)
            if name is not None and any(
                    name == e or name.endswith("." + e) for e in _ENTROPY):
                return name
    return None


def check_wallclock_seeds(corpus) -> Iterator[Finding]:
    for sf in corpus:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name is None or name.rsplit(".", 1)[-1] not in _SEEDERS:
                continue
            for arg in (*node.args, *(kw.value for kw in node.keywords)):
                src = _entropy_inside(arg)
                if src is not None:
                    yield Finding(
                        "RPL501", sf.rel, node.lineno, node.col_offset,
                        f"seed derived from {src}() — wall-clock/entropy "
                        f"seeds defeat every bit-identity gate; derive "
                        f"seeds from configuration (jax.random.split / "
                        f"fold_in of a configured root key)",
                        line_at(sf, node))


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, ast.Set):
        return True
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name is not None and name.rsplit(".", 1)[-1] in ("set",
                                                            "frozenset"):
            return True
    if isinstance(node, ast.BinOp) and isinstance(node.op, (ast.BitAnd,
                                                            ast.BitOr,
                                                            ast.Sub)):
        # set algebra: `pending & batchable`, `a - b` of sets — only
        # flagged when one side is syntactically a set
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


def check_set_iteration(corpus) -> Iterator[Finding]:
    for sf in corpus:
        if not any(sf.in_package(p) for p in _WIRE_PACKAGES):
            continue
        iters = []
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.For):
                iters.append((node.iter, node))
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                for gen in node.generators:
                    iters.append((gen.iter, node))
        for it, at in iters:
            if _is_set_expr(it):
                yield Finding(
                    "RPL502", sf.rel, at.lineno, at.col_offset,
                    "iteration over a set in wire-frame-constructing "
                    "code (repro.hw / repro.serving) — set order is "
                    "per-process, so the op stream (and therefore the "
                    "device physics it executes) would differ between "
                    "runs; iterate a list/tuple or wrap in sorted()",
                    line_at(sf, at))


RULES = [
    Rule(
        "RPL501", "seeds derive from configuration", check_wallclock_seeds,
        "PRNG constructors (jax.random.PRNGKey/key, "
        "np.random.default_rng/seed/RandomState) must not be fed from "
        "wall-clock or entropy sources (time.time, datetime.now, "
        "os.urandom, os.getpid, uuid4).\n\n"
        "Why: every correctness gate in this repo — transport "
        "bit-identity, token-identity at sigma=0, the benchmark "
        "regression gate — compares seeded reruns.  One wall-clock "
        "seed anywhere upstream and those gates compare noise to "
        "noise, i.e. they stop gating.\n\n"
        "Fix: accept a seed in the config/CLI (as every benchmark and "
        "the gateway's Poisson workload already do) and derive "
        "per-component keys with jax.random.split / fold_in."),
    Rule(
        "RPL502", "no set iteration into wire frames", check_set_iteration,
        "Inside repro.hw and repro.serving (the packages that build "
        "wire frames and op lists), iterating a set / frozenset / set "
        "algebra expression is forbidden — wrap in sorted() or use an "
        "ordered container.\n\n"
        "Why: set iteration order varies with insertion history and "
        "per-process hash state.  Driver ops are *stateful* (writes, "
        "drift advances, metered probes), so an op list whose order "
        "comes from a set executes different physics per process — "
        "breaking batched ≡ sequential bit-identity on exactly the "
        "transport that batched it, the hardest bug class to bisect.\n\n"
        "Fix: `for op in sorted(ops):` or keep the collection a list; "
        "membership tests on sets remain fine."),
]
