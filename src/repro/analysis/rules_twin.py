"""RPL1xx — twin-boundary analyzers.

The paper's whole premise (§3.2) is that on-chip learning sees only the
*observable* chip state: the end-to-end UΣV* response, the commanded
(not realized) settings, and metered probe results.  The digital twin's
ground truth — realized unitaries, drift state, exact mapping
distances — exists in this repo only for diagnostics, quarantined
behind ``driver.unsafe_twin()``.  Code that reaches around that hatch
is not "cheating a simulation detail": it is silently converting the
in-situ protocol into the idealized-model training the paper exists to
avoid, and it would break outright on real hardware (where the twin
modules simply do not exist).
"""

from __future__ import annotations

import ast
from typing import Iterator

from .astutil import SourceFile, line_at
from .findings import Finding, Rule

__all__ = ["RULES"]

# modules of repro.hw that are device-side internals: the twin physics,
# the realization sampler, the OU drift walk, the in-situ search jobs,
# and the wire server that hosts them.  Only repro.hw itself may import
# these; everything else routes through the `repro.hw` package surface
# (re-exported configs/factories) or `driver.unsafe_twin()`.
INTERNAL_MODULES = frozenset(["twin", "device", "drift", "jobs", "server"])

# symbols that only exist device-side; control-plane code naming them
# (outside an unsafe_twin() chain) has crossed the boundary
INTERNAL_SYMBOLS = frozenset([
    "DeviceRealization", "sample_device", "realized_unitaries",
    "realized_blocks", "DriftState", "init_drift", "TwinHandle",
    "chip_forward",
])
# legal only through the hatch: `driver.unsafe_twin().<attr>`
HATCH_ONLY_ATTRS = frozenset(["true_mapping_distance", "bias_deviation"])

# where unsafe_twin() may be *called*: tests, benchmarks, examples, the
# hw package itself (TwinDriver defines it; the server's unsafe/* ops
# and the stream client's remote handle back it), and the fleet
# registry's true_*distances diagnostics
UNSAFE_TWIN_ALLOWLIST = (
    "tests", "benchmarks", "examples", "repro.hw", "repro.analysis",
    "repro.runtime.fleet",
)


def _is_exempt(sf: SourceFile, prefixes) -> bool:
    return any(sf.in_package(p) for p in prefixes)


def _import_targets(node: ast.AST) -> Iterator[tuple[str, ast.AST]]:
    """(dotted module, node) for every module an import statement touches."""
    if isinstance(node, ast.Import):
        for alias in node.names:
            yield alias.name, node
    elif isinstance(node, ast.ImportFrom):
        mod = "." * node.level + (node.module or "")
        yield mod, node


def _targets_internal(mod: str) -> str | None:
    """The internal hw module a dotted import path reaches, if any.

    Matches absolute (``repro.hw.twin``), relative (``..hw.drift``,
    ``.twin`` from inside hw) and bare (``hw.device``) spellings.
    """
    parts = [p for p in mod.lstrip(".").split(".") if p]
    for i, p in enumerate(parts):
        if p == "hw" and i + 1 < len(parts) and parts[i + 1] in INTERNAL_MODULES:
            return parts[i + 1]
    return None


def check_twin_imports(corpus) -> Iterator[Finding]:
    for sf in corpus:
        if _is_exempt(sf, ("repro.hw", "repro.analysis",
                           "tests", "benchmarks", "examples")):
            continue
        for node in ast.walk(sf.tree):
            for mod, at in _import_targets(node):
                hit = _targets_internal(mod)
                if hit is not None:
                    yield Finding(
                        "RPL101", sf.rel, at.lineno, at.col_offset,
                        f"import of twin-internal module 'hw.{hit}' outside "
                        f"repro.hw — route through the repro.hw package "
                        f"surface or driver.unsafe_twin()",
                        line_at(sf, at))


def check_unsafe_twin_callsites(corpus) -> Iterator[Finding]:
    for sf in corpus:
        if _is_exempt(sf, UNSAFE_TWIN_ALLOWLIST):
            continue
        for node in ast.walk(sf.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "unsafe_twin"):
                yield Finding(
                    "RPL102", sf.rel, node.lineno, node.col_offset,
                    "unsafe_twin() call outside the diagnostic allowlist "
                    "(tests, benchmarks, repro.hw, runtime/fleet.py) — "
                    "control-plane code must stay on the observable surface",
                    line_at(sf, node))


def _via_hatch(node: ast.Attribute) -> bool:
    """True when the attribute hangs off an ``unsafe_twin()`` chain."""
    for sub in ast.walk(node.value):
        if (isinstance(sub, ast.Call)
                and isinstance(sub.func, ast.Attribute)
                and sub.func.attr == "unsafe_twin"):
            return True
        if isinstance(sub, ast.Name) and "unsafe" in sub.id:
            # a handle variable like `h = driver.unsafe_twin()` — covered
            # by the RPL102 allowlist at the call site
            return True
    return False


def check_twin_symbols(corpus) -> Iterator[Finding]:
    for sf in corpus:
        if _is_exempt(sf, ("repro.hw", "repro.analysis",
                           "tests", "benchmarks", "examples")):
            continue
        for node in ast.walk(sf.tree):
            name = None
            if isinstance(node, ast.Name) and node.id in INTERNAL_SYMBOLS:
                name = node.id
            elif isinstance(node, ast.Attribute):
                if node.attr in INTERNAL_SYMBOLS:
                    name = node.attr
                elif node.attr in HATCH_ONLY_ATTRS and not _via_hatch(node):
                    name = node.attr
            if name is not None:
                yield Finding(
                    "RPL103", sf.rel, node.lineno, node.col_offset,
                    f"twin-internal symbol {name!r} referenced in "
                    f"control-plane code — only reachable through "
                    f"driver.unsafe_twin() in allowlisted diagnostics",
                    line_at(sf, node))


RULES = [
    Rule(
        "RPL101", "twin-internal import boundary", check_twin_imports,
        "Only modules inside `repro.hw` may import the device-side "
        "internals `hw.twin`, `hw.device`, `hw.drift`, `hw.jobs`, or "
        "`hw.server` (any spelling: absolute, relative, or bare).\n\n"
        "Why: those modules hold the simulated ground truth (realized "
        "unitaries, OU drift state) that does not exist on real "
        "hardware.  Control-plane code that imports them compiles "
        "against a fiction — it would train on information the chip "
        "cannot give it (the idealized-model failure mode L2ight §3.2 "
        "exists to avoid) and crash on a real instrument driver.\n\n"
        "Fix: import the re-exported configuration/factory surface from "
        "`repro.hw` (e.g. `from ..hw import DriftConfig, make_twin`), "
        "or route twin readouts through `driver.unsafe_twin()` from an "
        "allowlisted diagnostic context."),
    Rule(
        "RPL102", "unsafe_twin() call-site allowlist",
        check_unsafe_twin_callsites,
        "`driver.unsafe_twin()` is the single audited escape hatch to "
        "twin ground truth, and its call sites are restricted to: "
        "tests, benchmarks, examples, `repro.hw` itself, and "
        "`repro.runtime.fleet`'s true_*distances diagnostics.\n\n"
        "Why: every call site is a place the stack depends on "
        "information a real chip cannot provide.  Keeping the list "
        "explicit (and small) is what makes the hardware-in-the-loop "
        "claim auditable: on real hardware the hatch raises "
        "TwinUnavailable, so anything outside diagnostics would break.\n\n"
        "Fix: compute the quantity from observable probes "
        "(driver.forward / readback_bases), or move the diagnostic into "
        "tests/benchmarks.  Extending the allowlist is an explicit, "
        "reviewed edit to repro/analysis/rules_twin.py."),
    Rule(
        "RPL103", "twin-internal symbol quarantine", check_twin_symbols,
        "Control-plane code (src/repro outside repro.hw) may not "
        "reference device-side symbols (DeviceRealization, "
        "sample_device, realized_unitaries, DriftState, init_drift, "
        "TwinHandle, chip_forward, ...), and may reach "
        "`true_mapping_distance` / `bias_deviation` only through an "
        "`unsafe_twin()` chain.\n\n"
        "Why: this is the AST-accurate version of the old regex guard "
        "in tests/test_driver.py — naming these symbols at all means "
        "the code's logic depends on unobservable state.\n\n"
        "Fix: as RPL101/RPL102 — use the observable driver surface, or "
        "move the code into a diagnostic context."),
]
