"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["ptc_block_matmul_ref", "mesh_apply_ref", "feedback_matmul_ref",
           "sigma_grad_ref"]


def sigma_grad_ref(dy, x, u, v):
    """In-situ Σ-grad oracle: ds_pq = Σ_t (U_pqᵀ δy_p) ⊙ (V*_pq x_q)."""
    p, q, k, _ = u.shape
    dyb = dy.reshape(dy.shape[0], p, k)
    xb = x.reshape(x.shape[0], q, k)
    gu = jnp.einsum("pqik,tpi->tpqk", u, dyb)
    xv = jnp.einsum("pqkj,tqj->tpqk", v, xb)
    return jnp.einsum("tpqk,tpqk->pqk", gu, xv)


def ptc_block_matmul_ref(x, u, s, v):
    """y[t, p·k+i] = Σ_q (U_pq (s_pq ⊙ (V*_pq x_q)))_i.

    x: (T, Q·k); u,v: (P, Q, k, k); s: (P, Q, k)  →  y: (T, P·k)
    """
    p, q, k, _ = u.shape
    xb = x.reshape(x.shape[0], q, k)
    yv = jnp.einsum("pqkj,tqj->tpqk", v, xb)
    y = jnp.einsum("pqik,tpqk->tpi", u, yv * s)
    return y.reshape(x.shape[0], p * k)


def mesh_apply_ref(x, phases, layer_slot, layer_partner, layer_sign, d=None):
    """Layered butterfly mesh U(Φ)·x — mirrors repro.core.unitary.apply_mesh.

    x: (B, k); phases: (T,); layer_*: (L, k) static schedules; d: (k,)|None.
    """
    if d is not None:
        x = x * d
    n_layers = layer_slot.shape[0]
    for l in range(n_layers):
        sl, pt, sg = layer_slot[l], layer_partner[l], layer_sign[l]
        ph = jnp.where(sl >= 0, phases[jnp.maximum(sl, 0)], 0.0)
        c = jnp.where(sl >= 0, jnp.cos(ph), 1.0).astype(x.dtype)
        s = jnp.where(sl >= 0, jnp.sin(ph), 0.0).astype(x.dtype) * sg.astype(x.dtype)
        x = c * x + s * x[:, pt]
    return x


def feedback_matmul_ref(dy, u, s, v, mask):
    """Block-masked error feedback: dx_q = Σ_p mask[q,p] · W_pqᵀ δy_p.

    dy: (T, P·k); mask: (Q, P) scaled float  →  dx: (T, Q·k)
    """
    p, q, k, _ = u.shape
    dyb = dy.reshape(dy.shape[0], p, k)
    gu = jnp.einsum("pqik,tpi->tpqk", u, dyb)          # Uᵀ δy
    gus = gu * s * mask.T[None, :, :, None]            # Σ ⊙ · with 𝑃_W
    dx = jnp.einsum("pqkj,tpqk->tqj", v, gus)          # V ·
    return dx.reshape(dy.shape[0], q * k)
