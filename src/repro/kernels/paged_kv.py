"""Pallas TPU kernels: paged KV-cache page assembly (gather/scatter).

The serving gateway (``repro.serving``) stores every in-flight request's
KV history in fixed-size pages of one shared pool; per-request *page
tables* map logical token blocks to physical pages.  Decode needs two
data movements per step:

* **gather** — assemble each request slot's pages into a contiguous
  (S_max, d) view the attention kernel can consume.  On TPU the page
  table rides in as a scalar-prefetch operand
  (``PrefetchScalarGridSpec``), so the index map can address the page
  dimension *before* the kernel body runs and each (slot, page) grid
  step is ONE VMEM-resident block copy — the standard paged-attention
  DMA idiom.  No compute, pure layout: the copy is exact, so the
  assembled view is bit-identical to the pool contents.
* **scatter** — write each slot's freshly projected k/v row into its
  current (page, offset) write position, in place (the pool is aliased
  into the output, ``input_output_aliases``), one dynamic-slice store
  per slot.

``interpret=True`` (the default off-TPU, via ``kernels.ops``) runs the
exact same kernel bodies on this CPU container; on a TPU backend the
same calls compile to Mosaic.  Pool/table shapes are static — only the
table *contents* change per step — so both calls jit cleanly.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_gather", "paged_scatter", "paged_scatter_rows"]


def _gather_kernel(tbl_ref, pages_ref, out_ref):
    # grid (slot b, page j): the in_spec already DMA'd page tbl[b, j]
    # into pages_ref; emit it as the j-th block of slot b's view.
    out_ref[0, 0] = pages_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_gather(table: jax.Array, pages: jax.Array, *,
                 interpret: bool = True) -> jax.Array:
    """Assemble per-slot contiguous KV views from a paged pool.

    table: (B, J) int32 physical page ids (unallocated entries must
    hold a valid id — 0 by convention; attention masks them by length).
    pages: (n_pages, page_size, d).  Returns (B, J·page_size, d).
    """
    b, j = table.shape
    _, ps, d = pages.shape
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, j),
            in_specs=[pl.BlockSpec((1, ps, d),
                                   lambda bb, jj, t: (t[bb, jj], 0, 0))],
            out_specs=pl.BlockSpec((1, 1, ps, d),
                                   lambda bb, jj, t: (bb, jj, 0, 0))),
        out_shape=jax.ShapeDtypeStruct((b, j, ps, d), pages.dtype),
        interpret=interpret,
    )(table, pages)
    return out.reshape(b, j * ps, d)


def _scatter_kernel(idx_ref, new_ref, pages_ref, out_ref):
    del pages_ref                     # aliased into out_ref
    b = pl.program_id(0)
    pid = idx_ref[b, 0]
    off = idx_ref[b, 1]
    out_ref[pid, pl.ds(off, 1), :] = new_ref[0][None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_scatter(idx: jax.Array, new: jax.Array, pages: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """Write one new KV row per slot into its page-table position.

    idx: (B, 2) int32 — per slot ``(page_id, offset)`` write position
    (idle slots must point somewhere harmless, e.g. a scratch page).
    new: (B, d) rows; pages: (n_pages, page_size, d), updated in place
    via output aliasing.  Returns the updated pool.
    """
    b = new.shape[0]
    d = new.shape[-1]
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b,),
            in_specs=[pl.BlockSpec((1, d), lambda bb, t: (bb, 0)),
                      pl.BlockSpec(pages.shape, lambda bb, t: (0, 0, 0))],
            out_specs=pl.BlockSpec(pages.shape, lambda bb, t: (0, 0, 0))),
        out_shape=jax.ShapeDtypeStruct(pages.shape, pages.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(idx, new, pages)


def paged_scatter_rows(idx: jax.Array, rows: jax.Array, pages: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """Multi-token scatter: R independent row writes in ONE aliased call.

    The chunked-prefill path writes C new KV entries per slot per step;
    the host splits each chunk against the slot's page table wherever it
    crosses a page boundary (``PagedKVPool.write_span``) and hands the
    flattened (R, 2) ``(page_id, offset)`` list here.  The scatter
    kernel is already row-count generic — the grid runs one program per
    row, sequentially, so duplicate targets (e.g. every invalid row
    parked on the scratch page) resolve deterministically last-wins —
    and the pool is updated in place through the same
    ``input_output_aliases`` wiring as the one-row path.

    idx: (R, 2) int32; rows: (R, d); pages: (n_pages, page_size, d).
    """
    return paged_scatter(idx, rows, pages, interpret=interpret)
