"""Dispatch layer: jit'd public ops that route to the Pallas kernels on TPU
and to interpret-mode (CPU-executed kernel bodies) elsewhere.

``interpret`` defaults to True off-TPU so the exact kernel code paths are
validated on this CPU container; on a real TPU backend the same calls
compile to Mosaic.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..core.unitary import MeshSpec
from .ptc_block_matmul import ptc_block_matmul as _ptc_block_matmul
from .mesh_apply import mesh_apply_butterfly as _mesh_apply_butterfly
from .feedback_matmul import feedback_matmul as _feedback_matmul
from .sigma_grad import sigma_grad as _sigma_grad
from .paged_kv import (paged_gather as _paged_gather,
                       paged_scatter as _paged_scatter,
                       paged_scatter_rows as _paged_scatter_rows)
from .prefill_attn import prefill_attention as _prefill_attention

__all__ = ["default_interpret", "ptc_block_matmul", "mesh_apply",
           "feedback_matmul", "sigma_grad", "paged_gather", "paged_scatter",
           "paged_scatter_rows", "prefill_attention"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_t_tile(t: int, cap: int = 256) -> int:
    """Largest divisor of t that is ≤ cap (grids need exact tiling)."""
    best = 1
    for d in range(1, min(t, cap) + 1):
        if t % d == 0:
            best = d
    return best


def ptc_block_matmul(x, u, s, v, *, interpret: bool | None = None):
    """Blocked PTC forward (paper dataflow) via the Pallas kernel."""
    if interpret is None:
        interpret = default_interpret()
    return _ptc_block_matmul(x, u, s, v, t_tile=_pick_t_tile(x.shape[0]),
                             interpret=interpret)


def _coeff_tables(spec: MeshSpec, phases, dtype):
    """Per-layer wire coefficient tables (cheap, O(T) cos/sin)."""
    slot = jnp.asarray(spec.layer_slot)          # (L, k)
    sign = jnp.asarray(spec.layer_sign, dtype)   # (L, k)
    live = slot >= 0
    ph = jnp.where(live, jnp.take(phases, jnp.maximum(slot, 0)), 0.0)
    c = jnp.where(live, jnp.cos(ph), 1.0).astype(dtype)
    s = (jnp.where(live, jnp.sin(ph), 0.0) * sign).astype(dtype)
    return c, s, sign


def mesh_apply(spec: MeshSpec, phases, x, d=None, *,
               interpret: bool | None = None):
    """U(Φ, D) @ x via the butterfly kernel.  x: (B, k); phases: (T,)."""
    if interpret is None:
        interpret = default_interpret()
    c, s, sign = _coeff_tables(spec, phases, x.dtype)
    if d is None:
        d = jnp.ones((spec.k,), x.dtype)
    return _mesh_apply_butterfly(c, s, sign, d.astype(x.dtype), x,
                                 b_tile=_pick_t_tile(x.shape[0]),
                                 interpret=interpret)


def feedback_matmul(dy, u, s, v, mask, *, interpret: bool | None = None):
    """Block-masked feedback pass via the predicated Pallas kernel."""
    if interpret is None:
        interpret = default_interpret()
    return _feedback_matmul(dy, u, s, v, mask,
                            t_tile=_pick_t_tile(dy.shape[0]),
                            interpret=interpret)


def sigma_grad(dy, x, u, v, *, interpret: bool | None = None):
    """Fused in-situ Σ-gradient (paper Eq. 5) via the Pallas kernel."""
    if interpret is None:
        interpret = default_interpret()
    return _sigma_grad(dy, x, u, v, t_tile=_pick_t_tile(dy.shape[0]),
                       interpret=interpret)


def paged_gather(table, pages, *, interpret: bool | None = None):
    """Paged-KV page assembly (serving gateway) via the Pallas kernel."""
    if interpret is None:
        interpret = default_interpret()
    return _paged_gather(table, pages, interpret=interpret)


def paged_scatter(idx, new, pages, *, interpret: bool | None = None):
    """Paged-KV token insertion (serving gateway) via the Pallas kernel."""
    if interpret is None:
        interpret = default_interpret()
    return _paged_scatter(idx, new, pages, interpret=interpret)


def paged_scatter_rows(idx, rows, pages, *, interpret: bool | None = None):
    """Multi-token paged-KV insertion (chunked prefill) in one call."""
    if interpret is None:
        interpret = default_interpret()
    return _paged_scatter_rows(idx, rows, pages, interpret=interpret)


def prefill_attention(lens, q, k, v, *, blk=None, window=None, cap=None,
                      interpret: bool | None = None):
    """Chunked paged-prefill attention (serving gateway) via Pallas."""
    if interpret is None:
        interpret = default_interpret()
    return _prefill_attention(lens, q, k, v, blk=blk, window=window,
                              cap=cap, interpret=interpret)
