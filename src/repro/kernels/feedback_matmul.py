"""Pallas TPU kernel: block-masked error feedback ``dx_q = Σ_p 𝑃_W[q,p]·W_pqᵀ δy_p``.

The paper's feedback sampling makes masked PTC blocks "entirely idle,
directly saving energy" (§3.4.2).  On TPU the same structured sparsity
becomes REAL compute savings only at block granularity: the kernel
predicates the whole (p, q) block-matmul on the mask value, so dropped
blocks skip both MXU issue and the accumulate — a ~(1−α_W) FLOP cut on
the feedback pass, and the btopk row-balance guarantees every output
tile finishes in the same number of accumulation steps (no stragglers
across the grid — the photonic load-balance argument, Fig. 7, transfers
verbatim to the sequential grid walk).

Grid = (T/T_TILE, Q, P), p innermost for consecutive output revisits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["feedback_matmul"]


def _kernel(dy_ref, u_ref, s_ref, v_ref, m_ref, o_ref):
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    m = m_ref[0, 0]

    @pl.when(m != 0.0)
    def _compute():
        dy = dy_ref[...]                 # (T_TILE, k)
        gu = jnp.dot(dy, u_ref[0, 0],
                     preferred_element_type=jnp.float32)   # Uᵀ δy
        gus = gu * (s_ref[0, 0] * m)                       # Σ ⊙ · (scaled)
        dx = jnp.dot(gus, v_ref[0, 0],
                     preferred_element_type=jnp.float32)   # V ·
        o_ref[...] += dx.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("t_tile", "interpret"))
def feedback_matmul(dy: jax.Array, u: jax.Array, s: jax.Array, v: jax.Array,
                    mask: jax.Array, *, t_tile: int = 256,
                    interpret: bool = False) -> jax.Array:
    """dy: (T, P·k), u/v: (P, Q, k, k), s: (P, Q, k), mask: (Q, P) scaled
    float → dx: (T, Q·k)."""
    t, mdim = dy.shape
    p, q, k, _ = u.shape
    assert mdim == p * k
    t_tile = min(t_tile, t)
    assert t % t_tile == 0, (t, t_tile)
    grid = (t // t_tile, q, p)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_tile, k), lambda i, qq, pp: (i, pp)),
            pl.BlockSpec((1, 1, k, k), lambda i, qq, pp: (pp, qq, 0, 0)),
            pl.BlockSpec((1, 1, k), lambda i, qq, pp: (pp, qq, 0)),
            pl.BlockSpec((1, 1, k, k), lambda i, qq, pp: (pp, qq, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, qq, pp: (qq, pp)),
        ],
        out_specs=pl.BlockSpec((t_tile, k), lambda i, qq, pp: (i, qq)),
        out_shape=jax.ShapeDtypeStruct((t, q * k), dy.dtype),
        interpret=interpret,
    )(dy, u, s, v, mask)
