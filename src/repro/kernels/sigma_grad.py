"""Pallas TPU kernel: in-situ Σ-gradient ``ds_pq = Σ_t (U_pqᵀδy_p) ⊙ (V*_pq x_q)``.

The paper's Eq. (5) backward-weight step — the two reciprocal PTC passes
and the electronic Hadamard-accumulate — as one fused kernel: per (p, q)
block it streams token tiles, computes both k-projections on the MXU,
multiplies element-wise and accumulates the (k,) gradient in VMEM.  The
(T, P, Q, k) intermediates of the naive formulation never exist: the
working set is two (T_TILE, k) tiles + two k×k bases + the (k,)
accumulator per grid step.

Grid = (P, Q, T/T_TILE), token tiles innermost so the per-block
accumulator stays resident across the whole stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["sigma_grad"]


def _kernel(dy_ref, x_ref, u_ref, v_ref, o_ref):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    dy = dy_ref[...]                                  # (T_TILE, k)
    x = x_ref[...]                                    # (T_TILE, k)
    gu = jnp.dot(dy, u_ref[0, 0],
                 preferred_element_type=jnp.float32)  # Uᵀ δy
    xv = jnp.dot(x, v_ref[0, 0].T,
                 preferred_element_type=jnp.float32)  # V* x
    o_ref[...] += jnp.sum(gu * xv, axis=0)[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("t_tile", "interpret"))
def sigma_grad(dy: jax.Array, x: jax.Array, u: jax.Array, v: jax.Array,
               *, t_tile: int = 256, interpret: bool = False) -> jax.Array:
    """dy: (T, P·k); x: (T, Q·k); u/v: (P, Q, k, k) → ds: (P, Q, k)."""
    t, mdim = dy.shape
    p, q, k, _ = u.shape
    assert mdim == p * k and x.shape == (t, q * k)
    t_tile = min(t_tile, t)
    assert t % t_tile == 0, (t, t_tile)
    out = pl.pallas_call(
        _kernel,
        grid=(p, q, t // t_tile),
        in_specs=[
            pl.BlockSpec((t_tile, k), lambda pp, qq, tt: (tt, pp)),
            pl.BlockSpec((t_tile, k), lambda pp, qq, tt: (tt, qq)),
            pl.BlockSpec((1, 1, k, k), lambda pp, qq, tt: (pp, qq, 0, 0)),
            pl.BlockSpec((1, 1, k, k), lambda pp, qq, tt: (pp, qq, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, k), lambda pp, qq, tt: (pp, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((p, q, k), jnp.float32),
        interpret=interpret,
    )(dy, x, u, v)
    return out
