"""Pallas TPU kernel: chunked paged-prefill attention (serving gateway).

One grid step handles one slot × one KV block: a causal chunk of C
query tokens (the slot's next prompt tokens, already rope'd at absolute
positions ``lens[b] + c``) attends over the slot's page-assembled KV
view with an online-softmax accumulation (running max / denominator /
accumulator in VMEM scratch), so a long context is consumed block by
block and the full (C, S_max) score matrix never materializes beyond
one (C, blk) tile.

The caller splices the chunk's own freshly-projected K/V rows into the
view at ``lens[b]..lens[b]+C-1`` before the call, so in-chunk causal
attention (token c attending to tokens < c of the same chunk) falls out
of the ordinary position mask — the kernel needs no intra-chunk special
case.  Per-slot valid lengths ride in as a scalar-prefetch operand, the
same layout trick as ``paged_kv.py``.

Masking discipline for the online update: masked logits are forced to a
*finite* floor (NEG_INF) before the block max so an all-masked block
keeps the running max finite, and the exponentiated weights are zeroed
*by the mask* (not by the floor) so ``exp(NEG_INF - NEG_INF) = 1``
can never leak a masked key into the accumulator — that is what makes
a fully-out-of-window block contribute exactly +0.0 and keeps the
result bitwise independent of how many padding columns ride along.

Off-TPU this runs in interpret mode (kernel body executed by XLA:CPU),
like every other kernel in this package; on a TPU backend the same call
compiles to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["prefill_attention"]

NEG_INF = -2.0 ** 30   # finite floor: keeps max/exp arithmetic NaN-free


def _prefill_kernel(lens_ref, q_ref, k_ref, v_ref, out_ref,
                    acc_ref, m_ref, denom_ref, *,
                    blk, rep, scale, cap, window):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        denom_ref[...] = jnp.zeros_like(denom_ref)

    q = q_ref[0]                                   # (C, H, Dh)
    kb = jnp.repeat(k_ref[0], rep, axis=1)         # (blk, H, Dh) GQA expand
    vb = jnp.repeat(v_ref[0], rep, axis=1)
    c = q.shape[0]
    logits = jnp.einsum("qhd,khd->hqk", q, kb).astype(jnp.float32) * scale
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    ln = lens_ref[b]
    qi = ln + jax.lax.broadcasted_iota(jnp.int32, (c, blk), 0)
    ki = j * blk + jax.lax.broadcasted_iota(jnp.int32, (c, blk), 1)
    ok = ki <= qi
    if window is not None:
        ok = ok & (ki > qi - window)
    logits = jnp.where(ok[None], logits, NEG_INF)
    m_new = jnp.maximum(m_ref[...], logits.max(-1))          # (H, C)
    alpha = jnp.exp(m_ref[...] - m_new)
    p = jnp.where(ok[None], jnp.exp(logits - m_new[..., None]), 0.0)
    denom_ref[...] = denom_ref[...] * alpha + p.sum(-1)
    acc_ref[...] = (acc_ref[...] * alpha[..., None]
                    + jnp.einsum("hqk,khd->hqd", p,
                                 vb.astype(jnp.float32)))
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        out = acc_ref[...] / denom_ref[...][..., None]
        out_ref[0] = jnp.swapaxes(out, 0, 1).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("blk", "window", "cap", "interpret"))
def prefill_attention(lens, q, k, v, *, blk: int | None = None,
                      window: int | None = None, cap: float | None = None,
                      interpret: bool = True):
    """Chunked-causal prefill attention over per-slot KV views.

    lens: (B,) int32 — tokens already in each slot's cache (the chunk's
    first query sits at absolute position ``lens[b]``).
    q: (B, C, H, Dh) rope'd queries for the C-token chunk.
    k, v: (B, S_max, Hkv, Dh) page-assembled views WITH the chunk's own
    rows already spliced in at ``lens[b]..lens[b]+C-1``.
    blk: KV block size (must divide S_max); None = one block, the whole
    view.  cap: attention logit soft-cap (gemma2); window: sliding
    window.  Returns (B, C, H, Dh) attended values in q's dtype.
    """
    b, c, h, hd = q.shape
    s, hkv = k.shape[1], k.shape[2]
    blk = s if blk is None else int(blk)
    if s % blk:
        raise ValueError(f"kv view length {s} not divisible by block {blk}")
    kern = functools.partial(_prefill_kernel, blk=blk, rep=h // hkv,
                             scale=hd ** -0.5, cap=cap, window=window)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, s // blk),
            in_specs=[
                pl.BlockSpec((1, c, h, hd), lambda bb, jj, t: (bb, 0, 0, 0)),
                pl.BlockSpec((1, blk, hkv, hd),
                             lambda bb, jj, t: (bb, jj, 0, 0)),
                pl.BlockSpec((1, blk, hkv, hd),
                             lambda bb, jj, t: (bb, jj, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, c, h, hd),
                                   lambda bb, jj, t: (bb, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((h, c, hd), jnp.float32),
                pltpu.VMEM((h, c), jnp.float32),
                pltpu.VMEM((h, c), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, c, h, hd), q.dtype),
        interpret=interpret,
    )(lens.astype(jnp.int32), q, k, v)
