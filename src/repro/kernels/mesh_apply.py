"""Pallas TPU kernel: MZI mesh application as a VPU butterfly network.

A Clements mesh is k alternating layers of disjoint adjacent 2×2
rotations — the photonic interference network IS a butterfly: each layer
recombines wire pairs ``y_a = c·x_a − s·x_b, y_b = s·x_a + c·x_b``.

On TPU this is a *lane-local* pattern: the partner exchange of adjacent
wires is a lane roll by ±1 with a parity select, and the per-wire cos/sin
coefficients are precomputed (L, k) tables (``ops.mesh_apply`` does the
cheap cos/sin gather outside).  The kernel is then a pure
roll+select+FMA pipeline over layers — no gathers, no matmuls, no HBM
traffic beyond one x tile in and out.  This applies U(Φ) WITHOUT
materializing it: O(L·k) work per row instead of O(k²), the TPU-native
analogue of light propagating through the mesh.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["mesh_apply_butterfly"]


def _kernel(c_ref, s_ref, dir_ref, d_ref, x_ref, o_ref):
    x = x_ref[...] * d_ref[...]          # sign diagonal D first
    n_layers = c_ref.shape[0]

    def body(l, x):
        c = c_ref[l]                     # (k,) cos, 1 on idle wires
        s = s_ref[l]                     # (k,) ±sin, 0 on idle wires
        sg = dir_ref[l]                  # (k,) -1 upper, +1 lower, 0 idle
        up = jnp.roll(x, -1, axis=1)     # partner of an upper wire is a+1
        dn = jnp.roll(x, 1, axis=1)      # partner of a lower wire is a-1
        xp = jnp.where(sg < 0, up, jnp.where(sg > 0, dn, x))
        return c * x + s * xp

    o_ref[...] = jax.lax.fori_loop(0, n_layers, body, x)


@functools.partial(jax.jit, static_argnames=("b_tile", "interpret"))
def mesh_apply_butterfly(c: jax.Array, s: jax.Array, direction: jax.Array,
                         d: jax.Array, x: jax.Array, *, b_tile: int = 256,
                         interpret: bool = False) -> jax.Array:
    """Apply the layered mesh to x.

    c, s, direction: (L, k) per-layer wire coefficient tables
    d: (k,) ±1 sign diagonal;  x: (B, k)  →  (B, k)
    """
    b, k = x.shape
    b_tile = min(b_tile, b)
    assert b % b_tile == 0, (b, b_tile)
    l = c.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(b // b_tile,),
        in_specs=[
            pl.BlockSpec((l, k), lambda i: (0, 0)),
            pl.BlockSpec((l, k), lambda i: (0, 0)),
            pl.BlockSpec((l, k), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((b_tile, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b_tile, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), x.dtype),
        interpret=interpret,
    )(c, s, direction, d, x)
