"""Pallas TPU kernel: blockwise PTC forward ``y_p = Σ_q U_pq(Σ_pq ⊙ (V*_pq x_q))``.

The paper's photonic dataflow — input mesh, attenuator column, output
mesh, electronic cross-PTC accumulation — maps onto the TPU as three
VMEM-resident ops per (p, q) block: two k×k MXU matmuls around a VPU
scale, accumulated over q into the output tile.

Tiling: grid = (T/T_TILE, P, Q), q innermost so output revisits are
consecutive (standard TPU accumulation pattern).  Per grid step the
working set is ``T_TILE·k (x) + 2·k² (U,V) + k (s) + T_TILE·k (acc)``
floats — at the production k=128, T_TILE=256 that is ~0.6 MB, well
inside the ~16 MB VMEM budget; k=128 also exactly fills the MXU's
128×128 systolic array (DESIGN §3: block size is the hardware-alignment
knob on TPU, not a noise-robustness compromise).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ptc_block_matmul"]


def _kernel(x_ref, u_ref, s_ref, v_ref, o_ref):
    q = pl.program_id(2)

    @pl.when(q == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                       # (T_TILE, k)
    v = v_ref[0, 0]                      # (k, k) = V*_pq
    u = u_ref[0, 0]                      # (k, k) = U_pq
    s = s_ref[0, 0]                      # (k,)
    yv = jnp.dot(x, v.T, preferred_element_type=jnp.float32)   # V* x
    ys = yv * s                                                # Σ ⊙ ·
    yu = jnp.dot(ys, u.T, preferred_element_type=jnp.float32)  # U ·
    o_ref[...] += yu.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("t_tile", "interpret"))
def ptc_block_matmul(x: jax.Array, u: jax.Array, s: jax.Array, v: jax.Array,
                     *, t_tile: int = 256, interpret: bool = False
                     ) -> jax.Array:
    """x: (T, Q·k), u/v: (P, Q, k, k), s: (P, Q, k) → y: (T, P·k)."""
    t, n = x.shape
    p, q, k, _ = u.shape
    assert n == q * k, (n, q, k)
    t_tile = min(t_tile, t)
    assert t % t_tile == 0, (t, t_tile)
    grid = (t // t_tile, p, q)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_tile, k), lambda i, pp, qq: (i, qq)),
            pl.BlockSpec((1, 1, k, k), lambda i, pp, qq: (pp, qq, 0, 0)),
            pl.BlockSpec((1, 1, k), lambda i, pp, qq: (pp, qq, 0)),
            pl.BlockSpec((1, 1, k, k), lambda i, pp, qq: (pp, qq, 0, 0)),
        ],
        out_specs=pl.BlockSpec((t_tile, k), lambda i, pp, qq: (i, pp)),
        out_shape=jax.ShapeDtypeStruct((t, p * k), x.dtype),
        interpret=interpret,
    )(x, u, s, v)
