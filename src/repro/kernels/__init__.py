"""Pallas TPU kernels for the PTC compute hot-spots.

* ``ptc_block_matmul`` — blockwise U(Σ⊙(V*x)) forward (the paper's PTC
  dataflow as MXU tiles);
* ``mesh_apply``       — MZI mesh as a VPU butterfly (applies U(Φ)
  without materializing it);
* ``feedback_matmul``  — block-masked feedback pass (structured sparsity
  → predicated MXU blocks);
* ``sigma_grad``       — fused in-situ Σ-gradient (Eq. 5): both reciprocal
  projections + Hadamard-accumulate without the (T,P,Q,k) intermediate;
* ``paged_gather`` / ``paged_scatter`` — paged-KV page assembly and
  token insertion for the continuous-batching serving gateway
  (scalar-prefetched page tables → per-page DMA block copies).

``ops`` is the jit'd dispatch layer; ``ref`` holds the pure-jnp oracles
each kernel is allclose-tested against (interpret=True on CPU).
"""

from .ops import (ptc_block_matmul, mesh_apply, feedback_matmul,  # noqa: F401
                  sigma_grad, paged_gather, paged_scatter)
from . import ref  # noqa: F401
