"""`SocketDriver`: the op-stream driver protocol over a TCP socket.

Same framing, same v3 surface, same bit-identical results as the pipe
transport — but the twin server can live on *another host*: point the
driver at an ``address=(host, port)`` where ``python -m repro.hw.server
--socket HOST:PORT`` is listening, and the whole control plane (IC, PM,
monitoring, recalibration, fleet serving) runs against the remote
device unchanged.

With ``address=None`` the driver self-hosts: it spawns a local server
child bound to an ephemeral loopback port (``--socket 127.0.0.1:0
--max-conns 1``), reads the announced port off the child's stdout, and
connects — which is how the conformance suite and benchmarks exercise
the TCP path hermetically.

``TCP_NODELAY`` is set on the connection: the protocol is strictly
request/response, so Nagle's algorithm would add a delayed-ACK stall to
every small frame — fatal for a data plane whose whole point is
round-trip amortization.
"""

from __future__ import annotations

import os
import socket
import subprocess
import sys
import tempfile

import jax

from ..core.noise import NoiseModel
from .drift import DriftConfig
from .protocol import ProtocolError
from .stream_driver import StreamDriver
from .subprocess_driver import server_env, stderr_tail

__all__ = ["SocketDriver"]


class SocketDriver(StreamDriver):
    """Control-plane client to a twin server over TCP."""

    def __init__(self, key: jax.Array, n_blocks: int, k: int,
                 model: NoiseModel, kind: str = "clements", *,
                 m: int | None = None, n: int | None = None,
                 drift: DriftConfig | None = None,
                 address: tuple[str, int] | None = None,
                 python: str | None = None, connect_timeout: float = 30.0):
        self._proc = None
        self._stderr = None
        if address is None:
            # self-hosted: spawn a loopback server child and learn its port
            self._stderr = tempfile.NamedTemporaryFile(
                mode="w+", prefix="repro-hw-server-", suffix=".err",
                delete=False)
            self._proc = subprocess.Popen(
                [python or sys.executable, "-u", "-m", "repro.hw.server",
                 "--socket", "127.0.0.1:0", "--max-conns", "1"],
                stdin=subprocess.DEVNULL, stdout=subprocess.PIPE,
                stderr=self._stderr, text=True, env=server_env())
            line = self._proc.stdout.readline()
            if not line.startswith("LISTENING "):
                self.close()
                raise ProtocolError(
                    f"socket server failed to announce its port: {line!r}"
                    + self._transport_diagnostics())
            address = ("127.0.0.1", int(line.split()[1]))
        self._sock = socket.create_connection(address,
                                              timeout=connect_timeout)
        self._sock.settimeout(None)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        # 1 MiB stream buffers (batched frames are ~100 KB; the default
        # 8 KB would syscall a dozen times per frame)
        self._fin = self._sock.makefile("r", encoding="utf-8", newline="\n",
                                        buffering=1 << 20)
        self._fout = self._sock.makefile("w", encoding="utf-8", newline="\n",
                                         buffering=1 << 20)
        self._handshake(key, n_blocks, k, model, kind, m, n, drift)

    # -- transport hooks -----------------------------------------------------

    def _transport_alive(self) -> bool:
        return getattr(self, "_sock", None) is not None

    def _transport_diagnostics(self) -> str:
        return stderr_tail(self._stderr)

    def close(self) -> None:
        sock = getattr(self, "_sock", None)
        if sock is not None:
            self._shutdown_stream()
            try:
                self._fin.close()
                self._fout.close()
            except Exception:
                pass
            try:
                sock.close()
            except OSError:
                pass
            self._sock = None
            self._fin = self._fout = None
        if self._proc is not None:
            try:
                self._proc.wait(timeout=5)
            except Exception:
                self._proc.kill()
                self._proc.wait(timeout=5)
            self._proc = None
        if self._stderr is not None:
            try:
                self._stderr.close()
                os.unlink(self._stderr.name)
            except OSError:
                pass
            self._stderr = None

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
