"""`SocketDriver`: the op-stream driver protocol over a TCP socket.

Same framing, same op surface, same bit-identical results as the pipe
transport — but the twin server can live on *another host*: point the
driver at an ``address=(host, port)`` where ``python -m repro.hw.server
--socket HOST:PORT`` is listening, and the whole control plane (IC, PM,
monitoring, recalibration, fleet serving) runs against the remote
device unchanged.  The v4 server is concurrent (thread-per-connection),
so many SocketDrivers — a whole fleet — can share one server process,
each with its own independent session.

With ``address=None`` the driver self-hosts: it spawns a local server
child bound to an ephemeral loopback port (``--socket 127.0.0.1:0
--sessions 1``), reads the announced port off the child's stdout, and
connects — which is how the conformance suite and benchmarks exercise
the TCP path hermetically.  The announce read is bounded by
``connect_timeout`` (a child that dies silently, or never binds, fails
construction instead of hanging it), and any construction failure —
refused connection, handshake error — tears the child and its stderr
spool back down before the exception propagates.

``TCP_NODELAY`` is set on the connection: the protocol is strictly
request/response, so Nagle's algorithm would add a delayed-ACK stall to
every small frame — fatal for a data plane whose whole point is
round-trip amortization.
"""

from __future__ import annotations

import os
import select
import socket
import subprocess
import sys
import tempfile
import time

import jax

from ..core.noise import NoiseModel
from .drift import DriftConfig
from .protocol import ProtocolError
from .stream_driver import StreamDriver
from .subprocess_driver import server_env, stderr_tail

__all__ = ["SocketDriver"]


class SocketDriver(StreamDriver):
    """Control-plane client to a twin server over TCP."""

    def __init__(self, key: jax.Array, n_blocks: int, k: int,
                 model: NoiseModel, kind: str = "clements", *,
                 m: int | None = None, n: int | None = None,
                 drift: DriftConfig | None = None,
                 address: tuple[str, int] | None = None,
                 python: str | None = None, connect_timeout: float = 30.0,
                 protocol: int | None = None):
        self._proc = None
        self._stderr = None
        self._sock = None
        # any failure from here on — a child that never announces, a
        # refused connection, a handshake error — must not leak the
        # spawned server or its stderr spool: unwind via close()
        try:
            if address is None:
                # self-hosted: spawn a loopback server child, learn its port
                self._stderr = tempfile.NamedTemporaryFile(
                    mode="w+", prefix="repro-hw-server-", suffix=".err",
                    delete=False)
                self._proc = subprocess.Popen(
                    [python or sys.executable, "-u", "-m", "repro.hw.server",
                     "--socket", "127.0.0.1:0", "--sessions", "1"],
                    stdin=subprocess.DEVNULL, stdout=subprocess.PIPE,
                    stderr=self._stderr, env=server_env())
                line = self._read_announce(connect_timeout)
                if not line.startswith("LISTENING "):
                    raise ProtocolError(
                        f"socket server failed to announce its port: "
                        f"{line!r}" + self._transport_diagnostics())
                address = ("127.0.0.1", int(line.split()[1]))
            self._sock = socket.create_connection(address,
                                                  timeout=connect_timeout)
            self._sock.settimeout(None)
            self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            # 1 MiB stream buffers (batched frames are ~100 KB; the
            # default 8 KB would syscall a dozen times per frame)
            self._fin = self._sock.makefile("rb", buffering=1 << 20)
            self._fout = self._sock.makefile("wb", buffering=1 << 20)
            self._handshake(key, n_blocks, k, model, kind, m, n, drift,
                            protocol=protocol)
        except Exception:
            self.close()
            raise

    def _read_announce(self, timeout: float) -> str:
        """Bounded read of the child's ``LISTENING <port>`` line.

        Raw fd reads under ``select`` with a deadline: a child that dies
        before binding hits the EOF branch, one that never announces
        hits the deadline — either way construction fails promptly
        instead of blocking forever on ``readline()``."""
        fd = self._proc.stdout.fileno()
        deadline = time.monotonic() + timeout
        buf = b""
        while b"\n" not in buf:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise ProtocolError(
                    f"socket server did not announce its port within "
                    f"{timeout:.1f}s" + self._transport_diagnostics())
            ready, _, _ = select.select([fd], [], [], remaining)
            if not ready:
                continue
            chunk = os.read(fd, 4096)
            if not chunk:
                raise ProtocolError(
                    "socket server exited before announcing its port"
                    + self._transport_diagnostics())
            buf += chunk
        return buf.split(b"\n", 1)[0].decode("utf-8", "replace")

    # -- transport hooks -----------------------------------------------------

    def _transport_alive(self) -> bool:
        return getattr(self, "_sock", None) is not None

    def _transport_diagnostics(self) -> str:
        return stderr_tail(self._stderr)

    def close(self) -> None:
        sock = getattr(self, "_sock", None)
        if sock is not None:
            self._shutdown_stream()
            try:
                self._fin.close()
                self._fout.close()
            except Exception:
                pass
            try:
                sock.close()
            except OSError:
                pass
            self._sock = None
            self._fin = self._fout = None
        if getattr(self, "_proc", None) is not None:
            if sock is None:
                # construction never reached a session (announce timeout,
                # refused connection): the child is parked in accept()
                # and will not exit on its own — reap it
                self._proc.kill()
            try:
                self._proc.wait(timeout=5)
            except Exception:
                self._proc.kill()
                self._proc.wait(timeout=5)
            self._proc = None
        if getattr(self, "_stderr", None) is not None:
            try:
                self._stderr.close()
                os.unlink(self._stderr.name)
            except OSError:
                pass
            self._stderr = None

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
