"""`PhotonicDriver`: the single observability boundary to a device.

The paper's premise (§3.2) is that on chip only the end-to-end ``UΣV*``
response is observable — there is no free readout of the realized
unitaries, the phase biases, or the drift state.  Every stateful
control-plane path in this repo (IC, PM, health monitoring, closed-loop
recalibration, fleet serving) therefore talks to a device exclusively
through this ABC, which models the narrow surface a real single-chip
in-situ training stack exposes (Bandyopadhyay et al.):

================  =========================================================
op                physical meaning
================  =========================================================
write_phases      command the MZI rotation phases Φ^U / Φ^V
write_sigma       command the Σ attenuators (precisely tunable, §2)
write_signs       command the ±1 crossing configuration (topological)
read_phases/...   read back the *commanded* state (controller-known)
forward           stream probe columns through the realized UΣV* response
forward_layer     serve-path forward through the assembled P×Q block grid
readback_bases    reciprocal-probe readout of the realized bases (the
                  OSP primitive, Claim 1: 2 reciprocal PTC passes/block)
zo_refine         in-situ job: hardware-restricted ZCD on Φ against
                  electronically compared targets (runs on the device's
                  local controller — per-probe round-trips would defeat
                  in-situ operation)
run_ic            in-situ job: Identity Calibration's multi-Σ_cal
                  surrogate search (§3.2, Eq. 2)
advance           let (virtual) time pass: real chips drift by themselves;
                  the twin steps its OU walk from a device-owned chain
================  =========================================================

Every op that touches light is metered in :class:`DriverStats` with the
paper's Appendix-G normalized energy unit (PTC calls), replacing the
ad-hoc ``core.profiler`` bookkeeping the runtime previously scattered
around.  One probe column through B = P·Q blocks costs B calls — the
same ``E_fwd = P·Q·n_cols`` the profiler charges a layer.

Twin-only readouts (exact distances, the drifted ``DeviceRealization``)
are quarantined behind :meth:`PhotonicDriver.unsafe_twin`, which raises
:class:`TwinUnavailable` for drivers not backed by an inspectable twin.
Only tests and benchmarks may use it; the conformance suite's guard test
keeps it out of ``repro.runtime`` / ``core.calibration`` /
``core.mapping`` except through that explicit hatch.

Multi-tenancy
-------------
One physical chip is time-multiplexed across several mapped layers
("tenants", Bandyopadhyay et al.): each tenant owns a contiguous range
of the chip's block batch.  Every stateful or light-touching op
therefore takes an optional ``block_range=(start, stop)`` that scopes it
to those blocks only — writes land on the range alone, probes stream
through the range alone (and are charged for the range alone), and
in-situ jobs re-tune the range alone.  ``block_range=None`` means the
whole chip, which is the single-tenant behavior these APIs always had.

Batched op lists
----------------
Every driver also executes an *ordered op list* via :meth:`run_batch`
(``[(op_name, kwargs), ...]`` → per-op results).  In process this is
plain sequential dispatch; on the stream transports (subprocess pipe,
TCP socket) the whole list travels as ONE wire frame (protocol v3's
``batch`` op), amortizing the ~1 ms round-trip that otherwise dominates
fine-grained probe sweeps.  Semantics are identical by construction —
ops execute in list order against the same device, every op is metered
individually — so batched and sequential encodings are bit-identical
for equal seeds, which the conformance suite asserts on all transports.
Stream transports additionally *pipeline* result-less writes
(``write_*`` / ``advance`` / ``charge`` / ``reset_stats``): they queue
client-side and flush ahead of the next observable op in the same
frame (see :mod:`repro.hw.stream_driver`); :meth:`flush` forces the
queue down early.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DriverStats", "PhotonicDriver", "ZORefineResult", "ICJobResult",
           "TwinUnavailable", "CompletedBatch", "probe_cost",
           "readback_cost", "readout_blocks", "resolve_block_range",
           "BATCHABLE_OPS", "WIRE_INTERNAL_OPS", "STAT_CATEGORIES",
           "forward_coalesce_key", "coalesce_spans", "validate_batch_ops"]

# the PTC meter's categories (DriverStats fields a charge may land in)
STAT_CATEGORIES = frozenset(["serve", "probe", "readback", "search"])

# the op surface a batched list may carry — identical to the wire
# protocol's dispatchable set, so in-process and stream transports
# accept/reject exactly the same lists (lifecycle ops like ``close`` /
# ``unsafe_twin`` are excluded on every transport).
#
# Extending this set means wiring a server branch in hw/server.py and a
# client emitter in hw/stream_driver.py in the same commit — repro-lint
# (RPL201/RPL202/RPL204, `python -m repro.analysis.lint --explain
# RPL201`) blocks half-wired ops in CI.
BATCHABLE_OPS = frozenset([
    "write_phases", "write_sigma", "write_signs", "read_phases",
    "read_sigma", "forward", "forward_layer", "readback_bases",
    "zo_refine", "run_ic", "advance", "charge", "reset_stats", "stats",
])

# ops that exist only INSIDE a wire batch frame, never in a user op
# list: the v4 client rewrites a coalescible span of ``forward`` ops
# into one ``forward_many`` entry before encoding, and the server
# answers it with the same stacked shape its own coalescer emits.
# Each must have both a client emitter and a server branch (repro-lint
# RPL203 enforces the symmetry) but is rejected by
# ``validate_batch_ops`` — users batch ``forward``; the wire form is a
# transport detail.
WIRE_INTERNAL_OPS = frozenset(["forward_many"])


def forward_coalesce_key(kw: dict):
    """Coalescibility key for a batched ``forward`` op: consecutive
    forwards merge into one vmapped device call only when probe shape,
    metering category, and tenant scope all agree.  Works on python
    kwargs and decoded wire kwargs alike."""
    br = kw.get("block_range")
    x = kw.get("x")
    # .shape directly: np.shape() round-trips scalars through asarray,
    # which is ~5µs/op of pure overhead on the batch-64 hot path
    shape = getattr(x, "shape", None)
    return (tuple(shape) if shape is not None else np.shape(x),
            kw.get("category", "probe"),
            None if br is None else (int(br[0]), int(br[1])))


def coalesce_spans(keys: list) -> "list[tuple[int, int]]":
    """``[start, stop)`` spans of a batch op list, merging runs of equal
    consecutive non-None keys — the ONE definition of the coalescing
    rule shared by the in-process ``run_batch`` and the wire server's
    batch dispatcher (divergence would break batched ≡ sequential
    bit-identity on exactly one transport)."""
    spans = []
    i = 0
    while i < len(keys):
        j = i
        while (keys[i] is not None and j + 1 < len(keys)
               and keys[j + 1] == keys[i]):
            j += 1
        spans.append((i, j + 1))
        i = j + 1
    return spans


def validate_batch_ops(ops) -> None:
    """Reject a batched op list BEFORE executing anything: the stream
    transports validate at encode time (nothing ships), so the
    in-process dispatchers must not apply earlier ops and then die
    mid-list where the wire encoding would have refused up front."""
    for name, kw in ops:
        if name not in BATCHABLE_OPS:
            raise ValueError(
                f"op {name!r} cannot appear inside a batch")
        if kw.get("category") is not None \
                and kw["category"] not in STAT_CATEGORIES:
            raise ValueError(
                f"{name}: unknown PTC-meter category "
                f"{kw['category']!r} (one of {sorted(STAT_CATEGORIES)})")


class TwinUnavailable(RuntimeError):
    """The driver is not backed by an inspectable digital twin."""


def resolve_block_range(n_blocks: int,
                        block_range: tuple[int, int] | None
                        ) -> tuple[int, int]:
    """Validate a tenant block range against the chip geometry.

    ``None`` means the whole chip ``(0, n_blocks)``; otherwise the range
    must be a non-empty ``(start, stop)`` inside ``[0, n_blocks]``.
    """
    if block_range is None:
        return 0, n_blocks
    start, stop = int(block_range[0]), int(block_range[1])
    if not (0 <= start < stop <= n_blocks):
        raise ValueError(
            f"block_range {block_range!r} out of bounds for a chip with "
            f"{n_blocks} blocks")
    return start, stop


def probe_cost(n_blocks: int, n_cols: int) -> float:
    """PTC calls for ``n_cols`` probe columns through ``n_blocks`` blocks
    (Appendix-G: E_fwd = P·Q·n_cols with B = P·Q)."""
    return float(n_blocks * n_cols)


def readback_cost(n_blocks: int, k: int) -> float:
    """PTC calls for one reciprocal readback of the realized bases:
    two reciprocal passes of k columns per block (Claim 1)."""
    return float(2 * n_blocks * k)


def readout_blocks(driver: "PhotonicDriver", category: str = "probe",
                   block_range: tuple[int, int] | None = None) -> jax.Array:
    """Exact Ŵ readout, (B, k, k): k unit-vector probe columns per block
    — observability-legal (forward probes only), costs B·k PTC calls.
    The shared full-readout primitive for PM's error audit and the
    monitor's exact distance.  ``block_range`` scopes the readout to one
    tenant's blocks (and charges only those)."""
    k = driver.k
    y = driver.forward(jnp.eye(k, dtype=jnp.float32), category=category,
                       block_range=block_range)
    return jnp.transpose(y, (0, 2, 1))


@dataclasses.dataclass
class DriverStats:
    """PTC-call meter, split by control-plane purpose.

    ``serve``    — traffic through ``forward_layer``
    ``probe``    — health probes / observability reads (``forward``)
    ``readback`` — reciprocal basis readbacks (``readback_bases``)
    ``search``   — in-situ optimization jobs (``zo_refine`` / ``run_ic``)
    """

    serve: float = 0.0
    probe: float = 0.0
    readback: float = 0.0
    search: float = 0.0

    @property
    def total(self) -> float:
        return self.serve + self.probe + self.readback + self.search

    def as_dict(self) -> dict:
        return dict(serve=self.serve, probe=self.probe,
                    readback=self.readback, search=self.search,
                    total=self.total)

    def charge(self, category: str, calls: float) -> None:
        # same call-site error the stream transports raise from their
        # wire encoder — a bad category must not diverge by transport
        # (ValueError here vs AttributeError there) or slip through as
        # a new attribute on the stats object
        if category not in STAT_CATEGORIES:
            raise ValueError(
                f"unknown PTC-meter category {category!r} "
                f"(one of {sorted(STAT_CATEGORIES)})")
        setattr(self, category, getattr(self, category) + float(calls))


class ZORefineResult(NamedTuple):
    """Result of an in-situ ``zo_refine`` job (phases are also written)."""

    phi: jax.Array        # refreshed commanded phases, (B, 2T)
    loss: jax.Array       # final per-block objective values, (B,)
    history: jax.Array    # best-loss traces, (B, steps // record_every)
    steps: int            # ZCD probe steps actually spent per block


class ICJobResult(NamedTuple):
    """Result of an in-situ ``run_ic`` job (phases are also written)."""

    phi: jax.Array        # commanded phases after IC, (B, 2T)
    u: jax.Array          # readback of the realized Ĩ_U, (B, k, k)
    v: jax.Array          # readback of the realized Ĩ_V
    loss: jax.Array       # final surrogate loss per block
    history: jax.Array    # best-loss traces across restarts


class CompletedBatch:
    """Already-resolved future-like handle for :meth:`run_batch_async`.

    The minimal surface async callers rely on (``done()`` /
    ``result(timeout=None)``), backed by results computed before the
    handle was constructed — what a driver with no round-trip to overlap
    (the in-process twin) hands back, and what stream transports fall
    back to when a frame must be split synchronously."""

    def __init__(self, results: list):
        self._results = results

    def done(self) -> bool:
        return True

    def result(self, timeout=None) -> list:
        return self._results


class PhotonicDriver(abc.ABC):
    """Abstract control-plane handle to one photonic chip.

    A driver owns: the commanded state (phases, attenuators, signs), the
    device's clock, and the PTC-call meter.  Concrete transports:

    * :class:`repro.hw.twin.TwinDriver` — in-process digital twin,
      jit-friendly (the default for tests and simulation studies);
    * :class:`repro.hw.subprocess_driver.SubprocessDriver` — JSON-over-
      pipe protocol to an out-of-process twin, the hardware-in-the-loop
      shape a real instrument server would slot into.
    """

    # -- geometry (fixed at deployment) -------------------------------------

    @property
    @abc.abstractmethod
    def k(self) -> int:
        """PTC block size."""

    @property
    @abc.abstractmethod
    def kind(self) -> str:
        """Mesh topology (e.g. ``"clements"``)."""

    @property
    @abc.abstractmethod
    def n_blocks(self) -> int:
        """Number of independent k×k blocks on the chip."""

    @property
    @abc.abstractmethod
    def layer_shape(self) -> tuple[int, int]:
        """(M, N) of the logical weight the block grid assembles."""

    # -- commanded state -----------------------------------------------------
    #
    # All writes take an optional ``block_range=(start, stop)`` scoping
    # the command to one tenant's blocks; the arrays then carry the
    # range's block count as their leading dim instead of B.

    @abc.abstractmethod
    def write_phases(self, phi_u: jax.Array, phi_v: jax.Array, *,
                     block_range: tuple[int, int] | None = None) -> None:
        """Command the rotation phases, each (B, T)."""

    @abc.abstractmethod
    def write_sigma(self, sigma: jax.Array, *,
                    block_range: tuple[int, int] | None = None) -> None:
        """Command the Σ attenuators, (B, k)."""

    @abc.abstractmethod
    def write_signs(self, d_u: jax.Array, d_v: jax.Array, *,
                    block_range: tuple[int, int] | None = None) -> None:
        """Command the ±1 crossing configuration, each (B, k)."""

    @abc.abstractmethod
    def read_phases(self) -> tuple[jax.Array, jax.Array]:
        """Commanded (Φ^U, Φ^V) — controller-known, free."""

    @abc.abstractmethod
    def read_sigma(self) -> jax.Array:
        """Commanded Σ — controller-known, free."""

    # -- observability-legal probes (metered) --------------------------------

    @abc.abstractmethod
    def forward(self, x: jax.Array, category: str = "probe", *,
                block_range: tuple[int, int] | None = None) -> jax.Array:
        """Stream shared probe columns ``x`` (n, k) through every block's
        realized response; returns (B, n, k).  Costs B·n PTC calls.
        With ``block_range`` only that tenant's blocks are probed (and
        charged)."""

    @abc.abstractmethod
    def forward_layer(self, x: jax.Array, *,
                      block_range: tuple[int, int] | None = None,
                      out_dim: int | None = None) -> jax.Array:
        """Serve-path forward (..., N) → (..., M) through the assembled
        P×Q grid.  Costs B·n_rows PTC calls (metered as ``serve``).
        With ``block_range``/``out_dim`` the forward runs through one
        tenant's sub-grid: the range's blocks assemble an
        (out_dim × n_t) layer."""

    @abc.abstractmethod
    def readback_bases(self, cols=None, *,
                       block_range: tuple[int, int] | None = None
                       ) -> tuple[jax.Array, jax.Array]:
        """Reciprocal-probe readout of the realized bases (U, V*), each
        (B, k, k) — or, with ``cols`` (a column-index sequence), only
        those columns, (B, k, len(cols)).  Costs 2·B·k PTC calls for the
        full readout, 2·B·len(cols) for a partial one (metered as
        ``readback``).  ``block_range`` scopes the readout to one
        tenant's blocks."""

    # -- in-situ jobs (run on the device's local controller; metered) --------

    @abc.abstractmethod
    def zo_refine(self, w_blocks: jax.Array, key: jax.Array, cfg,
                  method: str = "zcd", *,
                  block_range: tuple[int, int] | None = None
                  ) -> ZORefineResult:
        """Hardware-restricted alternate ZCD on the commanded phases
        against per-block targets ``w_blocks`` (electronic comparison),
        warm-started from the current written state.  ``cfg`` is a
        :class:`repro.optim.zo.ZOConfig` budget.  Writes the result and
        returns it.  Costs steps·2·B·k PTC calls.  With ``block_range``
        the search touches only that tenant's blocks — the partial-
        recalibration primitive: co-resident tenants' phases are
        untouched (bit-identical before/after)."""

    @abc.abstractmethod
    def run_ic(self, key: jax.Array, sigs: jax.Array, cfg, *,
               restarts: int = 4, method: str = "zcd") -> ICJobResult:
        """Identity Calibration: ZO search on the multi-Σ_cal intensity
        surrogate (Eq. 2) with probe attenuator schedule ``sigs``
        (n_sigma, k).  Writes the resulting phases and returns them with
        a basis readback."""

    # -- time ----------------------------------------------------------------

    @abc.abstractmethod
    def advance(self, dt: float = 1.0) -> None:
        """Let ``dt`` ticks of (virtual) time pass.  Real hardware drifts
        on its own; the twin steps its seeded OU walk."""

    # -- accounting ----------------------------------------------------------

    @property
    @abc.abstractmethod
    def stats(self) -> DriverStats:
        """Cumulative PTC-call meter."""

    @abc.abstractmethod
    def charge(self, category: str, calls: float) -> None:
        """Meter probes consumed by controller-side estimators that reuse
        already-read state (e.g. the in-situ Σ descent's Eq.-5 probes)."""

    def reset_stats(self) -> None:
        s = self.stats
        s.serve = s.probe = s.readback = s.search = 0.0

    # -- batched op lists ----------------------------------------------------

    def run_batch(self, ops: "list[tuple[str, dict]]") -> list:
        """Execute an ordered op list; returns per-op results.

        ``ops`` entries are ``(method_name, kwargs)`` — any op in
        :data:`BATCHABLE_OPS` (``"stats"`` yields a snapshot of the
        meter at that point in the list); anything else — lifecycle
        ops, private internals — is rejected on EVERY transport, so a
        list that works in-process also works over the wire.  This
        default dispatches sequentially; stream transports override it
        to ship the whole list in one wire frame.  Either way the ops
        run in list order against the same device and each op is
        metered individually, so results are bit-identical across
        encodings.
        """
        validate_batch_ops(ops)
        out = []
        for name, kw in ops:
            if name == "stats":
                s = self.stats
                out.append(DriverStats(serve=s.serve, probe=s.probe,
                                       readback=s.readback, search=s.search))
            else:
                out.append(getattr(self, name)(**kw))
        return out

    def run_batch_async(self, ops: "list[tuple[str, dict]]"):
        """Issue an op list for asynchronous collection.

        Returns a future-like handle with ``done()`` and
        ``result(timeout=None)``; ``result()`` returns — or raises —
        exactly what :meth:`run_batch` would have for the same list.
        This default executes synchronously and hands back an
        already-resolved :class:`CompletedBatch` (an in-process driver
        has no round-trip to overlap); stream transports override it to
        write the frame immediately and resolve the future from a
        response-reader thread.  Either way ops execute in issue order
        against the device, so async results are bit-identical to the
        synchronous encoding.
        """
        return CompletedBatch(self.run_batch(ops))

    def flush(self) -> None:
        """Force any client-side pipelined writes onto the device
        (no-op for in-process drivers, which apply writes eagerly)."""

    # -- lifecycle / escape hatch --------------------------------------------

    def close(self) -> None:
        """Release transport resources (no-op for in-process drivers)."""

    def unsafe_twin(self):
        """Escape hatch to the digital twin's internals (exact distances,
        the drifted :class:`DeviceRealization`).  Tests and benchmarks
        only — raises :class:`TwinUnavailable` when the device is not an
        inspectable twin (i.e. real hardware).

        Call sites are statically audited: repro-lint restricts them to
        an explicit diagnostic allowlist (RPL102) and quarantines
        twin-only symbols outside the hatch (RPL101/RPL103) — see
        ``python -m repro.analysis.lint --explain RPL102``."""
        raise TwinUnavailable(
            f"{type(self).__name__} is not backed by an inspectable twin")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
