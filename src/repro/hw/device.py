"""Digital-twin device physics: the *unobservable* side of the boundary.

This module is the simulator's ground truth — the quantities a real chip
never exposes (paper §3.2: only the end-to-end ``UΣV*`` response is
measurable).  Everything here is quarantined behind the
:class:`~repro.hw.driver.PhotonicDriver` boundary:

* :class:`DeviceRealization` / :func:`sample_device` — the fixed, unknown
  physical state (Γ, Φ_b, manufacturing sign diagonals) of a batch of
  PTC blocks;
* :func:`realized_unitaries` / :func:`realized_blocks` — the transfer
  function the physical mesh actually implements for commanded settings;
* :func:`true_mapping_distance` — the exact full-readout fidelity metric
  (the probe estimator's ground truth);
* :func:`chip_forward` — layer-level ``y = Ŵ x`` through the drifted
  realized blocks (the serve-path dataflow).

Control-plane code (``repro.runtime``, ``core.calibration``,
``core.mapping``) must NOT import this module — the conformance suite's
guard test enforces it.  Legal access paths are the driver ops
(``forward`` / ``readback_bases`` / jobs) or, for tests and benchmarks
only, the explicit ``driver.unsafe_twin()`` escape hatch.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import unitary as un
from ..core.noise import NoiseModel, PhaseNoise, sample_phase_noise, \
    apply_phase_noise

__all__ = ["DeviceRealization", "sample_device", "realized_unitaries",
           "realized_blocks", "true_mapping_distance", "chip_forward"]


class DeviceRealization(NamedTuple):
    """The fixed, unknown physical state of a batch of PTC blocks.

    Sampled once per chip; IC exists because this is not observable.
    Leading dims = block batch (e.g. (B,) flattened blocks).
    """

    noise_u: PhaseNoise     # Γ, Φ_b realizations for the U mesh
    noise_v: PhaseNoise     # ... for the V* mesh
    d_u: jax.Array          # ±1 manufacturing sign diagonals
    d_v: jax.Array


def sample_device(key: jax.Array, batch: tuple[int, ...], k: int,
                  model: NoiseModel, kind: str = "clements"
                  ) -> DeviceRealization:
    spec = un.mesh_spec(k, kind)
    t = spec.n_rot
    ku, kv, kd1, kd2 = jax.random.split(key, 4)
    nu = sample_phase_noise(ku, batch + (t,), model)
    nv = sample_phase_noise(kv, batch + (t,), model)
    d_u = jnp.where(jax.random.bernoulli(kd1, 0.5, batch + (k,)), 1.0, -1.0)
    d_v = jnp.where(jax.random.bernoulli(kd2, 0.5, batch + (k,)), 1.0, -1.0)
    return DeviceRealization(noise_u=nu, noise_v=nv, d_u=d_u, d_v=d_v)


def realized_unitaries(spec: un.MeshSpec, phi_u, phi_v,
                       dev: DeviceRealization, model: NoiseModel):
    """The unitaries the physical mesh actually implements for commanded Φ."""
    pu = apply_phase_noise(spec, phi_u, dev.noise_u, model)
    pv = apply_phase_noise(spec, phi_v, dev.noise_v, model)
    u = un.build_unitary(spec, pu, dev.d_u)
    v = un.build_unitary(spec, pv, dev.d_v)
    return u, v


def realized_blocks(spec: un.MeshSpec, phi: jax.Array, sigma: jax.Array,
                    dev: DeviceRealization, model: NoiseModel) -> jax.Array:
    """Ŵ blocks the device currently implements for commanded phases
    ``phi = [Φ^U | Φ^V]`` (..., 2T) and attenuators ``sigma``.

    The single definition of the runtime's transfer function — probes,
    jobs, and the serve path all go through it, so every consumer of the
    driver sees the same physics.
    """
    t = spec.n_rot
    u, v = realized_unitaries(spec, phi[..., :t], phi[..., t:], dev, model)
    return (u * sigma[..., None, :]) @ v


def true_mapping_distance(spec: un.MeshSpec, phi: jax.Array,
                          sigma: jax.Array, dev: DeviceRealization,
                          model: NoiseModel, w_blocks: jax.Array) -> jax.Array:
    """Exact aggregate distance (full transfer-matrix readout) —
    the probe estimator's ground truth.  Twin-only: a real chip cannot
    evaluate this for free."""
    w_hat = realized_blocks(spec, phi, sigma, dev, model)
    num = jnp.sum((w_hat - w_blocks) ** 2, axis=(-2, -1))
    den = jnp.sum(w_blocks ** 2, axis=(-2, -1)) + 1e-12
    return jnp.sum(num) / jnp.sum(den)


def chip_forward(spec, phi, sigma, dev, model, x, out_dim):
    """y = Ŵ x through the drifted realized blocks (paper dataflow:
    per-block V* → Σ → U, electronic accumulation over q is implicit
    here because each chip hosts a flat batch of blocks of one weight)."""
    k = spec.k
    w_hat = realized_blocks(spec, phi, sigma, dev, model)  # (B, k, k)
    b = w_hat.shape[0]
    # reassemble the (P, Q) grid from the flat block batch
    p = -(-out_dim // k)
    q = b // p
    w = w_hat.reshape(p, q, k, k)
    xb = x
    n = q * k
    if x.shape[-1] != n:
        xb = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, n - x.shape[-1])])
    xb = xb.reshape(x.shape[:-1] + (q, k))
    y = jnp.einsum("pqij,...qj->...pi", w, xb)
    y = y.reshape(x.shape[:-1] + (p * k,))
    return y[..., :out_dim]
