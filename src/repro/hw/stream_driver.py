"""`StreamDriver`: shared op-stream client for out-of-process drivers.

Both wire transports — :class:`~repro.hw.subprocess_driver.SubprocessDriver`
(frames over stdin/stdout pipes) and :class:`~repro.hw.socket_driver.SocketDriver`
(the same framing over TCP) — are thin subclasses of this base, which owns
everything above the byte stream: the init version handshake (v4 with a
v3 fallback), per-op encode/decode, the ``batch`` frame, client-side
write pipelining, and the async response reader.

Write pipelining (v3)
---------------------
``BENCH_driver_overhead.json`` (PR 3) put the per-op RPC overhead at
~1.15 ms — a 23× probe-throughput gap versus the in-process twin — and
the closed loop is made of exactly such fine-grained ops.  Two data-plane
rules close most of it:

* **Pipelined writes** — ops with no observable result (``write_phases``,
  ``write_sigma``, ``write_signs``, ``advance``, ``charge``,
  ``reset_stats``) do not round-trip.  They queue client-side and are
  auto-flushed — *in order, ahead of the reading op, in the same
  ``batch`` frame* — the moment anything observable (a read, probe, job,
  stats, or ``unsafe/*`` readout) is issued.  Server-side execution
  order is therefore exactly the issue order, and results are
  bit-identical to the unpipelined encoding; a fleet tick that only
  advances clocks costs zero round-trips.
* **Explicit batching** — :meth:`run_batch` ships an ordered op list in
  one frame and returns the per-op results, for hot paths that *read*
  repeatedly (probe sweeps, recalibration's job+readback sequence).

Async issue/collect (v4)
------------------------
:meth:`run_batch_async` writes the batch frame and returns a
:class:`BatchFuture` immediately; a lazily-started daemon reader thread
matches response frames to futures by request id.  Frames on one stream
still execute strictly in issue order server-side (one session = one
driver = one thread there), so async results are bit-identical to the
synchronous encoding — the only thing that overlaps is *this* client's
wait.  ``FleetRouter`` uses it to overlap probe sweeps and serve passes
across chips.  Once the reader exists, synchronous ops route through the
same id-matched path, so sync and async calls interleave safely.

Arguments are validated client-side where the driver has the geometry
(``block_range`` bounds), so a queued write still raises ``ValueError``
at the call site, not at the flush boundary.  Server-side failures of a
flushed batch raise at the flushing op and name the failing index.
"""

from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np

from ..core import unitary as un
from ..optim.zo import ZOConfig
from .device import DeviceRealization
from ..core.noise import PhaseNoise
from .driver import (PhotonicDriver, DriverStats, ZORefineResult, ICJobResult,
                     TwinUnavailable, resolve_block_range, BATCHABLE_OPS,
                     STAT_CATEGORIES, CompletedBatch, forward_coalesce_key,
                     coalesce_spans)
from .protocol import (encode, decode, send, recv, ProtocolError,
                       PROTOCOL_VERSION, SUPPORTED_VERSIONS)

__all__ = ["StreamDriver", "RemoteTwinHandle", "BatchFuture", "PIPELINED_OPS"]


def _rng_kw(block_range):
    """Wire form of a block range (JSON list, or None for whole-chip)."""
    return None if block_range is None else [int(i) for i in block_range]


# ops with no observable result: safe to queue client-side and flush
# ahead of the next reading op (order is preserved server-side)
PIPELINED_OPS = frozenset([
    "write_phases", "write_sigma", "write_signs", "advance", "charge",
    "reset_stats",
])


class RemoteTwinHandle:
    """Remote twin readouts behind ``unsafe_twin()``.

    Exists only because the peer happens to be a simulator exposing
    ``unsafe/*`` debug ops; a real-hardware daemon would not, and the
    driver would raise :class:`TwinUnavailable` instead.
    """

    def __init__(self, driver: "StreamDriver"):
        self._d = driver

    @property
    def dev(self) -> DeviceRealization:
        r = self._d._exec("unsafe/dev", {})
        return DeviceRealization(
            noise_u=PhaseNoise(gamma=jnp.asarray(r["gamma_u"]),
                               bias=jnp.asarray(r["bias_u"])),
            noise_v=PhaseNoise(gamma=jnp.asarray(r["gamma_v"]),
                               bias=jnp.asarray(r["bias_v"])),
            d_u=jnp.asarray(r["d_u"]), d_v=jnp.asarray(r["d_v"]))

    def realized_unitaries(self) -> tuple[jax.Array, jax.Array]:
        r = self._d._exec("unsafe/realized_unitaries", {})
        return jnp.asarray(r["u"]), jnp.asarray(r["v"])

    def true_mapping_distance(self, w_blocks: jax.Array,
                              block_range=None) -> float:
        r = self._d._exec("unsafe/true_mapping_distance",
                          dict(w_blocks=self._d._encode(w_blocks),
                               block_range=_rng_kw(block_range)))
        return float(r["d"])

    def bias_deviation(self) -> float:
        return float(self._d._exec("unsafe/bias_deviation", {})["d"])


class BatchFuture:
    """Handle to an in-flight :meth:`StreamDriver.run_batch_async` frame.

    ``result()`` blocks until the response frame arrives (optionally
    bounded by ``timeout`` seconds), then decodes to exactly what the
    synchronous :meth:`~StreamDriver.run_batch` would have returned —
    same objects, same per-op errors, bit-identical values."""

    def __init__(self, driver: "StreamDriver", names: list,
                 n_head: int, raw: Future):
        self._driver = driver
        self._names = names
        self._n_head = n_head
        self._raw = raw

    def done(self) -> bool:
        return self._raw.done()

    def result(self, timeout=None):
        resp = self._raw.result(timeout)
        return self._driver._finish_batch(self._names, self._n_head, resp)


class StreamDriver(PhotonicDriver):
    """Control-plane client over a framed op byte stream.

    Subclasses own the transport: they must create ``self._fin`` /
    ``self._fout`` (binary-mode stream files), then call
    :meth:`_handshake`, and implement :meth:`_transport_alive`,
    :meth:`_transport_diagnostics`, and :meth:`close`.
    """

    _fin = None
    _fout = None

    # -- transport hooks -----------------------------------------------------

    def _transport_alive(self) -> bool:
        """False once the peer is known dead / the driver closed."""
        return self._fout is not None

    def _transport_diagnostics(self) -> str:
        """Extra context appended to transport-failure errors (e.g. the
        subprocess server's stderr tail)."""
        return ""

    # -- handshake -----------------------------------------------------------

    def _handshake(self, key, n_blocks: int, k: int, model, kind: str,
                   m, n, drift, protocol: int | None = None) -> None:
        """Init the session, negotiating the wire protocol.

        Offers v4 (binary frames) by default; a v3-only peer answers the
        init with a ``protocol mismatch`` error — same connection, still
        framed — and the client retries the init at v3, staying on JSON
        lines for the session.  ``protocol`` forces a specific version
        (no fallback), which is how the conformance tests pin the v3
        encoding for bit-identity comparisons."""
        self._rid = 0
        self._rpc_count = 0          # frames sent (introspection/benchmarks)
        self._pending: list[dict] = []
        self._binary = False         # init always travels as a JSON line
        self._twin_verified = False
        self._lock = threading.Lock()
        self._inflight: dict[int, Future] = {}
        self._reader: threading.Thread | None = None
        self._reader_err: BaseException | None = None
        want = PROTOCOL_VERSION if protocol is None else int(protocol)
        if want not in SUPPORTED_VERSIONS:
            raise ValueError(
                f"unsupported driver protocol v{want} "
                f"(client speaks {SUPPORTED_VERSIONS})")
        base = dict(
            key=encode(np.asarray(key)), n_blocks=int(n_blocks), k=int(k),
            kind=kind, m=m, n=n, model=dataclasses.asdict(model),
            drift=drift._asdict() if drift is not None else None)
        try:
            meta = self._exec("init", dict(base, v=want))
        except ProtocolError:
            self.close()
            raise
        except RuntimeError as e:
            if not (protocol is None and want > 3
                    and "protocol mismatch" in str(e)):
                self.close()
                raise
            # v3-only peer refused the init (a clean error frame — the
            # stream is still framed): retry as a v3 session
            want = 3
            try:
                meta = self._exec("init", dict(base, v=want))
            except Exception:
                self.close()
                raise
        if int(meta.get("v", 1)) != want:
            self.close()
            raise ProtocolError(
                f"driver protocol mismatch: server negotiated "
                f"v{meta.get('v', 1)}, client asked for v{want}")
        self._binary = want >= 4     # everything after init goes binary
        self._protocol = want
        self._meta = meta

    # -- op stream -----------------------------------------------------------

    def _encode(self, obj):
        """Session-codec array encoding (binary once v4 is negotiated)."""
        return encode(obj, binary=getattr(self, "_binary", False))

    def _ensure_reader(self) -> None:
        """Start the response reader (idempotent; caller holds _lock).

        Until the first async op, the driver is purely synchronous and
        no thread exists; once started, ALL responses flow through the
        reader and are matched to futures by request id."""
        if self._reader is None:
            t = threading.Thread(target=self._read_loop, daemon=True,
                                 name=f"{type(self).__name__}-reader")
            self._reader = t
            t.start()

    def _read_loop(self) -> None:
        while True:
            try:
                resp = recv(self._fin)
            except Exception as e:
                with self._lock:
                    self._reader_err = e
                    inflight, self._inflight = self._inflight, {}
                err = ProtocolError(
                    f"driver stream failed: {e}"
                    + self._transport_diagnostics())
                for fut in inflight.values():
                    fut.set_exception(err)
                return
            with self._lock:
                fut = self._inflight.pop(resp.get("id"), None)
            if fut is not None:
                # unmatched ids (e.g. the id=0 shutdown ack) are dropped
                fut.set_result(resp)

    def _post(self, msg: dict) -> Future:
        """Write one request frame; return a Future of the raw response.

        The future is registered *before* the frame is written (under
        the stream lock), so a fast peer cannot race the reader.  Raises
        :class:`ProtocolError` without writing if the frame is oversized
        or the transport is down — the stream stays framed."""
        fut: Future = Future()
        with self._lock:
            if not self._transport_alive():
                raise ProtocolError(
                    "driver stream is closed (peer exited or driver closed)"
                    + self._transport_diagnostics())
            if self._reader_err is not None:
                raise ProtocolError(
                    f"driver stream failed: {self._reader_err}"
                    + self._transport_diagnostics())
            self._ensure_reader()
            self._rid += 1
            rid = self._rid
            self._inflight[rid] = fut
            try:
                send(self._fout, dict(msg, id=rid), binary=self._binary)
                self._rpc_count += 1
            except Exception:
                del self._inflight[rid]
                raise
        return fut

    def _send_frame(self, msg: dict) -> dict:
        """One request frame → one decoded response (blocking)."""
        if not self._transport_alive():
            raise ProtocolError(
                "driver stream is closed (peer exited or driver closed)"
                + self._transport_diagnostics())
        try:
            if self._reader is not None:
                # async reader owns the receive side: route through it
                resp = self._post(msg).result()
            else:
                self._rid += 1
                framed = dict(msg, id=self._rid)
                send(self._fout, framed, binary=self._binary)
                resp = recv(self._fin)
                self._rpc_count += 1
        except (ProtocolError, OSError) as e:
            raise ProtocolError(
                f"driver stream failed during op {msg.get('op')!r}: {e}"
                + self._transport_diagnostics()) from e
        if not resp.get("ok"):
            raise RuntimeError(
                f"remote driver op {msg.get('op')!r} failed:\n"
                f"{resp.get('error')}")
        return decode(resp.get("result"))

    def _queue(self, op: str, kw: dict) -> None:
        """Pipeline a result-less op: no round-trip until the next read."""
        self._pending.append(dict(op=op, kw=kw))

    def _send_ops(self, entries: list) -> list:
        """Per-op results for an entry list, preferring ONE batch frame.

        If the *aggregated* frame would exceed ``MAX_FRAME_BYTES`` —
        ``send()`` refuses before writing anything, so the stream stays
        framed and no op has executed — fall back to halving the list:
        the sequential encoding is always reachable and has identical
        semantics, so a sequence of individually-legal ops can never
        fail just because pipelining packed it into one frame.
        """
        if len(entries) == 1:
            return [self._send_frame(dict(op=entries[0]["op"],
                                          kw=entries[0]["kw"]))]
        try:
            return self._send_frame(dict(op="batch", kw=dict(ops=entries)))
        except ProtocolError as e:
            if "refusing to send oversized frame" not in str(e):
                raise
            self._send_split = True      # frame indices got renumbered
            mid = len(entries) // 2
            return self._send_ops(entries[:mid]) + self._send_ops(
                entries[mid:])

    def _exec(self, op: str, kw: dict):
        """Issue an observable op, flushing any pipelined writes ahead of
        it in the same ``batch`` frame (one round-trip total).  Ops
        outside the batch whitelist (``init``, ``unsafe/*``) flush
        first and then travel in their own frame — the server rejects
        them inside batch frames."""
        if op not in BATCHABLE_OPS:
            self.flush()
            return self._send_frame(dict(op=op, kw=kw))
        ops, self._pending = self._pending, []
        ops.append(dict(op=op, kw=kw))
        return self._send_ops(ops)[-1]

    def flush(self) -> None:
        """Force any pipelined writes onto the device now."""
        if self._pending:
            ops, self._pending = self._pending, []
            self._send_ops(ops)

    # -- batched op lists ----------------------------------------------------

    def _validated_entries(self, ops) -> list:
        """Wire entries for an op list, with consecutive coalescible
        ``forward`` ops merged CLIENT-SIDE into one stacked
        ``forward_many`` entry (one codec pass + one metadata node
        instead of n — the dominant per-op cost of a batched probe
        sweep).  The merge rule is the shared ``coalesce_spans``, so the
        server's reply (one ``coalesced`` result per span) re-expands to
        exactly the per-op results sequential dispatch would return."""
        for name, _ in ops:
            if name not in BATCHABLE_OPS:
                raise ValueError(
                    f"op {name!r} cannot appear inside a batch")
        first = ops[0] if ops else None
        if (len(ops) > 1 and all(o is first for o in ops)
                and first[0] == "forward"):
            # the replicated-op list (`[op] * n`, the canonical probe
            # sweep) coalesces by construction: one key, one span —
            # skip n-1 redundant key derivations on the hot path
            keys = [forward_coalesce_key(first[1])] * len(ops)
        else:
            keys = [forward_coalesce_key(kw) if name == "forward" else None
                    for name, kw in ops]
        entries = []
        for i, j in coalesce_spans(keys):
            if j - i > 1:
                kw = ops[i][1]
                # same dtype coercion the device applies to each op; a
                # span of the SAME array object (the common probe-sweep
                # shape) converts once and broadcasts instead of paying
                # n host transfers + a stack copy
                span = [k.get("x") for _, k in ops[i:j]]
                if all(s is span[0] for s in span):
                    x0 = np.asarray(span[0], np.float32)
                    xs = np.broadcast_to(x0, (len(span),) + x0.shape)
                else:
                    xs = np.stack([np.asarray(s, np.float32)
                                   for s in span])
                entries.append(dict(op="forward_many", kw=self._wire_kw(
                    "forward_many",
                    dict(xs=xs, category=kw.get("category", "probe"),
                         block_range=kw.get("block_range")))))
            else:
                name, kw = ops[i]
                entries.append(
                    dict(op=name, kw=self._wire_kw(name, dict(kw))))
        return entries

    @staticmethod
    def _split_coalesced(raw: list) -> list:
        # a coalesced probe span comes back as one stacked array (op
        # axis leading): split it into per-op results — bit-identical
        # to per-op payloads at a fraction of the codec cost
        flat: list = []
        for r in raw:
            if isinstance(r, dict) and "coalesced" in r:
                flat.extend(dict(y=y) for y in r["y"])
            else:
                flat.append(r)
        return flat

    def run_batch(self, ops):
        """Execute ``[(op_name, kwargs), ...]`` in ONE round-trip.

        Pipelined writes flush ahead of the list in the same frame.
        Results are the same Python objects the individual methods
        return, in op order — bit-identical to issuing the ops
        sequentially (the server dispatches to the same driver methods,
        metering each op individually).  Only :data:`BATCHABLE_OPS` are
        accepted — the same validation every transport applies, so a
        list that runs in-process runs over the wire and vice versa.
        """
        entries = self._validated_entries(ops)
        if not entries:
            return []
        head, self._pending = self._pending, []
        self._send_split = False
        try:
            raw = self._send_ops(head + entries)
        except RuntimeError as e:
            if head and not getattr(self, "_send_split", False):
                # server indices count the pipelined-write head this
                # client prepended invisibly — translate for the caller
                raise RuntimeError(
                    f"{e}\n(note: {len(head)} pipelined write(s) were "
                    f"flushed ahead of this run_batch in the same frame; "
                    f"server batch indices include them — subtract "
                    f"{len(head)} for this call's op list)") from e
            if head:
                # the aggregated frame was split; server indices are
                # per-sub-frame and cannot be mapped back precisely
                raise RuntimeError(
                    f"{e}\n(note: {len(head)} pipelined write(s) were "
                    f"flushed with this run_batch and the frame was "
                    f"split for size — server batch indices are "
                    f"relative to a sub-frame, not this call's op "
                    f"list)") from e
            raise
        raw = raw[len(head):]
        flat = self._split_coalesced(raw)
        return [self._decode_result(name, r)
                for (name, _), r in zip(ops, flat)]

    def run_batch_async(self, ops):
        """Issue ``[(op_name, kwargs), ...]`` NOW; collect results later.

        The batch frame (with any pipelined writes flushed ahead of it,
        exactly as :meth:`run_batch`) is written before this returns; a
        daemon reader thread resolves the returned :class:`BatchFuture`
        when the response frame arrives.  ``future.result()`` returns —
        or raises — exactly what the synchronous call would have.
        Frames on one stream execute in issue order server-side, so
        interleaved sync/async ops keep their program order and results
        stay bit-identical to the synchronous encoding.
        """
        entries = self._validated_entries(ops)
        head, self._pending = self._pending, []
        all_entries = head + entries
        if not all_entries:
            return CompletedBatch([])
        names = [name for name, _ in ops]
        try:
            raw = self._post(dict(op="batch", kw=dict(ops=all_entries)))
        except ProtocolError as e:
            if "refusing to send oversized frame" not in str(e):
                raise
            # nothing was written: fall back to the synchronous halving
            # split (identical semantics) and hand back a resolved handle
            self._send_split = True
            raw_results = self._send_ops(all_entries)[len(head):]
            flat = self._split_coalesced(raw_results)
            return CompletedBatch([self._decode_result(name, r)
                                   for name, r in zip(names, flat)])
        return BatchFuture(self, names, len(head), raw)

    def _finish_batch(self, names: list, n_head: int, resp: dict) -> list:
        """Decode a raw ``batch`` response frame for :class:`BatchFuture`."""
        if not resp.get("ok"):
            err = RuntimeError(
                f"remote driver op 'batch' failed:\n{resp.get('error')}")
            if n_head:
                raise RuntimeError(
                    f"{err}\n(note: {n_head} pipelined write(s) were "
                    f"flushed ahead of this run_batch_async in the same "
                    f"frame; server batch indices include them — subtract "
                    f"{n_head} for this call's op list)") from err
            raise err
        raw = decode(resp.get("result"))[n_head:]
        flat = self._split_coalesced(raw)
        return [self._decode_result(name, r)
                for name, r in zip(names, flat)]

    # -- per-op wire encoding / result decoding ------------------------------

    def _wire_kw(self, op: str, kw: dict) -> dict:
        """Python kwargs → wire kwargs for ``op`` (client-side validation
        happens here so pipelined ops still fail at the call site)."""
        nb = self.n_blocks
        if "block_range" in kw:
            br = kw["block_range"]
            if br is not None:
                start, stop = resolve_block_range(nb, br)
                nb = stop - start
            kw["block_range"] = _rng_kw(br)
        # validate pipelined/metered kwargs NOW: a bad bank or category
        # must raise at the call site (as the in-process twin does), not
        # surface as a server error at some later flush boundary — or
        # vanish entirely when the flush happens inside close()
        if op in ("write_phases", "write_sigma", "write_signs"):
            t = un.mesh_spec(self.k, self.kind).n_rot
            want = dict(phi_u=nb * t, phi_v=nb * t, sigma=nb * self.k,
                        d_u=nb * self.k, d_v=nb * self.k)
            for name, n_want in want.items():
                if name in kw and int(np.size(kw[name])) != n_want:
                    raise ValueError(
                        f"{op}: {name} has {int(np.size(kw[name]))} "
                        f"elements, expected {n_want} for {nb} blocks "
                        f"of k={self.k}")
        if "category" in kw and kw["category"] not in STAT_CATEGORIES:
            raise ValueError(
                f"{op}: unknown PTC-meter category {kw['category']!r} "
                f"(one of {sorted(STAT_CATEGORIES)})")
        if op in ("write_phases", "write_sigma", "write_signs", "forward",
                  "forward_layer"):
            for name in ("phi_u", "phi_v", "sigma", "d_u", "d_v", "x"):
                if name in kw:
                    kw[name] = self._encode(kw[name])
        if op == "forward_many":
            kw["xs"] = self._encode(kw["xs"])
        if op == "forward_layer" and kw.get("out_dim") is not None:
            kw["out_dim"] = int(kw["out_dim"])
        if op == "readback_bases" and kw.get("cols") is not None:
            kw["cols"] = [int(c) for c in np.asarray(kw["cols"]).tolist()]
        if op in ("zo_refine", "run_ic"):
            kw["key"] = self._encode(np.asarray(kw["key"]))
            kw["cfg"] = kw["cfg"]._asdict()
            if "w_blocks" in kw:
                kw["w_blocks"] = self._encode(kw["w_blocks"])
            if "sigs" in kw:
                kw["sigs"] = self._encode(kw["sigs"])
            if "restarts" in kw:
                kw["restarts"] = int(kw["restarts"])
        if op == "charge":
            kw["calls"] = float(kw["calls"])
        if op == "advance":
            kw["dt"] = float(kw["dt"])
        return kw

    @staticmethod
    def _decode_result(op: str, r):
        # Array payloads come off the wire as host (numpy) arrays and
        # are returned as such: values are bit-identical to the twin's,
        # jax consumes them transparently on first use, and skipping an
        # eager device_put here is worth ~0.2 ms/op on the hot probe
        # path (the whole point of the batched data plane).
        if op in PIPELINED_OPS:
            return None
        if op == "read_phases":
            return r["phi_u"], r["phi_v"]
        if op == "read_sigma":
            return r["sigma"]
        if op in ("forward", "forward_layer"):
            return r["y"]
        if op == "readback_bases":
            return r["u"], r["v"]
        if op == "zo_refine":
            return ZORefineResult(phi=jnp.asarray(r["phi"]),
                                  loss=jnp.asarray(r["loss"]),
                                  history=jnp.asarray(r["history"]),
                                  steps=int(r["steps"]))
        if op == "run_ic":
            return ICJobResult(phi=jnp.asarray(r["phi"]),
                               u=jnp.asarray(r["u"]), v=jnp.asarray(r["v"]),
                               loss=jnp.asarray(r["loss"]),
                               history=jnp.asarray(r["history"]))
        if op == "stats":
            return DriverStats(serve=r["serve"], probe=r["probe"],
                               readback=r["readback"], search=r["search"])
        return r

    # -- geometry ------------------------------------------------------------

    @property
    def k(self) -> int:
        return int(self._meta["k"])

    @property
    def kind(self) -> str:
        return str(self._meta["kind"])

    @property
    def n_blocks(self) -> int:
        return int(self._meta["n_blocks"])

    @property
    def layer_shape(self) -> tuple[int, int]:
        return int(self._meta["m"]), int(self._meta["n"])

    @property
    def protocol(self) -> int:
        """The wire protocol version this session negotiated (3 or 4)."""
        return int(getattr(self, "_protocol", PROTOCOL_VERSION))

    # -- commanded state (pipelined: no round-trip) --------------------------

    def write_phases(self, phi_u, phi_v, *, block_range=None) -> None:
        self._queue("write_phases", self._wire_kw(
            "write_phases", dict(phi_u=phi_u, phi_v=phi_v,
                                 block_range=block_range)))

    def write_sigma(self, sigma, *, block_range=None) -> None:
        self._queue("write_sigma", self._wire_kw(
            "write_sigma", dict(sigma=sigma, block_range=block_range)))

    def write_signs(self, d_u, d_v, *, block_range=None) -> None:
        self._queue("write_signs", self._wire_kw(
            "write_signs", dict(d_u=d_u, d_v=d_v, block_range=block_range)))

    def read_phases(self) -> tuple[jax.Array, jax.Array]:
        return self._decode_result("read_phases",
                                   self._exec("read_phases", {}))

    def read_sigma(self) -> jax.Array:
        return self._decode_result("read_sigma", self._exec("read_sigma", {}))

    # -- probes --------------------------------------------------------------

    def forward(self, x, category: str = "probe", *,
                block_range=None) -> jax.Array:
        kw = self._wire_kw("forward", dict(x=x, category=category,
                                           block_range=block_range))
        return self._decode_result("forward", self._exec("forward", kw))

    def forward_layer(self, x, *, block_range=None,
                      out_dim: int | None = None) -> jax.Array:
        kw = self._wire_kw("forward_layer", dict(x=x, block_range=block_range,
                                                 out_dim=out_dim))
        return self._decode_result("forward_layer",
                                   self._exec("forward_layer", kw))

    def readback_bases(self, cols=None, *,
                       block_range=None) -> tuple[jax.Array, jax.Array]:
        kw = self._wire_kw("readback_bases", dict(cols=cols,
                                                  block_range=block_range))
        return self._decode_result("readback_bases",
                                   self._exec("readback_bases", kw))

    # -- in-situ jobs --------------------------------------------------------

    def zo_refine(self, w_blocks, key, cfg: ZOConfig,
                  method: str = "zcd", *, block_range=None) -> ZORefineResult:
        kw = self._wire_kw("zo_refine", dict(
            w_blocks=w_blocks, key=key, cfg=cfg, method=method,
            block_range=block_range))
        return self._decode_result("zo_refine", self._exec("zo_refine", kw))

    def run_ic(self, key, sigs, cfg: ZOConfig, *, restarts: int = 4,
               method: str = "zcd") -> ICJobResult:
        kw = self._wire_kw("run_ic", dict(key=key, sigs=sigs, cfg=cfg,
                                          restarts=restarts, method=method))
        return self._decode_result("run_ic", self._exec("run_ic", kw))

    # -- time / accounting / escape hatch ------------------------------------

    def advance(self, dt: float = 1.0) -> None:
        self._queue("advance", self._wire_kw("advance", dict(dt=dt)))

    @property
    def stats(self) -> DriverStats:
        return self._decode_result("stats", self._exec("stats", {}))

    def reset_stats(self) -> None:
        self._queue("reset_stats", {})

    def charge(self, category: str, calls: float) -> None:
        self._queue("charge", self._wire_kw(
            "charge", dict(category=category, calls=calls)))

    def unsafe_twin(self) -> RemoteTwinHandle:
        # a dead stream means NO twin, not a confusing ProtocolError
        # three calls deep into a RemoteTwinHandle
        if not self._transport_alive():
            raise TwinUnavailable(
                "driver stream is closed (peer exited or driver closed)")
        # probe the peer's unsafe/* support once per live stream, then
        # trust it (close() invalidates the cache)
        if not getattr(self, "_twin_verified", False):
            try:
                self._exec("unsafe/bias_deviation", {})
            except RuntimeError as e:
                raise TwinUnavailable(str(e)) from e
            self._twin_verified = True
        return RemoteTwinHandle(self)

    # -- lifecycle -----------------------------------------------------------

    def _shutdown_stream(self) -> None:
        """Best-effort orderly goodbye: fire the shutdown frame and
        return — no flush, no ack wait.  Pending pipelined writes are
        dropped deliberately (their only observable effect would be on
        reads that will never happen), and waiting on a reply from a
        possibly-wedged peer would make close() unbounded; the
        transports' close() paths already escalate to kill/disconnect
        on a timeout.  (The id=0 ack, if it ever arrives, matches no
        in-flight future and is dropped by the reader.)  Errors are
        swallowed — close() must succeed on a dead peer.  The
        ``unsafe_twin`` capability cache dies with the stream: a
        re-verified probe on a future stream must start from scratch."""
        self._twin_verified = False
        try:
            self._pending = []
            send(self._fout, dict(id=0, op="shutdown", kw={}),
                 binary=getattr(self, "_binary", False))
        except Exception:
            pass
