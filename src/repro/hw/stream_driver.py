"""`StreamDriver`: shared op-stream client for out-of-process drivers.

Both wire transports — :class:`~repro.hw.subprocess_driver.SubprocessDriver`
(JSON over stdin/stdout pipes) and :class:`~repro.hw.socket_driver.SocketDriver`
(the same framing over TCP) — are thin subclasses of this base, which owns
everything above the byte stream: the init version handshake, per-op
encode/decode, the v3 ``batch`` frame, and client-side write pipelining.

Write pipelining (v3)
---------------------
``BENCH_driver_overhead.json`` (PR 3) put the per-op RPC overhead at
~1.15 ms — a 23× probe-throughput gap versus the in-process twin — and
the closed loop is made of exactly such fine-grained ops.  Two data-plane
rules close most of it:

* **Pipelined writes** — ops with no observable result (``write_phases``,
  ``write_sigma``, ``write_signs``, ``advance``, ``charge``,
  ``reset_stats``) do not round-trip.  They queue client-side and are
  auto-flushed — *in order, ahead of the reading op, in the same
  ``batch`` frame* — the moment anything observable (a read, probe, job,
  stats, or ``unsafe/*`` readout) is issued.  Server-side execution
  order is therefore exactly the issue order, and results are
  bit-identical to the unpipelined encoding; a fleet tick that only
  advances clocks costs zero round-trips.
* **Explicit batching** — :meth:`run_batch` ships an ordered op list in
  one frame and returns the per-op results, for hot paths that *read*
  repeatedly (probe sweeps, recalibration's job+readback sequence).

Arguments are validated client-side where the driver has the geometry
(``block_range`` bounds), so a queued write still raises ``ValueError``
at the call site, not at the flush boundary.  Server-side failures of a
flushed batch raise at the flushing op and name the failing index.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core import unitary as un
from ..optim.zo import ZOConfig
from .device import DeviceRealization
from ..core.noise import PhaseNoise
from .driver import (PhotonicDriver, DriverStats, ZORefineResult, ICJobResult,
                     TwinUnavailable, resolve_block_range, BATCHABLE_OPS,
                     STAT_CATEGORIES)
from .protocol import (encode, decode, send, recv, ProtocolError,
                       PROTOCOL_VERSION)

__all__ = ["StreamDriver", "RemoteTwinHandle", "PIPELINED_OPS"]


def _rng_kw(block_range):
    """Wire form of a block range (JSON list, or None for whole-chip)."""
    return None if block_range is None else [int(i) for i in block_range]


# ops with no observable result: safe to queue client-side and flush
# ahead of the next reading op (order is preserved server-side)
PIPELINED_OPS = frozenset([
    "write_phases", "write_sigma", "write_signs", "advance", "charge",
    "reset_stats",
])


class RemoteTwinHandle:
    """Remote twin readouts behind ``unsafe_twin()``.

    Exists only because the peer happens to be a simulator exposing
    ``unsafe/*`` debug ops; a real-hardware daemon would not, and the
    driver would raise :class:`TwinUnavailable` instead.
    """

    def __init__(self, driver: "StreamDriver"):
        self._d = driver

    @property
    def dev(self) -> DeviceRealization:
        r = self._d._exec("unsafe/dev", {})
        return DeviceRealization(
            noise_u=PhaseNoise(gamma=jnp.asarray(r["gamma_u"]),
                               bias=jnp.asarray(r["bias_u"])),
            noise_v=PhaseNoise(gamma=jnp.asarray(r["gamma_v"]),
                               bias=jnp.asarray(r["bias_v"])),
            d_u=jnp.asarray(r["d_u"]), d_v=jnp.asarray(r["d_v"]))

    def realized_unitaries(self) -> tuple[jax.Array, jax.Array]:
        r = self._d._exec("unsafe/realized_unitaries", {})
        return jnp.asarray(r["u"]), jnp.asarray(r["v"])

    def true_mapping_distance(self, w_blocks: jax.Array,
                              block_range=None) -> float:
        r = self._d._exec("unsafe/true_mapping_distance",
                          dict(w_blocks=encode(w_blocks),
                               block_range=_rng_kw(block_range)))
        return float(r["d"])

    def bias_deviation(self) -> float:
        return float(self._d._exec("unsafe/bias_deviation", {})["d"])


class StreamDriver(PhotonicDriver):
    """Control-plane client over a newline-JSON op stream.

    Subclasses own the transport: they must create ``self._fin`` /
    ``self._fout`` (text-mode stream files), then call
    :meth:`_handshake`, and implement :meth:`_transport_alive`,
    :meth:`_transport_diagnostics`, and :meth:`close`.
    """

    _fin = None
    _fout = None

    # -- transport hooks -----------------------------------------------------

    def _transport_alive(self) -> bool:
        """False once the peer is known dead / the driver closed."""
        return self._fout is not None

    def _transport_diagnostics(self) -> str:
        """Extra context appended to transport-failure errors (e.g. the
        subprocess server's stderr tail)."""
        return ""

    # -- handshake -----------------------------------------------------------

    def _handshake(self, key, n_blocks: int, k: int, model, kind: str,
                   m, n, drift) -> None:
        self._rid = 0
        self._rpc_count = 0          # frames sent (introspection/benchmarks)
        self._pending: list[dict] = []
        meta = self._exec("init", dict(
            v=PROTOCOL_VERSION, key=encode(np.asarray(key)),
            n_blocks=int(n_blocks), k=int(k), kind=kind, m=m, n=n,
            model=dataclasses.asdict(model),
            drift=drift._asdict() if drift is not None else None))
        if int(meta.get("v", 1)) != PROTOCOL_VERSION:
            self.close()
            raise ProtocolError(
                f"driver protocol mismatch: server speaks "
                f"v{meta.get('v', 1)}, client speaks v{PROTOCOL_VERSION}")
        self._meta = meta

    # -- op stream -----------------------------------------------------------

    def _send_frame(self, msg: dict) -> dict:
        """One request frame → one response frame (raw JSON dicts)."""
        if not self._transport_alive():
            raise ProtocolError(
                "driver stream is closed (peer exited or driver closed)"
                + self._transport_diagnostics())
        self._rid += 1
        msg = dict(msg, id=self._rid)
        try:
            send(self._fout, msg)
            resp = recv(self._fin)
            self._rpc_count += 1
        except (ProtocolError, OSError) as e:
            raise ProtocolError(
                f"driver stream failed during op {msg.get('op')!r}: {e}"
                + self._transport_diagnostics()) from e
        if not resp.get("ok"):
            raise RuntimeError(
                f"remote driver op {msg.get('op')!r} failed:\n"
                f"{resp.get('error')}")
        return decode(resp.get("result"))

    def _queue(self, op: str, kw: dict) -> None:
        """Pipeline a result-less op: no round-trip until the next read."""
        self._pending.append(dict(op=op, kw=kw))

    def _send_ops(self, entries: list) -> list:
        """Per-op results for an entry list, preferring ONE batch frame.

        If the *aggregated* frame would exceed ``MAX_FRAME_BYTES`` —
        ``send()`` refuses before writing anything, so the stream stays
        framed and no op has executed — fall back to halving the list:
        the sequential encoding is always reachable and has identical
        semantics, so a sequence of individually-legal ops can never
        fail just because pipelining packed it into one frame.
        """
        if len(entries) == 1:
            return [self._send_frame(dict(op=entries[0]["op"],
                                          kw=entries[0]["kw"]))]
        try:
            return self._send_frame(dict(op="batch", kw=dict(ops=entries)))
        except ProtocolError as e:
            if "refusing to send oversized frame" not in str(e):
                raise
            self._send_split = True      # frame indices got renumbered
            mid = len(entries) // 2
            return self._send_ops(entries[:mid]) + self._send_ops(
                entries[mid:])

    def _exec(self, op: str, kw: dict):
        """Issue an observable op, flushing any pipelined writes ahead of
        it in the same ``batch`` frame (one round-trip total).  Ops
        outside the batch whitelist (``init``, ``unsafe/*``) flush
        first and then travel in their own frame — the server rejects
        them inside batch frames."""
        if op not in BATCHABLE_OPS:
            self.flush()
            return self._send_frame(dict(op=op, kw=kw))
        ops, self._pending = self._pending, []
        ops.append(dict(op=op, kw=kw))
        return self._send_ops(ops)[-1]

    def flush(self) -> None:
        """Force any pipelined writes onto the device now."""
        if self._pending:
            ops, self._pending = self._pending, []
            self._send_ops(ops)

    # -- batched op lists ----------------------------------------------------

    def run_batch(self, ops):
        """Execute ``[(op_name, kwargs), ...]`` in ONE round-trip.

        Pipelined writes flush ahead of the list in the same frame.
        Results are the same Python objects the individual methods
        return, in op order — bit-identical to issuing the ops
        sequentially (the server dispatches to the same driver methods,
        metering each op individually).  Only :data:`BATCHABLE_OPS` are
        accepted — the same validation every transport applies, so a
        list that runs in-process runs over the wire and vice versa.
        """
        for name, _ in ops:
            if name not in BATCHABLE_OPS:
                raise ValueError(
                    f"op {name!r} cannot appear inside a batch")
        entries = [dict(op=name, kw=self._wire_kw(name, dict(kw)))
                   for name, kw in ops]
        if not entries:
            return []
        head, self._pending = self._pending, []
        self._send_split = False
        try:
            raw = self._send_ops(head + entries)
        except RuntimeError as e:
            if head and not getattr(self, "_send_split", False):
                # server indices count the pipelined-write head this
                # client prepended invisibly — translate for the caller
                raise RuntimeError(
                    f"{e}\n(note: {len(head)} pipelined write(s) were "
                    f"flushed ahead of this run_batch in the same frame; "
                    f"server batch indices include them — subtract "
                    f"{len(head)} for this call's op list)") from e
            if head:
                # the aggregated frame was split; server indices are
                # per-sub-frame and cannot be mapped back precisely
                raise RuntimeError(
                    f"{e}\n(note: {len(head)} pipelined write(s) were "
                    f"flushed with this run_batch and the frame was "
                    f"split for size — server batch indices are "
                    f"relative to a sub-frame, not this call's op "
                    f"list)") from e
            raise
        raw = raw[len(head):]
        # a coalesced probe span comes back as one stacked array (op
        # axis leading): split it into per-op results — bit-identical
        # to per-op payloads at a fraction of the codec cost
        flat = []
        for r in raw:
            if isinstance(r, dict) and "coalesced" in r:
                flat.extend(dict(y=y) for y in r["y"])
            else:
                flat.append(r)
        return [self._decode_result(name, r)
                for (name, _), r in zip(ops, flat)]

    # -- per-op wire encoding / result decoding ------------------------------

    def _wire_kw(self, op: str, kw: dict) -> dict:
        """Python kwargs → wire kwargs for ``op`` (client-side validation
        happens here so pipelined ops still fail at the call site)."""
        nb = self.n_blocks
        if "block_range" in kw:
            br = kw["block_range"]
            if br is not None:
                start, stop = resolve_block_range(nb, br)
                nb = stop - start
            kw["block_range"] = _rng_kw(br)
        # validate pipelined/metered kwargs NOW: a bad bank or category
        # must raise at the call site (as the in-process twin does), not
        # surface as a server error at some later flush boundary — or
        # vanish entirely when the flush happens inside close()
        if op in ("write_phases", "write_sigma", "write_signs"):
            t = un.mesh_spec(self.k, self.kind).n_rot
            want = dict(phi_u=nb * t, phi_v=nb * t, sigma=nb * self.k,
                        d_u=nb * self.k, d_v=nb * self.k)
            for name, n_want in want.items():
                if name in kw and int(np.size(kw[name])) != n_want:
                    raise ValueError(
                        f"{op}: {name} has {int(np.size(kw[name]))} "
                        f"elements, expected {n_want} for {nb} blocks "
                        f"of k={self.k}")
        if "category" in kw and kw["category"] not in STAT_CATEGORIES:
            raise ValueError(
                f"{op}: unknown PTC-meter category {kw['category']!r} "
                f"(one of {sorted(STAT_CATEGORIES)})")
        if op in ("write_phases", "write_sigma", "write_signs", "forward",
                  "forward_layer"):
            for name in ("phi_u", "phi_v", "sigma", "d_u", "d_v", "x"):
                if name in kw:
                    kw[name] = encode(kw[name])
        if op == "forward_layer" and kw.get("out_dim") is not None:
            kw["out_dim"] = int(kw["out_dim"])
        if op == "readback_bases" and kw.get("cols") is not None:
            kw["cols"] = [int(c) for c in np.asarray(kw["cols"]).tolist()]
        if op in ("zo_refine", "run_ic"):
            kw["key"] = encode(np.asarray(kw["key"]))
            kw["cfg"] = kw["cfg"]._asdict()
            if "w_blocks" in kw:
                kw["w_blocks"] = encode(kw["w_blocks"])
            if "sigs" in kw:
                kw["sigs"] = encode(kw["sigs"])
            if "restarts" in kw:
                kw["restarts"] = int(kw["restarts"])
        if op == "charge":
            kw["calls"] = float(kw["calls"])
        if op == "advance":
            kw["dt"] = float(kw["dt"])
        return kw

    @staticmethod
    def _decode_result(op: str, r):
        # Array payloads come off the wire as host (numpy) arrays and
        # are returned as such: values are bit-identical to the twin's,
        # jax consumes them transparently on first use, and skipping an
        # eager device_put here is worth ~0.2 ms/op on the hot probe
        # path (the whole point of the batched data plane).
        if op in PIPELINED_OPS:
            return None
        if op == "read_phases":
            return r["phi_u"], r["phi_v"]
        if op == "read_sigma":
            return r["sigma"]
        if op in ("forward", "forward_layer"):
            return r["y"]
        if op == "readback_bases":
            return r["u"], r["v"]
        if op == "zo_refine":
            return ZORefineResult(phi=jnp.asarray(r["phi"]),
                                  loss=jnp.asarray(r["loss"]),
                                  history=jnp.asarray(r["history"]),
                                  steps=int(r["steps"]))
        if op == "run_ic":
            return ICJobResult(phi=jnp.asarray(r["phi"]),
                               u=jnp.asarray(r["u"]), v=jnp.asarray(r["v"]),
                               loss=jnp.asarray(r["loss"]),
                               history=jnp.asarray(r["history"]))
        if op == "stats":
            return DriverStats(serve=r["serve"], probe=r["probe"],
                               readback=r["readback"], search=r["search"])
        return r

    # -- geometry ------------------------------------------------------------

    @property
    def k(self) -> int:
        return int(self._meta["k"])

    @property
    def kind(self) -> str:
        return str(self._meta["kind"])

    @property
    def n_blocks(self) -> int:
        return int(self._meta["n_blocks"])

    @property
    def layer_shape(self) -> tuple[int, int]:
        return int(self._meta["m"]), int(self._meta["n"])

    # -- commanded state (pipelined: no round-trip) --------------------------

    def write_phases(self, phi_u, phi_v, *, block_range=None) -> None:
        self._queue("write_phases", self._wire_kw(
            "write_phases", dict(phi_u=phi_u, phi_v=phi_v,
                                 block_range=block_range)))

    def write_sigma(self, sigma, *, block_range=None) -> None:
        self._queue("write_sigma", self._wire_kw(
            "write_sigma", dict(sigma=sigma, block_range=block_range)))

    def write_signs(self, d_u, d_v, *, block_range=None) -> None:
        self._queue("write_signs", self._wire_kw(
            "write_signs", dict(d_u=d_u, d_v=d_v, block_range=block_range)))

    def read_phases(self) -> tuple[jax.Array, jax.Array]:
        return self._decode_result("read_phases",
                                   self._exec("read_phases", {}))

    def read_sigma(self) -> jax.Array:
        return self._decode_result("read_sigma", self._exec("read_sigma", {}))

    # -- probes --------------------------------------------------------------

    def forward(self, x, category: str = "probe", *,
                block_range=None) -> jax.Array:
        kw = self._wire_kw("forward", dict(x=x, category=category,
                                           block_range=block_range))
        return self._decode_result("forward", self._exec("forward", kw))

    def forward_layer(self, x, *, block_range=None,
                      out_dim: int | None = None) -> jax.Array:
        kw = self._wire_kw("forward_layer", dict(x=x, block_range=block_range,
                                                 out_dim=out_dim))
        return self._decode_result("forward_layer",
                                   self._exec("forward_layer", kw))

    def readback_bases(self, cols=None, *,
                       block_range=None) -> tuple[jax.Array, jax.Array]:
        kw = self._wire_kw("readback_bases", dict(cols=cols,
                                                  block_range=block_range))
        return self._decode_result("readback_bases",
                                   self._exec("readback_bases", kw))

    # -- in-situ jobs --------------------------------------------------------

    def zo_refine(self, w_blocks, key, cfg: ZOConfig,
                  method: str = "zcd", *, block_range=None) -> ZORefineResult:
        kw = self._wire_kw("zo_refine", dict(
            w_blocks=w_blocks, key=key, cfg=cfg, method=method,
            block_range=block_range))
        return self._decode_result("zo_refine", self._exec("zo_refine", kw))

    def run_ic(self, key, sigs, cfg: ZOConfig, *, restarts: int = 4,
               method: str = "zcd") -> ICJobResult:
        kw = self._wire_kw("run_ic", dict(key=key, sigs=sigs, cfg=cfg,
                                          restarts=restarts, method=method))
        return self._decode_result("run_ic", self._exec("run_ic", kw))

    # -- time / accounting / escape hatch ------------------------------------

    def advance(self, dt: float = 1.0) -> None:
        self._queue("advance", self._wire_kw("advance", dict(dt=dt)))

    @property
    def stats(self) -> DriverStats:
        return self._decode_result("stats", self._exec("stats", {}))

    def reset_stats(self) -> None:
        self._queue("reset_stats", {})

    def charge(self, category: str, calls: float) -> None:
        self._queue("charge", self._wire_kw(
            "charge", dict(category=category, calls=calls)))

    def unsafe_twin(self) -> RemoteTwinHandle:
        # probe the peer's unsafe/* support once, then trust it
        if not getattr(self, "_twin_verified", False):
            try:
                self._exec("unsafe/bias_deviation", {})
            except RuntimeError as e:
                raise TwinUnavailable(str(e)) from e
            self._twin_verified = True
        return RemoteTwinHandle(self)

    # -- lifecycle -----------------------------------------------------------

    def _shutdown_stream(self) -> None:
        """Best-effort orderly goodbye: fire the shutdown frame and
        return — no flush, no ack wait.  Pending pipelined writes are
        dropped deliberately (their only observable effect would be on
        reads that will never happen), and waiting on a reply from a
        possibly-wedged peer would make close() unbounded; the
        transports' close() paths already escalate to kill/disconnect
        on a timeout.  Errors are swallowed — close() must succeed on a
        dead peer."""
        try:
            self._pending = []
            send(self._fout, dict(id=0, op="shutdown", kw={}))
        except Exception:
            pass
