"""`SubprocessDriver`: op-stream client to a child twin server over pipes.

The hardware-in-the-loop transport: the device (a ``repro.hw.server``
process hosting a TwinDriver) lives outside this interpreter, and the
control plane reaches it only through the wire protocol — the same
topology a lab instrument server or a remote chip simulator would have.
Results are bit-identical to :class:`TwinDriver` for equal construction
seeds (the server runs the same physics and job code on the same
backend; raw array bytes round-trip the stream exactly).

All protocol behavior (v4 binary frames with the v3 fallback, batch
frames, write pipelining, the async reader, per-op encode/decode) lives
in the shared :class:`~repro.hw.stream_driver.StreamDriver` base; this
class only owns the child process and its (binary) stdin/stdout pipes.
"""

from __future__ import annotations

import os
import subprocess
import sys
import tempfile
from pathlib import Path

import jax

from ..core.noise import NoiseModel
from .drift import DriftConfig
from .stream_driver import StreamDriver, RemoteTwinHandle  # noqa: F401

__all__ = ["SubprocessDriver", "RemoteTwinHandle"]


def _src_root() -> str:
    # .../src/repro/hw/subprocess_driver.py → .../src
    return str(Path(__file__).resolve().parents[2])


def server_env() -> dict:
    """Environment for a spawned twin server: import path + matching
    precision regime (or results stop being bit-identical across
    transports)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = _src_root() + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_ENABLE_X64"] = "1" if jax.config.jax_enable_x64 else "0"
    return env


def stderr_tail(spool, n: int = 2000) -> str:
    """Diagnostic tail of a spawned server's stderr spool file (shared
    by every transport that hosts a server child)."""
    if spool is None:
        return ""
    try:
        spool.flush()
        with open(spool.name) as f:
            tail = f.read()[-n:]
    except OSError:
        return ""
    return "\nserver stderr tail:\n" + tail


class SubprocessDriver(StreamDriver):
    """Control-plane client to a ``repro.hw.server`` child process."""

    def __init__(self, key: jax.Array, n_blocks: int, k: int,
                 model: NoiseModel, kind: str = "clements", *,
                 m: int | None = None, n: int | None = None,
                 drift: DriftConfig | None = None,
                 python: str | None = None, protocol: int | None = None):
        self._proc = None
        self._stderr = None
        try:
            # server stderr (jax chatter, crash tracebacks) goes to a
            # spool file so a dead pipe can be diagnosed without
            # polluting stdout
            self._stderr = tempfile.NamedTemporaryFile(
                mode="w+", prefix="repro-hw-server-", suffix=".err",
                delete=False)
            # binary pipes (the wire is framed bytes, not text); 1 MiB
            # buffers — a batched probe sweep's response frame is
            # ~100 KB, and default 8 KB buffering costs a dozen
            # syscalls per frame on the hot path
            self._proc = subprocess.Popen(
                [python or sys.executable, "-u", "-m", "repro.hw.server"],
                stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                stderr=self._stderr, env=server_env(), bufsize=1 << 20)
            self._fin = self._proc.stdout
            self._fout = self._proc.stdin
            self._handshake(key, n_blocks, k, model, kind, m, n, drift,
                            protocol=protocol)
        except Exception:
            # a half-built driver (spawn failed, handshake refused) must
            # not leak the child or the spool file
            self.close()
            raise

    # -- transport hooks -----------------------------------------------------

    def _transport_alive(self) -> bool:
        return (getattr(self, "_proc", None) is not None
                and self._proc.poll() is None)

    def _transport_diagnostics(self) -> str:
        if getattr(self, "_proc", None) is None:
            return ""
        return stderr_tail(self._stderr)

    def close(self) -> None:
        proc = getattr(self, "_proc", None)
        if proc is not None:
            try:
                if proc.poll() is None:
                    self._shutdown_stream()
                    proc.wait(timeout=5)
            except Exception:
                proc.kill()
                proc.wait(timeout=5)
            self._proc = None
            self._fin = self._fout = None
        if getattr(self, "_stderr", None) is not None:
            try:
                self._stderr.close()
                os.unlink(self._stderr.name)
            except OSError:
                pass
            self._stderr = None

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
