"""`SubprocessDriver`: JSON-over-pipe client to an out-of-process twin.

The hardware-in-the-loop transport: the device (a ``repro.hw.server``
process hosting a TwinDriver) lives outside this interpreter, and the
control plane reaches it only through the wire protocol — the same
topology a lab instrument server or a remote chip simulator would have.
Results are bit-identical to :class:`TwinDriver` for equal construction
seeds (the server runs the same physics and job code on the same
backend; float32 arrays round-trip the pipe exactly).
"""

from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..core.noise import NoiseModel
from ..optim.zo import ZOConfig
from .device import DeviceRealization
from .drift import DriftConfig
from ..core.noise import PhaseNoise
from .driver import (PhotonicDriver, DriverStats, ZORefineResult, ICJobResult,
                     TwinUnavailable)
from .protocol import (encode, decode, send, recv, ProtocolError,
                       PROTOCOL_VERSION)


def _rng_kw(block_range):
    """Wire form of a block range (JSON list, or None for whole-chip)."""
    return None if block_range is None else [int(i) for i in block_range]

__all__ = ["SubprocessDriver", "RemoteTwinHandle"]


def _src_root() -> str:
    # .../src/repro/hw/subprocess_driver.py → .../src
    return str(Path(__file__).resolve().parents[2])


class RemoteTwinHandle:
    """Remote twin readouts behind ``unsafe_twin()``.

    Exists only because the peer happens to be a simulator exposing
    ``unsafe/*`` debug ops; a real-hardware daemon would not, and the
    driver would raise :class:`TwinUnavailable` instead.
    """

    def __init__(self, driver: "SubprocessDriver"):
        self._d = driver

    @property
    def dev(self) -> DeviceRealization:
        r = self._d._rpc("unsafe/dev")
        return DeviceRealization(
            noise_u=PhaseNoise(gamma=jnp.asarray(r["gamma_u"]),
                               bias=jnp.asarray(r["bias_u"])),
            noise_v=PhaseNoise(gamma=jnp.asarray(r["gamma_v"]),
                               bias=jnp.asarray(r["bias_v"])),
            d_u=jnp.asarray(r["d_u"]), d_v=jnp.asarray(r["d_v"]))

    def realized_unitaries(self) -> tuple[jax.Array, jax.Array]:
        r = self._d._rpc("unsafe/realized_unitaries")
        return jnp.asarray(r["u"]), jnp.asarray(r["v"])

    def true_mapping_distance(self, w_blocks: jax.Array,
                              block_range=None) -> float:
        r = self._d._rpc("unsafe/true_mapping_distance", w_blocks=w_blocks,
                         block_range=_rng_kw(block_range))
        return float(r["d"])

    def bias_deviation(self) -> float:
        return float(self._d._rpc("unsafe/bias_deviation")["d"])


class SubprocessDriver(PhotonicDriver):
    """Control-plane client to a ``repro.hw.server`` child process."""

    def __init__(self, key: jax.Array, n_blocks: int, k: int,
                 model: NoiseModel, kind: str = "clements", *,
                 m: int | None = None, n: int | None = None,
                 drift: DriftConfig | None = None,
                 python: str | None = None):
        env = dict(os.environ)
        env["PYTHONPATH"] = _src_root() + os.pathsep + env.get("PYTHONPATH",
                                                               "")
        # the server must compute in the same precision regime as this
        # process, or results stop being bit-identical across transports
        env["JAX_ENABLE_X64"] = "1" if jax.config.jax_enable_x64 else "0"
        # server stderr (jax chatter, crash tracebacks) goes to a spool
        # file so a dead pipe can be diagnosed without polluting stdout
        self._stderr = tempfile.NamedTemporaryFile(
            mode="w+", prefix="repro-hw-server-", suffix=".err", delete=False)
        self._proc = subprocess.Popen(
            [python or sys.executable, "-u", "-m", "repro.hw.server"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            stderr=self._stderr, text=True, env=env)
        self._rid = 0
        meta = self._rpc(
            "init", v=PROTOCOL_VERSION, key=np.asarray(key),
            n_blocks=int(n_blocks), k=int(k),
            kind=kind, m=m, n=n, model=dataclasses.asdict(model),
            drift=drift._asdict() if drift is not None else None)
        if int(meta.get("v", 1)) != PROTOCOL_VERSION:
            self.close()
            raise ProtocolError(
                f"driver protocol mismatch: server speaks "
                f"v{meta.get('v', 1)}, client speaks v{PROTOCOL_VERSION}")
        self._meta = meta

    # -- transport -----------------------------------------------------------

    def _server_stderr_tail(self, n: int = 2000) -> str:
        try:
            self._stderr.flush()
            with open(self._stderr.name) as f:
                return f.read()[-n:]
        except OSError:
            return ""

    def _rpc(self, op: str, **kw):
        if getattr(self, "_proc", None) is None or \
                self._proc.poll() is not None:
            raise ProtocolError(
                "driver server process has exited (or driver was closed)"
                + ("\nserver stderr tail:\n" + self._server_stderr_tail()
                   if getattr(self, "_proc", None) is not None else ""))
        self._rid += 1
        try:
            send(self._proc.stdin, dict(id=self._rid, op=op, kw=encode(kw)))
            resp = recv(self._proc.stdout)
        except (ProtocolError, OSError) as e:
            raise ProtocolError(
                f"driver pipe failed during op {op!r}: {e}\n"
                f"server stderr tail:\n{self._server_stderr_tail()}") from e
        if not resp.get("ok"):
            raise RuntimeError(
                f"remote driver op {op!r} failed:\n{resp.get('error')}")
        return decode(resp.get("result"))

    def close(self) -> None:
        if getattr(self, "_proc", None) is None:
            return
        try:
            if self._proc.poll() is None:
                send(self._proc.stdin, dict(id=0, op="shutdown", kw={}))
                self._proc.wait(timeout=5)
        except Exception:
            self._proc.kill()
            self._proc.wait(timeout=5)
        finally:
            self._proc = None
            try:
                self._stderr.close()
                os.unlink(self._stderr.name)
            except OSError:
                pass

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # -- geometry ------------------------------------------------------------

    @property
    def k(self) -> int:
        return int(self._meta["k"])

    @property
    def kind(self) -> str:
        return str(self._meta["kind"])

    @property
    def n_blocks(self) -> int:
        return int(self._meta["n_blocks"])

    @property
    def layer_shape(self) -> tuple[int, int]:
        return int(self._meta["m"]), int(self._meta["n"])

    # -- commanded state -----------------------------------------------------

    def write_phases(self, phi_u, phi_v, *, block_range=None) -> None:
        self._rpc("write_phases", phi_u=phi_u, phi_v=phi_v,
                  block_range=_rng_kw(block_range))

    def write_sigma(self, sigma, *, block_range=None) -> None:
        self._rpc("write_sigma", sigma=sigma,
                  block_range=_rng_kw(block_range))

    def write_signs(self, d_u, d_v, *, block_range=None) -> None:
        self._rpc("write_signs", d_u=d_u, d_v=d_v,
                  block_range=_rng_kw(block_range))

    def read_phases(self) -> tuple[jax.Array, jax.Array]:
        r = self._rpc("read_phases")
        return jnp.asarray(r["phi_u"]), jnp.asarray(r["phi_v"])

    def read_sigma(self) -> jax.Array:
        return jnp.asarray(self._rpc("read_sigma")["sigma"])

    # -- probes --------------------------------------------------------------

    def forward(self, x, category: str = "probe", *,
                block_range=None) -> jax.Array:
        return jnp.asarray(self._rpc("forward", x=x, category=category,
                                     block_range=_rng_kw(block_range))["y"])

    def forward_layer(self, x, *, block_range=None,
                      out_dim: int | None = None) -> jax.Array:
        return jnp.asarray(self._rpc(
            "forward_layer", x=x, block_range=_rng_kw(block_range),
            out_dim=int(out_dim) if out_dim is not None else None)["y"])

    def readback_bases(self, cols=None, *,
                       block_range=None) -> tuple[jax.Array, jax.Array]:
        if cols is not None:
            cols = [int(c) for c in np.asarray(cols).tolist()]
        r = self._rpc("readback_bases", cols=cols,
                      block_range=_rng_kw(block_range))
        return jnp.asarray(r["u"]), jnp.asarray(r["v"])

    # -- in-situ jobs --------------------------------------------------------

    def zo_refine(self, w_blocks, key, cfg: ZOConfig,
                  method: str = "zcd", *, block_range=None) -> ZORefineResult:
        r = self._rpc("zo_refine", w_blocks=w_blocks, key=np.asarray(key),
                      cfg=cfg._asdict(), method=method,
                      block_range=_rng_kw(block_range))
        return ZORefineResult(phi=jnp.asarray(r["phi"]),
                              loss=jnp.asarray(r["loss"]),
                              history=jnp.asarray(r["history"]),
                              steps=int(r["steps"]))

    def run_ic(self, key, sigs, cfg: ZOConfig, *, restarts: int = 4,
               method: str = "zcd") -> ICJobResult:
        r = self._rpc("run_ic", key=np.asarray(key), sigs=sigs,
                      cfg=cfg._asdict(), restarts=restarts, method=method)
        return ICJobResult(phi=jnp.asarray(r["phi"]),
                           u=jnp.asarray(r["u"]), v=jnp.asarray(r["v"]),
                           loss=jnp.asarray(r["loss"]),
                           history=jnp.asarray(r["history"]))

    # -- time / accounting / escape hatch ------------------------------------

    def advance(self, dt: float = 1.0) -> None:
        self._rpc("advance", dt=float(dt))

    @property
    def stats(self) -> DriverStats:
        s = self._rpc("stats")
        return DriverStats(serve=s["serve"], probe=s["probe"],
                           readback=s["readback"], search=s["search"])

    def reset_stats(self) -> None:
        self._rpc("reset_stats")

    def charge(self, category: str, calls: float) -> None:
        self._rpc("charge", category=category, calls=calls)

    def unsafe_twin(self) -> RemoteTwinHandle:
        # probe the peer's unsafe/* support once, then trust it
        if not getattr(self, "_twin_verified", False):
            try:
                self._rpc("unsafe/bias_deviation")
            except RuntimeError as e:
                raise TwinUnavailable(str(e)) from e
            self._twin_verified = True
        return RemoteTwinHandle(self)
